//! Fault-tolerance demo: inject task failures and a lost worker's shuffle
//! outputs mid-job, and show lineage-based recomputation still produces a
//! byte-identical MSA (paper §Overview of Apache Spark: "RDDs will be
//! recomputed after data loss").
//!
//! ```bash
//! cargo run --release --example fault_tolerance
//! ```

use halign2::align::center_star::{align_nucleotide, CenterStarConfig};
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig, FaultPlan};

fn main() -> anyhow::Result<()> {
    let seqs = DatasetSpec { count: 48, ..DatasetSpec::mito(0.05, 9) }.generate();

    // Reference run, no faults.
    let clean = Cluster::new(ClusterConfig::spark(4));
    let reference = align_nucleotide(&clean, &seqs, &CenterStarConfig::default())?;
    println!(
        "clean run:   width {}, tasks {}",
        reference.width,
        clean.stats().tasks_run
    );

    // 30% of first-attempt tasks fail; retries recompute from lineage.
    let mut cfg = ClusterConfig::spark(4);
    cfg.fault = FaultPlan::random(0.30, 1234);
    cfg.max_retries = 8;
    let faulty = Cluster::new(cfg);
    let survived = align_nucleotide(&faulty, &seqs, &CenterStarConfig::default())?;
    let stats = faulty.stats();
    println!(
        "faulty run:  width {}, tasks {} ({} injected failures survived)",
        survived.width, stats.tasks_run, stats.injected_failures
    );
    assert!(stats.injected_failures > 0, "fault plan should have fired");

    // The result must be identical to the clean run.
    assert_eq!(reference.width, survived.width);
    for (a, b) in reference.aligned.iter().zip(&survived.aligned) {
        assert_eq!(a.codes, b.codes, "row {} diverged", a.id);
    }
    println!("MSA identical across {} rows ✓", reference.aligned.len());

    // Kill a specific worker's first attempts (stable-placement loss).
    let mut cfg = ClusterConfig::spark(4);
    cfg.fault = FaultPlan::fail_first_attempt_on_worker(2);
    cfg.max_retries = 4;
    let lossy = Cluster::new(cfg);
    let relost = align_nucleotide(&lossy, &seqs, &CenterStarConfig::default())?;
    assert_eq!(relost.width, reference.width);
    println!(
        "worker-loss run: {} failures injected, result identical ✓",
        lossy.stats().injected_failures
    );
    Ok(())
}

//! Quickstart: align a handful of DNA sequences and build their tree.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use halign2::align::center_star::{align_nucleotide, CenterStarConfig};
use halign2::engine::{Cluster, ClusterConfig};
use halign2::fasta::{Alphabet, Sequence};
use halign2::tree::{build_tree, TreeConfig};

fn main() -> anyhow::Result<()> {
    // A toy family: one reference and three mutated relatives.
    let seqs = vec![
        Sequence::from_text("ref", "ACGTACGTTGCAACGTGGCCTTAAACGTACGT", Alphabet::Dna),
        Sequence::from_text("snp", "ACGTACGTTGCAACGTGGCCTTTAACGTACGT", Alphabet::Dna),
        Sequence::from_text("ins", "ACGTACGTTGCAACCGTGGCCTTAAACGTACGT", Alphabet::Dna),
        Sequence::from_text("del", "ACGTACGTTGCAACGTGGCCTTAACGTACGT", Alphabet::Dna),
    ];

    // A 4-worker in-memory (Spark-style) cluster.
    let cluster = Cluster::new(ClusterConfig::spark(4));

    // Distributed center-star MSA.
    let msa = align_nucleotide(
        &cluster,
        &seqs,
        &CenterStarConfig { segment_len: 8, ..Default::default() },
    )?;
    println!("MSA (width {}):", msa.width);
    for row in &msa.aligned {
        println!("  {:>4}  {}", row.id, row.text());
    }
    println!("avg SP (penalty, lower = better): {:.2}", msa.avg_sp()?);

    // Clustered neighbor-joining tree + its JC69 log-likelihood.
    let tree = build_tree(&cluster, &msa.aligned, None, &TreeConfig::default())?;
    println!("tree: {}", tree.tree.to_newick());
    println!("logML: {:.2}", tree.log_likelihood);
    Ok(())
}

//! Figure-6-style scaling sweep: the same MSA workload at 1..12 workers,
//! reporting wall-clock, per-worker busy time and peak memory.  On a
//! 1-core CI box the wall-clock flattens (threads timeshare); the
//! engine-accounted busy time and per-worker memory still show the
//! distribution effect — see EXPERIMENTS.md §Figure 6.
//!
//! ```bash
//! cargo run --release --example scaling_sweep
//! ```

use std::time::Instant;

use halign2::align::center_star::{align_nucleotide, CenterStarConfig};
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig};
use halign2::util::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let count = std::env::var("COUNT").ok().and_then(|v| v.parse().ok()).unwrap_or(1344usize);
    let seqs = DatasetSpec { count, ..DatasetSpec::mito(0.1, 21) }.generate();
    println!("workload: {} genomes x ~1.66 kb\n", seqs.len());
    println!(
        "{:>7} | {:>10} | {:>12} | {:>16} | {:>10}",
        "workers", "wall", "busy(sum)", "avg max mem (MB)", "tasks"
    );

    let mut base_mem = 0.0f64;
    for workers in [1usize, 2, 4, 8, 12] {
        let cluster = Cluster::new(ClusterConfig::spark(workers));
        let t = Instant::now();
        let msa = align_nucleotide(&cluster, &seqs, &CenterStarConfig::default())?;
        let wall = t.elapsed();
        let stats = cluster.stats();
        let mem_mb = stats.avg_max_memory_bytes / (1 << 20) as f64;
        if workers == 1 {
            base_mem = mem_mb;
        }
        println!(
            "{workers:>7} | {:>10} | {:>12} | {:>16.1} | {:>10}",
            fmt_duration(wall),
            fmt_duration(stats.total_busy),
            mem_mb,
            stats.tasks_run
        );
        assert_eq!(msa.aligned.len(), seqs.len());
    }
    println!(
        "\nper-worker memory at 12 workers should be a fraction of the 1-worker\n\
         run ({base_mem:.1} MB) — the paper's 'capacity grows with nodes' claim."
    );
    Ok(())
}

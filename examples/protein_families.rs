//! Protein MSA through the XLA hot path: BAliBASE-like families aligned
//! by the batched Smith-Waterman wavefront kernel (AOT Pallas → PJRT),
//! with the SparkSW baseline for comparison.
//!
//! ```bash
//! make artifacts && cargo run --release --example protein_families
//! ```

use std::time::Instant;

use halign2::align::protein::{align_protein, ProteinConfig};
use halign2::baselines::sparksw::sparksw_msa;
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig};
use halign2::runtime::XlaService;
use halign2::util::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    let count = std::env::var("COUNT").ok().and_then(|v| v.parse().ok()).unwrap_or(300usize);
    let seqs = DatasetSpec::protein(count, 0.6, 11).generate();
    println!(
        "=== protein center-star: {} sequences, avg len {} ===",
        seqs.len(),
        seqs.iter().map(|s| s.len()).sum::<usize>() / seqs.len()
    );

    let svc = match XlaService::start("artifacts") {
        Ok(svc) => {
            println!("XLA service up: {} executables", svc.executables().len());
            Some(svc)
        }
        Err(e) => {
            println!("(no artifacts: {e}; falling back to native SW)");
            None
        }
    };

    // HAlign-II protein pipeline (XLA-batched SW when available).
    let cluster = Cluster::new(ClusterConfig::spark(8));
    let t = Instant::now();
    let msa = align_protein(&cluster, &seqs, svc.as_ref(), &ProteinConfig::default())?;
    let halign_time = t.elapsed();
    let sp = msa.avg_sp_distributed(&cluster)?;
    msa.validate(&seqs)?;
    println!(
        "halign2:  {}  width {}  avg SP {:.1}  (avg max mem {:.1} MB)",
        fmt_duration(halign_time),
        msa.width,
        sp,
        cluster.stats().avg_max_memory_bytes / (1 << 20) as f64
    );

    // SparkSW baseline: same cluster size, full-matrix native SW.
    let t = Instant::now();
    let (sw_msa, sw_engine) = sparksw_msa(8, &seqs, 5.0)?;
    let sw_time = t.elapsed();
    let sw_sp = sw_msa.avg_sp_distributed(&sw_engine)?;
    println!(
        "sparksw:  {}  width {}  avg SP {:.1}  (avg max mem {:.1} MB)",
        fmt_duration(sw_time),
        sw_msa.width,
        sw_sp,
        sw_engine.stats().avg_max_memory_bytes / (1 << 20) as f64
    );

    println!(
        "\nspeedup halign2 vs sparksw: {:.2}x",
        sw_time.as_secs_f64() / halign_time.as_secs_f64().max(1e-9)
    );
    Ok(())
}

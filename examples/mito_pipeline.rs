//! End-to-end driver (DESIGN.md §6): the Φ_DNA mitochondrial workload
//! through the full distributed stack — dataset generation → center-star
//! MSA on an 8-worker in-memory cluster → distributed avg-SP → sampling
//! clustering → per-cluster NJ → merged tree → JC69 logML — with
//! stage-by-stage wall-clock and engine stats. Results are recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example mito_pipeline            # 672 x 1.66 kb
//! SCALE=1.0 COUNT=672 cargo run --release --example mito_pipeline  # paper-length genomes
//! ```

use halign2::align::center_star::{align_nucleotide, CenterStarConfig};
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig};
use halign2::runtime::XlaService;
use halign2::tree::{build_tree, TreeConfig};
use halign2::util::timer::fmt_duration;
use halign2::util::Stopwatch;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let length_scale = env_f64("SCALE", 0.1); // 1.0 = full 16.5 kb genomes
    let count = env_f64("COUNT", 672.0) as usize;
    let workers = env_f64("WORKERS", 8.0) as usize;

    println!("=== HAlign-II end-to-end: mitochondrial genome pipeline ===");
    println!(
        "dataset: {} genomes x ~{} bp, {} workers (in-memory backend)",
        count,
        (16_569.0 * length_scale) as usize,
        workers
    );

    let mut sw = Stopwatch::new();
    let spec = DatasetSpec { count, ..DatasetSpec::mito(length_scale, 42) };
    let seqs = spec.generate();
    let total_bases: usize = seqs.iter().map(|s| s.len()).sum();
    println!(
        "[1] generate        {}  ({:.1} MB of sequence)",
        fmt_duration(sw.lap("gen")),
        total_bases as f64 / 1e6
    );

    // XLA distance kernels are the TPU-architecture path; on the CPU PJRT
    // plugin (interpret-mode Pallas) they are slower than native (see
    // EXPERIMENTS.md §Perf), so opt in via HALIGN2_XLA=1.
    let svc = if std::env::var("HALIGN2_XLA").ok().as_deref() == Some("1") {
        let svc = XlaService::start("artifacts").ok();
        if svc.is_some() {
            println!("    XLA artifacts loaded (distance kernels on PJRT)");
        }
        svc
    } else {
        None
    };

    let cluster = Cluster::new(ClusterConfig::spark(workers));
    let msa = align_nucleotide(&cluster, &seqs, &CenterStarConfig::default())?;
    println!(
        "[2] center-star MSA {}  (width {}, {} rows)",
        fmt_duration(sw.lap("msa")),
        msa.width,
        msa.aligned.len()
    );

    let sp = msa.avg_sp_distributed(&cluster)?;
    println!(
        "[3] avg SP          {}  (avg SP = {:.2}, lower is better)",
        fmt_duration(sw.lap("sp")),
        sp
    );

    let tree = build_tree(&cluster, &msa.aligned, svc.as_ref(), &TreeConfig::default())?;
    println!(
        "[4] NJ tree         {}  ({} clusters, logML {:.1})",
        fmt_duration(sw.lap("tree")),
        tree.num_clusters,
        tree.log_likelihood
    );

    let stats = cluster.stats();
    println!("\n--- engine stats ---");
    println!("tasks run:            {}", stats.tasks_run);
    println!("worker busy time:     {}", fmt_duration(stats.total_busy));
    println!(
        "shuffle bytes:        {} written / {} read",
        stats.shuffle_bytes_written, stats.shuffle_bytes_read
    );
    println!(
        "avg max worker memory: {:.1} MB (peak worker: {:.1} MB)",
        stats.avg_max_memory_bytes / (1 << 20) as f64,
        stats.max_peak_memory_bytes as f64 / (1 << 20) as f64
    );
    println!("total wall:           {}", fmt_duration(sw.elapsed()));

    // Structural invariants — loudly verify the run was real.
    msa.validate(&seqs)?;
    tree.tree.validate()?;
    assert_eq!(tree.tree.num_leaves(), seqs.len());
    println!("\nall invariants hold ✓");
    Ok(())
}

//! Micro-benchmarks of the hot paths (EXPERIMENTS.md §Perf): trie scan
//! rate, native vs XLA Smith-Waterman cell rate, shuffle throughput per
//! backend, NJ join rate, executor dispatch overhead.  Median of N runs,
//! no criterion (offline build).
#[allow(dead_code)]
mod common;

use std::time::Instant;

use halign2::align::banded::{banded_global, sw_align_i32, IntSwParams};
use halign2::align::myers::{edit_distance_dp, myers_edit_distance, pack_row};
use halign2::tree::distance::{pdist_pair, pdist_pair_packed};
use halign2::align::pairwise::global_dp;
use halign2::align::sw::{sw_align, sw_matrix, SwParams};
use halign2::align::trie::SegmentTrie;
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig, FaultPlan};
use halign2::fasta::{alphabet::substitution_matrix, Alphabet, Sequence};
use halign2::runtime::batcher::SwBatcher;
use halign2::tree::nj::neighbor_joining;
use halign2::util::Rng;

/// Hand-rolled JSON (no deps) recording the kernel A/B rates.  Written
/// to the repo root — the parent of the `rust/` crate dir — so the CI
/// smoke step can assert its presence from the workflow's
/// `working-directory: rust` with `test -f ../BENCH_micro.json`.
fn write_bench_micro_json(rows: &[(String, &'static str, f64)]) {
    let mut json = String::from(
        "{\n  \"bench\": \"micro_kernel_ab\",\n  \"unit\": \"cells_per_sec\",\n  \"rows\": [\n",
    );
    for (i, (kernel, backend, cps)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        json.push_str(&format!(
            "    {{\"kernel\": \"{kernel}\", \"backend\": \"{backend}\", \
             \"cells_per_sec\": {cps:.0}}}{comma}\n"
        ));
    }
    json.push_str("  ]\n}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_micro.json");
    std::fs::write(&path, json).expect("writing BENCH_micro.json");
    println!("wrote {}", path.display());
}

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn bench<F: FnMut()>(name: &str, work_units: f64, unit: &str, iters: usize, mut f: F) {
    // Warmup.
    f();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let med = median(times);
    println!(
        "{name:<38} {:>10.3} ms   {:>12.2} {unit}",
        med * 1e3,
        work_units / med
    );
}

fn main() {
    let quick = std::env::var("QUICK").is_ok()
        || std::env::args().any(|a| a == "--quick" || a == "--test");
    let iters = if quick { 3 } else { 9 };
    println!("{:<38} {:>13}   {:>12}", "bench", "median", "rate");

    // --- trie scan rate ----------------------------------------------------
    let genome = DatasetSpec { count: 2, ..DatasetSpec::mito(1.0, 3) }.generate();
    let trie = SegmentTrie::build(&genome[0].codes, 16);
    let query = &genome[1].codes;
    bench(
        "trie chain (16.5 kb genome)",
        query.len() as f64 / 1e6,
        "Mchar/s",
        iters,
        || {
            std::hint::black_box(trie.chain(query));
        },
    );

    // --- native SW cell rate ------------------------------------------------
    let alpha = Alphabet::Protein;
    let params = SwParams {
        subst: substitution_matrix(alpha),
        alpha: alpha.size(),
        gap: 5.0,
    };
    let mut rng = Rng::seed_from_u64(4);
    let a: Vec<i32> = (0..400).map(|_| rng.below(20) as i32).collect();
    let b: Vec<i32> = (0..400).map(|_| rng.below(20) as i32).collect();
    bench("native SW 400x400", (400 * 400) as f64 / 1e6, "Mcell/s", iters, || {
        std::hint::black_box(sw_matrix(&a, &b, &params));
    });

    // --- exact-kernel A/B: cells/sec per backend -----------------------------
    // Scalar full-DP kernels vs the integer bit-parallel/banded kernels
    // behind `KernelBackend::BitParallel`.  The table below is the CI
    // contract (header carries `cells_per_sec`, rows carry `scalar` and
    // `bitparallel`), and the same numbers land in BENCH_micro.json at
    // the repo root.
    let kernel_rows = {
        let n = if quick { 160usize } else { 400 };
        let mut krng = Rng::seed_from_u64(7);
        // ~4% divergent pair: realistic band width for the banded kernel.
        let da: Vec<u8> = (0..n).map(|_| krng.below(4) as u8).collect();
        let db: Vec<u8> = da
            .iter()
            .map(|&c| if krng.chance(0.04) { krng.below(4) as u8 } else { c })
            .collect();
        let cells = (n * n) as f64;
        let sw_cells = (a.len() * b.len()) as f64;
        let rate = |cells: f64, iters: usize, f: &mut dyn FnMut()| -> f64 {
            f(); // warmup
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t = Instant::now();
                f();
                times.push(t.elapsed().as_secs_f64());
            }
            cells / median(times).max(1e-9)
        };
        let ip = IntSwParams::from_f32(&params).expect("built-in matrix is integer-valued");
        // p-distance over aligned rows: the distance-matrix inner loop
        // (scalar byte walk vs packed popcount; bit-identical ratios, see
        // tree/distance.rs).
        let m = if quick { 4096usize } else { 16384 };
        let gap = Alphabet::Dna.gap();
        let ra: Vec<u8> = (0..m)
            .map(|_| if krng.chance(0.05) { gap } else { krng.below(4) as u8 })
            .collect();
        let rb: Vec<u8> = ra
            .iter()
            .map(|&c| {
                if krng.chance(0.05) {
                    gap
                } else if c != gap && krng.chance(0.03) {
                    krng.below(4) as u8
                } else {
                    c
                }
            })
            .collect();
        let (pa, pb) = (pack_row(&ra, gap), pack_row(&rb, gap));
        let rows: Vec<(String, &'static str, f64)> = vec![
            (
                format!("global_{n}x{n}"),
                "scalar",
                rate(cells, iters, &mut || {
                    std::hint::black_box(global_dp(&da, &db));
                }),
            ),
            (
                format!("global_{n}x{n}"),
                "bitparallel",
                rate(cells, iters, &mut || {
                    std::hint::black_box(banded_global(&da, &db));
                }),
            ),
            (
                format!("edit_distance_{n}x{n}"),
                "scalar",
                rate(cells, iters, &mut || {
                    std::hint::black_box(edit_distance_dp(&da, &db));
                }),
            ),
            (
                format!("edit_distance_{n}x{n}"),
                "bitparallel",
                rate(cells, iters, &mut || {
                    std::hint::black_box(myers_edit_distance(&da, &db));
                }),
            ),
            (
                "local_sw_400x400".into(),
                "scalar",
                rate(sw_cells, iters, &mut || {
                    std::hint::black_box(sw_align(&a, &b, &params));
                }),
            ),
            (
                "local_sw_400x400".into(),
                "bitparallel",
                rate(sw_cells, iters, &mut || {
                    std::hint::black_box(sw_align_i32(&a, &b, &ip));
                }),
            ),
            (
                format!("pdist_row_{m}"),
                "scalar",
                rate(m as f64, iters, &mut || {
                    std::hint::black_box(pdist_pair(&ra, &rb, gap));
                }),
            ),
            (
                format!("pdist_row_{m}"),
                "bitparallel",
                rate(m as f64, iters, &mut || {
                    std::hint::black_box(pdist_pair_packed(&pa, &pb));
                }),
            ),
        ];
        println!("{:<26} {:>12} {:>18}", "kernel A/B", "backend", "cells_per_sec");
        for (kernel, backend, cps) in &rows {
            println!("{kernel:<26} {backend:>12} {cps:>18.0}");
        }
        rows
    };
    write_bench_micro_json(&kernel_rows);

    // --- XLA SW cell rate ---------------------------------------------------
    if let Some(svc) = common::service_forced() {
        let center: Vec<i32> = (0..500).map(|_| rng.below(20) as i32).collect();
        let queries: Vec<Vec<i32>> =
            (0..8).map(|_| (0..500).map(|_| rng.below(20) as i32).collect()).collect();
        let batcher =
            SwBatcher::new(&svc, center, params.subst.clone(), params.alpha, 5.0).unwrap();
        bench(
            "XLA SW batch 8x(500x500)",
            (8 * 500 * 500) as f64 / 1e6,
            "Mcell/s",
            iters.min(5),
            || {
                std::hint::black_box(batcher.score(&queries).unwrap());
            },
        );
    } else {
        println!("(skipping XLA benches: run `make artifacts`)");
    }

    // --- shuffle throughput per backend -------------------------------------
    for (name, cfg) in [
        ("shuffle in-memory (spark)", ClusterConfig::spark(4)),
        ("shuffle disk-kv (hadoop)", ClusterConfig::hadoop(4)),
    ] {
        let pairs: Vec<(u64, Vec<u8>)> =
            (0..2048u64).map(|i| (i % 64, vec![0u8; 512])).collect();
        let bytes = 2048.0 * 512.0 / 1e6;
        bench(name, bytes, "MB/s", iters.min(5), || {
            let c = Cluster::new(cfg.clone());
            let out = c
                .parallelize(pairs.clone(), 8)
                .group_by_key(4)
                .count()
                .unwrap();
            std::hint::black_box(out);
        });
    }

    // --- NJ join rate --------------------------------------------------------
    let n = if quick { 48 } else { 128 };
    let labels: Vec<String> = (0..n).map(|i| format!("t{i}")).collect();
    let mut d = vec![vec![0f64; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let v = 0.05 + rng.f64();
            d[i][j] = v;
            d[j][i] = v;
        }
    }
    bench(&format!("neighbor-joining n={n}"), n as f64, "taxa/s", iters, || {
        std::hint::black_box(neighbor_joining(&labels, &d).unwrap());
    });

    // --- executor dispatch overhead ------------------------------------------
    let cluster = Cluster::new(ClusterConfig::spark(4));
    bench("executor 512 empty tasks", 512.0 / 1e3, "ktask/s", iters, || {
        cluster.executor_probe(512).unwrap();
    });

    // --- fault-injected retry overhead ----------------------------------------
    let mut cfg = ClusterConfig::spark(4);
    cfg.fault = FaultPlan::random(0.1, 5);
    cfg.max_retries = 4;
    let faulty = Cluster::new(cfg);
    let seqs: Vec<Sequence> = DatasetSpec { count: 64, ..DatasetSpec::mito(0.01, 5) }.generate();
    bench("MSA 64 genomes, 10% task faults", 64.0, "seq/s", iters.min(3), || {
        let msa = halign2::align::center_star::align_nucleotide(
            &faulty,
            &seqs,
            &halign2::align::center_star::CenterStarConfig::default(),
        )
        .unwrap();
        std::hint::black_box(msa);
    });
}

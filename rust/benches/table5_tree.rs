//! Regenerates Table 5 — phylogenetic tree construction times + logML
//! (IQ-TREE-like ML search vs HPTree(Hadoop) vs HAlign-II).
#[allow(dead_code)]
mod common;

fn main() {
    let cfg = common::config_from_env();
    let svc = common::service();
    common::emit(
        "Table 5 — tree construction (time + JC69 logML)",
        halign2::bench::table5_tree(&cfg, svc.as_ref()),
    );
}

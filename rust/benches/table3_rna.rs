//! Regenerates Table 3 — RNA MSA running time + avg SP on the divergent
//! 16S-like datasets.
#[allow(dead_code)]
mod common;

fn main() {
    let cfg = common::config_from_env();
    common::emit("Table 3 — RNA MSA (time + avg SP)", halign2::bench::table3_rna(&cfg));
}

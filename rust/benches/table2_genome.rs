//! Regenerates Table 2 — genome MSA running time + avg SP
//! (MUSCLE/MAFFT-like progressive vs HAlign(Hadoop) vs HAlign-II).
//! Env: QUICK=1, SCALE=<f64>, WORKERS=<n>, BUDGET_SECS=<n>.
#[allow(dead_code)]
mod common;

fn main() {
    let cfg = common::config_from_env();
    common::emit(
        "Table 2 — genome MSA (time + avg SP; SP is a penalty, lower = better)",
        halign2::bench::table2_genome(&cfg),
    );
}

//! Regenerates Figure 6 — running time, memory and busy-time skew with
//! increasing worker nodes (1, 2, 4, 8, 12), work stealing on vs off,
//! the skewed-partition straggler scenario, and the sharded-vs-global
//! scheduler A/B at 16/32/64 simulated workers (busy skew must be <= the
//! global-lock baseline and wall-clock no worse from 16 workers up).
#[allow(dead_code)]
mod common;

fn main() {
    let cfg = common::config_from_env();
    common::emit(
        "Figure 6 — scaling with worker count (steal on vs off)",
        halign2::bench::fig6_scaling(&cfg),
    );
    common::emit(
        "Figure 6b — skewed partitions (straggler scenario)",
        halign2::bench::fig6_skew(&cfg),
    );
    common::emit(
        "Figure 6c — sharded deques vs global lock at 16/32/64 workers",
        halign2::bench::fig6_sharded(&cfg),
    );
}

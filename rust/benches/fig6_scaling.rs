//! Regenerates Figure 6 — running time and memory with increasing worker
//! nodes (1, 2, 4, 8, 12).
#[allow(dead_code)]
mod common;

fn main() {
    let cfg = common::config_from_env();
    common::emit(
        "Figure 6 — scaling with worker count",
        halign2::bench::fig6_scaling(&cfg),
    );
}

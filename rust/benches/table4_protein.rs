//! Regenerates Table 4 — protein MSA (progressive vs SparkSW vs
//! HAlign-II with the XLA-batched SW kernel).
#[allow(dead_code)]
mod common;

fn main() {
    let cfg = common::config_from_env();
    let svc = common::service();
    common::emit(
        "Table 4 — protein MSA (time + avg SP)",
        halign2::bench::table4_protein(&cfg, svc.as_ref()),
    );
}

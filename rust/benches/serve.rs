//! Serving-layer bench (ISSUE: "1 big job then 100 small appends"): a
//! base alignment job followed by a chain of append requests, served
//! through the content-hash result cache, against the no-cache baseline
//! that recomputes every union from scratch.
//!
//! Emits BENCH_serve.json at the repo root (same convention as
//! BENCH_micro.json).  The *counts* (hits/misses/appends) and the two
//! correctness booleans are mode-independent — `scripts/bench_compare.py`
//! pins them exactly and checks the speedup against a floor; raw
//! wall-clock seconds are informational only and never compared across
//! machines.
use std::time::Instant;

use halign2::align::append::{append_nucleotide, MsaArtifact};
use halign2::align::center_star::{
    align_nucleotide, align_nucleotide_with_artifact, CenterStarConfig,
};
use halign2::cache::{canonical_digest, ArtifactStore};
use halign2::engine::{Cluster, ClusterConfig};
use halign2::fasta::{Alphabet, Sequence};
use halign2::obs::Histogram;
use halign2::util::Rng;

/// Mutate `base`: substitutions at rate `subs`, insert/delete at rate
/// `indels` (indel-free variants never widen the merged profile, which
/// is what keeps most appends on the render-one-row fast path).
fn variant(rng: &mut Rng, base: &[u8], subs: f64, indels: f64) -> Vec<u8> {
    let mut out = Vec::with_capacity(base.len() + 8);
    for &c in base {
        if rng.chance(indels) {
            if rng.chance(0.5) {
                continue; // deletion
            }
            out.push(rng.below(4) as u8); // insertion
            out.push(c);
        } else if rng.chance(subs) {
            out.push(rng.below(4) as u8);
        } else {
            out.push(c);
        }
    }
    out
}

fn write_bench_serve_json(fields: &[(&str, String)]) {
    let mut json = String::from("{\n  \"bench\": \"serve_append\",\n");
    for (i, (k, v)) in fields.iter().enumerate() {
        let comma = if i + 1 < fields.len() { "," } else { "" };
        json.push_str(&format!("  \"{k}\": {v}{comma}\n"));
    }
    json.push_str("}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_serve.json");
    std::fs::write(&path, json).expect("writing BENCH_serve.json");
    println!("wrote {}", path.display());
}

fn main() {
    let quick = std::env::var("QUICK").is_ok()
        || std::env::args().any(|a| a == "--quick" || a == "--test");
    // K is mode-independent so the hit/miss/append counts in
    // BENCH_serve.json match the committed baseline in both modes; QUICK
    // only shrinks the sequences and the base job.
    let appends = 100usize;
    let base_n = if quick { 16 } else { 48 };
    let len = if quick { 320 } else { 500 };
    let budget = 128 << 10;

    let mut rng = Rng::seed_from_u64(0xA11C);
    let reference: Vec<u8> = (0..len).map(|_| rng.below(4) as u8).collect();
    let base: Vec<Sequence> = (0..base_n)
        .map(|i| {
            // The base set carries indels, so the merged profile starts
            // non-trivial.
            Sequence::new(format!("s{i}"), variant(&mut rng, &reference, 0.02, 0.004), Alphabet::Dna)
        })
        .collect();
    // Appended sequences: mostly substitution-only (no widening), every
    // 20th carries indels to exercise the widen-and-rerender path.
    let extra: Vec<Sequence> = (0..appends)
        .map(|i| {
            let indels = if i % 20 == 19 { 0.01 } else { 0.0 };
            Sequence::new(
                format!("a{i}"),
                variant(&mut rng, &reference, 0.02, indels),
                Alphabet::Dna,
            )
        })
        .collect();

    let cluster = Cluster::new(ClusterConfig::spark(4));
    let cfg = CenterStarConfig::default();
    let store = ArtifactStore::new(
        std::env::temp_dir().join(format!("halign2-serve-bench-{}", std::process::id())),
        budget,
    )
    .expect("artifact store");
    let mut max_artifact_bytes = 0usize;

    // --- 1 big job -----------------------------------------------------------
    let mut union = base.clone();
    let base_key = canonical_digest(&union);
    assert!(store.get(base_key).unwrap().is_none(), "fresh store must miss");
    let t = Instant::now();
    let (mut parent_msa, mut parent_art) =
        align_nucleotide_with_artifact(&cluster, &union, &cfg).unwrap();
    let base_secs = t.elapsed().as_secs_f64();
    let bytes = parent_art.to_bytes();
    max_artifact_bytes = max_artifact_bytes.max(bytes.len());
    store.put(base_key, bytes).unwrap();
    // Exact resubmission of the big job: decode + render, engine untouched.
    let blob = store.get(base_key).unwrap().expect("stored job must hit");
    let rendered = MsaArtifact::from_bytes(&blob).unwrap().render().unwrap();
    let mut bit_identical = rendered.aligned.iter().zip(&parent_msa.aligned).all(|(a, b)| {
        a.id == b.id && a.codes == b.codes
    });

    // --- 100 small appends (cached path) -------------------------------------
    // Per-append latency goes into an obs log2 histogram; the JSON
    // reports p50/p99 and their ratio (tail shape is host-independent
    // enough to gate, absolute milliseconds are not).
    let append_hist = Histogram::new();
    let mut rows_rendered_total = 0usize;
    let mut widened_appends = 0usize;
    let t = Instant::now();
    for s in &extra {
        union.push(s.clone());
        let key = canonical_digest(&union);
        assert!(store.get(key).unwrap().is_none(), "union job must be new");
        let one = Instant::now();
        let out =
            append_nucleotide(&cluster, &parent_art, std::slice::from_ref(s), Some(&parent_msa))
                .unwrap();
        append_hist.record(one.elapsed().as_nanos() as u64);
        rows_rendered_total += out.rows_rendered;
        widened_appends += usize::from(out.widened);
        let bytes = out.artifact.to_bytes();
        max_artifact_bytes = max_artifact_bytes.max(bytes.len());
        store.put(key, bytes).unwrap();
        parent_msa = out.msa;
        parent_art = out.artifact;
    }
    let append_secs = t.elapsed().as_secs_f64();
    let append_snap = append_hist.snapshot();
    let append_p50_ms = append_snap.percentile(0.50) as f64 / 1e6;
    let append_p99_ms = append_snap.percentile(0.99) as f64 / 1e6;
    let latency_tail_ratio =
        append_snap.percentile(0.99) as f64 / (append_snap.percentile(0.50).max(1)) as f64;
    // Resubmit the final union: it hits (re-read from disk if the LRU
    // spilled it) and must render bit-identically.
    let final_key = canonical_digest(&union);
    let blob = store.get(final_key).unwrap().expect("final union must hit");
    let from_cache = MsaArtifact::from_bytes(&blob).unwrap().render().unwrap();
    bit_identical &= from_cache.aligned.iter().zip(&parent_msa.aligned).all(|(a, b)| {
        a.id == b.id && a.codes == b.codes
    });

    // --- no-cache baseline: recompute every union from scratch ---------------
    let t = Instant::now();
    let mut scratch_msa = None;
    for k in 0..appends {
        let upto = &union[..base_n + k + 1];
        scratch_msa = Some(align_nucleotide(&cluster, upto, &cfg).unwrap());
    }
    let recompute_secs = t.elapsed().as_secs_f64();
    // The append chain must equal the from-scratch union bit for bit.
    let scratch = scratch_msa.unwrap();
    bit_identical &= scratch.width == parent_msa.width
        && scratch.aligned.iter().zip(&parent_msa.aligned).all(|(a, b)| {
            a.id == b.id && a.codes == b.codes
        });

    let speedup = recompute_secs / append_secs.max(1e-9);
    let peak = store.peak_resident_bytes();
    let peak_within_budget = peak <= budget + max_artifact_bytes;

    println!("serve bench: 1 big job (n={base_n}, {base_secs:.3}s) + {appends} appends");
    println!(
        "  appends: {append_secs:.3}s total ({widened_appends} widened, \
         {rows_rendered_total} rows rendered)"
    );
    println!(
        "  append latency: p50 {append_p50_ms:.3}ms, p99 {append_p99_ms:.3}ms \
         (tail ratio {latency_tail_ratio:.1}x)"
    );
    println!("  recompute baseline: {recompute_secs:.3}s total");
    println!("  append_speedup: {speedup:.1}x");
    println!(
        "  cache: {} hits / {} misses, peak {peak} bytes (budget {budget}, \
         largest artifact {max_artifact_bytes})",
        store.hits(),
        store.misses()
    );
    println!("  bit_identical: {bit_identical}   peak_within_budget: {peak_within_budget}");

    write_bench_serve_json(&[
        ("hits", store.hits().to_string()),
        ("misses", store.misses().to_string()),
        ("appends", appends.to_string()),
        ("widened_appends", widened_appends.to_string()),
        ("append_secs", format!("{append_secs:.6}")),
        ("append_p50_ms", format!("{append_p50_ms:.6}")),
        ("append_p99_ms", format!("{append_p99_ms:.6}")),
        ("latency_tail_ratio", format!("{latency_tail_ratio:.3}")),
        ("recompute_secs", format!("{recompute_secs:.6}")),
        ("speedup", format!("{speedup:.3}")),
        ("cache_peak_bytes", peak.to_string()),
        ("cache_budget_bytes", budget.to_string()),
        ("cache_max_artifact_bytes", max_artifact_bytes.to_string()),
        ("peak_within_budget", peak_within_budget.to_string()),
        ("bit_identical", bit_identical.to_string()),
    ]);
    assert!(bit_identical, "append chain must be bit-identical to from-scratch unions");
    assert!(peak_within_budget, "cache peak {peak} exceeds budget + one artifact");
}

//! Regenerates Figure 5 — average maximum per-worker memory of
//! HAlign(Hadoop) vs SparkSW vs HAlign-II on DNA and protein workloads.
#[allow(dead_code)]
mod common;

fn main() {
    let cfg = common::config_from_env();
    let svc = common::service();
    common::emit(
        "Figure 5 — avg max per-worker memory (MB)",
        halign2::bench::fig5_memory(&cfg, svc.as_ref()),
    );
}

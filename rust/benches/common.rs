//! Shared plumbing for the paper-table bench targets (harness = false —
//! the offline build has no criterion; each bench is a timed driver that
//! prints the paper-style table plus machine-readable TSV).

use std::time::Duration;

use halign2::bench::BenchConfig;
use halign2::metrics::{print_table, tsv_line, RunReport};
use halign2::runtime::XlaService;

pub fn config_from_env() -> BenchConfig {
    let env_f = |k: &str, d: f64| {
        std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
    };
    BenchConfig {
        workers: env_f("WORKERS", 8.0) as usize,
        scale: env_f("SCALE", 1.0),
        budget: Duration::from_secs(env_f("BUDGET_SECS", 60.0) as u64),
        quick: std::env::var("QUICK").is_ok()
            || std::env::args().any(|a| a == "--quick" || a == "--test"),
        seed: 0xBEEF,
    }
}

/// XLA routing for table benches: interpret-mode Pallas on the CPU PJRT
/// plugin is an architecture/correctness path, not a CPU speed path
/// (native SW is ~5x faster on this box — EXPERIMENTS.md §Perf), so the
/// paper tables run native unless HALIGN2_XLA=1 forces the XLA route.
pub fn service() -> Option<XlaService> {
    if std::env::var("HALIGN2_XLA").ok().as_deref() != Some("1") {
        return None;
    }
    service_forced()
}

/// Unconditional load (micro benches measure the XLA path explicitly).
pub fn service_forced() -> Option<XlaService> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(dir).join("manifest.txt").exists() {
        XlaService::start(dir).ok()
    } else {
        None
    }
}

pub fn emit(title: &str, rows: Vec<RunReport>) {
    print_table(title, &rows);
    println!("\n# {}", halign2::metrics::TSV_HEADER);
    for r in &rows {
        println!("{}", tsv_line(r));
    }
}

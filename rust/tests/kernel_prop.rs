//! Property suite pinning the integer bit-parallel/banded kernels to the
//! scalar full-DP references, bit-for-bit: seeded cases spanning band
//! widths, lengths crossing multiple 64-column words, all-ties inputs
//! (1- and 2-symbol alphabets), and the adaptive band re-run path (tiny
//! initial band forced to double).  Each suite runs >= 100 cases.

use halign2::align::banded::{
    affine_banded, affine_full, banded_global, banded_global_with_band, sw_align_i32, AffineCosts,
    IntSwParams,
};
use halign2::align::myers::{edit_distance_dp, myers_edit_distance, pack_row, pdist_counts_packed};
use halign2::align::pairwise::global_dp;
use halign2::align::sw::{sw_align, SwParams};
use halign2::fasta::{alphabet::substitution_matrix, Alphabet};
use halign2::util::Rng;

fn rand_seq(rng: &mut Rng, len: usize, alpha: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(alpha) as u8).collect()
}

/// Lengths that straddle the 64-column word boundaries of the
/// bit-parallel kernels, plus short/empty edges.
fn word_spanning_len(rng: &mut Rng) -> usize {
    match rng.below(4) {
        0 => rng.below(10),              // short / empty
        1 => 60 + rng.below(10),         // around one word
        2 => 125 + rng.below(8),         // around two words
        _ => 180 + rng.below(60),        // three-to-four words
    }
}

#[test]
fn myers_edit_distance_matches_dp_across_words_and_alphabets() {
    let mut cases = 0;
    for &alpha in &[1usize, 2, 4] {
        let mut rng = Rng::seed_from_u64(0x1000 + alpha as u64);
        for _ in 0..40 {
            let a = rand_seq(&mut rng, word_spanning_len(&mut rng), alpha);
            let b = rand_seq(&mut rng, word_spanning_len(&mut rng), alpha);
            assert_eq!(
                myers_edit_distance(&a, &b),
                edit_distance_dp(&a, &b),
                "alpha {alpha}, lens ({}, {})",
                a.len(),
                b.len()
            );
            cases += 1;
        }
    }
    assert!(cases >= 100);
}

#[test]
fn banded_global_is_bit_identical_to_full_dp() {
    // 3 alphabets x 4 band widths x 12 reps = 144 cases.  The 1-symbol
    // alphabet makes every DP cell a tie chain (gap placement is all
    // ties); w0 = 1 forces the adaptive widening/re-run path whenever
    // the optimum strays; w0 = 256 covers the full matrix immediately.
    let mut cases = 0;
    for &alpha in &[1usize, 2, 4] {
        for &w0 in &[1usize, 2, 8, 256] {
            let mut rng = Rng::seed_from_u64(0x2000 + (alpha * 1000 + w0) as u64);
            for _ in 0..12 {
                let a = rand_seq(&mut rng, word_spanning_len(&mut rng), alpha);
                let b = rand_seq(&mut rng, word_spanning_len(&mut rng), alpha);
                let want = global_dp(&a, &b);
                assert_eq!(
                    banded_global_with_band(&a, &b, w0),
                    want,
                    "alpha {alpha}, w0 {w0}, lens ({}, {})",
                    a.len(),
                    b.len()
                );
                cases += 1;
            }
        }
    }
    assert!(cases >= 100);
}

#[test]
fn banded_global_default_band_seed_is_bit_identical() {
    // The Myers-seeded production entry point (no explicit band).
    let mut rng = Rng::seed_from_u64(0x3000);
    for case in 0..120 {
        let alpha = 1 + rng.below(4);
        let a = rand_seq(&mut rng, word_spanning_len(&mut rng), alpha);
        let b = rand_seq(&mut rng, word_spanning_len(&mut rng), alpha);
        assert_eq!(banded_global(&a, &b), global_dp(&a, &b), "case {case}");
    }
}

#[test]
fn affine_banded_matches_full_gotoh_bit_exactly() {
    // 3 penalty schemes x 2 band seeds x 20 reps = 120 cases; score AND
    // op path must agree (the op comparison is what catches a traceback
    // that picks a different co-optimal predecessor).
    let subst = |mat: i32, mis: i32| -> Vec<i32> {
        let mut s = vec![mis; 16];
        for k in 0..4 {
            s[k * 4 + k] = mat;
        }
        s
    };
    let schemes = [
        AffineCosts { subst: subst(2, -3), alpha: 4, open: 5, ext: 1 },
        AffineCosts { subst: subst(5, -4), alpha: 4, open: 10, ext: 2 },
        AffineCosts { subst: subst(1, -1), alpha: 4, open: 1, ext: 3 },
    ];
    let mut cases = 0;
    for (si, p) in schemes.iter().enumerate() {
        for &w0 in &[1usize, 16] {
            let mut rng = Rng::seed_from_u64(0x4000 + (si * 100 + w0) as u64);
            for rep in 0..20 {
                let alpha = 1 + rng.below(4); // include all-ties inputs
                let a = rand_seq(&mut rng, 1 + rng.below(130), alpha);
                let b = rand_seq(&mut rng, 1 + rng.below(130), alpha);
                let (fs, fo) = affine_full(&a, &b, p);
                let (bs, bo) = affine_banded(&a, &b, p, w0);
                assert_eq!(fs, bs, "scheme {si}, w0 {w0}, rep {rep}: score");
                assert_eq!(fo, bo, "scheme {si}, w0 {w0}, rep {rep}: ops");
                cases += 1;
            }
        }
    }
    assert!(cases >= 100);
}

#[test]
fn packed_pdist_counts_match_scalar_loop_across_words() {
    // DNA (gap 5) and protein (gap 23) rows, lengths spanning words.
    let mut cases = 0;
    for &(residues, gap) in &[(5usize, 5u8), (23usize, 23u8)] {
        let mut rng = Rng::seed_from_u64(0x5000 + gap as u64);
        for _ in 0..60 {
            let len = 1 + word_spanning_len(&mut rng);
            let row = |rng: &mut Rng| -> Vec<u8> {
                (0..len)
                    .map(|_| if rng.chance(0.15) { gap } else { rng.below(residues) as u8 })
                    .collect()
            };
            let a = row(&mut rng);
            let b = row(&mut rng);
            let (mut compared, mut mismatch) = (0u64, 0u64);
            for (x, y) in a.iter().zip(&b) {
                if *x != gap && *y != gap {
                    compared += 1;
                    mismatch += u64::from(x != y);
                }
            }
            let (pa, pb) = (pack_row(&a, gap), pack_row(&b, gap));
            assert_eq!(pdist_counts_packed(&pa, &pb), (compared, mismatch), "len {len}");
            cases += 1;
        }
    }
    assert!(cases >= 100);
}

#[test]
fn integer_sw_matches_f32_kernel_for_builtin_matrices() {
    // Every built-in matrix is integer-valued, so the i32 kernel must be
    // bit-identical to the f32 one: score, op path, and ranges.
    let mut cases = 0;
    let combos = [(Alphabet::Dna, 6.0f32), (Alphabet::Dna, 2.0), (Alphabet::Protein, 4.0)];
    for &(alphabet, gap) in &combos {
        let p = SwParams {
            subst: substitution_matrix(alphabet),
            alpha: alphabet.size(),
            gap,
        };
        let ip = IntSwParams::from_f32(&p).expect("built-in matrices are integer-valued");
        let mut rng = Rng::seed_from_u64(0x6000 + gap as u64);
        for rep in 0..40 {
            let residues = alphabet.residues();
            let a: Vec<i32> =
                (0..1 + rng.below(150)).map(|_| rng.below(residues) as i32).collect();
            let b: Vec<i32> =
                (0..1 + rng.below(150)).map(|_| rng.below(residues) as i32).collect();
            let sf = sw_align(&a, &b, &p);
            let si = sw_align_i32(&a, &b, &ip);
            assert_eq!(sf.score, si.score, "{alphabet:?} gap {gap} rep {rep}: score");
            assert_eq!(sf.ops, si.ops, "{alphabet:?} gap {gap} rep {rep}: ops");
            assert_eq!(
                (sf.a_start, sf.a_end, sf.b_start, sf.b_end),
                (si.a_start, si.a_end, si.b_start, si.b_end),
                "{alphabet:?} gap {gap} rep {rep}: ranges"
            );
            cases += 1;
        }
    }
    assert!(cases >= 100);
}

//! Property tests for the observability substrate (`src/obs/`): the
//! histogram's exact-merge algebra and percentile contract, and the
//! trace ring's overflow/concurrency discipline.  Randomness is the
//! project's seeded [`halign2::util::Rng`], so every run checks the
//! same cases — failures reproduce, and the suite stays dependency-free.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use halign2::obs::{
    registry::{bucket_index, bucket_lower_bound, bucket_upper_bound, NUM_BUCKETS},
    Counter, HistSnapshot, Histogram, TraceKind, TraceSink,
};
use halign2::util::Rng;

/// A random value with a log-uniform-ish spread: small latencies and
/// huge outliers both show up, which is what exercises bucket edges.
fn sample(rng: &mut Rng) -> u64 {
    let magnitude = rng.below(50) as u32;
    let base = 1u64 << magnitude;
    base + rng.below(base.min(1 << 20) as usize + 1) as u64 - 1
}

fn record_all(values: &[u64]) -> HistSnapshot {
    let h = Histogram::new();
    for &v in values {
        h.record(v);
    }
    h.snapshot()
}

// ------------------------------------------------------- histogram --

#[test]
fn prop_bucket_bounds_contain_their_values() {
    let mut rng = Rng::seed_from_u64(0x0B5);
    for _ in 0..10_000 {
        let v = sample(&mut rng);
        let i = bucket_index(v);
        assert!(i < NUM_BUCKETS);
        assert!(
            bucket_lower_bound(i) <= v && v <= bucket_upper_bound(i),
            "value {v} outside bucket {i} bounds [{}, {}]",
            bucket_lower_bound(i),
            bucket_upper_bound(i),
        );
    }
    // The edges the random sweep is unlikely to hit exactly.
    for v in [0, 1, 2, 3, 4, u64::MAX - 1, u64::MAX] {
        let i = bucket_index(v);
        assert!(bucket_lower_bound(i) <= v && v <= bucket_upper_bound(i));
    }
}

#[test]
fn prop_merge_is_exact_associative_and_commutative() {
    let mut rng = Rng::seed_from_u64(0xABBA);
    for _ in 0..64 {
        let mut make = |n: usize| -> Vec<u64> { (0..n).map(|_| sample(&mut rng)).collect() };
        let (a, b, c) = (make(37), make(11), make(53));

        let (sa, sb, sc) = (record_all(&a), record_all(&b), record_all(&c));
        // Exact: merging snapshots equals recording the union.
        let union: Vec<u64> = a.iter().chain(&b).copied().collect();
        assert_eq!(sa.merge(&sb), record_all(&union), "merge must equal the recorded union");
        // Commutative and associative, and the empty snapshot is the
        // identity — counts, sums, maxes, and every bucket.
        assert_eq!(sa.merge(&sb), sb.merge(&sa));
        assert_eq!(sa.merge(&sb).merge(&sc), sa.merge(&sb.merge(&sc)));
        assert_eq!(sa.merge(&HistSnapshot::empty()), sa);
    }
}

#[test]
fn prop_percentiles_are_monotone_and_bounded() {
    let mut rng = Rng::seed_from_u64(0xCAFE);
    for round in 0..64 {
        let n = 1 + rng.below(300);
        let values: Vec<u64> = (0..n).map(|_| sample(&mut rng)).collect();
        let snap = record_all(&values);
        let max = *values.iter().max().unwrap();
        let min = *values.iter().min().unwrap();

        let qs = [0.0, 0.01, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0];
        let ps: Vec<u64> = qs.iter().map(|&q| snap.percentile(q)).collect();
        assert!(
            ps.windows(2).all(|w| w[0] <= w[1]),
            "percentiles must be monotone in q (round {round}): {ps:?}"
        );
        // Never above the largest observation, and p100 reaches it
        // exactly; never below the smallest observation's bucket floor.
        assert!(ps.iter().all(|&p| p <= max));
        assert_eq!(snap.percentile(1.0), max);
        assert!(snap.percentile(0.0) >= bucket_lower_bound(bucket_index(min)));
    }
    assert_eq!(HistSnapshot::empty().percentile(0.5), 0, "empty snapshot reads 0");
}

#[test]
fn prop_concurrent_recording_loses_nothing() {
    const THREADS: u64 = 8;
    const PER_THREAD: u64 = 10_000;
    let h = Arc::new(Histogram::new());
    // Deterministic per-thread value streams, so the expected bucket
    // counts can be recomputed serially and compared exactly.
    let value_at = |t: u64, j: u64| -> u64 {
        let mix = (t.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ j.wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .rotate_left((j % 63) as u32);
        mix >> (mix % 50)
    };
    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let h = Arc::clone(&h);
            thread::spawn(move || {
                for j in 0..PER_THREAD {
                    h.record(value_at(t, j));
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    let expected = record_all(
        &(0..THREADS)
            .flat_map(|t| (0..PER_THREAD).map(move |j| value_at(t, j)))
            .collect::<Vec<u64>>(),
    );
    let got = h.snapshot();
    assert_eq!(got, expected, "concurrent recording must match the serial recording exactly");
    assert_eq!(got.count, THREADS * PER_THREAD);
}

// ------------------------------------------------------ trace ring --

fn sink(lanes: usize, capacity: usize) -> Arc<TraceSink> {
    TraceSink::new(lanes, capacity, Arc::new(Counter::default()))
}

/// The kind/payload pairing every fixture event carries, so a torn slot
/// (old kind, new payload or vice versa) is detectable after any wrap.
fn kind_for(payload: u64) -> TraceKind {
    match payload % 3 {
        0 => TraceKind::Enqueue,
        1 => TraceKind::Steal,
        _ => TraceKind::KillDrain,
    }
}

#[test]
fn prop_overflow_keeps_newest_and_counts_drops_exactly() {
    let mut rng = Rng::seed_from_u64(0x71AC);
    for _ in 0..32 {
        let capacity = 1 + rng.below(64);
        let pushes = 1 + rng.below(capacity * 4);
        let s = sink(1, capacity);
        for i in 0..pushes as u64 {
            s.emit(0, kind_for(i), i);
        }
        let expected_drops = pushes.saturating_sub(capacity) as u64;
        assert_eq!(s.dropped(), expected_drops, "drops = pushes - capacity, exactly");
        let ev = s.drain_new();
        assert_eq!(ev.len(), pushes.min(capacity), "ring retains min(pushes, capacity)");
        let payloads: Vec<u64> = ev.iter().map(|e| e.payload).collect();
        let newest: Vec<u64> = (expected_drops..pushes as u64).collect();
        assert_eq!(payloads, newest, "exactly the oldest events are displaced");
        assert!(ev.iter().all(|e| e.kind == kind_for(e.payload)));
    }
}

#[test]
fn prop_concurrent_wrap_never_tears_an_event() {
    const WRITERS: u64 = 4;
    const PER_WRITER: u64 = 5_000;
    const CAPACITY: usize = 32; // tiny ring: every writer wraps it many times over
    let s = sink(1, CAPACITY);
    let stop = Arc::new(AtomicBool::new(false));

    // A concurrent drainer races the writers: drained events may be an
    // arbitrary subset (overwritten slots are discarded), but every one
    // must carry a consistent kind/payload pair.
    let drainer = {
        let s = Arc::clone(&s);
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            let mut seen = 0usize;
            while !stop.load(Ordering::SeqCst) {
                for e in s.drain_new() {
                    assert_eq!(e.kind, kind_for(e.payload), "torn slot escaped the drain guard");
                    seen += 1;
                }
            }
            seen
        })
    };
    let writers: Vec<_> = (0..WRITERS)
        .map(|t| {
            let s = Arc::clone(&s);
            thread::spawn(move || {
                for j in 0..PER_WRITER {
                    let payload = t * PER_WRITER + j;
                    s.emit(0, kind_for(payload), payload);
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    drainer.join().unwrap();

    // After quiesce: one final drain is tear-free too, and the drop
    // counter is exact even under multi-writer contention (every claim
    // past the capacity watermark counted exactly once).
    for e in s.drain_new() {
        assert_eq!(e.kind, kind_for(e.payload));
    }
    assert_eq!(s.dropped(), WRITERS * PER_WRITER - CAPACITY as u64);
}

#[test]
fn prop_quiesced_multiwriter_ring_retains_exactly_capacity() {
    const WRITERS: u64 = 4;
    const PER_WRITER: u64 = 2_000;
    const CAPACITY: usize = 64;
    // No mid-flight drains here, so the final drain must surface the
    // full window: exactly `capacity` events, all well-formed, with
    // nondecreasing timestamps after the sink's (nanos, lane) sort.
    let s = sink(2, CAPACITY);
    let writers: Vec<_> = (0..WRITERS)
        .map(|t| {
            let s = Arc::clone(&s);
            thread::spawn(move || {
                for j in 0..PER_WRITER {
                    let payload = t * PER_WRITER + j;
                    s.emit((t % 2) as usize, kind_for(payload), payload);
                }
            })
        })
        .collect();
    for w in writers {
        w.join().unwrap();
    }
    let ev = s.drain_new();
    assert_eq!(ev.len(), 2 * CAPACITY, "both lanes retain exactly their capacity");
    assert!(ev.windows(2).all(|w| w[0].nanos <= w[1].nanos), "drain is time-sorted");
    assert!(ev.iter().all(|e| e.kind == kind_for(e.payload)));
    assert_eq!(s.dropped(), WRITERS * PER_WRITER - 2 * CAPACITY as u64);
}

//! Property tests for the incremental-append + memoization subsystem:
//! the bit-identity certificate (`align/append.rs` module docs) says an
//! appended alignment equals a from-scratch run on the union, bit for
//! bit, across worker counts, scheduler modes, kernel backends and
//! mid-job worker kills.  This suite is that certificate's enforcement
//! arm, plus the cache-side properties the server leans on: eviction
//! never exceeds budget + one artifact and never loses bytes, and
//! corrupt artifacts are rejected rather than half-decoded.

use halign2::align::append::{append_nucleotide, MsaArtifact};
use halign2::align::center_star::{align_nucleotide_with_artifact, CenterStarConfig};
use halign2::align::KernelBackend;
use halign2::cache::ArtifactStore;
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig, FaultPlan, SchedulerMode};
use halign2::util::Rng;

/// Case count for the property sweep: overridable via
/// `HALIGN_STRESS_CASES` so the sanitizer CI jobs (ThreadSanitizer,
/// Miri) can run the same tests at instrumentation-friendly depth.
fn stress_cases(default: u64) -> u64 {
    std::env::var("HALIGN_STRESS_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// A small mito-like family: shared ancestor, per-case divergence.  The
/// `indel_rate` knob is what decides whether appends widen the profile,
/// so sweeping it exercises both the fast path and the re-render path.
fn family(n: usize, indel_rate: f64, seed: u64) -> Vec<halign2::fasta::Sequence> {
    DatasetSpec {
        count: n,
        base_len: 96,
        indel_rate,
        ..DatasetSpec::mito(0.01, seed)
    }
    .generate()
}

/// ≥100 seeded cases: split a family into a base job and `k` appended
/// sequences, run the parent job, append onto its artifact, and require
/// the result — alignment *and* artifact — to equal a from-scratch run
/// on the union exactly.  Cases vary worker count, scheduler mode,
/// kernel backend, widening vs non-widening divergence, duplicate
/// appends, and (every fifth case) a worker killed mid-append; a third
/// of the cases also round-trip the parent artifact through its byte
/// encoding first, the way the server's cache serves it.
#[test]
fn append_is_bit_identical_to_scratch_across_100_cases() {
    let mut rng = Rng::seed_from_u64(0xA99E_4D);
    for case in 0..stress_cases(100) {
        let base_n = 2 + rng.below(10);
        let k = 1 + rng.below(5);
        // Low indel rates keep most appends inside the parent's column
        // space (fast path); high ones force widening merges.
        let indel_rate = [0.0, 0.0005, 0.002, 0.01][rng.below(4)];
        let mut all = family(base_n + k, indel_rate, 0x5EED + case);
        if rng.chance(0.25) {
            // Duplicate traffic: an appended sequence that already exists
            // in the base set (same residues, fresh id) must still match
            // the scratch run on the same union.
            let src = rng.below(base_n);
            let dup = all.len() - 1;
            all[dup].codes = all[src].codes.clone();
        }
        let (base, new) = all.split_at(base_n);

        let workers = [2usize, 3, 4, 8, 16][rng.below(5)];
        let mut ccfg = ClusterConfig::spark(workers);
        ccfg.scheduler.mode = if rng.chance(0.5) {
            SchedulerMode::Sharded
        } else {
            SchedulerMode::GlobalLock
        };
        if case % 5 == 0 {
            ccfg.fault = FaultPlan::kill_worker_at(rng.below(workers), rng.below(6));
        }
        let cluster = Cluster::new(ccfg);
        let cfg = CenterStarConfig {
            kernel: if rng.chance(0.5) {
                KernelBackend::Scalar
            } else {
                KernelBackend::BitParallel
            },
            ..CenterStarConfig::default()
        };

        let (base_msa, art) = align_nucleotide_with_artifact(&cluster, base, &cfg)
            .unwrap_or_else(|e| panic!("case {case}: base job failed: {e:#}"));
        // A third of the cases decode the artifact from bytes first —
        // the shape a cache hit hands the append path.
        let art = if rng.chance(0.33) {
            MsaArtifact::from_bytes(&art.to_bytes())
                .unwrap_or_else(|e| panic!("case {case}: artifact round-trip failed: {e:#}"))
        } else {
            art
        };
        let parent_msa = if rng.chance(0.5) { Some(&base_msa) } else { None };
        let out = append_nucleotide(&cluster, &art, new, parent_msa)
            .unwrap_or_else(|e| panic!("case {case}: append failed: {e:#}"));

        let (scratch, scratch_art) = align_nucleotide_with_artifact(&cluster, &all, &cfg)
            .unwrap_or_else(|e| panic!("case {case}: scratch union failed: {e:#}"));
        assert_eq!(
            out.msa.width, scratch.width,
            "case {case}: n={base_n} k={k} w={workers} — widths differ"
        );
        for (a, b) in out.msa.aligned.iter().zip(&scratch.aligned) {
            assert_eq!(
                a.codes, b.codes,
                "case {case}: n={base_n} k={k} w={workers} indel={indel_rate} \
                 — append must equal from-scratch union bit for bit ({})",
                a.id
            );
        }
        assert_eq!(
            out.artifact, scratch_art,
            "case {case}: appended artifact must equal the scratch artifact"
        );
        if !out.widened && parent_msa.is_some() {
            assert_eq!(
                out.rows_rendered, k,
                "case {case}: no-widening fast path must render only the {k} new rows"
            );
        }
    }
}

/// Seeded eviction sweep: hammer an `ArtifactStore` with random-sized
/// blobs under a tiny budget and require (a) peak residency never
/// exceeds budget + one artifact, (b) every key remains readable, and
/// (c) every read returns the exact bytes that were put — LRU spilling
/// must lose nothing and corrupt nothing.
#[test]
fn eviction_under_budget_loses_no_bytes_across_cases() {
    let mut rng = Rng::seed_from_u64(0xE71C_7104);
    for case in 0..stress_cases(30) {
        let budget = 256 + rng.below(2048);
        let dir = std::env::temp_dir().join(format!(
            "halign2-appendprop-evict-{}-{case}",
            std::process::id()
        ));
        let store = ArtifactStore::new(dir, budget).unwrap();
        let n_blobs = 4 + rng.below(24);
        let mut blobs: Vec<(u64, Vec<u8>)> = Vec::with_capacity(n_blobs);
        let mut max_blob = 0usize;
        for i in 0..n_blobs {
            let len = 1 + rng.below(budget);
            let data: Vec<u8> = (0..len).map(|j| (i * 31 + j) as u8 ^ case as u8).collect();
            max_blob = max_blob.max(data.len());
            store.put(i as u64, data.clone()).unwrap();
            blobs.push((i as u64, data));
            if rng.chance(0.3) {
                // Interleave reads so the LRU order is non-trivial.
                let (k, want) = &blobs[rng.below(blobs.len())];
                let got = store.get(*k).unwrap().expect("known key must hit");
                assert_eq!(&*got, want, "case {case}: read-back during churn");
            }
        }
        assert!(
            store.peak_resident_bytes() <= budget + max_blob,
            "case {case}: peak {} must stay within budget {budget} + one blob {max_blob}",
            store.peak_resident_bytes()
        );
        for (k, want) in &blobs {
            let got = store.get(*k).unwrap().unwrap_or_else(|| {
                panic!("case {case}: key {k} lost after eviction churn")
            });
            assert_eq!(&*got, want, "case {case}: key {k} bytes must survive spilling");
        }
        assert_eq!(store.entries(), n_blobs, "case {case}: every key stays known");
    }
}

/// Seeded corruption sweep: random byte flips, truncations and junk
/// prefixes over a real artifact encoding must all be rejected by
/// `from_bytes` — the checksum + structural validation is what lets the
/// cache treat a decodable blob as truth.
#[test]
fn corrupt_artifacts_are_rejected_across_cases() {
    let cluster = Cluster::new(ClusterConfig::spark(2));
    let seqs = family(6, 0.002, 0xC0FF);
    let (_, art) =
        align_nucleotide_with_artifact(&cluster, &seqs, &CenterStarConfig::default()).unwrap();
    let good = art.to_bytes();
    assert!(MsaArtifact::from_bytes(&good).is_ok());

    let mut rng = Rng::seed_from_u64(0xBAD_B17);
    for case in 0..stress_cases(100) {
        let mut bad = good.clone();
        match rng.below(3) {
            0 => {
                // Flip 1-4 random bits.
                for _ in 0..1 + rng.below(4) {
                    let pos = rng.below(bad.len());
                    bad[pos] ^= 1 << rng.below(8);
                }
            }
            1 => {
                // Truncate anywhere, including inside the header.
                bad.truncate(rng.below(bad.len()));
            }
            _ => {
                // Append trailing junk past the checksum.
                for _ in 0..1 + rng.below(16) {
                    bad.push(rng.below(256) as u8);
                }
            }
        }
        if bad == good {
            continue;
        }
        assert!(
            MsaArtifact::from_bytes(&bad).is_err(),
            "case {case}: corrupted artifact ({} bytes vs {} good) must be rejected",
            bad.len(),
            good.len()
        );
    }
}

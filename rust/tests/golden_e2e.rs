//! Deterministic end-to-end golden test: DNA center-star MSA over a
//! seeded synthetic mito dataset, asserting the *exact* alignment width,
//! SP score, and row bytes are identical across worker counts (1 and 4),
//! shuffle backends (Spark in-memory and Hadoop disk-KV), scheduler modes
//! (stealing on/off), and fault plans (random task failures, a targeted
//! worker fault, and a worker kill) — the engine must never change
//! results, only performance.

use halign2::align::center_star::{align_nucleotide, CenterStarConfig};
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig, FaultPlan, SchedulerMode};
use halign2::fasta::Sequence;

fn dataset() -> Vec<Sequence> {
    DatasetSpec { count: 28, ..DatasetSpec::mito(0.01, 0x601D) }.generate()
}

struct GoldenRun {
    width: usize,
    avg_sp: f64,
    rows: Vec<Vec<u8>>,
    cluster: Cluster,
}

fn run(cfg: ClusterConfig) -> GoldenRun {
    let seqs = dataset();
    let cluster = Cluster::new(cfg);
    let msa = align_nucleotide(&cluster, &seqs, &CenterStarConfig::default()).unwrap();
    msa.validate(&seqs).unwrap();
    let avg_sp = msa.avg_sp_distributed(&cluster).unwrap();
    // The distributed scorer folds the same integer column counts as the
    // local one; the values must match bit-for-bit.
    assert_eq!(avg_sp, msa.avg_sp().unwrap(), "distributed SP == local SP");
    GoldenRun {
        width: msa.width,
        avg_sp,
        rows: msa.aligned.iter().map(|s| s.codes.clone()).collect(),
        cluster,
    }
}

#[test]
fn golden_msa_identical_across_workers_backends_schedulers_and_faults() {
    let golden = run(ClusterConfig::spark(1));
    let max_input = dataset().iter().map(Sequence::len).max().unwrap();
    assert!(golden.width >= max_input, "MSA at least as wide as the longest input");
    assert!(golden.avg_sp >= 0.0 && golden.avg_sp.is_finite());

    fn with_fault(mut cfg: ClusterConfig, fault: FaultPlan, retries: usize) -> ClusterConfig {
        cfg.fault = fault;
        cfg.max_retries = retries;
        cfg
    }
    let mut nosteal = ClusterConfig::spark(4);
    nosteal.scheduler.work_stealing = false;
    nosteal.scheduler.speculation = false;
    let mut global_lock = ClusterConfig::spark(4);
    global_lock.scheduler.mode = SchedulerMode::GlobalLock;

    let variants: Vec<(&str, ClusterConfig, bool)> = vec![
        ("spark-4w", ClusterConfig::spark(4), false),
        ("hadoop-1w", ClusterConfig::hadoop(1), false),
        ("hadoop-4w", ClusterConfig::hadoop(4), false),
        ("spark-4w-nosteal", nosteal, false),
        ("spark-4w-globallock", global_lock, false),
        (
            "spark-1w-faults",
            with_fault(
                ClusterConfig::spark(1),
                FaultPlan::fail_first_attempt_on_worker(0),
                4,
            ),
            true,
        ),
        (
            "spark-4w-random-faults",
            with_fault(ClusterConfig::spark(4), FaultPlan::random(0.25, 0xFA117), 10),
            true,
        ),
        (
            "spark-4w-worker-fault",
            with_fault(
                ClusterConfig::spark(4),
                FaultPlan::fail_first_attempt_on_worker(2),
                4,
            ),
            true,
        ),
        (
            "spark-4w-kill",
            with_fault(ClusterConfig::spark(4), FaultPlan::kill_worker_at(1, 10), 2),
            true,
        ),
        (
            "hadoop-4w-random-faults",
            with_fault(ClusterConfig::hadoop(4), FaultPlan::random(0.2, 0xFA118), 10),
            true,
        ),
    ];

    for (name, cfg, expects_fault) in variants {
        let got = run(cfg);
        assert_eq!(got.width, golden.width, "{name}: width must match golden");
        assert_eq!(got.avg_sp, golden.avg_sp, "{name}: SP must match golden exactly");
        assert_eq!(got.rows, golden.rows, "{name}: aligned rows must be byte-identical");
        if expects_fault {
            assert!(
                got.cluster.config().fault.fired() > 0,
                "{name}: the fault plan never fired, the variant proves nothing"
            );
        }
    }
}

#[test]
fn golden_run_is_reproducible_within_a_config() {
    let a = run(ClusterConfig::spark(4));
    let b = run(ClusterConfig::spark(4));
    assert_eq!(a.width, b.width);
    assert_eq!(a.avg_sp, b.avg_sp);
    assert_eq!(a.rows, b.rows);
}

//! Property tests (hand-rolled generators on the deterministic PRNG —
//! the offline build has no proptest).  Each property runs across many
//! random cases; failures print the seed for replay.

use halign2::align::pairwise::{
    center_space_profile, decode_ops, encode_ops, global_dp, merge_profiles, path_consumes,
    render_center_row, render_query_row,
};
use halign2::align::sp_score::{sp_columnwise, sp_pairwise};
use halign2::align::sw::{sw_align, sw_matrix, SwParams};
use halign2::align::trie::SegmentTrie;
use halign2::engine::{Cluster, ClusterConfig};
use halign2::fasta::{alphabet::substitution_matrix, Alphabet, Sequence};
use halign2::tree::nj::neighbor_joining;
use halign2::util::codec::{Decode, Encode};
use halign2::util::Rng;

const CASES: usize = 60;

fn rand_codes(rng: &mut Rng, len: usize, alpha: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(alpha) as u8).collect()
}

/// Property: every center-star path algebra invariant holds for random
/// pairs — full consumption, profile consistency, render round-trip,
/// equal widths.
#[test]
fn prop_pairwise_algebra() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + case as u64);
        let n = 1 + rng.below(40);
        let m = 1 + rng.below(40);
        let center = rand_codes(&mut rng, n, 4);
        let query = rand_codes(&mut rng, m, 4);
        let ops = global_dp(&query, &center);
        assert_eq!(path_consumes(&ops), (m, n), "case {case}");
        assert_eq!(decode_ops(&encode_ops(&ops)), ops, "case {case}");

        let own = center_space_profile(&ops, n);
        let mut global = own.clone();
        for _ in 0..rng.below(4) {
            let k = rng.below(n + 1);
            global[k] += rng.below(3) as u32;
        }
        let global = merge_profiles(global, &own);
        let row = render_query_row(&query, &ops, &global, &own, Alphabet::Dna);
        let center_row = render_center_row(&center, &global, Alphabet::Dna);
        assert_eq!(row.len(), center_row.len(), "case {case}");
        let degapped: Vec<u8> =
            row.iter().copied().filter(|&c| c != Alphabet::Dna.gap()).collect();
        assert_eq!(degapped, query, "case {case}");
    }
}

/// Property: trie chains are monotone, anchors are exact matches, and a
/// sequence always fully chains against itself.
#[test]
fn prop_trie_chain_soundness() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + case as u64);
        let n = 30 + rng.below(200);
        let seg = 4 + rng.below(8);
        let center = rand_codes(&mut rng, n, 4);
        let trie = SegmentTrie::build(&center, seg);
        // Mutate a copy lightly.
        let mut query = center.clone();
        for _ in 0..rng.below(6) {
            let k = rng.below(query.len());
            query[k] = rng.below(4) as u8;
        }
        let chain = trie.chain(&query);
        let mut prev_c = 0usize;
        let mut prev_q = 0usize;
        for (i, a) in chain.iter().enumerate() {
            if i > 0 {
                assert!(a.center_pos >= prev_c, "case {case}: center monotone");
                assert!(a.query_pos >= prev_q, "case {case}: query monotone");
            }
            assert_eq!(
                &query[a.query_pos..a.query_pos + a.len],
                &center[a.center_pos..a.center_pos + a.len],
                "case {case}: anchors must be exact matches"
            );
            prev_c = a.center_pos + a.len;
            prev_q = a.query_pos + a.len;
        }
        // Self-chain covers every full segment.
        let self_chain = trie.chain(&center);
        assert_eq!(self_chain.len(), trie.num_segments(), "case {case}");
    }
}

/// Property: SW H-matrix cells are within valid bounds and the traceback
/// path's score equals H's maximum.
#[test]
fn prop_sw_score_consistency() {
    let alpha = Alphabet::Dna;
    let params = SwParams {
        subst: substitution_matrix(alpha),
        alpha: alpha.size(),
        gap: 4.0,
    };
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(3000 + case as u64);
        let a: Vec<i32> = (0..1 + rng.below(30)).map(|_| rng.below(4) as i32).collect();
        let b: Vec<i32> = (0..1 + rng.below(30)).map(|_| rng.below(4) as i32).collect();
        let h = sw_matrix(&a, &b, &params);
        let (_, _, best) = h.argmax();
        assert!(best >= 0.0, "case {case}: SW is non-negative");
        let al = sw_align(&a, &b, &params);
        assert_eq!(al.score, best, "case {case}");
        // Re-score the path manually.
        let (mut i, mut j, mut score) = (al.a_start, al.b_start, 0f32);
        for op in &al.ops {
            match op {
                halign2::align::sw::Op::Diag => {
                    score += params.score(a[i], b[j]);
                    i += 1;
                    j += 1;
                }
                halign2::align::sw::Op::Up => {
                    score -= params.gap;
                    i += 1;
                }
                halign2::align::sw::Op::Left => {
                    score -= params.gap;
                    j += 1;
                }
            }
        }
        assert!(
            (score - al.score).abs() < 1e-3,
            "case {case}: path score {score} vs H max {}",
            al.score
        );
    }
}

/// Property: column-wise SP equals the O(n^2 L) pairwise definition.
#[test]
fn prop_sp_columnwise_matches_pairwise() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(4000 + case as u64);
        let n = 2 + rng.below(7);
        let w = 1 + rng.below(30);
        let rows: Vec<Sequence> = (0..n)
            .map(|i| {
                Sequence::new(format!("r{i}"), rand_codes(&mut rng, w, 6), Alphabet::Dna)
            })
            .collect();
        assert_eq!(
            sp_columnwise(&rows).unwrap(),
            sp_pairwise(&rows),
            "case {case}"
        );
    }
}

/// Property: codec round-trips arbitrary nested structures.
#[test]
fn prop_codec_roundtrip() {
    for case in 0..CASES {
        let mut rng = Rng::seed_from_u64(5000 + case as u64);
        let value: Vec<(u64, String, Vec<u8>)> = (0..rng.below(10))
            .map(|_| {
                let s: String = (0..rng.below(12))
                    .map(|_| (b'a' + rng.below(26) as u8) as char)
                    .collect();
                let len = rng.below(20);
                (rng.next_u64(), s, rand_codes(&mut rng, len, 255))
            })
            .collect();
        let bytes = value.to_bytes();
        let back = Vec::<(u64, String, Vec<u8>)>::from_bytes(&bytes).unwrap();
        assert_eq!(back, value, "case {case}");
    }
}

/// Property: NJ trees preserve leaf sets and have non-negative branches
/// for arbitrary (noisy, non-additive) distance matrices.
#[test]
fn prop_nj_structural() {
    for case in 0..30 {
        let mut rng = Rng::seed_from_u64(6000 + case as u64);
        let n = 3 + rng.below(20);
        let labels: Vec<String> = (0..n).map(|i| format!("t{i}")).collect();
        let mut d = vec![vec![0f64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = rng.f64() + 0.01;
                d[i][j] = v;
                d[j][i] = v;
            }
        }
        let t = neighbor_joining(&labels, &d).unwrap();
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), n, "case {case}");
        assert!(t.nodes.iter().all(|nd| nd.branch >= 0.0), "case {case}");
        let mut leaves: Vec<&str> = t.leaf_labels();
        leaves.sort();
        let mut want: Vec<String> = labels.clone();
        want.sort();
        assert_eq!(leaves, want.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    }
}

/// Property: engine shuffles conserve elements for random pair datasets
/// on both backends.
#[test]
fn prop_shuffle_conserves_elements() {
    for case in 0..10 {
        let mut rng = Rng::seed_from_u64(7000 + case as u64);
        let n = 1 + rng.below(300);
        let pairs: Vec<(u32, u32)> =
            (0..n).map(|i| (rng.below(17) as u32, i as u32)).collect();
        for cfg in [ClusterConfig::spark(3), ClusterConfig::hadoop(3)] {
            let c = Cluster::new(cfg);
            let grouped = c
                .parallelize(pairs.clone(), 1 + rng.below(6))
                .group_by_key(1 + rng.below(5))
                .collect()
                .unwrap();
            let total: usize = grouped.iter().map(|(_, vs)| vs.len()).sum();
            assert_eq!(total, n, "case {case}");
            let mut all: Vec<u32> =
                grouped.into_iter().flat_map(|(_, vs)| vs).collect();
            all.sort();
            let mut want: Vec<u32> = (0..n as u32).collect();
            want.sort();
            assert_eq!(all, want, "case {case}");
        }
    }
}

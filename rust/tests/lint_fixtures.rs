//! Fixture tests for `pallas-lint`: every rule has at least one
//! must-fire and one must-not-fire snippet, checked by exact rule ID.
//! The snippets are linted with [`halign2::lint::lint_source`] under
//! synthetic paths (the linter scopes W1/W4 by path substring, so the
//! files never need to exist on disk).

use halign2::lint::{lint_source, Finding, LintConfig, Report, Rule};

/// The declared-locks config the fixtures run against — parsed through
/// the real `LOCKS.md` parser so the markdown grammar is exercised too.
fn cfg() -> LintConfig {
    LintConfig::parse_locks_md(
        "## Hierarchy\n\
         1. `kill_lock`\n\
         2. `state`\n\
         3. `deque`\n\
         4. `epoch`\n\
         ## Helper lock acquisitions\n\
         - `lock_shard` returns `deque`\n\
         - `bump_epoch` acquires `epoch`\n\
         ## Condvar-paired atomics\n\
         - `shutdown`\n",
    )
}

/// `cfg()` plus a declared metric-family table, parsed through the real
/// `OBSERVABILITY.md` parser (the markdown grammar is exercised too).
fn obs_cfg() -> LintConfig {
    let mut cfg = cfg();
    cfg.metric_names = LintConfig::parse_observability_md(
        "## Metric families\n\
         | family | kind |\n\
         |---|---|\n\
         | `halign_tasks_run_total` | counter |\n\
         | `halign_request_seconds` | histogram |\n\
         - `halign_workers` — gauge, bullet form\n",
    );
    cfg
}

fn ids(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().filter(|f| !f.suppressed).map(|f| f.rule.id()).collect()
}

fn lint(path: &str, src: &str) -> Vec<Finding> {
    lint_source(path, src, &cfg())
}

// ---------------------------------------------------------------- W1 --

#[test]
fn w1_fires_on_unwrap_in_engine() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    let findings = lint("rust/src/engine/fx.rs", src);
    assert_eq!(ids(&findings), ["W1"]);
    assert_eq!(findings[0].line, 2);
}

#[test]
fn w1_fires_on_panic_macro_and_expect() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    \
               if x.is_none() { panic!(\"no\"); }\n    x.expect(\"checked\")\n}\n";
    let findings = lint("rust/src/distmat/fx.rs", src);
    assert_eq!(ids(&findings), ["W1", "W1"]);
}

#[test]
fn w1_silent_outside_worker_dirs_and_in_tests() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n";
    assert!(ids(&lint("rust/src/align/fx.rs", src)).is_empty());
    let test_src = "#[cfg(test)]\nmod tests {\n    fn f(x: Option<u32>) -> u32 {\n        \
                    x.unwrap()\n    }\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", test_src)).is_empty());
}

#[test]
fn w1_poison_carve_out_spares_lock_unwrap() {
    let src = "fn f(&self) -> usize {\n    self.inner.lock().unwrap().len()\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", src)).is_empty());
    // The carve-out must survive rustfmt breaking the chain.
    let multiline = "fn f(&self) -> usize {\n    self.inner\n        .lock()\n        \
                     .unwrap()\n        .len()\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", multiline)).is_empty());
}

#[test]
fn w1_ignores_unwrap_or_and_doc_mentions() {
    let src = "// .unwrap() would panic! here\nfn f(x: Option<u32>) -> u32 {\n    \
               x.unwrap_or(0)\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", src)).is_empty());
}

// ---------------------------------------------------------------- W2 --

#[test]
fn w2_fires_on_io_under_guard() {
    let src = "fn spill(&self) {\n    let g = self.inner.lock().unwrap();\n    \
               fs::write(g.path(), b\"x\").ok();\n}\n";
    let findings = lint("rust/src/distmat/fx.rs", src);
    assert_eq!(ids(&findings), ["W2"]);
    assert_eq!(findings[0].line, 3);
}

#[test]
fn w2_silent_after_drop_or_scope_end() {
    let dropped = "fn spill(&self) {\n    let g = self.inner.lock().unwrap();\n    \
                   let p = g.path();\n    drop(g);\n    fs::write(p, b\"x\").ok();\n}\n";
    assert!(ids(&lint("rust/src/distmat/fx.rs", dropped)).is_empty());
    let scoped = "fn spill(&self) {\n    {\n        let g = self.inner.lock().unwrap();\n        \
                  g.touch();\n    }\n    fs::write(\"p\", b\"x\").ok();\n}\n";
    assert!(ids(&lint("rust/src/distmat/fx.rs", scoped)).is_empty());
}

#[test]
fn w2_guard_not_live_inside_its_own_initializer() {
    let src = "fn load(&self) {\n    let g = self.inner.lock().expect(\n        \
               fs::read_to_string(\"p\").unwrap().as_str(),\n    );\n    g.touch();\n}\n";
    // Contrived, but the I/O happens before the guard exists; only the
    // worker-dir unwrap-on-read should fire, not W2.
    let findings = lint("rust/src/distmat/fx.rs", src);
    assert!(!ids(&findings).contains(&"W2"));
}

// ---------------------------------------------------------------- W3 --

#[test]
fn w3_fires_on_hierarchy_inversion() {
    let src = "fn f(&self) {\n    let q = self.deque.lock().unwrap();\n    \
               let s = self.state.lock().unwrap();\n    q.push(s.next());\n}\n";
    let findings = lint("rust/src/engine/fx.rs", src);
    assert_eq!(ids(&findings), ["W3"]);
    assert_eq!(findings[0].line, 3);
}

#[test]
fn w3_fires_on_self_deadlock_and_undeclared() {
    let twice = "fn f(&self) {\n    let a = self.state.lock().unwrap();\n    \
                 let b = self.state.lock().unwrap();\n    a.merge(b);\n}\n";
    assert_eq!(ids(&lint("rust/src/engine/fx.rs", twice)), ["W3"]);
    let undeclared = "fn f(&self) {\n    let a = self.mystery.lock().unwrap();\n    \
                      let b = self.state.lock().unwrap();\n    a.merge(b);\n}\n";
    assert_eq!(ids(&lint("rust/src/engine/fx.rs", undeclared)), ["W3"]);
}

#[test]
fn w3_silent_on_declared_order_and_helpers() {
    let ordered = "fn f(&self) {\n    let k = self.kill_lock.lock().unwrap();\n    \
                   let q = self.deque.lock().unwrap();\n    q.clear();\n    k.done();\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", ordered)).is_empty());
    // `lock_shard` returns a `deque` guard; `bump_epoch` takes `epoch`
    // internally — deque(3) before epoch(4) is the declared order.
    let helpers = "fn f(&self, w: usize) {\n    let q = lock_shard(w);\n    \
                   q.push(1);\n    bump_epoch();\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", helpers)).is_empty());
}

#[test]
fn w3_helper_guard_counts_as_outer_lock() {
    let src = "fn f(&self, w: usize) {\n    let q = lock_shard(w);\n    \
               let s = self.state.lock().unwrap();\n    q.push(s.next());\n}\n";
    assert_eq!(ids(&lint("rust/src/engine/fx.rs", src)), ["W3"]);
}

// ---------------------------------------------------------------- W4 --

#[test]
fn w4_fires_on_eps_and_abs_tolerance_in_align() {
    let src = "fn close(a: f64, b: f64) -> bool {\n    (a - b).abs() < EPS\n}\n";
    let findings = lint("rust/src/align/fx.rs", src);
    // Both the `EPS` token and the `.abs() <` comparison fire.
    assert_eq!(ids(&findings), ["W4", "W4"]);
}

#[test]
fn w4_silent_outside_align_in_tests_and_on_other_idents() {
    let src = "fn close(a: f64, b: f64) -> bool {\n    (a - b).abs() < EPS\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", src)).is_empty());
    let test_src = "#[cfg(test)]\nmod tests {\n    fn close(a: f64, b: f64) -> bool {\n        \
                    (a - b).abs() < EPS\n    }\n}\n";
    assert!(ids(&lint("rust/src/align/fx.rs", test_src)).is_empty());
    let other = "const STEPS: usize = 4;\nfn f(x: u64) -> u64 {\n    x.abs() << 1\n}\n";
    assert!(ids(&lint("rust/src/align/fx.rs", other)).is_empty());
}

// ---------------------------------------------------------------- W5 --

#[test]
fn w5_fires_on_relaxed_condvar_atomic() {
    let src = "fn stop(&self) {\n    self.shutdown.store(true, Ordering::Relaxed);\n}\n";
    let findings = lint("rust/src/engine/fx.rs", src);
    assert_eq!(ids(&findings), ["W5"]);
    assert_eq!(findings[0].line, 2);
}

#[test]
fn w5_silent_on_seqcst_and_unlisted_atomics() {
    let seqcst = "fn stop(&self) {\n    self.shutdown.store(true, Ordering::SeqCst);\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", seqcst)).is_empty());
    let other = "fn tick(&self) {\n    self.counter.fetch_add(1, Ordering::Relaxed);\n}\n";
    assert!(ids(&lint("rust/src/engine/fx.rs", other)).is_empty());
}

// ---------------------------------------------------------------- W6 --

#[test]
fn w6_fires_on_header_row_arity_skew() {
    let src = "pub const TSV_HEADER: &str = \"a\\tb\\tc\";\n\
               fn row() -> String {\n    \
               format!(\"{}\\t{}\\t{}\\t{}\", 1, 2, 3, 4)\n}\n";
    let findings = lint("rust/src/metrics/fx.rs", src);
    assert_eq!(ids(&findings), ["W6"]);
    assert_eq!(findings[0].line, 3);
}

#[test]
fn w6_silent_on_matching_arity_and_tab_strings_without_placeholders() {
    let matching = "pub const TSV_HEADER: &str = \"a\\tb\\tc\";\n\
                    fn row() -> String {\n    \
                    format!(\"{}\\t{}\\t{}\", 1, 2, 3)\n}\n";
    assert!(ids(&lint("rust/src/metrics/fx.rs", matching)).is_empty());
    let plain = "pub const TSV_HEADER: &str = \"a\\tb\\tc\";\n\
                 const LEGEND: &str = \"x\\ty\\tz\\tw\";\n";
    assert!(ids(&lint("rust/src/metrics/fx.rs", plain)).is_empty());
}

// ---------------------------------------------------------------- W7 --

#[test]
fn w7_fires_on_direct_write_in_cache_module() {
    let src = "fn persist(&self, p: &Path, data: &[u8]) {\n    \
               fs::write(p, data).ok();\n}\n";
    let findings = lint("rust/src/cache/fx.rs", src);
    assert_eq!(ids(&findings), ["W7"]);
    assert_eq!(findings[0].line, 2);
    let create = "fn persist(&self, p: &Path) {\n    let f = File::create(p);\n    drop(f);\n}\n";
    assert_eq!(ids(&lint("rust/src/cache/fx.rs", create)), ["W7"]);
    let rename = "fn swap(&self) {\n    fs::rename(\"a\", \"b\").ok();\n}\n";
    assert_eq!(ids(&lint("rust/src/cache/fx.rs", rename)), ["W7"]);
}

#[test]
fn w7_silent_on_write_atomic_reads_and_other_modules() {
    // The blessed path plus the read/lifecycle calls the store uses.
    let blessed = "fn persist(&self, p: &Path, data: &[u8]) -> Result<()> {\n    \
                   write_atomic(p, data)\n}\n\
                   fn load(&self, p: &Path) -> Vec<u8> {\n    \
                   std::fs::read(p).unwrap_or_default()\n}\n\
                   fn init(&self) {\n    std::fs::create_dir_all(&self.dir).ok();\n    \
                   std::fs::remove_dir_all(&self.dir).ok();\n}\n";
    assert!(ids(&lint("rust/src/cache/fx.rs", blessed)).is_empty());
    // Same direct write outside cache/ is not W7's business (W2 handles
    // the under-lock case there).
    let elsewhere = "fn persist(p: &Path, data: &[u8]) {\n    fs::write(p, data).ok();\n}\n";
    assert!(!ids(&lint("rust/src/engine/fx.rs", elsewhere)).contains(&"W7"));
    // Test code inside cache/ may write directly (corruption fixtures).
    let test_src = "#[cfg(test)]\nmod tests {\n    fn corrupt(p: &Path) {\n        \
                    fs::write(p, b\"junk\").ok();\n    }\n}\n";
    assert!(ids(&lint("rust/src/cache/fx.rs", test_src)).is_empty());
}

#[test]
fn w7_suppressible_with_reason() {
    let src = "fn persist(&self, p: &Path, data: &[u8]) {\n    \
               // lint: allow(cache-atomic-write) metadata sidecar, rewritten on startup\n    \
               fs::write(p, data).ok();\n}\n";
    let findings = lint("rust/src/cache/fx.rs", src);
    assert!(ids(&findings).is_empty());
    assert!(findings.iter().any(|f| f.suppressed && f.rule == Rule::CacheAtomicWrite));
}

// ---------------------------------------------------------------- W8 --

#[test]
fn w8_fires_on_undeclared_family() {
    let src = "fn obs(r: &Registry) {\n    \
               let c = r.register_counter(\"halign_mystery_total\", \"?\");\n    drop(c);\n}\n";
    let findings = lint_source("rust/src/obs/fx.rs", src, &obs_cfg());
    assert_eq!(ids(&findings), ["W8"]);
    assert_eq!(findings[0].line, 2);
    assert!(findings[0].message.contains("halign_mystery_total"));
}

#[test]
fn w8_fires_on_non_snake_case_and_duplicate() {
    let camel = "fn obs(r: &Registry) {\n    \
                 let c = r.register_gauge(\"halignWorkers\", \"?\");\n    drop(c);\n}\n";
    let findings = lint_source("rust/src/obs/fx.rs", camel, &obs_cfg());
    assert_eq!(ids(&findings), ["W8"]);
    assert!(findings[0].message.contains("snake_case"));
    // Same family registered twice in one file: the second site fires.
    let twice = "fn obs(r: &Registry) {\n    \
                 let a = r.register_counter(\"halign_tasks_run_total\", \"a\");\n    \
                 let b = r.register_counter(\"halign_tasks_run_total\", \"b\");\n    \
                 drop((a, b));\n}\n";
    let findings = lint_source("rust/src/obs/fx.rs", twice, &obs_cfg());
    assert_eq!(ids(&findings), ["W8"]);
    assert_eq!(findings[0].line, 3);
    assert!(findings[0].message.contains("more than once"));
}

#[test]
fn w8_silent_on_declared_names_multiline_and_labeled() {
    // The real registration idiom: name literal on its own line, labeled
    // variants, one site per family.
    let src = "fn obs(r: &Registry) {\n    \
               let c = r.register_counter(\n        \
               \"halign_tasks_run_total\",\n        \"tasks\",\n    );\n    \
               let h = r.register_histogram_labeled(\n        \
               \"halign_request_seconds\",\n        \"latency\",\n        \
               &[(\"route\", \"align\")],\n    );\n    \
               let g = r.register_gauge(\"halign_workers\", \"workers\");\n    \
               drop((c, h, g));\n}\n";
    assert!(ids(&lint_source("rust/src/obs/fx.rs", src, &obs_cfg())).is_empty());
}

#[test]
fn w8_skips_pass_through_definitions_tests_and_stays_inert_unconfigured() {
    // The registry's own delegation passes `name` (a variable, not a
    // literal) and its `fn` definitions are not registrations.
    let passthrough = "impl Registry {\n    \
                       pub fn register_counter(&self, name: &str, help: &str) -> Arc<Counter> {\n        \
                       self.register_counter_labeled(name, help, &[])\n    }\n}\n";
    assert!(ids(&lint_source("rust/src/obs/fx.rs", passthrough, &obs_cfg())).is_empty());
    // Unit tests may register undeclared scratch names.
    let test_src = "#[cfg(test)]\nmod tests {\n    fn t(r: &Registry) {\n        \
                    r.register_counter(\"requests_total\", \"t\").inc();\n    }\n}\n";
    assert!(ids(&lint_source("rust/src/obs/fx.rs", test_src, &obs_cfg())).is_empty());
    // With no OBSERVABILITY.md (empty declared list) the rule is inert.
    let undeclared = "fn obs(r: &Registry) {\n    \
                      r.register_counter(\"halign_mystery_total\", \"?\").inc();\n}\n";
    assert!(ids(&lint_source("rust/src/obs/fx.rs", undeclared, &cfg())).is_empty());
}

#[test]
fn w8_suppressible_with_reason() {
    let src = "fn obs(r: &Registry) {\n    \
               // lint: allow(metric-name-registry) migration shim, removed next release\n    \
               r.register_counter(\"halign_legacy_total\", \"old name\").inc();\n}\n";
    let findings = lint_source("rust/src/obs/fx.rs", src, &obs_cfg());
    assert!(ids(&findings).is_empty());
    assert!(findings.iter().any(|f| f.suppressed && f.rule == Rule::MetricNameRegistry));
}

// ---------------------------------------------------------------- W9 --

/// `cfg()` plus one committed bench baseline (scenario `table9`),
/// parsed through the real baseline-key parser so the lexical JSON
/// grammar is exercised too.
fn bench_cfg() -> LintConfig {
    let mut cfg = cfg();
    cfg.bench_baseline_keys = vec![(
        "table9".to_string(),
        LintConfig::parse_bench_baseline(
            "{\n  \"bench\": \"table9\",\n  \"note\": \"fixture\",\n  \
             \"steals\": 1,\n  \"critical_path_frac\": 0.9,\n  \
             \"max_critical_path_frac\": 0.95\n}\n",
        ),
    )];
    cfg
}

#[test]
fn w9_fires_on_undeclared_key() {
    let src = "fn emit(n: u64) {\n    write_bench_json(\n        \"table9\",\n        \
               &[(\"steals\", n.to_string()), (\"mystery_key\", n.to_string())],\n    );\n}\n";
    let findings = lint_source("rust/src/bench/fx.rs", src, &bench_cfg());
    assert_eq!(ids(&findings), ["W9"]);
    assert_eq!(findings[0].line, 4);
    assert!(findings[0].message.contains("mystery_key"));
    assert!(findings[0].message.contains("BENCH_table9.baseline.json"));
}

#[test]
fn w9_fires_on_missing_baseline() {
    let src = "fn emit(n: u64) {\n    \
               write_bench_json(\"table10\", &[(\"steals\", n.to_string())]);\n}\n";
    let findings = lint_source("rust/src/bench/fx.rs", src, &bench_cfg());
    assert_eq!(ids(&findings), ["W9"]);
    assert_eq!(findings[0].line, 2);
    assert!(findings[0].message.contains("BENCH_table10.baseline.json"));
}

#[test]
fn w9_silent_on_declared_keys_tests_definitions_and_unconfigured() {
    // Every emitted key is declared in the committed baseline.
    let declared = "fn emit(n: u64) {\n    write_bench_json(\n        \"table9\",\n        \
                    &[(\"steals\", n.to_string()), (\"critical_path_frac\", format!(\"{n}\"))],\n    \
                    );\n}\n";
    assert!(ids(&lint_source("rust/src/bench/fx.rs", declared, &bench_cfg())).is_empty());
    // The writer's own definition has no scenario literal after the paren.
    let definition = "pub fn write_bench_json(scenario: &str, fields: &[(&str, String)]) {\n    \
                      let body = format!(\"{scenario} {}\", fields.len());\n    drop(body);\n}\n";
    assert!(ids(&lint_source("rust/src/bench/fx.rs", definition, &bench_cfg())).is_empty());
    // Test code may emit scratch scenarios.
    let test_src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        \
                    write_bench_json(\"scratch\", &[(\"anything_goes\", 1.to_string())]);\n    \
                    }\n}\n";
    assert!(ids(&lint_source("rust/src/bench/fx.rs", test_src, &bench_cfg())).is_empty());
    // With no committed baselines at all the rule is inert.
    let undeclared = "fn emit(n: u64) {\n    \
                      write_bench_json(\"table10\", &[(\"anything_goes\", n.to_string())]);\n}\n";
    assert!(ids(&lint_source("rust/src/bench/fx.rs", undeclared, &cfg())).is_empty());
}

#[test]
fn w9_suppressible_with_reason() {
    let src = "fn emit(n: u64) {\n    \
               // lint: allow(bench-json-schema) exploratory scenario, gated next PR\n    \
               write_bench_json(\n        \"table10\",\n        \
               &[(\"mystery_key\", n.to_string())],\n    );\n}\n";
    let findings = lint_source("rust/src/bench/fx.rs", src, &bench_cfg());
    assert!(ids(&findings).is_empty());
    assert!(findings.iter().any(|f| f.suppressed && f.rule == Rule::BenchJsonSchema));
}

// -------------------------------------------------- suppression + W0 --

#[test]
fn allow_comment_suppresses_with_reason() {
    let src = "fn f(x: Option<u32>) -> u32 {\n    \
               // lint: allow(panic) caller guarantees Some\n    x.unwrap()\n}\n";
    let findings = lint("rust/src/engine/fx.rs", src);
    assert_eq!(findings.len(), 1);
    assert!(findings[0].suppressed);
    assert_eq!(findings[0].allow_reason.as_deref(), Some("caller guarantees Some"));
    assert!(ids(&findings).is_empty());
}

#[test]
fn allow_comment_covers_whole_statement() {
    // One comment above a multi-line builder chain covers every line of
    // the statement, including the `.expect(...)` on a later line.
    let src = "fn f(&self) {\n    // lint: allow(panic) startup path, no tasks yet\n    \
               let t = Builder::new()\n        .name(\"w\".into())\n        \
               .spawn(run)\n        .expect(\"spawn\");\n    t.join();\n}\n";
    let findings = lint("rust/src/engine/fx.rs", src);
    assert!(ids(&findings).is_empty());
    assert!(findings.iter().any(|f| f.suppressed && f.rule == Rule::PanicInWorker));
}

#[test]
fn w0_fires_on_reasonless_or_unknown_allow() {
    let reasonless = "fn f(x: Option<u32>) -> u32 {\n    \
                      // lint: allow(panic)\n    x.unwrap()\n}\n";
    let findings = lint("rust/src/engine/fx.rs", reasonless);
    // The W0 *and* the now-unsuppressed W1 both surface.
    assert_eq!(ids(&findings), ["W0", "W1"]);
    let unknown = "// lint: allow(everything) because\nfn f() {}\n";
    assert_eq!(ids(&lint("rust/src/engine/fx.rs", unknown)), ["W0"]);
}

#[test]
fn w0_cannot_be_suppressed() {
    let src = "// lint: allow(allow-syntax) nice try\nfn f() {}\n";
    let findings = lint("rust/src/engine/fx.rs", src);
    assert_eq!(ids(&findings), ["W0"]);
}

// ----------------------------------------------------- deny semantics --

#[test]
fn deny_exit_flips_on_unsuppressed_count() {
    // `pallas_lint --deny` exits nonzero iff `unsuppressed_count() > 0`;
    // assert the counter the binary branches on.
    let denied = Report {
        findings: lint(
            "rust/src/engine/fx.rs",
            "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
        ),
        files_scanned: 1,
    };
    assert_eq!(denied.unsuppressed_count(), 1);
    let clean = Report {
        findings: lint(
            "rust/src/engine/fx.rs",
            "fn f(x: Option<u32>) -> u32 {\n    \
             // lint: allow(panic) caller guarantees Some\n    x.unwrap()\n}\n",
        ),
        files_scanned: 1,
    };
    assert_eq!(clean.unsuppressed_count(), 0);
    assert_eq!(clean.suppressed_count(), 1);
}

#[test]
fn findings_render_stable_grep_format() {
    let findings = lint(
        "rust/src/engine/fx.rs",
        "fn f(x: Option<u32>) -> u32 {\n    x.unwrap()\n}\n",
    );
    let line = findings[0].render();
    assert!(line.starts_with("rust/src/engine/fx.rs:2 W1 panic "), "got: {line}");
}

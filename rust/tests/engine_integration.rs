//! Engine integration: multi-stage jobs across both shuffle backends,
//! fault recovery through real pipelines, memory-accounting invariants.

use halign2::engine::{Backend, Cluster, ClusterConfig, FaultPlan, SchedulerMode};

fn wordcount(c: &Cluster, text: &[&str]) -> Vec<(String, usize)> {
    let lines: Vec<String> = text.iter().map(|s| s.to_string()).collect();
    let mut counts = c
        .parallelize(lines, 4)
        .flat_map(|line| line.split_whitespace().map(|w| w.to_string()).collect::<Vec<_>>())
        .map(|w| (w, 1usize))
        .reduce_by_key(3, |a, b| a + b)
        .collect()
        .unwrap();
    counts.sort();
    counts
}

#[test]
fn wordcount_identical_across_backends() {
    let text = ["a b a", "c b a", "c c c c", "", "b"];
    let spark = wordcount(&Cluster::new(ClusterConfig::spark(3)), &text);
    let hadoop = wordcount(&Cluster::new(ClusterConfig::hadoop(3)), &text);
    assert_eq!(spark, hadoop);
    assert_eq!(
        spark,
        vec![("a".into(), 3), ("b".into(), 3), ("c".into(), 5)]
    );
}

#[test]
fn multi_stage_pipeline_with_joins() {
    let c = Cluster::new(ClusterConfig::spark(4));
    let users: Vec<(u32, String)> = (0..50).map(|i| (i, format!("user{i}"))).collect();
    let purchases: Vec<(u32, u64)> = (0..200).map(|i| (i % 50, (i * 3) as u64)).collect();
    let spend = c.parallelize(purchases, 6).reduce_by_key(4, |a, b| a + b);
    let joined = c.parallelize(users, 5).join(&spend, 4);
    let total: u64 = joined.collect().unwrap().iter().map(|(_, (_, s))| s).sum();
    let expect: u64 = (0..200u64).map(|i| i * 3).sum();
    assert_eq!(total, expect);
}

#[test]
fn random_faults_do_not_change_results() {
    // `HALIGN_STRESS_CASES` scales the seed sweep down for the
    // sanitizer CI jobs (TSan/Miri run far slower per case).
    let seeds: u64 = std::env::var("HALIGN_STRESS_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(5);
    let clean = {
        let c = Cluster::new(ClusterConfig::spark(3));
        wordcount(&c, &["x y z", "x x", "z"])
    };
    for seed in 0..seeds {
        let mut cfg = ClusterConfig::spark(3);
        cfg.fault = FaultPlan::random(0.4, seed);
        cfg.max_retries = 10;
        let c = Cluster::new(cfg);
        assert_eq!(wordcount(&c, &["x y z", "x x", "z"]), clean, "seed {seed}");
    }
}

#[test]
fn scheduler_modes_and_kills_do_not_change_results() {
    let text = ["a b a", "c b a", "c c c c", "", "b"];
    let reference = wordcount(&Cluster::new(ClusterConfig::spark(3)), &text);

    // Work stealing and speculation off: same answer.
    let mut cfg = ClusterConfig::spark(3);
    cfg.scheduler.work_stealing = false;
    cfg.scheduler.speculation = false;
    assert_eq!(wordcount(&Cluster::new(cfg), &text), reference);

    // A worker killed mid-job (its deque drained back into the steal
    // pool): same answer, one fewer node.
    let mut cfg = ClusterConfig::spark(3);
    cfg.fault = FaultPlan::kill_worker_at(1, 3);
    let c = Cluster::new(cfg);
    assert_eq!(wordcount(&c, &text), reference);
    assert_eq!(c.config().fault.fired(), 1, "the kill must have fired");
}

#[test]
fn scheduler_architectures_agree_on_results() {
    let text = ["a b a", "c b a", "c c c c", "", "b"];
    let reference = wordcount(&Cluster::new(ClusterConfig::spark(3)), &text);
    let mut cfg = ClusterConfig::spark(3);
    cfg.scheduler.mode = SchedulerMode::GlobalLock;
    assert_eq!(wordcount(&Cluster::new(cfg), &text), reference);
}

#[test]
fn diskkv_io_counters_identical_with_speculation_on_and_off() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    // Regression for duplicate-task double counting: a DiskKv job whose
    // checkpoint stage contains a deliberate straggler (so speculation,
    // when on, launches a duplicate that re-writes the same files) must
    // report exactly the same write-side IO as the speculation-off run —
    // at-least-once execution may not inflate the Fig-5/Table-2 numbers.
    let run = |speculate: bool| {
        let mut cfg = ClusterConfig::hadoop(4);
        cfg.scheduler.speculation = speculate;
        let c = Cluster::new(cfg);
        let straggled = Arc::new(AtomicBool::new(false));
        let s = straggled.clone();
        let pairs: Vec<(u32, u32)> = (0..120).map(|i| (i % 6, i)).collect();
        let ck = c
            .parallelize(pairs, 6)
            .map_partitions_with_index(move |part, xs| {
                if part == 0 && !s.swap(true, Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(150));
                }
                xs
            })
            .checkpoint()
            .unwrap();
        let mut counts = ck.reduce_by_key(3, |a, b| a + b).collect().unwrap();
        counts.sort();
        // A superseded original (still sleeping after its duplicate won)
        // or an in-flight duplicate may finish its replace-and-release
        // accounting after the job returns: sample the counters until
        // they hold still rather than trusting a fixed sleep.
        let sample = |c: &Cluster| {
            (c.stats().shuffle_bytes_written, c.io().spill_files.load(Ordering::SeqCst))
        };
        let mut prev = sample(&c);
        let mut stable = 0;
        for _ in 0..100 {
            std::thread::sleep(Duration::from_millis(25));
            let cur = sample(&c);
            if cur == prev {
                stable += 1;
                if stable >= 8 {
                    break;
                }
            } else {
                stable = 0;
                prev = cur;
            }
        }
        (counts, prev.0, prev.1)
    };

    let (res_on, written_on, spills_on) = run(true);
    let (res_off, written_off, spills_off) = run(false);
    assert_eq!(res_on, res_off, "speculation must not change results");
    assert_eq!(written_on, written_off, "duplicate tasks must not double-count bytes written");
    assert_eq!(spills_on, spills_off, "duplicate tasks must not double-count spill files");
}

#[test]
fn diskkv_pays_io_inmemory_pays_memory() {
    let payload: Vec<(u32, Vec<u8>)> = (0..256).map(|i| (i % 16, vec![7u8; 2048])).collect();

    let spark = Cluster::new(ClusterConfig::spark(4));
    spark.parallelize(payload.clone(), 8).group_by_key(4).count().unwrap();
    let s = spark.stats();
    assert_eq!(s.shuffle_bytes_written, 0, "spark shuffles stay in memory");
    assert!(s.avg_max_memory_bytes > 0.0);

    let hadoop = Cluster::new(ClusterConfig::hadoop(4));
    hadoop.parallelize(payload, 8).group_by_key(4).count().unwrap();
    let h = hadoop.stats();
    assert!(h.shuffle_bytes_written as f64 > 256.0 * 2048.0 * 0.9, "hadoop spills");
}

#[test]
fn results_independent_of_parallelism() {
    let job = |workers: usize| {
        let c = Cluster::new(ClusterConfig::spark(workers));
        let data: Vec<u64> = (0..64).collect();
        c.parallelize(data, 16)
            .map(|x| {
                let mut acc = x;
                for i in 0..20_000u64 {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
                }
                acc
            })
            .reduce(|a, b| a ^ b)
            .unwrap()
    };
    assert_eq!(job(1), job(4));
}

#[test]
fn checkpoint_chain_across_backends() {
    for cfg in [ClusterConfig::spark(2), ClusterConfig::hadoop(2)] {
        let is_disk = cfg.backend == Backend::DiskKv;
        let c = Cluster::new(cfg);
        let r1 = c.parallelize((0..100u64).collect(), 5).map(|x| x * 2);
        let ck1 = r1.checkpoint().unwrap();
        let r2 = ck1.filter(|x| x % 4 == 0);
        let ck2 = r2.checkpoint().unwrap();
        let sum: u64 = ck2.collect().unwrap().iter().sum();
        assert_eq!(sum, (0..100u64).map(|x| x * 2).filter(|x| x % 4 == 0).sum());
        if is_disk {
            assert!(c.stats().shuffle_bytes_written > 0);
        }
    }
}

#[test]
fn broadcast_reaches_all_tasks() {
    let c = Cluster::new(ClusterConfig::spark(4));
    let table: Vec<u64> = (0..1000).map(|i| i * i).collect();
    let bc = c.broadcast(table).unwrap();
    let arc = bc.arc();
    let out = c
        .parallelize((0..100u64).collect(), 8)
        .map(move |i| arc[i as usize])
        .collect()
        .unwrap();
    let mut sorted = out;
    sorted.sort();
    assert_eq!(sorted, (0..100u64).map(|i| i * i).collect::<Vec<_>>());
}

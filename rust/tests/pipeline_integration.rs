//! Full-stack integration: dataset → MSA → SP score → tree, across
//! alphabets, backends, and (when artifacts exist) the XLA service.

use halign2::align::center_star::{align_nucleotide, CenterStarConfig};
use halign2::align::protein::{align_protein, ProteinConfig};
use halign2::align::sp_score;
use halign2::baselines::progressive::{progressive_msa, ProgressiveConfig};
use halign2::baselines::sparksw::sparksw_msa;
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig};
use halign2::fasta::Sequence;
use halign2::runtime::XlaService;
use halign2::tree::{build_tree, ClusterConfig as TreeClusterConfig, TreeConfig};

fn service() -> Option<XlaService> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.txt").exists() {
        return None;
    }
    XlaService::start(dir).ok()
}

#[test]
fn dna_msa_to_tree_end_to_end() {
    let seqs = DatasetSpec { count: 40, ..DatasetSpec::mito(0.02, 17) }.generate();
    let cluster = Cluster::new(ClusterConfig::spark(4));
    let msa = align_nucleotide(&cluster, &seqs, &CenterStarConfig::default()).unwrap();
    msa.validate(&seqs).unwrap();

    let sp = msa.avg_sp_distributed(&cluster).unwrap();
    assert!(sp >= 0.0 && sp.is_finite());

    let tree = build_tree(
        &cluster,
        &msa.aligned,
        None,
        &TreeConfig {
            clustering: TreeClusterConfig { max_cluster_size: 16, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(tree.tree.num_leaves(), 40);
    assert!(tree.log_likelihood.is_finite() && tree.log_likelihood < 0.0);
}

#[test]
fn protein_msa_with_xla_matches_native() {
    let seqs: Vec<Sequence> = DatasetSpec::protein(20, 0.2, 23)
        .generate()
        .into_iter()
        .filter(|s| s.len() <= 500) // keep within the 512 SW bucket
        .take(12)
        .collect();
    assert!(seqs.len() >= 8, "dataset should have short proteins");
    let native = align_protein(
        &Cluster::new(ClusterConfig::spark(2)),
        &seqs,
        None,
        &ProteinConfig::default(),
    )
    .unwrap();
    native.validate(&seqs).unwrap();

    if let Some(svc) = service() {
        let xla = align_protein(
            &Cluster::new(ClusterConfig::spark(2)),
            &seqs,
            Some(&svc),
            &ProteinConfig::default(),
        )
        .unwrap();
        assert_eq!(native.width, xla.width, "XLA and native SW must agree");
        for (a, b) in native.aligned.iter().zip(&xla.aligned) {
            assert_eq!(a.codes, b.codes, "row {}", a.id);
        }
    } else {
        eprintln!("skipping XLA comparison (no artifacts)");
    }
}

#[test]
fn rna_divergent_pipeline_holds_invariants() {
    let seqs = DatasetSpec::rrna(30, 0.1, 29).generate();
    let cluster = Cluster::new(ClusterConfig::hadoop(3));
    let msa = align_nucleotide(
        &cluster,
        &seqs,
        &CenterStarConfig { segment_len: 10, ..Default::default() },
    )
    .unwrap();
    msa.validate(&seqs).unwrap();
    // Hadoop mode must have spilled the edit paths.
    assert!(cluster.stats().shuffle_bytes_written > 0);
}

#[test]
fn all_aligners_agree_on_column_conservation() {
    // Different aligners produce different MSAs, but de-gapped rows must
    // always round-trip and SP must stay finite.
    let seqs = DatasetSpec::protein(10, 0.15, 31).generate();
    let engine = Cluster::new(ClusterConfig::spark(2));

    let cs = align_protein(&engine, &seqs, None, &ProteinConfig::default()).unwrap();
    let (sw, _) = sparksw_msa(2, &seqs, 5.0).unwrap();
    let prog = progressive_msa(&seqs, &ProgressiveConfig::default()).unwrap();

    for msa in [&cs, &sw, &prog] {
        msa.validate(&seqs).unwrap();
        let sp = sp_score::avg_sp(&msa.aligned).unwrap();
        assert!(sp.is_finite() && sp >= 0.0);
    }
}

#[test]
fn tree_quality_consistent_between_backends() {
    let seqs = DatasetSpec { count: 20, ..DatasetSpec::mito(0.02, 37) }.generate();
    let spark = Cluster::new(ClusterConfig::spark(3));
    let msa = align_nucleotide(&spark, &seqs, &CenterStarConfig::default()).unwrap();

    let cfg = TreeConfig {
        clustering: TreeClusterConfig { max_cluster_size: 8, ..Default::default() },
        ..Default::default()
    };
    let t_spark = build_tree(&spark, &msa.aligned, None, &cfg).unwrap();
    let hadoop = Cluster::new(ClusterConfig::hadoop(3));
    let t_hadoop = build_tree(&hadoop, &msa.aligned, None, &cfg).unwrap();
    // Same deterministic algorithm, same seed -> identical trees.
    assert_eq!(t_spark.tree.to_newick(), t_hadoop.tree.to_newick());
    assert!((t_spark.log_likelihood - t_hadoop.log_likelihood).abs() < 1e-9);
}

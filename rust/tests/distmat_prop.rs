//! Property tests for the distmat subsystem: across random shapes, tile
//! sizes, worker counts and fault plans, the tiled `DistSource` pipeline
//! must hand NJ the exact same f64s as the dense single-node path —
//! yielding bit-identical topologies and branch lengths.

use halign2::distmat::{distance_tiled, DistKind, DistMatConfig};
use halign2::engine::{Cluster, ClusterConfig, FaultPlan};
use halign2::fasta::{Alphabet, Sequence};
use halign2::tree::distance::{jc_distance, pdistance_native};
use halign2::tree::{neighbor_joining, neighbor_joining_src, NjConfig};
use halign2::util::Rng;

/// Case count for the property sweep: 100 by default, overridable via
/// `HALIGN_STRESS_CASES` so the sanitizer CI jobs (ThreadSanitizer,
/// Miri) can run the same test at instrumentation-friendly depth.
fn stress_cases(default: u64) -> u64 {
    std::env::var("HALIGN_STRESS_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

fn random_aligned_rows(n: usize, width: usize, rng: &mut Rng) -> Vec<Sequence> {
    let residues = [b'A', b'C', b'G', b'T'];
    (0..n)
        .map(|i| {
            let text: String = (0..width)
                .map(|_| {
                    if rng.chance(0.08) {
                        '-'
                    } else {
                        residues[rng.below(4)] as char
                    }
                })
                .collect();
            Sequence::from_text(format!("t{i}"), &text, Alphabet::Dna)
        })
        .collect()
}

/// ≥100 seeded cases: dense NJ (materialized matrix) vs tiled NJ (tile
/// jobs on the engine + byte-budgeted out-of-core consumption) must be
/// *equal*, i.e. identical topology and f64-equal branch lengths.  A
/// fifth of the cases kill a worker mid-tile-job to prove the
/// at-least-once recovery path preserves the bits too.
#[test]
fn tiled_nj_is_bit_identical_to_dense_across_100_cases() {
    let mut rng = Rng::seed_from_u64(0xD157_A7);
    for case in 0..stress_cases(100) {
        let n = 4 + rng.below(24);
        let width = 24 + rng.below(48);
        let rows = random_aligned_rows(n, width, &mut rng);

        // Dense single-node path.
        let p = pdistance_native(&rows).unwrap();
        let states = rows[0].alphabet.residues();
        let d: Vec<Vec<f64>> = p
            .iter()
            .map(|r| r.iter().map(|&x| jc_distance(x, states)).collect())
            .collect();
        let labels: Vec<String> = rows.iter().map(|s| s.id.clone()).collect();
        let dense_tree = neighbor_joining(&labels, &d)
            .unwrap_or_else(|e| panic!("case {case}: dense NJ failed: {e:#}"));

        // Tiled engine path: random tile size, worker count, tiny byte
        // budget (forces spills), and an occasional worker kill.
        let workers = [2usize, 3, 4, 8, 16][rng.below(5)];
        let mut ccfg = ClusterConfig::spark(workers);
        if case % 5 == 0 {
            ccfg.fault = FaultPlan::kill_worker_at(rng.below(workers), rng.below(6));
        }
        let engine = Cluster::new(ccfg);
        let tile_rows = 1 + rng.below(n);
        let byte_budget = 128 + rng.below(4096);
        let cfg = DistMatConfig {
            tile_rows,
            byte_budget,
            kind: DistKind::PDistance { jukes_cantor: true },
        };
        let tiled = distance_tiled(&engine, &rows, &cfg)
            .unwrap_or_else(|e| panic!("case {case}: tile jobs failed: {e:#}"));
        // Key base past the tile *and* sidecar blobs (`distance_tiled`
        // writes per-tile (sum,min) sidecars above the tiles).
        let nj_cfg = NjConfig {
            row_store: Some(tiled.store_arc()),
            row_key_base: tiled.row_key_base(),
        };
        let tiled_tree = neighbor_joining_src(&labels, &tiled, &nj_cfg)
            .unwrap_or_else(|e| panic!("case {case}: tiled NJ failed: {e:#}"));

        assert_eq!(
            dense_tree, tiled_tree,
            "case {case}: n={n} w={workers} tile={tile_rows} budget={byte_budget} \
             — tiled NJ must equal dense NJ bit for bit"
        );
    }
}

//! Property tests for the post-hoc profiler (`src/obs/profile.rs`).
//!
//! Two trace sources feed the same invariant battery:
//!
//! * **Synthetic traces** — seeded, stage-sequential event streams
//!   built directly from `TraceEvent` values, so each invariant is
//!   checked against a ground truth the generator controls (longest
//!   span, task count, stage windows).
//! * **Real engine traces** — jobs run through the public cluster API
//!   under BOTH scheduler modes with speculation disabled (so every
//!   completed span is a winning attempt and the critical path bounds
//!   below by the longest task).
//!
//! Randomness is the project's seeded [`halign2::util::Rng`]: every run
//! checks the same 100+ traces, failures reproduce by seed.

use halign2::engine::{Cluster, ClusterConfig, SchedulerMode};
use halign2::obs::{Profile, TraceEvent, TraceKind};
use halign2::util::Rng;

// ------------------------------------------------ invariant battery --

/// The profiler contract every trace must satisfy.  `longest_span`
/// is the ground-truth longest completed winner span when the caller
/// knows it (synthetic traces), else recovered from the aggregate.
fn check_invariants(p: &Profile, longest_span: Option<u64>, label: &str) {
    // Critical path never exceeds wall time (stages are sequential).
    assert!(
        p.critical_path_nanos <= p.wall_nanos,
        "{label}: path {} > wall {}",
        p.critical_path_nanos,
        p.wall_nanos
    );
    // ...and never undercuts the longest completed task: that task
    // alone is a lower bound on any schedule.
    let longest =
        longest_span.unwrap_or_else(|| p.aggregate.iter().map(|r| r.max_nanos).max().unwrap_or(0));
    assert!(
        p.critical_path_nanos >= longest,
        "{label}: path {} < longest task {longest}",
        p.critical_path_nanos
    );
    // The headline fraction is an honest fraction whenever work ran.
    if !p.aggregate.is_empty() {
        assert!(
            p.critical_path_frac > 0.0 && p.critical_path_frac <= 1.0,
            "{label}: frac {} outside (0, 1]",
            p.critical_path_frac
        );
    }
    // Worker-lane gap analysis partitions the wall exactly: executing,
    // steal-wait, drain-wait, and idle account for every nanosecond.
    assert_eq!(p.lanes.len(), p.num_lanes.saturating_sub(1).max(1).min(p.num_lanes), "{label}");
    for g in &p.lanes {
        assert_eq!(
            g.self_nanos + g.steal_wait_nanos + g.drain_wait_nanos + g.idle_nanos,
            p.wall_nanos,
            "{label}: lane {} gap partition does not sum to wall",
            g.lane
        );
    }
    // Queue delays are bounded by the window they were measured in.
    assert!(p.queue.max_nanos <= p.wall_nanos, "{label}: queue max exceeds wall");
    assert!(p.queue.total_nanos >= p.queue.max_nanos, "{label}");

    // Collapsed-stack round-trip: every line is `a;b;c <weight>` with a
    // positive integer weight, and re-serializing the parsed parts
    // reproduces the export byte-for-byte.
    let collapsed = p.collapsed_stack();
    let mut rebuilt = String::new();
    for line in collapsed.lines() {
        let (stack, weight) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("{label}: collapsed line has no weight separator: {line:?}")
        });
        let w: u64 = weight
            .parse()
            .unwrap_or_else(|_| panic!("{label}: non-integer weight in {line:?}"));
        assert!(w >= 1, "{label}: zero weight in {line:?}");
        assert_eq!(stack.split(';').count(), 3, "{label}: frame arity != 3 in {line:?}");
        assert!(
            stack.split(';').all(|frame| !frame.is_empty()),
            "{label}: empty frame in {line:?}"
        );
        rebuilt.push_str(&format!("{stack} {w}\n"));
    }
    assert_eq!(rebuilt, collapsed, "{label}: collapsed stack does not round-trip");
    assert_eq!(collapsed.lines().count(), p.aggregate.len(), "{label}: one line per row");
}

// ------------------------------------------------- synthetic traces --

struct Synth {
    events: Vec<TraceEvent>,
    num_lanes: usize,
    longest_span: u64,
    num_tasks: u64,
    num_spans: u64,
}

/// A stage-sequential trace: stages occupy disjoint time windows (the
/// barrier the executor enforces between `run_tasks` calls), each task
/// runs exactly once (speculation off), spans never overlap on a lane.
fn synth_trace(rng: &mut Rng) -> Synth {
    let num_lanes = 2 + rng.below(4); // 1..=4 workers + driver
    let workers = num_lanes - 1;
    let driver = num_lanes - 1;
    let num_stages = 1 + rng.below(3) as u64;
    let mut events = Vec::new();
    let mut t = 10 + rng.below(100) as u64;
    let mut longest_span = 0u64;
    let mut num_tasks = 0u64;
    for stage in 1..=num_stages {
        let tasks = 1 + rng.below(6) as u64;
        num_tasks += tasks;
        let mut lane_cursor = vec![t; workers];
        for task in 0..tasks {
            let payload = (stage << 32) | task;
            events.push(TraceEvent {
                nanos: t,
                lane: driver,
                kind: TraceKind::Enqueue,
                payload,
            });
            let lane = rng.below(workers);
            let start = lane_cursor[lane] + rng.below(40) as u64;
            let dur = 1 + rng.below(500) as u64;
            events.push(TraceEvent { nanos: start, lane, kind: TraceKind::Start, payload });
            events.push(TraceEvent {
                nanos: start + dur,
                lane,
                kind: TraceKind::Finish,
                payload,
            });
            lane_cursor[lane] = start + dur;
            longest_span = longest_span.max(dur);
        }
        let stage_end = *lane_cursor.iter().max().unwrap();
        // Scheduling noise inside the stage window: steal markers on
        // worker lanes, the occasional kill-drain.
        if rng.below(2) == 0 {
            events.push(TraceEvent {
                nanos: t + rng.below((stage_end - t + 1) as usize) as u64,
                lane: rng.below(workers),
                kind: TraceKind::Steal,
                payload: 1 + rng.below(4) as u64,
            });
        }
        if rng.below(4) == 0 {
            events.push(TraceEvent {
                nanos: t + rng.below((stage_end - t + 1) as usize) as u64,
                lane: driver,
                kind: TraceKind::KillDrain,
                payload: 1,
            });
        }
        t = stage_end + 1 + rng.below(30) as u64;
    }
    // Deliver in scrambled order: `from_events` must re-sort.
    for i in (1..events.len()).rev() {
        events.swap(i, rng.below(i + 1));
    }
    Synth { events, num_lanes, longest_span, num_tasks, num_spans: num_tasks }
}

#[test]
fn prop_synthetic_traces_satisfy_profile_invariants() {
    let mut rng = Rng::seed_from_u64(0x0F1A);
    for case in 0..80 {
        let s = synth_trace(&mut rng);
        let p = Profile::from_events(&s.events, s.num_lanes);
        let label = format!("synthetic case {case}");
        check_invariants(&p, Some(s.longest_span), &label);
        // Generator ground truth: every task span completed and was
        // observed, every enqueue→start delay was measurable.
        let counted: u64 = p.aggregate.iter().map(|r| r.count).sum();
        assert_eq!(counted, s.num_spans, "{label}: aggregate loses spans");
        assert_eq!(p.queue.samples, s.num_tasks, "{label}: queue samples != tasks");
        assert_eq!(p.lanes.len(), s.num_lanes - 1, "{label}: one gap row per worker lane");
    }
}

#[test]
fn prop_degenerate_traces_do_not_panic() {
    // Empty trace: everything zero, frac pinned at 0.
    let p = Profile::from_events(&[], 3);
    assert_eq!(p.wall_nanos, 0);
    assert_eq!(p.critical_path_frac, 0.0);
    assert!(p.collapsed_stack().is_empty());
    // Single instantaneous task: wall 0 but work ran — frac reads 1.
    let payload = (1u64 << 32) | 7;
    let ev = [
        TraceEvent { nanos: 5, lane: 0, kind: TraceKind::Start, payload },
        TraceEvent { nanos: 5, lane: 0, kind: TraceKind::Finish, payload },
    ];
    let p = Profile::from_events(&ev, 2);
    assert_eq!(p.wall_nanos, 0);
    assert_eq!(p.critical_path_frac, 1.0);
    check_invariants(&p, None, "degenerate single-task");
}

// ----------------------------------------------- real engine traces --

/// Run a seeded two-stage job (busy map + empty probe stage) and return
/// the profile of its drained trace.
fn engine_profile(mode: SchedulerMode, seed: u64) -> Profile {
    let mut rng = Rng::seed_from_u64(seed);
    let workers = 2 + rng.below(2);
    let mut cfg = ClusterConfig::spark(workers);
    cfg.scheduler.mode = mode;
    // Speculation off: every completed span is a winning attempt, so
    // the critical path lower-bounds at the longest task (a zombie
    // speculative duplicate would break that accounting).
    cfg.scheduler.speculation = false;
    cfg.scheduler.trace_capacity = 1 << 12;
    let c = Cluster::new(cfg);

    let n = 8 + rng.below(17) as u64;
    let parts = 2 + rng.below(3);
    let spin = 50 + rng.below(400) as u64;
    let out = c
        .parallelize((0..n).collect::<Vec<u64>>(), parts)
        .map(move |x| {
            let mut acc = x;
            for i in 0..spin {
                acc = std::hint::black_box(acc.wrapping_mul(0x9E37_79B9).rotate_left(7) ^ i);
            }
            acc
        })
        .collect()
        .unwrap();
    assert_eq!(out.len(), n as usize);
    c.executor_probe(1 + rng.below(8)).unwrap();

    let events = c.trace().drain_new();
    assert!(
        events.iter().any(|e| e.kind == TraceKind::Finish),
        "traced job produced no Finish events"
    );
    Profile::from_events(&events, c.trace().num_lanes())
}

#[test]
fn prop_engine_traces_satisfy_profile_invariants_both_modes() {
    for mode in [SchedulerMode::Sharded, SchedulerMode::GlobalLock] {
        for seed in 0..15u64 {
            let p = engine_profile(mode, 0xE_0000 + seed);
            let label = format!("engine {mode:?} seed {seed}");
            check_invariants(&p, None, &label);
            // The job ran at least two stages (map stage + probe stage)
            // and the profiler saw both.
            let stages: std::collections::BTreeSet<u64> =
                p.aggregate.iter().map(|r| r.stage).collect();
            assert!(stages.len() >= 2, "{label}: expected >= 2 stages, saw {stages:?}");
            assert!(p.queue.samples > 0, "{label}: no enqueue->start delays measured");
            assert_eq!(p.lanes.len(), p.num_lanes - 1, "{label}");
            // Machine-readable export stays structurally valid JSON.
            assert!(halign2::obs::is_json_object(&p.to_json()), "{label}: to_json invalid");
        }
    }
}

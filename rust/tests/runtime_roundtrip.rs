//! Integration tests: AOT artifacts (built by `make artifacts QUICK=1`)
//! loaded and executed through the PJRT runtime, checked against native
//! Rust oracles. Requires `artifacts/manifest.txt`; tests self-skip when
//! artifacts are absent so `cargo test` stays green pre-`make artifacts`.

use halign2::align::sw::{sw_matrix, SwParams};
use halign2::runtime::{batcher, ArtifactKind, XlaService};

fn service() -> Option<XlaService> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.txt").exists() {
        eprintln!("skipping runtime test: run `make artifacts` first");
        return None;
    }
    Some(XlaService::start(dir).expect("starting XLA service"))
}

/// Deterministic LCG for test inputs.
fn lcg(seed: &mut u64) -> u64 {
    *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *seed >> 33
}

fn random_codes(len: usize, alpha: usize, seed: &mut u64) -> Vec<i32> {
    (0..len).map(|_| (lcg(seed) % (alpha as u64 - 1)) as i32).collect()
}

fn test_subst(alpha: usize) -> Vec<f32> {
    // +5 match / -3 mismatch, sentinel row & column strongly negative.
    let mut s = vec![-3f32; alpha * alpha];
    for i in 0..alpha {
        s[i * alpha + i] = 5.0;
        s[i * alpha + alpha - 1] = -1e4;
        s[(alpha - 1) * alpha + i] = -1e4;
    }
    s
}

#[test]
fn sw_artifact_matches_native_dp() {
    let Some(svc) = service() else { return };
    let alpha = 25usize;
    let gap = 3.0f32;
    let mut seed = 42u64;
    let center = random_codes(100, alpha, &mut seed);
    let queries: Vec<Vec<i32>> = (0..10)
        .map(|k| random_codes(40 + 7 * k, alpha, &mut seed))
        .collect();

    let subst = test_subst(alpha);
    let b = batcher::SwBatcher::new(&svc, center.clone(), subst.clone(), alpha, gap).unwrap();
    let hs = b.score(&queries).unwrap();
    assert_eq!(hs.len(), queries.len());

    let params = SwParams { subst: subst.clone(), alpha, gap };
    for (q, h) in queries.iter().zip(&hs) {
        let native = sw_matrix(q, &center, &params);
        assert_eq!(h.m, q.len());
        assert_eq!(h.n, center.len());
        for i in 0..=h.m {
            for j in 0..=h.n {
                assert_eq!(
                    h.at(i, j),
                    native.at(i, j),
                    "H[{i}][{j}] mismatch (query len {})",
                    q.len()
                );
            }
        }
    }
}

#[test]
fn sw_batcher_spans_multiple_chunks() {
    let Some(svc) = service() else { return };
    let alpha = 25usize;
    let mut seed = 7u64;
    let center = random_codes(64, alpha, &mut seed);
    // 19 queries forces 3 chunks at bucket batch 8.
    let queries: Vec<Vec<i32>> = (0..19).map(|_| random_codes(50, alpha, &mut seed)).collect();
    let subst = test_subst(alpha);
    let b = batcher::SwBatcher::new(&svc, center.clone(), subst.clone(), alpha, 2.0).unwrap();
    let hs = b.score(&queries).unwrap();
    let params = SwParams { subst, alpha, gap: 2.0 };
    for (q, h) in queries.iter().zip(&hs) {
        let native = sw_matrix(q, &center, &params);
        let (_, _, best) = h.argmax();
        let (_, _, best_native) = native.argmax();
        assert_eq!(best, best_native);
    }
}

#[test]
fn match_counts_artifact_exact() {
    let Some(svc) = service() else { return };
    let alpha = 7usize; // DNA_ALPHA (gap=5, sentinel=6)
    let mut seed = 9u64;
    let rows: Vec<Vec<i32>> = (0..20).map(|_| random_codes(90, alpha, &mut seed)).collect();
    let mc = batcher::match_counts(&svc, ArtifactKind::MatchDna, &rows, alpha).unwrap();
    for i in 0..rows.len() {
        for j in 0..rows.len() {
            let expect = rows[i]
                .iter()
                .zip(&rows[j])
                .filter(|(a, b)| a == b)
                .count() as f32;
            assert_eq!(mc[i][j], expect, "pair ({i},{j})");
        }
    }
}

#[test]
fn kmer_sqdist_artifact_close() {
    let Some(svc) = service() else { return };
    let mut seed = 5u64;
    let profiles: Vec<Vec<f32>> = (0..30)
        .map(|_| (0..256).map(|_| (lcg(&mut seed) % 7) as f32).collect())
        .collect();
    let d2 = batcher::kmer_sqdist(&svc, &profiles).unwrap();
    for i in 0..profiles.len() {
        assert_eq!(d2[i][i], 0.0);
        for j in 0..profiles.len() {
            let expect: f32 = profiles[i]
                .iter()
                .zip(&profiles[j])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(
                (d2[i][j] - expect).abs() <= 1e-2 * expect.max(1.0),
                "pair ({i},{j}): {} vs {}",
                d2[i][j],
                expect
            );
        }
    }
}

#[test]
fn service_lists_compiled_executables() {
    let Some(svc) = service() else { return };
    let names = svc.executables();
    assert!(!names.is_empty());
    assert!(names.iter().any(|n| n.starts_with("sw_")));
}

//! Offline stand-in for the `anyhow` crate.
//!
//! The halign2 build is fully offline (no registry access), so this
//! vendored micro-crate implements exactly the subset of the anyhow API
//! the workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!`
//! macros.  Like the real crate, [`Error`] deliberately does *not*
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.
//!
//! Error chains are stored as rendered strings (outermost context first);
//! `{err}` prints the outermost message, `{err:#}` the full chain joined
//! with `": "` — matching anyhow's Display behaviour closely enough for
//! the tests and CLI output in this repo.

use std::error::Error as StdError;
use std::fmt;

/// The ubiquitous result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically-typed error with a chain of context messages.
pub struct Error {
    /// Outermost message first, root cause last. Never empty.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root-cause (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`
// (the same trick the real anyhow uses).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring anyhow's.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn from_std_error_and_context_chain() {
        let err = fails_io().context("spilling bucket").unwrap_err();
        assert_eq!(format!("{err}"), "spilling bucket");
        let full = format!("{err:#}");
        assert!(full.contains("spilling bucket") && full.contains("disk on fire"));
        assert_eq!(err.root_cause(), "disk on fire");
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 3;
        let b = anyhow!("value {x} and {}", 4);
        assert_eq!(b.to_string(), "value 3 and 4");

        fn bails(n: u32) -> Result<()> {
            ensure!(n < 10, "n too big: {n}");
            if n == 5 {
                bail!("five is right out");
            }
            Ok(())
        }
        assert!(bails(3).is_ok());
        assert!(bails(5).unwrap_err().to_string().contains("five"));
        assert!(bails(50).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let err = v.context("missing field").unwrap_err();
        assert_eq!(err.to_string(), "missing field");
        assert_eq!(Some(7u8).context("unused").unwrap(), 7);
    }

    #[test]
    fn error_context_method_stacks() {
        let err = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{err:#}"), "outer: mid: root");
    }
}

//! # halign2 — HAlign-II reproduction
//!
//! Distributed ultra-large multiple sequence alignment (MSA) and
//! phylogenetic tree reconstruction, after *Wan & Zou, 2017*:
//! center-star MSA (trie-accelerated for similar DNA/RNA, Smith-Waterman
//! for proteins) and sampling-clustered neighbor-joining trees, running on
//! an in-process Spark-like dataflow engine with swappable in-memory
//! (Spark) and disk key-value (Hadoop) shuffle backends.
//!
//! The compute hot spots (batched Smith-Waterman wavefront, Gram-matrix
//! distances) execute as AOT-compiled XLA programs authored in JAX/Pallas
//! (`python/compile/`) and served by [`runtime`]; Python never runs at
//! request time.
//!
//! Layering (bottom-up):
//! * [`util`]    — PRNG, binary codec, timing (std-only substitutes for the
//!                 usual crates; this build is fully offline).
//! * [`engine`]  — the mini-Spark substrate: lazy RDDs with lineage
//!                 (slice-aware, so `split_partitions` computes only each
//!                 slice's range over sources/caches/checkpoints), DAG
//!                 scheduler, a sharded work-stealing executor
//!                 (per-worker mutexed deques with no global lock on the
//!                 hot path, idle workers steal *half* the busiest
//!                 victim's deque per batch, stragglers re-executed
//!                 speculatively with first-completion-wins and
//!                 execution-time deadlines; a global-mutex baseline
//!                 remains selectable for A/B), shuffles, broadcast,
//!                 memory accounting, and fault injection including
//!                 worker kills that drain the dead node's deque back
//!                 into the steal pool.  Steal/steal-batch/contention/
//!                 speculation counters and busy-time skew (max/mean
//!                 worker busy nanos) surface through `ClusterStats`
//!                 into [`metrics`].
//! * [`fasta`]   — sequence types, alphabets, FASTA I/O.  DNA codes run
//!                 `A=0 C=1 G=2 T/U=3 N=4 gap=5` plus a *distinct*
//!                 batcher padding sentinel `6` (`DNA_ALPHA = 7`), so
//!                 padded tails can never be confused with real gap
//!                 columns.
//! * [`data`]    — deterministic synthetic dataset generators standing in
//!                 for the paper's mito-genome / 16S rRNA / BAliBASE data.
//! * [`align`]   — center-star MSA: trie, pairwise DP, space merging,
//!                 SP scoring, the DNA and protein pipelines.  Pairwise
//!                 hot paths dispatch on `KernelBackend`: `Scalar` keeps
//!                 the reference full-DP f32 kernels; `BitParallel` (the
//!                 default) routes through the exact integer kernels —
//!                 bit-parallel Myers edit distance ([`align::myers`])
//!                 and adaptive banded global/affine DP
//!                 ([`align::banded`]), certified bit-identical to the
//!                 full DP before a result is accepted.  All tracebacks
//!                 compare with exact equality; there are no epsilon
//!                 comparisons left in the alignment kernels.  Finished
//!                 nucleotide MSAs can retain an [`align::append::MsaArtifact`]
//!                 (center + merged space-profile + per-row edit paths);
//!                 [`align::append::append_nucleotide`] extends it with k
//!                 new sequences in O(k·L), bit-identical to a
//!                 from-scratch run on the union.
//! * [`cache`]   — content-hash result memoization for the serving
//!                 layer: a canonical FASTA digest (`canonical_digest`;
//!                 formatting-invariant, order-sensitive — see
//!                 `rust/CACHE.md`) keys a byte-budgeted LRU
//!                 `ArtifactStore` that spills encoded artifacts to disk
//!                 with the same atomic tmp+rename discipline as the
//!                 tile store.  Knobs: the store's `byte_budget` (server
//!                 default 64 MiB) and the artifact format version
//!                 (`align::append::ARTIFACT_VERSION`).
//! * [`distmat`] — distributed tiled distance matrices: a `TileGrid`
//!                 plans the n×n lower triangle as fixed-size tiles, each
//!                 one stealable engine job (via the
//!                 `Rdd::lower_triangle_blocks` pairwise-block
//!                 primitive); a byte-budgeted `TileStore` keeps
//!                 completed tiles resident up to a budget and spills the
//!                 rest (tmp+rename, bit-exact); the `DistSource` trait
//!                 (`dist`, `row_mins`/`row_stats`, `stream_row`)
//!                 abstracts dense-in-memory vs tiled-on-disk backends.
//!                 Tile jobs are idempotent (deterministic entries,
//!                 replace-on-put), so the executor's at-least-once
//!                 writes — speculation, retries, kill-recovery — apply
//!                 unchanged.  Knobs: `DistMatConfig { tile_rows,
//!                 byte_budget, kind }`, `DistBackend` on `TreeConfig`.
//! * [`tree`]    — distances, sampling clustering, neighbor-joining over
//!                 any `DistSource` (rapid-NJ-style row-min pruning;
//!                 merged-row working set spills through the same
//!                 `TileStore`, making million-pair trees buildable in
//!                 O(tile) resident memory, bit-identical to the dense
//!                 path), tree merge, Newick, JC69 likelihood.
//! * [`baselines`] — HAlign-v1 (Hadoop mode), SparkSW, MUSCLE/MAFFT-like
//!                 progressive, IQ-TREE-like ML search.
//! * [`runtime`] — PJRT service + shape-bucket batcher over the artifacts.
//! * [`obs`]     — unified observability: a process-wide registry of
//!                 named counters/gauges/log2-bucketed latency
//!                 histograms (lock-free record, exact merge,
//!                 percentile extraction, Prometheus text rendering)
//!                 plus bounded per-worker trace rings drained into
//!                 Chrome trace-event JSON.  Engine, distmat spill,
//!                 shuffle, cache, and server counters all register
//!                 here; naming contract in `rust/OBSERVABILITY.md`.
//! * [`metrics`] — wall-clock/memory reporting, paper-table printers.
//! * [`bench`]   — the in-tree benchmark harness regenerating every table
//!                 and figure of the paper's evaluation.
//! * [`lint`]    — `pallas-lint`, the project-native static-analysis
//!                 pass (binary: `cargo run --bin pallas_lint`): W1–W8
//!                 rules pinning the bug classes past PRs paid for
//!                 (worker panics, lock-across-I/O, lock ordering vs
//!                 `rust/LOCKS.md`, float tolerances in kernels,
//!                 relaxed condvar handshakes, TSV arity skew, raw
//!                 `fs` writes in cache/store modules that bypass
//!                 `write_atomic`, metric names undeclared in
//!                 `rust/OBSERVABILITY.md`).  See `rust/LINTS.md`.

#![forbid(unsafe_code)]

pub mod align;
pub mod baselines;
pub mod bench;
pub mod cache;
pub mod data;
pub mod distmat;
pub mod engine;
pub mod fasta;
pub mod lint;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod server;
pub mod tree;
pub mod util;

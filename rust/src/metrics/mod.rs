//! Reporting: run summaries and paper-style table printers shared by the
//! CLI, examples and benches.

use std::time::Duration;

use crate::engine::ClusterStats;
use crate::util::timer::fmt_duration;

/// One benchmark row: a (tool, dataset) cell of a paper table.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub tool: String,
    pub dataset: String,
    pub wall: Duration,
    /// Engine busy time summed over workers (CPU-seconds proxy; on a
    /// 1-core CI box this is the scale-free signal — see EXPERIMENTS.md).
    pub busy: Option<Duration>,
    /// avg SP (MSA tables) or logML (tree table).
    pub metric: Option<f64>,
    pub metric_name: &'static str,
    pub avg_max_memory_mb: Option<f64>,
    pub shuffle_mb: Option<f64>,
    /// Max/mean per-worker busy nanos (1.0 = perfectly balanced); the
    /// Fig-6 load-balance signal the work-stealing scheduler improves.
    pub busy_skew: Option<f64>,
    /// Tasks executed away from their owning node (work stealing).
    pub tasks_stolen: Option<usize>,
    /// Steal operations (steal-half: each migrates up to half a deque).
    pub steal_batches: Option<usize>,
    /// Scheduler-lock `try_lock` misses — the contention proxy the
    /// sharded-vs-global Fig-6 scenario compares.
    pub lock_contentions: Option<usize>,
    /// Speculative straggler duplicates launched.
    pub speculative_launches: Option<usize>,
    /// Peak resident distance-matrix MB (tree rows: dense = O(n²) in the
    /// largest cluster, tiled = bounded by the distmat byte budget).
    pub distmat_peak_mb: Option<f64>,
    /// Median worker-side task execution latency (ms), from the obs
    /// registry's log2 histogram.
    pub p50_ms: Option<f64>,
    /// 99th-percentile task execution latency (ms) — the tail signal
    /// means hide (see OBSERVABILITY.md).
    pub p99_ms: Option<f64>,
    /// "-" rows: tool did not finish (OOM / unsupported / over budget).
    pub dnf: Option<String>,
}

impl RunReport {
    pub fn dnf(tool: &str, dataset: &str, reason: impl Into<String>) -> Self {
        Self {
            tool: tool.into(),
            dataset: dataset.into(),
            wall: Duration::ZERO,
            busy: None,
            metric: None,
            metric_name: "",
            avg_max_memory_mb: None,
            shuffle_mb: None,
            busy_skew: None,
            tasks_stolen: None,
            steal_batches: None,
            lock_contentions: None,
            speculative_launches: None,
            distmat_peak_mb: None,
            p50_ms: None,
            p99_ms: None,
            dnf: Some(reason.into()),
        }
    }

    pub fn with_stats(mut self, stats: &ClusterStats) -> Self {
        self.busy = Some(stats.total_busy);
        self.avg_max_memory_mb = Some(stats.avg_max_memory_bytes / (1 << 20) as f64);
        self.shuffle_mb = Some(
            (stats.shuffle_bytes_written + stats.shuffle_bytes_read) as f64 / (1 << 20) as f64,
        );
        self.busy_skew = Some(stats.busy_skew);
        self.tasks_stolen = Some(stats.tasks_stolen);
        self.steal_batches = Some(stats.steal_batches);
        self.lock_contentions = Some(stats.lock_contentions);
        self.speculative_launches = Some(stats.speculative_launches);
        self.p50_ms = Some(stats.task_p50_ms);
        self.p99_ms = Some(stats.task_p99_ms);
        self
    }
}

/// Print a paper-style table: rows = tools, columns = datasets.
pub fn print_table(title: &str, reports: &[RunReport]) {
    println!("\n=== {title} ===");
    let mut datasets: Vec<&str> = Vec::new();
    let mut tools: Vec<&str> = Vec::new();
    for r in reports {
        if !datasets.contains(&r.dataset.as_str()) {
            datasets.push(&r.dataset);
        }
        if !tools.contains(&r.tool.as_str()) {
            tools.push(&r.tool);
        }
    }
    print!("{:<14}", "");
    for d in &datasets {
        print!("| {d:<26}");
    }
    println!();
    for t in &tools {
        print!("{t:<14}");
        for d in &datasets {
            let cell = reports
                .iter()
                .find(|r| r.tool == *t && r.dataset == *d)
                .map(|r| match &r.dnf {
                    Some(reason) => format!("- ({reason})"),
                    None => {
                        let metric = r
                            .metric
                            .map(|m| format!(" {}={m:.1}", r.metric_name))
                            .unwrap_or_default();
                        let mem = r
                            .avg_max_memory_mb
                            .map(|m| format!(" mem={m:.1}MB"))
                            .unwrap_or_default();
                        format!("{}{}{}", fmt_duration(r.wall), metric, mem)
                    }
                })
                .unwrap_or_else(|| "·".to_string());
            print!("| {cell:<26}");
        }
        println!();
    }
}

/// Column names matching [`tsv_line`]'s fields — keep the two in sync
/// here so every TSV emitter prints the same header.
pub const TSV_HEADER: &str = "tool\tdataset\twall_s\tbusy_s\tmetric\tavg_max_mem_mb\tbusy_skew\tstolen\tsteal_batches\tlock_contention\tspeculative\tdistmat_peak_mb\tp50_ms\tp99_ms\tstatus";

/// Machine-readable one-line record (appended to bench logs); fields as
/// in [`TSV_HEADER`].
pub fn tsv_line(r: &RunReport) -> String {
    format!(
        "{}\t{}\t{:.3}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
        r.tool,
        r.dataset,
        r.wall.as_secs_f64(),
        r.busy.map(|b| format!("{:.3}", b.as_secs_f64())).unwrap_or_else(|| "-".into()),
        r.metric.map(|m| format!("{m:.3}")).unwrap_or_else(|| "-".into()),
        r.avg_max_memory_mb.map(|m| format!("{m:.2}")).unwrap_or_else(|| "-".into()),
        r.busy_skew.map(|s| format!("{s:.3}")).unwrap_or_else(|| "-".into()),
        r.tasks_stolen.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
        r.steal_batches.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
        r.lock_contentions.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
        r.speculative_launches.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
        r.distmat_peak_mb.map(|m| format!("{m:.4}")).unwrap_or_else(|| "-".into()),
        r.p50_ms.map(|m| format!("{m:.3}")).unwrap_or_else(|| "-".into()),
        r.p99_ms.map(|m| format!("{m:.3}")).unwrap_or_else(|| "-".into()),
        r.dnf.clone().unwrap_or_else(|| "ok".into()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tsv_has_fifteen_fields() {
        let r = RunReport {
            tool: "halign2".into(),
            dataset: "dna1x".into(),
            wall: Duration::from_secs(14),
            busy: Some(Duration::from_secs(50)),
            metric: Some(195.0),
            metric_name: "avgSP",
            avg_max_memory_mb: Some(100.0),
            shuffle_mb: Some(0.0),
            busy_skew: Some(1.25),
            tasks_stolen: Some(7),
            steal_batches: Some(3),
            lock_contentions: Some(2),
            speculative_launches: Some(1),
            distmat_peak_mb: Some(0.0625),
            p50_ms: Some(1.5),
            p99_ms: Some(42.75),
            dnf: None,
        };
        let line = tsv_line(&r);
        assert_eq!(line.split('\t').count(), 15);
        assert_eq!(TSV_HEADER.split('\t').count(), 15, "header matches row arity");
        assert!(line.contains("1.250"));
        assert!(line.contains("0.0625"), "distmat peak column must render");
        assert!(TSV_HEADER.contains("distmat_peak_mb"));
        assert!(line.contains("42.750"), "latency percentiles must render");
        // The table5 smoke greps column 11 for distmat_peak_mb: the new
        // latency columns must come after it, never shift it.
        assert_eq!(TSV_HEADER.split('\t').nth(11), Some("distmat_peak_mb"));
        assert_eq!(TSV_HEADER.split('\t').nth(12), Some("p50_ms"));
        assert_eq!(TSV_HEADER.split('\t').nth(13), Some("p99_ms"));
        assert!(TSV_HEADER.ends_with("status"));
    }

    #[test]
    fn dnf_renders_reason() {
        let r = RunReport::dnf("muscle", "dna100x", "OOM");
        assert!(tsv_line(&r).ends_with("OOM"));
    }
}

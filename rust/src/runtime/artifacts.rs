//! Artifact registry: parses `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) into typed shape-bucket metadata.
//!
//! The manifest format is one tab-separated line per executable:
//!
//! ```text
//! name<TAB>file<TAB>kind<TAB>k=v,k=v,...
//! ```
//!
//! Shape buckets are the contract between the Rust batcher (which pads
//! requests up to a bucket) and the fixed-shape PJRT executables.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// What computation an artifact implements (mirrors aot.py's `kind` column).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Batched Smith-Waterman wavefront: params b, m (query), n (center), alpha.
    Sw,
    /// k-mer profile squared distances: params n, d.
    KmerDist,
    /// Match counts over aligned DNA codes: params n, l, alpha.
    MatchDna,
    /// Match counts over aligned protein codes: params n, l, alpha.
    MatchProtein,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sw" => ArtifactKind::Sw,
            "kmerdist" => ArtifactKind::KmerDist,
            "match_dna" => ArtifactKind::MatchDna,
            "match_protein" => ArtifactKind::MatchProtein,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One manifest line.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub params: HashMap<String, usize>,
}

impl ArtifactMeta {
    pub fn param(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .copied()
            .with_context(|| format!("artifact {} missing param {key}", self.name))
    }
}

/// Parsed manifest with kind-indexed lookup.
#[derive(Debug, Default)]
pub struct Manifest {
    entries: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                bail!("manifest line {}: expected 4 tab-separated columns", lineno + 1);
            }
            let mut params = HashMap::new();
            for kv in cols[3].split(',').filter(|s| !s.is_empty()) {
                let (k, v) = kv
                    .split_once('=')
                    .with_context(|| format!("manifest line {}: bad param {kv:?}", lineno + 1))?;
                params.insert(
                    k.to_string(),
                    v.parse::<usize>()
                        .with_context(|| format!("manifest line {}: non-integer {v:?}", lineno + 1))?,
                );
            }
            entries.push(ArtifactMeta {
                name: cols[0].to_string(),
                file: cols[1].to_string(),
                kind: ArtifactKind::parse(cols[2])?,
                params,
            });
        }
        Ok(Self { entries })
    }

    pub fn entries(&self) -> &[ArtifactMeta] {
        &self.entries
    }

    pub fn of_kind(&self, kind: ArtifactKind) -> impl Iterator<Item = &ArtifactMeta> {
        self.entries.iter().filter(move |m| m.kind == kind)
    }

    /// Smallest SW bucket whose (m, n) covers the given query/center
    /// lengths, by padded-cell count.
    pub fn sw_bucket(&self, query_len: usize, center_len: usize) -> Option<&ArtifactMeta> {
        self.of_kind(ArtifactKind::Sw)
            .filter(|m| {
                m.params.get("m").copied().unwrap_or(0) >= query_len
                    && m.params.get("n").copied().unwrap_or(0) >= center_len
            })
            .min_by_key(|m| m.params["m"] * m.params["n"])
    }

    /// Smallest match-count bucket covering `rows` x `cols` for the given
    /// alignment kind.
    pub fn match_bucket(
        &self,
        kind: ArtifactKind,
        rows: usize,
        cols: usize,
    ) -> Option<&ArtifactMeta> {
        self.of_kind(kind)
            .filter(|m| m.params["n"] >= rows && m.params["l"] >= cols)
            .min_by_key(|m| m.params["n"] * m.params["l"])
    }

    pub fn kmer_bucket(&self, rows: usize, dim: usize) -> Option<&ArtifactMeta> {
        self.of_kind(ArtifactKind::KmerDist)
            .filter(|m| m.params["n"] >= rows && m.params["d"] >= dim)
            .min_by_key(|m| m.params["n"] * m.params["d"])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "sw_b8_q128_c128\tsw_b8_q128_c128.hlo.txt\tsw\tb=8,m=128,n=128,alpha=25\n\
kmerdist_n128_d256\tkmerdist_n128_d256.hlo.txt\tkmerdist\tn=128,d=256\n\
matchdna_n128_l2048\tmatchdna_n128_l2048.hlo.txt\tmatch_dna\tn=128,l=2048,alpha=7\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries().len(), 3);
        assert_eq!(m.entries()[0].kind, ArtifactKind::Sw);
        assert_eq!(m.entries()[0].param("alpha").unwrap(), 25);
    }

    #[test]
    fn bucket_selection_prefers_smallest_cover() {
        let text = "sw_small\ta\tsw\tb=8,m=128,n=128\nsw_big\tb\tsw\tb=8,m=512,n=512\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.sw_bucket(100, 100).unwrap().name, "sw_small");
        assert_eq!(m.sw_bucket(200, 100).unwrap().name, "sw_big");
        assert!(m.sw_bucket(600, 600).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("only\tthree\tcols\n").is_err());
        assert!(Manifest::parse("a\tb\tsw\tnotkv\n").is_err());
        assert!(Manifest::parse("a\tb\tbadkind\tk=1\n").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# comment\n\nsw_x\tf\tsw\tb=1,m=2,n=3\n").unwrap();
        assert_eq!(m.entries().len(), 1);
    }
}

//! Runtime: loads the AOT artifacts (HLO text emitted by `python/compile/aot.py`)
//! and serves fixed-shape PJRT executions to the coordinator hot path.
//!
//! Python never runs here — `make artifacts` happens once at build time, and
//! this module is the only place the process touches XLA.
//!
//! Threading: the `xla` crate's client/executable wrappers are raw C++
//! pointers without `Send`/`Sync` guarantees, so a dedicated **service
//! thread** owns the `PjRtClient` and every compiled executable; callers talk
//! to it through an mpsc channel with plain host buffers (`HostTensor`).
//! A `XlaService` handle is cheaply cloneable and can be shared across all
//! engine workers.

pub mod artifacts;
pub mod batcher;

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

pub use artifacts::{ArtifactKind, ArtifactMeta, Manifest};

/// A host-side tensor crossing the service-channel boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v, _) => v.len(),
            HostTensor::I32(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            HostTensor::I32(..) => bail!("expected f32 tensor, got i32"),
        }
    }
}

enum Command {
    Execute {
        name: String,
        inputs: Vec<HostTensor>,
        resp: mpsc::Sender<Result<HostTensor>>,
    },
    ListExecutables {
        resp: mpsc::Sender<Vec<String>>,
    },
    Shutdown,
}

/// Handle to the PJRT service thread; clone freely and share across
/// workers (`std::sync::mpsc::Sender` is `!Sync`, so it sits behind a
/// mutex that is held only long enough to clone a sender).
#[derive(Clone)]
pub struct XlaService {
    tx: Arc<Mutex<mpsc::Sender<Command>>>,
    manifest: Arc<Manifest>,
    // Serializes shutdown; the service thread exits when the last sender drops
    // or an explicit Shutdown arrives.
    _guard: Arc<ServiceGuard>,
}

struct ServiceGuard {
    tx: Mutex<Option<mpsc::Sender<Command>>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for ServiceGuard {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.lock().unwrap().take() {
            let _ = tx.send(Command::Shutdown);
        }
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

impl XlaService {
    /// Start the service: compile every artifact in `dir`'s manifest on the
    /// PJRT CPU client (one executable per shape bucket).
    pub fn start(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Arc::new(Manifest::load(&dir)?);
        Self::start_with_manifest(dir, manifest)
    }

    pub fn start_with_manifest(dir: PathBuf, manifest: Arc<Manifest>) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Command>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let metas: Vec<ArtifactMeta> = manifest.entries().to_vec();
        let join = std::thread::Builder::new()
            .name("xla-service".into())
            .spawn(move || backend::service_main(dir, metas, rx, ready_tx))
            .context("spawning xla service thread")?;
        ready_rx
            .recv()
            .context("xla service thread died during startup")??;
        Ok(Self {
            tx: Arc::new(Mutex::new(tx.clone())),
            manifest,
            _guard: Arc::new(ServiceGuard {
                tx: Mutex::new(Some(tx)),
                join: Mutex::new(Some(join)),
            }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute artifact `name` with `inputs`; blocks until the result is
    /// back on the host. All our programs return a 1-tuple of one f32 array.
    pub fn execute(&self, name: &str, inputs: Vec<HostTensor>) -> Result<HostTensor> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let tx = self.tx.lock().unwrap().clone();
        tx.send(Command::Execute {
            name: name.to_string(),
            inputs,
            resp: resp_tx,
        })
        .map_err(|_| anyhow!("xla service thread is gone"))?;
        resp_rx
            .recv()
            .map_err(|_| anyhow!("xla service dropped the response"))?
    }

    pub fn executables(&self) -> Vec<String> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let tx = self.tx.lock().unwrap().clone();
        if tx.send(Command::ListExecutables { resp: resp_tx }).is_err() {
            return Vec::new();
        }
        resp_rx.recv().unwrap_or_default()
    }
}

/// Real PJRT backend — only compiled with `--features xla` (the offline
/// build cannot fetch the external `xla` crate).
#[cfg(feature = "xla")]
mod backend {
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::sync::mpsc;

    use anyhow::{anyhow, Context, Result};

    use super::{ArtifactMeta, Command, HostTensor};

    fn host_to_literal(t: &HostTensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        let lit = match t {
            HostTensor::F32(v, _) => xla::Literal::vec1(v),
            HostTensor::I32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub(super) fn service_main(
        dir: PathBuf,
        metas: Vec<ArtifactMeta>,
        rx: mpsc::Receiver<Command>,
        ready: mpsc::Sender<Result<()>>,
    ) {
        let setup = (|| -> Result<(xla::PjRtClient, HashMap<String, xla::PjRtLoadedExecutable>)> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let mut exes = HashMap::new();
            for meta in &metas {
                let path = dir.join(&meta.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling artifact {}", meta.name))?;
                exes.insert(meta.name.clone(), exe);
            }
            Ok((client, exes))
        })();

        let (client, exes) = match setup {
            Ok(v) => {
                let _ = ready.send(Ok(()));
                v
            }
            Err(e) => {
                let _ = ready.send(Err(e));
                return;
            }
        };
        let _client = client; // keep the client alive for the executables

        while let Ok(cmd) = rx.recv() {
            match cmd {
                Command::Shutdown => break,
                Command::ListExecutables { resp } => {
                    let mut names: Vec<String> = exes.keys().cloned().collect();
                    names.sort();
                    let _ = resp.send(names);
                }
                Command::Execute { name, inputs, resp } => {
                    let result = (|| -> Result<HostTensor> {
                        let exe = exes
                            .get(&name)
                            .ok_or_else(|| anyhow!("no artifact named {name}"))?;
                        let lits: Vec<xla::Literal> = inputs
                            .iter()
                            .map(host_to_literal)
                            .collect::<Result<_>>()?;
                        let out = exe.execute::<xla::Literal>(&lits)?[0][0]
                            .to_literal_sync()?;
                        // aot.py lowers with return_tuple=True -> 1-tuple.
                        let inner = out.to_tuple1()?;
                        let shape = inner.array_shape()?;
                        let dims: Vec<usize> =
                            shape.dims().iter().map(|&d| d as usize).collect();
                        let vals = inner.to_vec::<f32>()?;
                        Ok(HostTensor::F32(vals, dims))
                    })();
                    let _ = resp.send(result);
                }
            }
        }
    }
}

/// Stub backend for the offline build: service startup reports an error
/// instead of executing artifacts.  All callers treat a failed
/// `XlaService::start` as "no service" and fall back to the native Rust
/// kernels, and the artifact tests self-skip when no manifest exists.
#[cfg(not(feature = "xla"))]
mod backend {
    use std::path::PathBuf;
    use std::sync::mpsc;

    use anyhow::{anyhow, Result};

    use super::{ArtifactMeta, Command};

    pub(super) fn service_main(
        _dir: PathBuf,
        _metas: Vec<ArtifactMeta>,
        _rx: mpsc::Receiver<Command>,
        ready: mpsc::Sender<Result<()>>,
    ) {
        let _ = ready.send(Err(anyhow!(
            "halign2 was built without the `xla` feature; AOT artifacts cannot be executed \
             (rebuild with --features xla and an xla crate source)"
        )));
    }
}

//! Shape-bucket batcher: pads variable-length requests up to the fixed
//! shapes of the AOT executables, runs them through the [`XlaService`], and
//! slices the padded results back out.
//!
//! Padding contracts (must match python/compile/aot.py + the kernels):
//!  * SW queries pad with the sentinel code `alpha - 1`; the substitution
//!    matrix holds a large negative score on the sentinel row/column, so
//!    padded tails can never extend an alignment (tested on the python side
//!    by `test_padding_sentinel_never_extends` and here by the runtime
//!    integration tests).
//!  * Match-count rows pad columns with a shared fill code, adding a
//!    constant `width - L` to every count, which the caller subtracts.
//!  * Gram rows pad with zeros (exact).

use anyhow::{anyhow, Context, Result};

use crate::align::sw::HMatrix;

use super::{ArtifactKind, HostTensor, XlaService};

/// Batches SW scoring requests against one center sequence.
pub struct SwBatcher<'a> {
    svc: &'a XlaService,
    center: Vec<i32>,
    subst: Vec<f32>,
    alpha: usize,
    gap: f32,
}

impl<'a> SwBatcher<'a> {
    pub fn new(
        svc: &'a XlaService,
        center: Vec<i32>,
        subst: Vec<f32>,
        alpha: usize,
        gap: f32,
    ) -> Result<Self> {
        anyhow::ensure!(subst.len() == alpha * alpha, "subst must be alpha^2");
        Ok(Self { svc, center, subst, alpha, gap })
    }

    /// True if some artifact bucket covers a query of `len` vs this center.
    pub fn covers(&self, len: usize) -> bool {
        self.svc.manifest().sw_bucket(len, self.center.len()).is_some()
    }

    /// Score `queries` against the center; returns one H matrix per query
    /// trimmed to its true lengths. Queries beyond every bucket error out —
    /// callers route those to the native Rust SW fallback.
    pub fn score(&self, queries: &[Vec<i32>]) -> Result<Vec<HMatrix>> {
        if queries.is_empty() {
            return Ok(Vec::new());
        }
        let n = self.center.len();
        let max_q = queries.iter().map(|q| q.len()).max().unwrap();
        let meta = self
            .svc
            .manifest()
            .sw_bucket(max_q, n)
            .ok_or_else(|| anyhow!("no SW bucket covers query={max_q} center={n}"))?;
        let (bb, bm, bn) = (meta.param("b")?, meta.param("m")?, meta.param("n")?);
        anyhow::ensure!(
            meta.param("alpha")? == self.alpha,
            "artifact alpha {} != batcher alpha {}",
            meta.param("alpha")?,
            self.alpha
        );

        // Pad the center once per call.
        let sentinel = (self.alpha - 1) as i32;
        let mut center_pad = self.center.clone();
        center_pad.resize(bn, sentinel);

        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(bb) {
            let mut a = vec![sentinel; bb * bm];
            for (k, q) in chunk.iter().enumerate() {
                anyhow::ensure!(q.len() <= bm, "query overflows bucket");
                a[k * bm..k * bm + q.len()].copy_from_slice(q);
            }
            let result = self
                .svc
                .execute(
                    &meta.name,
                    vec![
                        HostTensor::I32(a, vec![bb, bm]),
                        HostTensor::I32(center_pad.clone(), vec![bn]),
                        HostTensor::F32(self.subst.clone(), vec![self.alpha, self.alpha]),
                        HostTensor::F32(vec![self.gap], vec![1]),
                    ],
                )
                .context("executing SW artifact")?;
            let hd = result.as_f32()?;
            // hd layout: (bb, bm+bn+1, bm+1), diagonal-major per element.
            let dlen = bm + bn + 1;
            let lanes = bm + 1;
            for (k, q) in chunk.iter().enumerate() {
                let (m, nn) = (q.len(), n);
                let base = k * dlen * lanes;
                let mut data = vec![0f32; (m + 1) * (nn + 1)];
                for i in 0..=m {
                    for j in 0..=nn {
                        // H[i][j] = hd[i+j][i]
                        data[i * (nn + 1) + j] = hd[base + (i + j) * lanes + i];
                    }
                }
                out.push(HMatrix::from_data(m, nn, data));
            }
        }
        Ok(out)
    }
}

/// Batched pairwise match counts over aligned integer codes.
///
/// `codes` are N aligned rows of equal length L with values in [0, alpha-1);
/// rows/columns are padded to the bucket with `alpha - 1` (shared fill), and
/// the constant padding contribution is subtracted before returning.
/// Rows beyond the largest bucket must be split by the caller.
pub fn match_counts(
    svc: &XlaService,
    kind: ArtifactKind,
    codes: &[Vec<i32>],
    alpha: usize,
) -> Result<Vec<Vec<f32>>> {
    let rows = codes.len();
    if rows == 0 {
        return Ok(Vec::new());
    }
    let cols = codes[0].len();
    anyhow::ensure!(
        codes.iter().all(|r| r.len() == cols),
        "match_counts requires equal-length aligned rows"
    );
    let meta = svc
        .manifest()
        .match_bucket(kind, rows, cols)
        .ok_or_else(|| anyhow!("no match bucket covers {rows}x{cols}"))?;
    let (bn, bl) = (meta.param("n")?, meta.param("l")?);
    let fill = (alpha - 1) as i32;
    let mut buf = vec![fill; bn * bl];
    for (i, row) in codes.iter().enumerate() {
        buf[i * bl..i * bl + cols].copy_from_slice(row);
    }
    let result = svc
        .execute(&meta.name, vec![HostTensor::I32(buf, vec![bn, bl])])
        .context("executing match-count artifact")?;
    let g = result.as_f32()?;
    let pad_const = (bl - cols) as f32;
    let mut out = vec![vec![0f32; rows]; rows];
    for i in 0..rows {
        for j in 0..rows {
            out[i][j] = g[i * bn + j] - pad_const;
        }
    }
    Ok(out)
}

/// Batched k-mer profile squared distances. Rows pad with zeros (exact for
/// the Gram matrix; the padded rows' distances are sliced away).
pub fn kmer_sqdist(svc: &XlaService, profiles: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
    let rows = profiles.len();
    if rows == 0 {
        return Ok(Vec::new());
    }
    let dim = profiles[0].len();
    anyhow::ensure!(profiles.iter().all(|r| r.len() == dim));
    let meta = svc
        .manifest()
        .kmer_bucket(rows, dim)
        .ok_or_else(|| anyhow!("no kmer bucket covers {rows}x{dim}"))?;
    let (bn, bd) = (meta.param("n")?, meta.param("d")?);
    let mut buf = vec![0f32; bn * bd];
    for (i, row) in profiles.iter().enumerate() {
        buf[i * bd..i * bd + dim].copy_from_slice(row);
    }
    let result = svc.execute(&meta.name, vec![HostTensor::F32(buf, vec![bn, bd])])?;
    let d2 = result.as_f32()?;
    let mut out = vec![vec![0f32; rows]; rows];
    for i in 0..rows {
        for j in 0..rows {
            out[i][j] = d2[i * bn + j];
        }
    }
    Ok(out)
}

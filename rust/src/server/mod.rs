//! Web server — the paper's third headline contribution ("HAlign-II
//! provides a user-friendly web server based on our distributed computing
//! infrastructure", cf. http://lab.malab.cn/soft/halign).
//!
//! A dependency-free HTTP/1.1 server on `std::net::TcpListener`: each
//! request is parsed, dispatched to the shared [`Cluster`] (and optional
//! [`XlaService`]), and answered with plain text / FASTA / Newick.
//!
//! Endpoints:
//!   GET  /            — status page (cluster config, stats, artifacts)
//!   GET  /health      — liveness probe ("ok")
//!   POST /align       — body: FASTA; query: ?alphabet=dna|protein
//!                       returns the aligned FASTA + an X-Avg-SP header
//!   POST /tree        — body: aligned FASTA; returns Newick +
//!                       X-Log-Likelihood header
//!
//! One OS thread per connection (the engine inside serializes onto the
//! worker pool); requests are independent jobs, which is exactly the
//! paper's deployment model.

mod http;

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context as _, Result};

use crate::align::center_star::{align_nucleotide, CenterStarConfig};
use crate::align::protein::{align_protein, ProteinConfig};
use crate::engine::Cluster;
use crate::fasta::{io as fio, Alphabet};
use crate::runtime::XlaService;
use crate::tree::{build_tree, TreeConfig};

use http::{ReadError, Request, Response};

/// Socket-hygiene knobs: a public-facing endpoint must bound how long a
/// connection can stall and how large a body it will accept.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Per-connection read timeout: a half-sent request is dropped when
    /// it stalls this long, instead of pinning its thread forever.
    pub read_timeout: std::time::Duration,
    /// Per-connection write timeout for the response.
    pub write_timeout: std::time::Duration,
    /// Declared Content-Length cap; larger bodies are answered 413
    /// before a byte of them is read or buffered.
    pub max_body_bytes: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            read_timeout: std::time::Duration::from_secs(30),
            write_timeout: std::time::Duration::from_secs(30),
            max_body_bytes: 256 << 20,
        }
    }
}

pub struct Server {
    cluster: Cluster,
    svc: Option<XlaService>,
    options: ServerOptions,
    requests: AtomicUsize,
    shutdown: AtomicBool,
}

/// Handle for a running server (port + stop control).
pub struct RunningServer {
    pub port: u16,
    inner: Arc<Server>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl RunningServer {
    pub fn stop(mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // Nudge the accept loop.
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Server {
    pub fn new(cluster: Cluster, svc: Option<XlaService>) -> Arc<Self> {
        Self::with_options(cluster, svc, ServerOptions::default())
    }

    pub fn with_options(
        cluster: Cluster,
        svc: Option<XlaService>,
        options: ServerOptions,
    ) -> Arc<Self> {
        Arc::new(Self {
            cluster,
            svc,
            options,
            requests: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        })
    }

    /// Bind to `addr` (use port 0 for an ephemeral port) and serve on a
    /// background thread.
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<RunningServer> {
        let listener = TcpListener::bind(addr).context("binding server socket")?;
        let port = listener.local_addr()?.port();
        let inner = self.clone();
        let join = std::thread::Builder::new()
            .name("halign2-http".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if inner.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let server = inner.clone();
                    std::thread::spawn(move || {
                        let _ = server.handle(stream);
                    });
                }
            })?;
        Ok(RunningServer { port, inner: self, join: Some(join) })
    }

    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        // Socket deadlines first: without them a half-sent request (or a
        // reader that never drains the response) pins this thread for
        // the life of the peer.
        stream.set_read_timeout(Some(self.options.read_timeout))?;
        stream.set_write_timeout(Some(self.options.write_timeout))?;
        let request = match Request::read_from(&mut stream, self.options.max_body_bytes) {
            Ok(r) => r,
            Err(e @ ReadError::TooLarge { .. }) => {
                let resp = Response::text(413, &format!("{e}\n"));
                stream.write_all(&resp.to_bytes())?;
                return Ok(());
            }
            Err(e) => {
                let resp = Response::text(400, &format!("bad request: {e}\n"));
                stream.write_all(&resp.to_bytes())?;
                return Ok(());
            }
        };
        self.requests.fetch_add(1, Ordering::Relaxed);
        let resp = self.route(&request).unwrap_or_else(|e| {
            Response::text(500, &format!("error: {e:#}\n"))
        });
        stream.write_all(&resp.to_bytes())?;
        Ok(())
    }

    fn route(&self, req: &Request) -> Result<Response> {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => Ok(Response::text(200, "ok\n")),
            ("GET", "/") => Ok(self.status_page()),
            ("POST", "/align") => self.do_align(req),
            ("POST", "/tree") => self.do_tree(req),
            _ => Ok(Response::text(404, "not found\n")),
        }
    }

    fn alphabet_of(req: &Request) -> Alphabet {
        match req.query.get("alphabet").map(String::as_str) {
            Some("protein") => Alphabet::Protein,
            _ => Alphabet::Dna,
        }
    }

    fn do_align(&self, req: &Request) -> Result<Response> {
        let alphabet = Self::alphabet_of(req);
        let seqs = fio::read_fasta(req.body.as_slice(), alphabet)?;
        anyhow::ensure!(!seqs.is_empty(), "empty FASTA body");
        let msa = match alphabet {
            Alphabet::Dna => {
                align_nucleotide(&self.cluster, &seqs, &CenterStarConfig::default())?
            }
            Alphabet::Protein => {
                align_protein(&self.cluster, &seqs, self.svc.as_ref(), &ProteinConfig::default())?
            }
        };
        let sp = msa.avg_sp_distributed(&self.cluster)?;
        let mut body = Vec::new();
        fio::write_fasta(&mut body, &msa.aligned)?;
        let mut resp = Response::bytes(200, "text/x-fasta", body);
        resp.headers.push(("X-Avg-SP".into(), format!("{sp:.4}")));
        resp.headers.push(("X-Width".into(), msa.width.to_string()));
        Ok(resp)
    }

    fn do_tree(&self, req: &Request) -> Result<Response> {
        let alphabet = Self::alphabet_of(req);
        let rows = fio::read_fasta(req.body.as_slice(), alphabet)?;
        let result = build_tree(&self.cluster, &rows, self.svc.as_ref(), &TreeConfig::default())?;
        let mut resp = Response::text(200, &format!("{}\n", result.tree.to_newick()));
        resp.headers.push((
            "X-Log-Likelihood".into(),
            format!("{:.4}", result.log_likelihood),
        ));
        resp.headers
            .push(("X-Clusters".into(), result.num_clusters.to_string()));
        Ok(resp)
    }

    fn status_page(&self) -> Response {
        let stats = self.cluster.stats();
        let artifacts = self
            .svc
            .as_ref()
            .map(|s| s.executables().join(", "))
            .unwrap_or_else(|| "(native fallback)".into());
        Response::text(
            200,
            &format!(
                "halign2 web server\n\
                 ==================\n\
                 workers:        {}\n\
                 backend:        {}\n\
                 requests:       {}\n\
                 tasks run:      {}\n\
                 shuffle bytes:  {} written / {} read\n\
                 avg max memory: {:.2} MB/worker\n\
                 artifacts:      {}\n\n\
                 POST /align (FASTA body, ?alphabet=dna|protein)\n\
                 POST /tree  (aligned FASTA body)\n",
                stats.workers,
                self.cluster.backend(),
                self.requests.load(Ordering::Relaxed),
                stats.tasks_run,
                stats.shuffle_bytes_written,
                stats.shuffle_bytes_read,
                stats.avg_max_memory_bytes / (1 << 20) as f64,
                artifacts,
            ),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ClusterConfig;
    use std::io::{Read, Write};

    fn start() -> RunningServer {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        Server::new(cluster, None).serve("127.0.0.1:0").unwrap()
    }

    fn talk(port: u16, raw: &str) -> String {
        let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_and_status() {
        let srv = start();
        let resp = talk(srv.port, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.ends_with("ok\n"));
        let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(status.contains("halign2 web server"));
        assert!(status.contains("workers:        2"));
        srv.stop();
    }

    #[test]
    fn align_roundtrip_over_http() {
        let srv = start();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTACGTAA\n";
        let req = format!(
            "POST /align?alphabet=dna HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("X-Avg-SP:"));
        assert!(resp.contains(">a\n"), "aligned FASTA returned");
        srv.stop();
    }

    #[test]
    fn tree_endpoint_returns_newick() {
        let srv = start();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTTA\n>c\nACGAACGTAA\n>d\nACGTACGGAA\n";
        let req = format!(
            "POST /tree HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("X-Log-Likelihood:"));
        assert!(resp.trim_end().ends_with(");"), "newick body: {resp}");
        srv.stop();
    }

    #[test]
    fn half_sent_request_is_dropped_not_hung() {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        let opts = ServerOptions {
            read_timeout: std::time::Duration::from_millis(200),
            ..ServerOptions::default()
        };
        let srv = Server::with_options(cluster, None, opts).serve("127.0.0.1:0").unwrap();
        let start = std::time::Instant::now();
        let mut s = TcpStream::connect(("127.0.0.1", srv.port)).unwrap();
        // Declare a 10-byte body but send only 2 bytes and stall.
        s.write_all(b"POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 10\r\n\r\nAC")
            .unwrap();
        let mut out = String::new();
        // The server must time the read out, answer 400 and close the
        // connection — not hold the thread (and this read) forever.
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        assert!(
            start.elapsed() < std::time::Duration::from_secs(10),
            "half-sent request must be dropped by the read timeout"
        );
        srv.stop();
    }

    #[test]
    fn oversized_body_gets_413() {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        let opts = ServerOptions { max_body_bytes: 1024, ..ServerOptions::default() };
        let srv = Server::with_options(cluster, None, opts).serve("127.0.0.1:0").unwrap();
        let resp = talk(
            srv.port,
            "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 10000\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        assert!(resp.contains("Payload Too Large"), "{resp}");
        srv.stop();
    }

    #[test]
    fn bad_requests_get_4xx() {
        let srv = start();
        let resp = talk(srv.port, "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nACGT");
        assert!(resp.starts_with("HTTP/1.1 500"), "{resp}"); // headerless FASTA
        let resp = talk(srv.port, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"));
        srv.stop();
    }
}

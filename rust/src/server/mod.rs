//! Web server — the paper's third headline contribution ("HAlign-II
//! provides a user-friendly web server based on our distributed computing
//! infrastructure", cf. http://lab.malab.cn/soft/halign).
//!
//! A dependency-free HTTP/1.1 server on `std::net::TcpListener`: each
//! request is parsed, dispatched to the shared [`Cluster`] (and optional
//! [`XlaService`]), and answered with plain text / FASTA / Newick.
//!
//! Endpoints:
//!   GET  /            — status page (cluster config, stats, artifacts,
//!                       per-route latency percentiles)
//!   GET  /health      — liveness probe ("ok")
//!   GET  /metrics     — Prometheus text exposition of the cluster's
//!                       obs registry (engine + I/O + server families)
//!   GET  /trace/<h>   — Chrome trace-event JSON for job hash `<h>`
//!                       (recorded when the cluster's trace rings are
//!                       enabled; load in Perfetto / chrome://tracing)
//!   GET  /profile/<h> — post-hoc profile of a traced job: aggregate
//!                       self-time table, scheduler gap analysis, and
//!                       the critical path with `critical_path_frac`;
//!                       append `/flame` for collapsed-stack text
//!                       (pipe into any flamegraph renderer)
//!   POST /align       — body: FASTA; query: ?alphabet=dna|protein
//!                       returns the aligned FASTA + an X-Avg-SP header
//!   POST /tree        — body: aligned FASTA; returns Newick +
//!                       X-Log-Likelihood header
//!
//! Every response carries `X-Request-Id`; request latency is recorded
//! into `halign_request_seconds{route,cache}` histograms (the status
//! page renders their p50/p95/p99).  Malformed bodies (unparsable or
//! empty FASTA, bad `parent` hash) are client errors — 400 with a
//! reason line — while engine faults stay 500.
//!
//! One OS thread per connection (the engine inside serializes onto the
//! worker pool); requests are independent jobs, which is exactly the
//! paper's deployment model.
//!
//! DNA `/align` jobs are memoized in a content-hash result cache
//! ([`crate::cache`]): an exact resubmission (same sequences, any
//! formatting) is served by rendering the stored [`MsaArtifact`] locally
//! — the engine never runs — and `?parent=<job hash>` appends the body's
//! sequences onto a cached parent alignment in O(new work).  Every DNA
//! response carries `X-Job-Hash` (the digest to pass back as `parent`)
//! and `X-Cache: hit|append|miss`.

mod http;

use std::collections::VecDeque;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context as _, Result};

use crate::align::append::{append_nucleotide, MsaArtifact};
use crate::align::center_star::{align_nucleotide_with_artifact, CenterStarConfig};
use crate::align::protein::{align_protein, ProteinConfig};
use crate::align::MsaResult;
use crate::cache::{canonical_digest, ArtifactStore, DigestBuilder};
use crate::engine::Cluster;
use crate::fasta::{io as fio, Alphabet};
use crate::obs::{
    chrome_trace_json, Counter, Gauge, Histogram, Profile, Registry, TraceEvent, TraceKind,
};
use crate::runtime::XlaService;
use crate::tree::{build_tree, TreeConfig};

use http::{ReadError, Request, Response};

/// Route labels of the request metric families (fixed vocabulary so
/// `/metrics` cardinality is bounded no matter what paths clients probe).
const ROUTES: [&str; 8] =
    ["align", "tree", "health", "status", "metrics", "trace", "profile", "other"];

/// `cache` label values of `halign_request_seconds` (`X-Cache` outcomes
/// on `/align`; everything else records under "none").
const CACHE_OUTCOMES: [&str; 4] = ["hit", "append", "miss", "none"];

/// Exported traces retained for `GET /trace/<job-hash>` and
/// `GET /profile/<job-hash>` (one per engine job, oldest evicted).
const TRACE_KEEP: usize = 16;

/// One retained engine-job trace: the rendered Chrome JSON plus the raw
/// drained events, kept so `/profile/<hash>` can aggregate, classify
/// gaps, and extract the critical path on demand.
struct RetainedTrace {
    key: u64,
    chrome_json: String,
    events: Vec<TraceEvent>,
    num_lanes: usize,
}

/// Server-side metric families, registered in the *cluster's* registry
/// at construction — a fresh server's `/metrics` already lists every
/// family, and engine + server metrics share one scrape surface.  All
/// label instances are pre-registered here (handles stored, lookups are
/// array scans), so the request path never takes the registry mutex.
struct ServerObs {
    requests: Vec<(&'static str, Arc<Counter>)>,
    latency: Vec<(&'static str, &'static str, Arc<Histogram>)>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    cache_appends: Arc<Counter>,
    cache_resident_bytes: Arc<Gauge>,
    cache_resident_bytes_peak: Arc<Gauge>,
    cache_entries: Arc<Gauge>,
    cache_spill_files: Arc<Gauge>,
}

impl ServerObs {
    fn register(registry: &Registry) -> Self {
        let mut requests = Vec::new();
        let mut latency = Vec::new();
        for route in ROUTES {
            requests.push((
                route,
                registry.register_counter_labeled(
                    "halign_requests_total",
                    "HTTP requests by route",
                    &[("route", route)],
                ),
            ));
            // /align gets a histogram per X-Cache outcome; every other
            // route only ever records under cache="none".
            let outcomes: &[&'static str] =
                if route == "align" { &CACHE_OUTCOMES } else { &["none"] };
            for outcome in outcomes {
                latency.push((
                    route,
                    *outcome,
                    registry.register_histogram_labeled(
                        "halign_request_seconds",
                        "HTTP request latency by route and cache outcome",
                        &[("route", route), ("cache", outcome)],
                    ),
                ));
            }
        }
        Self {
            requests,
            latency,
            cache_hits: registry.register_counter(
                "halign_cache_hits_total",
                "POST /align requests answered from the result cache",
            ),
            cache_misses: registry.register_counter(
                "halign_cache_misses_total",
                "POST /align requests that ran the full engine job",
            ),
            cache_appends: registry.register_counter(
                "halign_cache_appends_total",
                "POST /align?parent= requests served by profile-append",
            ),
            cache_resident_bytes: registry.register_gauge(
                "halign_cache_resident_bytes",
                "Result-cache bytes resident in memory (scrape-time)",
            ),
            cache_resident_bytes_peak: registry.register_gauge(
                "halign_cache_resident_bytes_peak",
                "Result-cache resident-bytes high-water mark (scrape-time)",
            ),
            cache_entries: registry.register_gauge(
                "halign_cache_entries",
                "Result-cache artifacts stored (scrape-time)",
            ),
            cache_spill_files: registry.register_gauge(
                "halign_cache_spill_files",
                "Result-cache artifacts spilled to disk (scrape-time)",
            ),
        }
    }

    fn count_request(&self, route: &str) {
        if let Some((_, c)) = self.requests.iter().find(|(r, _)| *r == route) {
            c.inc();
        }
    }

    fn record_latency(&self, route: &str, outcome: &str, nanos: u64) {
        let hist = self
            .latency
            .iter()
            .find(|(r, o, _)| *r == route && *o == outcome)
            .or_else(|| self.latency.iter().find(|(r, o, _)| *r == route && *o == "none"));
        if let Some((_, _, h)) = hist {
            h.record(nanos);
        }
    }
}

/// Which metric route label a request records under (bounded vocabulary;
/// unknown paths all land in "other").
fn route_label(method: &str, path: &str) -> &'static str {
    match (method, path) {
        ("POST", "/align") => "align",
        ("POST", "/tree") => "tree",
        ("GET", "/health") => "health",
        ("GET", "/") => "status",
        ("GET", "/metrics") => "metrics",
        _ if path.starts_with("/trace/") => "trace",
        _ if path.starts_with("/profile/") => "profile",
        _ => "other",
    }
}

/// Socket-hygiene knobs: a public-facing endpoint must bound how long a
/// connection can stall and how large a body it will accept.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Per-connection read timeout: a half-sent request is dropped when
    /// it stalls this long, instead of pinning its thread forever.
    pub read_timeout: std::time::Duration,
    /// Per-connection write timeout for the response.
    pub write_timeout: std::time::Duration,
    /// Declared Content-Length cap; larger bodies are answered 413
    /// before a byte of them is read or buffered.
    pub max_body_bytes: usize,
    /// Resident byte budget of the DNA alignment result cache; evicted
    /// artifacts spill to disk and stay servable.
    pub cache_budget_bytes: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            read_timeout: std::time::Duration::from_secs(30),
            write_timeout: std::time::Duration::from_secs(30),
            max_body_bytes: 256 << 20,
            cache_budget_bytes: 64 << 20,
        }
    }
}

pub struct Server {
    cluster: Cluster,
    svc: Option<XlaService>,
    options: ServerOptions,
    cache: ArtifactStore,
    obs: ServerObs,
    /// Exported engine traces by job hash, newest-last (bounded at
    /// [`TRACE_KEEP`]); only populated when the cluster's trace rings
    /// are enabled.
    traces: Mutex<VecDeque<RetainedTrace>>,
    requests: AtomicUsize,
    shutdown: AtomicBool,
}

/// Handle for a running server (port + stop control).
pub struct RunningServer {
    pub port: u16,
    inner: Arc<Server>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl RunningServer {
    pub fn stop(mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // Nudge the accept loop.
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Server {
    pub fn new(cluster: Cluster, svc: Option<XlaService>) -> Result<Arc<Self>> {
        Self::with_options(cluster, svc, ServerOptions::default())
    }

    pub fn with_options(
        cluster: Cluster,
        svc: Option<XlaService>,
        options: ServerOptions,
    ) -> Result<Arc<Self>> {
        static CACHE_DIR_SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "halign2-server-cache-{}-{}",
            std::process::id(),
            CACHE_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let cache = ArtifactStore::new(dir, options.cache_budget_bytes)?;
        let obs = ServerObs::register(cluster.registry());
        Ok(Arc::new(Self {
            cluster,
            svc,
            options,
            cache,
            obs,
            traces: Mutex::new(VecDeque::new()),
            requests: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        }))
    }

    /// Bind to `addr` (use port 0 for an ephemeral port) and serve on a
    /// background thread.
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<RunningServer> {
        let listener = TcpListener::bind(addr).context("binding server socket")?;
        let port = listener.local_addr()?.port();
        let inner = self.clone();
        let join = std::thread::Builder::new()
            .name("halign2-http".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if inner.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let server = inner.clone();
                    std::thread::spawn(move || {
                        let _ = server.handle(stream);
                    });
                }
            })?;
        Ok(RunningServer { port, inner: self, join: Some(join) })
    }

    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        // Socket deadlines first: without them a half-sent request (or a
        // reader that never drains the response) pins this thread for
        // the life of the peer.
        stream.set_read_timeout(Some(self.options.read_timeout))?;
        stream.set_write_timeout(Some(self.options.write_timeout))?;
        // The request id is allocated before the read so *every*
        // response path carries it — including the 413 body-cap and 400
        // parse-error branches below, which never reach the router.
        let seq = self.requests.fetch_add(1, Ordering::Relaxed);
        let request_id = format!("{:x}-{seq:06x}", std::process::id());
        let request = match Request::read_from(&mut stream, self.options.max_body_bytes) {
            Ok(r) => r,
            Err(e @ ReadError::TooLarge { .. }) => {
                let mut resp = Response::text(413, &format!("{e}\n"));
                resp.headers.push(("X-Request-Id".into(), request_id));
                stream.write_all(&resp.to_bytes())?;
                return Ok(());
            }
            Err(e) => {
                let mut resp = Response::text(400, &format!("bad request: {e}\n"));
                resp.headers.push(("X-Request-Id".into(), request_id));
                stream.write_all(&resp.to_bytes())?;
                return Ok(());
            }
        };
        let route = route_label(&request.method, &request.path);
        let started = Instant::now();
        let mut resp = self.route(&request).unwrap_or_else(|e| {
            Response::text(500, &format!("error: {e:#}\n"))
        });
        // Latency lands in the route's histogram keyed by the X-Cache
        // outcome the response carries (cache="none" elsewhere), so
        // hit/append/miss tails are separable on the status page.
        let outcome = resp
            .headers
            .iter()
            .find(|(k, _)| k == "X-Cache")
            .map(|(_, v)| v.as_str())
            .unwrap_or("none")
            .to_string();
        self.obs.count_request(route);
        self.obs.record_latency(route, &outcome, started.elapsed().as_nanos() as u64);
        resp.headers.push(("X-Request-Id".into(), request_id));
        stream.write_all(&resp.to_bytes())?;
        Ok(())
    }

    fn route(&self, req: &Request) -> Result<Response> {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => Ok(Response::text(200, "ok\n")),
            ("GET", "/") => Ok(self.status_page()),
            ("GET", "/metrics") => Ok(self.do_metrics()),
            ("GET", p) if p.starts_with("/trace/") => Ok(self.do_trace(p)),
            ("GET", p) if p.starts_with("/profile/") => Ok(self.do_profile(p)),
            ("POST", "/align") => self.do_align(req),
            ("POST", "/tree") => self.do_tree(req),
            _ => Ok(Response::text(404, "not found\n")),
        }
    }

    /// Prometheus text exposition of the cluster-wide registry.  The
    /// result-cache gauges are sampled here (scrape-time values), then
    /// every family renders in one pass.
    fn do_metrics(&self) -> Response {
        self.obs.cache_resident_bytes.set(self.cache.resident_bytes() as u64);
        self.obs.cache_resident_bytes_peak.set(self.cache.peak_resident_bytes() as u64);
        self.obs.cache_entries.set(self.cache.entries() as u64);
        self.obs.cache_spill_files.set(self.cache.spill_files_written() as u64);
        Response::bytes(
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            self.cluster.registry().render_prometheus().into_bytes(),
        )
    }

    /// Chrome trace-event JSON for a completed engine job; 404 for
    /// unknown hashes or when tracing is disabled.
    fn do_trace(&self, path: &str) -> Response {
        let hex = path.trim_start_matches("/trace/");
        let Ok(key) = u64::from_str_radix(hex, 16) else {
            return Response::text(400, &format!("bad request: bad job hash {hex:?}\n"));
        };
        let traces = self.traces.lock().unwrap();
        match traces.iter().find(|t| t.key == key) {
            Some(t) => {
                Response::bytes(200, "application/json", t.chrome_json.clone().into_bytes())
            }
            None => Response::text(404, &format!("no trace for job {key:016x}\n")),
        }
    }

    /// Post-hoc profile of a retained trace: `GET /profile/<hash>`
    /// answers the aggregate/gaps/critical-path JSON,
    /// `GET /profile/<hash>/flame` the collapsed-stack text.  Computed
    /// on demand from the retained raw events.
    fn do_profile(&self, path: &str) -> Response {
        let rest = path.trim_start_matches("/profile/");
        let (hex, flame) = match rest.strip_suffix("/flame") {
            Some(h) => (h, true),
            None => (rest, false),
        };
        let Ok(key) = u64::from_str_radix(hex, 16) else {
            return Response::text(400, &format!("bad request: bad job hash {hex:?}\n"));
        };
        let traces = self.traces.lock().unwrap();
        let Some(t) = traces.iter().find(|t| t.key == key) else {
            return Response::text(404, &format!("no trace for job {key:016x}\n"));
        };
        let profile = Profile::from_events(&t.events, t.num_lanes);
        if flame {
            Response::text(200, &profile.collapsed_stack())
        } else {
            Response::bytes(200, "application/json", profile.to_json().into_bytes())
        }
    }

    /// After an engine job ran for job `key`, drain the trace rings and
    /// retain both the Chrome JSON (for `GET /trace/<key>`) and the raw
    /// events (for `GET /profile/<key>`); no-op when the cluster's
    /// trace rings are disabled.
    fn retain_trace(&self, key: u64) {
        let sink = self.cluster.trace();
        if !sink.enabled() {
            return;
        }
        let events = sink.drain_new();
        let num_lanes = sink.num_lanes();
        let chrome_json = chrome_trace_json(&events, num_lanes);
        let mut traces = self.traces.lock().unwrap();
        traces.retain(|t| t.key != key);
        traces.push_back(RetainedTrace { key, chrome_json, events, num_lanes });
        while traces.len() > TRACE_KEEP {
            traces.pop_front();
        }
    }

    /// Cache-outcome bookkeeping shared by the `/align` paths: the
    /// obs counter plus a trace instant on the driver lane.
    fn note_cache_outcome(&self, outcome: &str, key: u64) {
        let (counter, kind) = match outcome {
            "hit" => (&self.obs.cache_hits, TraceKind::CacheHit),
            "append" => (&self.obs.cache_appends, TraceKind::CacheAppend),
            _ => (&self.obs.cache_misses, TraceKind::CacheMiss),
        };
        counter.inc();
        let sink = self.cluster.trace();
        sink.emit(sink.num_lanes().saturating_sub(1), kind, key);
    }

    fn alphabet_of(req: &Request) -> Alphabet {
        match req.query.get("alphabet").map(String::as_str) {
            Some("protein") => Alphabet::Protein,
            _ => Alphabet::Dna,
        }
    }

    /// Parse the request body as FASTA, classifying failures as client
    /// errors: an unparsable or empty body is the submitter's fault and
    /// answers 400 with the reason, never a 500 (engine faults keep
    /// that status).
    fn parse_fasta_body(req: &Request, alphabet: Alphabet) -> Result<Vec<crate::fasta::Sequence>, Response> {
        match fio::read_fasta(req.body.as_slice(), alphabet) {
            Ok(seqs) if seqs.is_empty() => {
                Err(Response::text(400, "bad request: empty FASTA body\n"))
            }
            Ok(seqs) => Ok(seqs),
            Err(e) => Err(Response::text(400, &format!("bad request: {e:#}\n"))),
        }
    }

    fn do_align(&self, req: &Request) -> Result<Response> {
        let alphabet = Self::alphabet_of(req);
        let seqs = match Self::parse_fasta_body(req, alphabet) {
            Ok(seqs) => seqs,
            Err(resp) => return Ok(resp),
        };
        match alphabet {
            Alphabet::Dna => self.align_dna(req, seqs),
            Alphabet::Protein => {
                let msa = align_protein(
                    &self.cluster,
                    &seqs,
                    self.svc.as_ref(),
                    &ProteinConfig::default(),
                )?;
                let sp = msa.avg_sp_distributed(&self.cluster)?;
                Self::msa_response(&msa, sp)
            }
        }
    }

    fn msa_response(msa: &MsaResult, sp: f64) -> Result<Response> {
        let mut body = Vec::new();
        fio::write_fasta(&mut body, &msa.aligned)?;
        let mut resp = Response::bytes(200, "text/x-fasta", body);
        resp.headers.push(("X-Avg-SP".into(), format!("{sp:.4}")));
        resp.headers.push(("X-Width".into(), msa.width.to_string()));
        Ok(resp)
    }

    /// Look up `key` and decode it; a corrupt or version-skewed blob is a
    /// miss (the job recomputes and overwrites it), never an error.
    fn cached_artifact(&self, key: u64) -> Option<MsaArtifact> {
        let bytes = self.cache.get(key).ok()??;
        MsaArtifact::from_bytes(&bytes).ok()
    }

    /// DNA alignment with content-hash memoization (see module docs):
    /// `?parent=<hash>` appends the body onto a cached parent job,
    /// otherwise the submission digest is looked up before the engine is
    /// touched.
    fn align_dna(&self, req: &Request, seqs: Vec<crate::fasta::Sequence>) -> Result<Response> {
        if let Some(parent_hex) = req.query.get("parent") {
            let Ok(parent_key) = u64::from_str_radix(parent_hex, 16) else {
                return Ok(Response::text(
                    400,
                    &format!("bad request: bad parent job hash {parent_hex:?}\n"),
                ));
            };
            let Some(parent) = self.cached_artifact(parent_key) else {
                return Ok(Response::text(
                    404,
                    &format!("unknown parent job {parent_key:016x}\n"),
                ));
            };
            // The union job's identity: parent rows ++ appended rows.
            let mut b = DigestBuilder::new();
            for row in &parent.rows {
                b.record(&row.id, &row.codes, parent.alphabet);
            }
            for s in &seqs {
                b.push(s);
            }
            let union_key = b.finish();
            if let Some(art) = self.cached_artifact(union_key) {
                let msa = art.render()?;
                let sp = msa.avg_sp()?;
                let mut resp = Self::msa_response(&msa, sp)?;
                Self::cache_headers(&mut resp, "hit", union_key);
                self.note_cache_outcome("hit", union_key);
                return Ok(resp);
            }
            let out = append_nucleotide(&self.cluster, &parent, &seqs, None)?;
            self.cache.put(union_key, out.artifact.to_bytes())?;
            let sp = out.msa.avg_sp_distributed(&self.cluster)?;
            let mut resp = Self::msa_response(&out.msa, sp)?;
            Self::cache_headers(&mut resp, "append", union_key);
            self.note_cache_outcome("append", union_key);
            self.retain_trace(union_key);
            return Ok(resp);
        }

        let key = canonical_digest(&seqs);
        if let Some(art) = self.cached_artifact(key) {
            // Hit: render locally — no engine job runs at all.
            let msa = art.render()?;
            let sp = msa.avg_sp()?;
            let mut resp = Self::msa_response(&msa, sp)?;
            Self::cache_headers(&mut resp, "hit", key);
            self.note_cache_outcome("hit", key);
            return Ok(resp);
        }
        let (msa, artifact) =
            align_nucleotide_with_artifact(&self.cluster, &seqs, &CenterStarConfig::default())?;
        self.cache.put(key, artifact.to_bytes())?;
        let sp = msa.avg_sp_distributed(&self.cluster)?;
        let mut resp = Self::msa_response(&msa, sp)?;
        Self::cache_headers(&mut resp, "miss", key);
        self.note_cache_outcome("miss", key);
        self.retain_trace(key);
        Ok(resp)
    }

    fn cache_headers(resp: &mut Response, outcome: &str, key: u64) {
        resp.headers.push(("X-Cache".into(), outcome.into()));
        resp.headers.push(("X-Job-Hash".into(), format!("{key:016x}")));
    }

    fn do_tree(&self, req: &Request) -> Result<Response> {
        let alphabet = Self::alphabet_of(req);
        let rows = match Self::parse_fasta_body(req, alphabet) {
            Ok(rows) => rows,
            Err(resp) => return Ok(resp),
        };
        let result = build_tree(&self.cluster, &rows, self.svc.as_ref(), &TreeConfig::default())?;
        let mut resp = Response::text(200, &format!("{}\n", result.tree.to_newick()));
        resp.headers.push((
            "X-Log-Likelihood".into(),
            format!("{:.4}", result.log_likelihood),
        ));
        resp.headers
            .push(("X-Clusters".into(), result.num_clusters.to_string()));
        Ok(resp)
    }

    /// Per-instance p50/p95/p99 lines of `halign_request_seconds` with
    /// at least one observation, e.g.
    /// `  route="align",cache="miss"  p50=12.4ms p95=30.1ms p99=30.1ms n=3`.
    fn latency_block(&self) -> String {
        let mut out = String::new();
        for (labels, hist) in self.cluster.registry().histograms("halign_request_seconds") {
            let snap = hist.snapshot();
            if snap.count == 0 {
                continue;
            }
            out.push_str(&format!(
                "  {labels}  p50={:.3}ms p95={:.3}ms p99={:.3}ms n={}\n",
                snap.percentile(0.50) as f64 / 1e6,
                snap.percentile(0.95) as f64 / 1e6,
                snap.percentile(0.99) as f64 / 1e6,
                snap.count,
            ));
        }
        if out.is_empty() {
            out.push_str("  (no requests observed yet)\n");
        }
        out
    }

    /// One-line profile summary of the most recently traced job:
    /// `critical_path_frac` plus the top-3 self-time stages (the same
    /// numbers `GET /profile/<hash>` serves in full).
    fn profile_block(&self) -> String {
        let traces = self.traces.lock().unwrap();
        let Some(t) = traces.back() else {
            return "  (no traced jobs yet)\n".into();
        };
        let p = Profile::from_events(&t.events, t.num_lanes);
        let tops = p
            .top_self_stages(3)
            .iter()
            .map(|(stage, nanos)| format!("stage{stage}={:.3}ms", *nanos as f64 / 1e6))
            .collect::<Vec<_>>()
            .join(" ");
        format!(
            "  job {:016x}: critical_path_frac={:.3} top_self: {}\n",
            t.key,
            p.critical_path_frac,
            if tops.is_empty() { "(none)".into() } else { tops },
        )
    }

    fn status_page(&self) -> Response {
        let stats = self.cluster.stats();
        let artifacts = self
            .svc
            .as_ref()
            .map(|s| s.executables().join(", "))
            .unwrap_or_else(|| "(native fallback)".into());
        Response::text(
            200,
            &format!(
                "halign2 web server\n\
                 ==================\n\
                 workers:        {}\n\
                 backend:        {}\n\
                 requests:       {}\n\
                 tasks run:      {}\n\
                 task latency:   p50={:.3}ms p99={:.3}ms\n\
                 shuffle bytes:  {} written / {} read\n\
                 avg max memory: {:.2} MB/worker\n\
                 artifacts:      {}\n\
                 result cache:   {} jobs, {} hits / {} misses, {} resident bytes (budget {})\n\
                 request latency (from halign_request_seconds):\n\
                 {}\
                 last traced job (from /profile):\n\
                 {}\n\
                 GET  /metrics (Prometheus text format)\n\
                 GET  /trace/<job hash> (Chrome trace JSON, when tracing is on)\n\
                 GET  /profile/<job hash> (profile JSON; append /flame for collapsed stacks)\n\
                 POST /align (FASTA body, ?alphabet=dna|protein, ?parent=<job hash>)\n\
                 POST /tree  (aligned FASTA body)\n",
                stats.workers,
                self.cluster.backend(),
                self.requests.load(Ordering::Relaxed),
                stats.tasks_run,
                stats.task_p50_ms,
                stats.task_p99_ms,
                stats.shuffle_bytes_written,
                stats.shuffle_bytes_read,
                stats.avg_max_memory_bytes / (1 << 20) as f64,
                artifacts,
                self.cache.entries(),
                self.cache.hits(),
                self.cache.misses(),
                self.cache.resident_bytes(),
                self.cache.byte_budget(),
                self.latency_block(),
                self.profile_block(),
            ),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ClusterConfig;
    use std::io::{Read, Write};

    fn start() -> RunningServer {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        Server::new(cluster, None).unwrap().serve("127.0.0.1:0").unwrap()
    }

    fn talk(port: u16, raw: &str) -> String {
        let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_and_status() {
        let srv = start();
        let resp = talk(srv.port, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.ends_with("ok\n"));
        let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(status.contains("halign2 web server"));
        assert!(status.contains("workers:        2"));
        srv.stop();
    }

    #[test]
    fn align_roundtrip_over_http() {
        let srv = start();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTACGTAA\n";
        let req = format!(
            "POST /align?alphabet=dna HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("X-Avg-SP:"));
        assert!(resp.contains(">a\n"), "aligned FASTA returned");
        srv.stop();
    }

    fn header_value<'a>(resp: &'a str, name: &str) -> &'a str {
        resp.lines()
            .find_map(|l| l.strip_prefix(&format!("{name}: ")))
            .unwrap_or_else(|| panic!("missing header {name} in {resp}"))
            .trim_end()
    }

    fn body_of(resp: &str) -> &str {
        resp.split_once("\r\n\r\n").expect("no body").1
    }

    #[test]
    fn resubmission_hits_the_cache_bit_identically_without_engine_work() {
        let srv = start();
        let post = |path: &str, body: &str| {
            talk(
                srv.port,
                &format!(
                    "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                ),
            )
        };
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTTCGTAA\n";
        let first = post("/align", fasta);
        assert!(first.starts_with("HTTP/1.1 200"), "{first}");
        assert_eq!(header_value(&first, "X-Cache"), "miss");
        let tasks_after_miss: usize = {
            let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
            header_like(&status, "tasks run:")
        };
        // Same job, different formatting: must hit and return the exact
        // same bytes, without running a single engine task.
        let reformatted = ">a trailing words\nacgtACGTAA\n>b\nACGT\nACGTA\n>c\nACGTTCGTAA\n";
        let second = post("/align", reformatted);
        assert_eq!(header_value(&second, "X-Cache"), "hit", "{second}");
        assert_eq!(header_value(&first, "X-Job-Hash"), header_value(&second, "X-Job-Hash"));
        assert_eq!(body_of(&first), body_of(&second), "hit must be bit-identical");
        let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(
            header_like(&status, "tasks run:"),
            tasks_after_miss,
            "a cache hit must not touch the engine"
        );
        srv.stop();
    }

    fn header_like(status: &str, label: &str) -> usize {
        status
            .lines()
            .find_map(|l| l.trim().strip_prefix(label))
            .and_then(|v| v.trim().split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("no {label} in {status}"))
    }

    #[test]
    fn append_extends_a_cached_job_and_matches_the_union() {
        let srv = start();
        let post = |path: &str, body: &str| {
            talk(
                srv.port,
                &format!(
                    "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                ),
            )
        };
        let base = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTTCGTAA\n";
        let extra = ">d\nACGGACGTAA\n>e\nACGTACGTAAT\n";
        let first = post("/align", base);
        let parent = header_value(&first, "X-Job-Hash").to_string();
        let appended = post(&format!("/align?parent={parent}"), extra);
        assert!(appended.starts_with("HTTP/1.1 200"), "{appended}");
        assert_eq!(header_value(&appended, "X-Cache"), "append");
        // From-scratch on the union was cached under the union digest by
        // the append, so posting the union now must *hit* and agree
        // byte-for-byte — the incremental path equals the full job.
        let union = format!("{base}{extra}");
        let scratch = post("/align", &union);
        assert_eq!(header_value(&scratch, "X-Cache"), "hit", "{scratch}");
        assert_eq!(header_value(&scratch, "X-Job-Hash"), header_value(&appended, "X-Job-Hash"));
        assert_eq!(body_of(&scratch), body_of(&appended));
        // An unknown parent is a clean 404, not a recompute.
        let nope = post("/align?parent=00000000deadbeef", extra);
        assert!(nope.starts_with("HTTP/1.1 404"), "{nope}");
        srv.stop();
    }

    #[test]
    fn tree_endpoint_returns_newick() {
        let srv = start();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTTA\n>c\nACGAACGTAA\n>d\nACGTACGGAA\n";
        let req = format!(
            "POST /tree HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("X-Log-Likelihood:"));
        assert!(resp.trim_end().ends_with(");"), "newick body: {resp}");
        srv.stop();
    }

    #[test]
    fn half_sent_request_is_dropped_not_hung() {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        let opts = ServerOptions {
            read_timeout: std::time::Duration::from_millis(200),
            ..ServerOptions::default()
        };
        let srv =
            Server::with_options(cluster, None, opts).unwrap().serve("127.0.0.1:0").unwrap();
        let start = std::time::Instant::now();
        let mut s = TcpStream::connect(("127.0.0.1", srv.port)).unwrap();
        // Declare a 10-byte body but send only 2 bytes and stall.
        s.write_all(b"POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 10\r\n\r\nAC")
            .unwrap();
        let mut out = String::new();
        // The server must time the read out, answer 400 and close the
        // connection — not hold the thread (and this read) forever.
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        assert!(
            start.elapsed() < std::time::Duration::from_secs(10),
            "half-sent request must be dropped by the read timeout"
        );
        srv.stop();
    }

    #[test]
    fn oversized_body_gets_413() {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        let opts = ServerOptions { max_body_bytes: 1024, ..ServerOptions::default() };
        let srv =
            Server::with_options(cluster, None, opts).unwrap().serve("127.0.0.1:0").unwrap();
        let resp = talk(
            srv.port,
            "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 10000\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        assert!(resp.contains("Payload Too Large"), "{resp}");
        srv.stop();
    }

    #[test]
    fn bad_requests_get_4xx() {
        let srv = start();
        // Headerless FASTA is the *submitter's* fault: 400 with the
        // parse reason, not a 500 (that status is reserved for engine
        // faults).
        let resp = talk(srv.port, "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nACGT");
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(body_of(&resp).starts_with("bad request:"), "{resp}");
        // An empty (but well-formed) body is equally a client error.
        let resp = talk(srv.port, "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        assert!(body_of(&resp).contains("empty FASTA"), "{resp}");
        // Unparsable parent hash: 400, not 500.
        let fasta = ">a\nACGT\n";
        let resp = talk(
            srv.port,
            &format!(
                "POST /align?parent=zzzz HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                fasta.len(),
                fasta
            ),
        );
        assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
        let resp = talk(srv.port, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"));
        srv.stop();
    }

    #[test]
    fn every_response_carries_a_request_id() {
        let srv = start();
        let ok = talk(srv.port, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(ok.contains("X-Request-Id: "), "{ok}");
        let a = header_value(&ok, "X-Request-Id").to_string();
        let missing = talk(srv.port, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        let b = header_value(&missing, "X-Request-Id").to_string();
        assert_ne!(a, b, "request ids must be distinct per request");
        srv.stop();
    }

    #[test]
    fn error_responses_carry_a_request_id_on_every_shape() {
        // 400 parse error: the request line is garbage, so the router
        // is never reached — the early-return path must still stamp
        // the header.
        let srv = start();
        let bad = talk(srv.port, "NOT_EVEN_HTTP\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");
        header_value(&bad, "X-Request-Id");
        // 404 unknown parent (flows through the router).
        let fasta = ">a\nACGT\n";
        let nope = talk(
            srv.port,
            &format!(
                "POST /align?parent=00000000deadbeef HTTP/1.1\r\nHost: x\r\n\
                 Content-Length: {}\r\n\r\n{}",
                fasta.len(),
                fasta
            ),
        );
        assert!(nope.starts_with("HTTP/1.1 404"), "{nope}");
        header_value(&nope, "X-Request-Id");
        srv.stop();
        // 413 body cap: another pre-router early return.
        let cluster = Cluster::new(ClusterConfig::spark(2));
        let opts = ServerOptions { max_body_bytes: 64, ..ServerOptions::default() };
        let srv =
            Server::with_options(cluster, None, opts).unwrap().serve("127.0.0.1:0").unwrap();
        let big = talk(
            srv.port,
            "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 100000\r\n\r\n",
        );
        assert!(big.starts_with("HTTP/1.1 413"), "{big}");
        header_value(&big, "X-Request-Id");
        srv.stop();
    }

    #[test]
    fn profile_endpoint_serves_json_and_flame_for_traced_jobs() {
        let mut cfg = ClusterConfig::spark(2);
        cfg.scheduler.trace_capacity = 1 << 12;
        let cluster = Cluster::new(cfg);
        let srv = Server::new(cluster, None).unwrap().serve("127.0.0.1:0").unwrap();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTTCGTAA\n";
        let req = format!(
            "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert_eq!(header_value(&resp, "X-Cache"), "miss", "{resp}");
        let job = header_value(&resp, "X-Job-Hash").to_string();
        // JSON profile: a valid object carrying all three sections.
        let prof = talk(srv.port, &format!("GET /profile/{job} HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert!(prof.starts_with("HTTP/1.1 200"), "{prof}");
        assert!(prof.contains("application/json"), "{prof}");
        let body = body_of(&prof);
        assert!(crate::obs::is_json_object(body), "profile must be valid JSON: {body}");
        for section in
            ["\"aggregate\"", "\"lanes\"", "\"critical_path\"", "\"critical_path_frac\""]
        {
            assert!(body.contains(section), "missing {section}: {body}");
        }
        // The engine ran real stages, so the path must be non-trivial.
        assert!(!body.contains("\"critical_path\":[]"), "{body}");
        // Flame export: `;`-arity 3 with positive integer weights.
        let flame =
            talk(srv.port, &format!("GET /profile/{job}/flame HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert!(flame.starts_with("HTTP/1.1 200"), "{flame}");
        let lines: Vec<&str> = body_of(&flame).lines().collect();
        assert!(!lines.is_empty(), "flame output must not be empty: {flame}");
        for line in lines {
            let (frames, weight) = line.rsplit_once(' ').unwrap();
            assert_eq!(frames.split(';').count(), 3, "{line}");
            assert!(weight.parse::<u64>().unwrap() >= 1, "{line}");
        }
        // Unknown hash: 404.  Malformed hash: 400.
        let nope = talk(srv.port, "GET /profile/00000000deadbeef HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(nope.starts_with("HTTP/1.1 404"), "{nope}");
        let bad = talk(srv.port, "GET /profile/zzzz HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");
        // The status page surfaces the headline number for this job.
        let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(status.contains("critical_path_frac="), "{status}");
        assert!(status.contains("top_self:"), "{status}");
        srv.stop();
    }

    #[test]
    fn metrics_endpoint_serves_every_family() {
        let srv = start();
        // A fresh server must already expose every family (CI greps
        // these names before any job has run).
        let scrape = talk(srv.port, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(scrape.starts_with("HTTP/1.1 200"), "{scrape}");
        for family in [
            "# TYPE halign_requests_total counter",
            "# TYPE halign_request_seconds histogram",
            "# TYPE halign_cache_hits_total counter",
            "# TYPE halign_cache_misses_total counter",
            "# TYPE halign_cache_appends_total counter",
            "# TYPE halign_cache_resident_bytes gauge",
            "# TYPE halign_cache_resident_bytes_peak gauge",
            "# TYPE halign_trace_dropped_total counter",
            "# TYPE halign_tasks_stolen_total counter",
            "# TYPE halign_tasks_run_total counter",
            "# TYPE halign_task_exec_seconds histogram",
            "# TYPE halign_shuffle_bytes_written_total counter",
            "# TYPE halign_workers gauge",
        ] {
            assert!(scrape.contains(family), "missing {family:?} in scrape");
        }
        // After one align job the labeled series must have moved.
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTACGTAA\n";
        let req = format!(
            "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        assert!(talk(srv.port, &req).starts_with("HTTP/1.1 200"));
        let scrape = talk(srv.port, "GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(
            scrape.contains("halign_requests_total{route=\"align\"} 1"),
            "align request must be counted: {scrape}"
        );
        assert!(scrape.contains("halign_cache_misses_total 1"), "{scrape}");
        assert!(
            scrape.contains("halign_request_seconds_count{route=\"align\",cache=\"miss\"} 1"),
            "{scrape}"
        );
        assert!(scrape.contains("halign_tasks_run_total "), "{scrape}");
        srv.stop();
    }

    #[test]
    fn status_page_renders_request_percentiles() {
        let srv = start();
        let before = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(before.contains("task latency:"), "{before}");
        // That first status request is itself recorded, so the second
        // one must render a populated latency line.
        let after = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(
            after.contains("route=\"status\",cache=\"none\""),
            "status route percentiles missing: {after}"
        );
        assert!(after.contains("p50="), "{after}");
        assert!(after.contains("p99="), "{after}");
        srv.stop();
    }

    #[test]
    fn trace_endpoint_serves_chrome_json_for_traced_jobs() {
        let mut cfg = ClusterConfig::spark(2);
        cfg.scheduler.trace_capacity = 1 << 12;
        let cluster = Cluster::new(cfg);
        let srv = Server::new(cluster, None).unwrap().serve("127.0.0.1:0").unwrap();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTTCGTAA\n";
        let req = format!(
            "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert_eq!(header_value(&resp, "X-Cache"), "miss", "{resp}");
        let job = header_value(&resp, "X-Job-Hash").to_string();
        let trace = talk(srv.port, &format!("GET /trace/{job} HTTP/1.1\r\nHost: x\r\n\r\n"));
        assert!(trace.starts_with("HTTP/1.1 200"), "{trace}");
        assert!(trace.contains("application/json"), "{trace}");
        let body = body_of(&trace);
        assert!(crate::obs::is_json_array(body), "trace must be valid JSON: {body}");
        assert!(body.contains("\"task\""), "trace must contain task events: {body}");
        assert!(body.contains("\"cache_miss\""), "miss instant must be traced: {body}");
        // Unknown hash: 404.  Malformed hash: 400.
        let nope = talk(srv.port, "GET /trace/00000000deadbeef HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(nope.starts_with("HTTP/1.1 404"), "{nope}");
        let bad = talk(srv.port, "GET /trace/zzzz HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");
        srv.stop();
    }
}

//! Web server — the paper's third headline contribution ("HAlign-II
//! provides a user-friendly web server based on our distributed computing
//! infrastructure", cf. http://lab.malab.cn/soft/halign).
//!
//! A dependency-free HTTP/1.1 server on `std::net::TcpListener`: each
//! request is parsed, dispatched to the shared [`Cluster`] (and optional
//! [`XlaService`]), and answered with plain text / FASTA / Newick.
//!
//! Endpoints:
//!   GET  /            — status page (cluster config, stats, artifacts)
//!   GET  /health      — liveness probe ("ok")
//!   POST /align       — body: FASTA; query: ?alphabet=dna|protein
//!                       returns the aligned FASTA + an X-Avg-SP header
//!   POST /tree        — body: aligned FASTA; returns Newick +
//!                       X-Log-Likelihood header
//!
//! One OS thread per connection (the engine inside serializes onto the
//! worker pool); requests are independent jobs, which is exactly the
//! paper's deployment model.
//!
//! DNA `/align` jobs are memoized in a content-hash result cache
//! ([`crate::cache`]): an exact resubmission (same sequences, any
//! formatting) is served by rendering the stored [`MsaArtifact`] locally
//! — the engine never runs — and `?parent=<job hash>` appends the body's
//! sequences onto a cached parent alignment in O(new work).  Every DNA
//! response carries `X-Job-Hash` (the digest to pass back as `parent`)
//! and `X-Cache: hit|append|miss`.

mod http;

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context as _, Result};

use crate::align::append::{append_nucleotide, MsaArtifact};
use crate::align::center_star::{align_nucleotide_with_artifact, CenterStarConfig};
use crate::align::protein::{align_protein, ProteinConfig};
use crate::align::MsaResult;
use crate::cache::{canonical_digest, ArtifactStore, DigestBuilder};
use crate::engine::Cluster;
use crate::fasta::{io as fio, Alphabet};
use crate::runtime::XlaService;
use crate::tree::{build_tree, TreeConfig};

use http::{ReadError, Request, Response};

/// Socket-hygiene knobs: a public-facing endpoint must bound how long a
/// connection can stall and how large a body it will accept.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Per-connection read timeout: a half-sent request is dropped when
    /// it stalls this long, instead of pinning its thread forever.
    pub read_timeout: std::time::Duration,
    /// Per-connection write timeout for the response.
    pub write_timeout: std::time::Duration,
    /// Declared Content-Length cap; larger bodies are answered 413
    /// before a byte of them is read or buffered.
    pub max_body_bytes: usize,
    /// Resident byte budget of the DNA alignment result cache; evicted
    /// artifacts spill to disk and stay servable.
    pub cache_budget_bytes: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        Self {
            read_timeout: std::time::Duration::from_secs(30),
            write_timeout: std::time::Duration::from_secs(30),
            max_body_bytes: 256 << 20,
            cache_budget_bytes: 64 << 20,
        }
    }
}

pub struct Server {
    cluster: Cluster,
    svc: Option<XlaService>,
    options: ServerOptions,
    cache: ArtifactStore,
    requests: AtomicUsize,
    shutdown: AtomicBool,
}

/// Handle for a running server (port + stop control).
pub struct RunningServer {
    pub port: u16,
    inner: Arc<Server>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl RunningServer {
    pub fn stop(mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        // Nudge the accept loop.
        let _ = TcpStream::connect(("127.0.0.1", self.port));
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Server {
    pub fn new(cluster: Cluster, svc: Option<XlaService>) -> Result<Arc<Self>> {
        Self::with_options(cluster, svc, ServerOptions::default())
    }

    pub fn with_options(
        cluster: Cluster,
        svc: Option<XlaService>,
        options: ServerOptions,
    ) -> Result<Arc<Self>> {
        static CACHE_DIR_SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "halign2-server-cache-{}-{}",
            std::process::id(),
            CACHE_DIR_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let cache = ArtifactStore::new(dir, options.cache_budget_bytes)?;
        Ok(Arc::new(Self {
            cluster,
            svc,
            options,
            cache,
            requests: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        }))
    }

    /// Bind to `addr` (use port 0 for an ephemeral port) and serve on a
    /// background thread.
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<RunningServer> {
        let listener = TcpListener::bind(addr).context("binding server socket")?;
        let port = listener.local_addr()?.port();
        let inner = self.clone();
        let join = std::thread::Builder::new()
            .name("halign2-http".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if inner.shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let server = inner.clone();
                    std::thread::spawn(move || {
                        let _ = server.handle(stream);
                    });
                }
            })?;
        Ok(RunningServer { port, inner: self, join: Some(join) })
    }

    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        // Socket deadlines first: without them a half-sent request (or a
        // reader that never drains the response) pins this thread for
        // the life of the peer.
        stream.set_read_timeout(Some(self.options.read_timeout))?;
        stream.set_write_timeout(Some(self.options.write_timeout))?;
        let request = match Request::read_from(&mut stream, self.options.max_body_bytes) {
            Ok(r) => r,
            Err(e @ ReadError::TooLarge { .. }) => {
                let resp = Response::text(413, &format!("{e}\n"));
                stream.write_all(&resp.to_bytes())?;
                return Ok(());
            }
            Err(e) => {
                let resp = Response::text(400, &format!("bad request: {e}\n"));
                stream.write_all(&resp.to_bytes())?;
                return Ok(());
            }
        };
        self.requests.fetch_add(1, Ordering::Relaxed);
        let resp = self.route(&request).unwrap_or_else(|e| {
            Response::text(500, &format!("error: {e:#}\n"))
        });
        stream.write_all(&resp.to_bytes())?;
        Ok(())
    }

    fn route(&self, req: &Request) -> Result<Response> {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => Ok(Response::text(200, "ok\n")),
            ("GET", "/") => Ok(self.status_page()),
            ("POST", "/align") => self.do_align(req),
            ("POST", "/tree") => self.do_tree(req),
            _ => Ok(Response::text(404, "not found\n")),
        }
    }

    fn alphabet_of(req: &Request) -> Alphabet {
        match req.query.get("alphabet").map(String::as_str) {
            Some("protein") => Alphabet::Protein,
            _ => Alphabet::Dna,
        }
    }

    fn do_align(&self, req: &Request) -> Result<Response> {
        let alphabet = Self::alphabet_of(req);
        let seqs = fio::read_fasta(req.body.as_slice(), alphabet)?;
        anyhow::ensure!(!seqs.is_empty(), "empty FASTA body");
        match alphabet {
            Alphabet::Dna => self.align_dna(req, seqs),
            Alphabet::Protein => {
                let msa = align_protein(
                    &self.cluster,
                    &seqs,
                    self.svc.as_ref(),
                    &ProteinConfig::default(),
                )?;
                let sp = msa.avg_sp_distributed(&self.cluster)?;
                Self::msa_response(&msa, sp)
            }
        }
    }

    fn msa_response(msa: &MsaResult, sp: f64) -> Result<Response> {
        let mut body = Vec::new();
        fio::write_fasta(&mut body, &msa.aligned)?;
        let mut resp = Response::bytes(200, "text/x-fasta", body);
        resp.headers.push(("X-Avg-SP".into(), format!("{sp:.4}")));
        resp.headers.push(("X-Width".into(), msa.width.to_string()));
        Ok(resp)
    }

    /// Look up `key` and decode it; a corrupt or version-skewed blob is a
    /// miss (the job recomputes and overwrites it), never an error.
    fn cached_artifact(&self, key: u64) -> Option<MsaArtifact> {
        let bytes = self.cache.get(key).ok()??;
        MsaArtifact::from_bytes(&bytes).ok()
    }

    /// DNA alignment with content-hash memoization (see module docs):
    /// `?parent=<hash>` appends the body onto a cached parent job,
    /// otherwise the submission digest is looked up before the engine is
    /// touched.
    fn align_dna(&self, req: &Request, seqs: Vec<crate::fasta::Sequence>) -> Result<Response> {
        if let Some(parent_hex) = req.query.get("parent") {
            let parent_key = u64::from_str_radix(parent_hex, 16)
                .with_context(|| format!("bad parent job hash {parent_hex:?}"))?;
            let Some(parent) = self.cached_artifact(parent_key) else {
                return Ok(Response::text(
                    404,
                    &format!("unknown parent job {parent_key:016x}\n"),
                ));
            };
            // The union job's identity: parent rows ++ appended rows.
            let mut b = DigestBuilder::new();
            for row in &parent.rows {
                b.record(&row.id, &row.codes, parent.alphabet);
            }
            for s in &seqs {
                b.push(s);
            }
            let union_key = b.finish();
            if let Some(art) = self.cached_artifact(union_key) {
                let msa = art.render()?;
                let sp = msa.avg_sp()?;
                let mut resp = Self::msa_response(&msa, sp)?;
                Self::cache_headers(&mut resp, "hit", union_key);
                return Ok(resp);
            }
            let out = append_nucleotide(&self.cluster, &parent, &seqs, None)?;
            self.cache.put(union_key, out.artifact.to_bytes())?;
            let sp = out.msa.avg_sp_distributed(&self.cluster)?;
            let mut resp = Self::msa_response(&out.msa, sp)?;
            Self::cache_headers(&mut resp, "append", union_key);
            return Ok(resp);
        }

        let key = canonical_digest(&seqs);
        if let Some(art) = self.cached_artifact(key) {
            // Hit: render locally — no engine job runs at all.
            let msa = art.render()?;
            let sp = msa.avg_sp()?;
            let mut resp = Self::msa_response(&msa, sp)?;
            Self::cache_headers(&mut resp, "hit", key);
            return Ok(resp);
        }
        let (msa, artifact) =
            align_nucleotide_with_artifact(&self.cluster, &seqs, &CenterStarConfig::default())?;
        self.cache.put(key, artifact.to_bytes())?;
        let sp = msa.avg_sp_distributed(&self.cluster)?;
        let mut resp = Self::msa_response(&msa, sp)?;
        Self::cache_headers(&mut resp, "miss", key);
        Ok(resp)
    }

    fn cache_headers(resp: &mut Response, outcome: &str, key: u64) {
        resp.headers.push(("X-Cache".into(), outcome.into()));
        resp.headers.push(("X-Job-Hash".into(), format!("{key:016x}")));
    }

    fn do_tree(&self, req: &Request) -> Result<Response> {
        let alphabet = Self::alphabet_of(req);
        let rows = fio::read_fasta(req.body.as_slice(), alphabet)?;
        let result = build_tree(&self.cluster, &rows, self.svc.as_ref(), &TreeConfig::default())?;
        let mut resp = Response::text(200, &format!("{}\n", result.tree.to_newick()));
        resp.headers.push((
            "X-Log-Likelihood".into(),
            format!("{:.4}", result.log_likelihood),
        ));
        resp.headers
            .push(("X-Clusters".into(), result.num_clusters.to_string()));
        Ok(resp)
    }

    fn status_page(&self) -> Response {
        let stats = self.cluster.stats();
        let artifacts = self
            .svc
            .as_ref()
            .map(|s| s.executables().join(", "))
            .unwrap_or_else(|| "(native fallback)".into());
        Response::text(
            200,
            &format!(
                "halign2 web server\n\
                 ==================\n\
                 workers:        {}\n\
                 backend:        {}\n\
                 requests:       {}\n\
                 tasks run:      {}\n\
                 shuffle bytes:  {} written / {} read\n\
                 avg max memory: {:.2} MB/worker\n\
                 artifacts:      {}\n\
                 result cache:   {} jobs, {} hits / {} misses, {} resident bytes (budget {})\n\n\
                 POST /align (FASTA body, ?alphabet=dna|protein, ?parent=<job hash>)\n\
                 POST /tree  (aligned FASTA body)\n",
                stats.workers,
                self.cluster.backend(),
                self.requests.load(Ordering::Relaxed),
                stats.tasks_run,
                stats.shuffle_bytes_written,
                stats.shuffle_bytes_read,
                stats.avg_max_memory_bytes / (1 << 20) as f64,
                artifacts,
                self.cache.entries(),
                self.cache.hits(),
                self.cache.misses(),
                self.cache.resident_bytes(),
                self.cache.byte_budget(),
            ),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ClusterConfig;
    use std::io::{Read, Write};

    fn start() -> RunningServer {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        Server::new(cluster, None).unwrap().serve("127.0.0.1:0").unwrap()
    }

    fn talk(port: u16, raw: &str) -> String {
        let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn health_and_status() {
        let srv = start();
        let resp = talk(srv.port, "GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 200"));
        assert!(resp.ends_with("ok\n"));
        let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(status.contains("halign2 web server"));
        assert!(status.contains("workers:        2"));
        srv.stop();
    }

    #[test]
    fn align_roundtrip_over_http() {
        let srv = start();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTACGTAA\n";
        let req = format!(
            "POST /align?alphabet=dna HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("X-Avg-SP:"));
        assert!(resp.contains(">a\n"), "aligned FASTA returned");
        srv.stop();
    }

    fn header_value<'a>(resp: &'a str, name: &str) -> &'a str {
        resp.lines()
            .find_map(|l| l.strip_prefix(&format!("{name}: ")))
            .unwrap_or_else(|| panic!("missing header {name} in {resp}"))
            .trim_end()
    }

    fn body_of(resp: &str) -> &str {
        resp.split_once("\r\n\r\n").expect("no body").1
    }

    #[test]
    fn resubmission_hits_the_cache_bit_identically_without_engine_work() {
        let srv = start();
        let post = |path: &str, body: &str| {
            talk(
                srv.port,
                &format!(
                    "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                ),
            )
        };
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTTCGTAA\n";
        let first = post("/align", fasta);
        assert!(first.starts_with("HTTP/1.1 200"), "{first}");
        assert_eq!(header_value(&first, "X-Cache"), "miss");
        let tasks_after_miss: usize = {
            let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
            header_like(&status, "tasks run:")
        };
        // Same job, different formatting: must hit and return the exact
        // same bytes, without running a single engine task.
        let reformatted = ">a trailing words\nacgtACGTAA\n>b\nACGT\nACGTA\n>c\nACGTTCGTAA\n";
        let second = post("/align", reformatted);
        assert_eq!(header_value(&second, "X-Cache"), "hit", "{second}");
        assert_eq!(header_value(&first, "X-Job-Hash"), header_value(&second, "X-Job-Hash"));
        assert_eq!(body_of(&first), body_of(&second), "hit must be bit-identical");
        let status = talk(srv.port, "GET / HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(
            header_like(&status, "tasks run:"),
            tasks_after_miss,
            "a cache hit must not touch the engine"
        );
        srv.stop();
    }

    fn header_like(status: &str, label: &str) -> usize {
        status
            .lines()
            .find_map(|l| l.trim().strip_prefix(label))
            .and_then(|v| v.trim().split_whitespace().next())
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("no {label} in {status}"))
    }

    #[test]
    fn append_extends_a_cached_job_and_matches_the_union() {
        let srv = start();
        let post = |path: &str, body: &str| {
            talk(
                srv.port,
                &format!(
                    "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
                    body.len(),
                    body
                ),
            )
        };
        let base = ">a\nACGTACGTAA\n>b\nACGTACGTA\n>c\nACGTTCGTAA\n";
        let extra = ">d\nACGGACGTAA\n>e\nACGTACGTAAT\n";
        let first = post("/align", base);
        let parent = header_value(&first, "X-Job-Hash").to_string();
        let appended = post(&format!("/align?parent={parent}"), extra);
        assert!(appended.starts_with("HTTP/1.1 200"), "{appended}");
        assert_eq!(header_value(&appended, "X-Cache"), "append");
        // From-scratch on the union was cached under the union digest by
        // the append, so posting the union now must *hit* and agree
        // byte-for-byte — the incremental path equals the full job.
        let union = format!("{base}{extra}");
        let scratch = post("/align", &union);
        assert_eq!(header_value(&scratch, "X-Cache"), "hit", "{scratch}");
        assert_eq!(header_value(&scratch, "X-Job-Hash"), header_value(&appended, "X-Job-Hash"));
        assert_eq!(body_of(&scratch), body_of(&appended));
        // An unknown parent is a clean 404, not a recompute.
        let nope = post("/align?parent=00000000deadbeef", extra);
        assert!(nope.starts_with("HTTP/1.1 404"), "{nope}");
        srv.stop();
    }

    #[test]
    fn tree_endpoint_returns_newick() {
        let srv = start();
        let fasta = ">a\nACGTACGTAA\n>b\nACGTACGTTA\n>c\nACGAACGTAA\n>d\nACGTACGGAA\n";
        let req = format!(
            "POST /tree HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{}",
            fasta.len(),
            fasta
        );
        let resp = talk(srv.port, &req);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("X-Log-Likelihood:"));
        assert!(resp.trim_end().ends_with(");"), "newick body: {resp}");
        srv.stop();
    }

    #[test]
    fn half_sent_request_is_dropped_not_hung() {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        let opts = ServerOptions {
            read_timeout: std::time::Duration::from_millis(200),
            ..ServerOptions::default()
        };
        let srv =
            Server::with_options(cluster, None, opts).unwrap().serve("127.0.0.1:0").unwrap();
        let start = std::time::Instant::now();
        let mut s = TcpStream::connect(("127.0.0.1", srv.port)).unwrap();
        // Declare a 10-byte body but send only 2 bytes and stall.
        s.write_all(b"POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 10\r\n\r\nAC")
            .unwrap();
        let mut out = String::new();
        // The server must time the read out, answer 400 and close the
        // connection — not hold the thread (and this read) forever.
        s.read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 400"), "{out}");
        assert!(
            start.elapsed() < std::time::Duration::from_secs(10),
            "half-sent request must be dropped by the read timeout"
        );
        srv.stop();
    }

    #[test]
    fn oversized_body_gets_413() {
        let cluster = Cluster::new(ClusterConfig::spark(2));
        let opts = ServerOptions { max_body_bytes: 1024, ..ServerOptions::default() };
        let srv =
            Server::with_options(cluster, None, opts).unwrap().serve("127.0.0.1:0").unwrap();
        let resp = talk(
            srv.port,
            "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 10000\r\n\r\n",
        );
        assert!(resp.starts_with("HTTP/1.1 413"), "{resp}");
        assert!(resp.contains("Payload Too Large"), "{resp}");
        srv.stop();
    }

    #[test]
    fn bad_requests_get_4xx() {
        let srv = start();
        let resp = talk(srv.port, "POST /align HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nACGT");
        assert!(resp.starts_with("HTTP/1.1 500"), "{resp}"); // headerless FASTA
        let resp = talk(srv.port, "GET /nope HTTP/1.1\r\nHost: x\r\n\r\n");
        assert!(resp.starts_with("HTTP/1.1 404"));
        srv.stop();
    }
}

//! Minimal HTTP/1.1 request parsing + response serialization (std-only).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read};
use std::net::TcpStream;

use anyhow::{anyhow, Context as _};

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: HashMap<String, String>,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

/// Parse-failure classification: the server answers 413 to an oversized
/// declared body and 400 to everything else (a plain `anyhow::Error`
/// can't be told apart reliably, so the distinction is in the type).
#[derive(Debug)]
pub enum ReadError {
    TooLarge { len: usize, max: usize },
    Bad(anyhow::Error),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::TooLarge { len, max } => {
                write!(f, "body too large ({len} bytes > cap {max})")
            }
            ReadError::Bad(e) => write!(f, "{e}"),
        }
    }
}

impl From<anyhow::Error> for ReadError {
    fn from(e: anyhow::Error) -> Self {
        ReadError::Bad(e)
    }
}

impl Request {
    pub fn read_from(
        stream: &mut TcpStream,
        max_body: usize,
    ) -> std::result::Result<Request, ReadError> {
        let mut reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        let mut line = String::new();
        reader.read_line(&mut line).context("reading request line")?;
        let mut parts = line.split_whitespace();
        let method = parts.next().context("missing method")?.to_string();
        let target = parts.next().context("missing path")?.to_string();
        let (path, query_str) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), q.to_string()),
            None => (target, String::new()),
        };
        let mut query = HashMap::new();
        for kv in query_str.split('&').filter(|s| !s.is_empty()) {
            let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
            query.insert(k.to_string(), v.to_string());
        }
        let mut headers = HashMap::new();
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).context("reading header line")?;
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            let Some((k, v)) = h.split_once(':') else {
                return Err(ReadError::Bad(anyhow!("malformed header {h:?}")));
            };
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
        let len: usize = headers
            .get("content-length")
            .map(|v| v.parse())
            .transpose()
            .context("bad content-length")?
            .unwrap_or(0);
        if len > max_body {
            // Checked against the *declared* length, before allocating
            // or reading a byte of the body.
            return Err(ReadError::TooLarge { len, max: max_body });
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).context("reading body")?;
        Ok(Request { method, path, query, headers, body })
    }
}

#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn text(status: u16, body: &str) -> Self {
        Self::bytes(status, "text/plain; charset=utf-8", body.as_bytes().to_vec())
    }

    pub fn bytes(status: u16, content_type: &str, body: Vec<u8>) -> Self {
        Self { status, content_type: content_type.to_string(), headers: Vec::new(), body }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let reason = match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            413 => "Payload Too Large",
            500 => "Internal Server Error",
            _ => "Unknown",
        };
        let mut out = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            reason,
            self.content_type,
            self.body.len()
        );
        for (k, v) in &self.headers {
            out.push_str(&format!("{k}: {v}\r\n"));
        }
        out.push_str("\r\n");
        let mut bytes = out.into_bytes();
        bytes.extend_from_slice(&self.body);
        bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_serializes_headers_and_body() {
        let mut r = Response::text(200, "hello");
        r.headers.push(("X-Test".into(), "1".into()));
        let s = String::from_utf8(r.to_bytes()).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 5\r\n"));
        assert!(s.contains("X-Test: 1\r\n"));
        assert!(s.ends_with("\r\n\r\nhello"));
    }
}

//! `halign2` — command-line launcher for the HAlign-II reproduction.
//!
//! Subcommands:
//!   gen         generate a synthetic dataset (mito / rrna / protein)
//!   align       distributed center-star MSA over a FASTA file
//!   tree        build a phylogenetic tree from an aligned FASTA
//!   bench-table regenerate a paper table/figure (t2 t3 t4 t5 f5 f6)
//!   info        show compiled XLA artifacts
//!
//! Argument parsing is hand-rolled (offline build: no clap); every flag
//! is `--key value`.

use std::collections::HashMap;
use std::process::ExitCode;
use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use halign2::align::center_star::{align_nucleotide, CenterStarConfig};
use halign2::align::protein::{align_protein, ProteinConfig};
use halign2::bench::{self, BenchConfig};
use halign2::data::DatasetSpec;
use halign2::engine::{Cluster, ClusterConfig};
use halign2::fasta::{io as fio, Alphabet};
use halign2::metrics::{print_table, tsv_line};
use halign2::runtime::XlaService;
use halign2::tree::{build_tree, TreeConfig};
use halign2::util::timer::fmt_duration;

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument {a:?} (flags are --key value)");
            };
            let val = argv
                .get(i + 1)
                .with_context(|| format!("--{key} needs a value"))?;
            flags.insert(key.to_string(), val.clone());
            i += 2;
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }

    fn alphabet(&self) -> Result<Alphabet> {
        Ok(match self.get("alphabet").unwrap_or("dna") {
            "dna" | "rna" => Alphabet::Dna,
            "protein" => Alphabet::Protein,
            other => bail!("--alphabet must be dna|rna|protein, got {other:?}"),
        })
    }

    fn cluster_config(&self) -> Result<ClusterConfig> {
        let workers = self.parse_or("workers", 8usize)?;
        let mut cfg = match self.get("backend").unwrap_or("spark") {
            "spark" => ClusterConfig::spark(workers),
            "hadoop" => ClusterConfig::hadoop(workers),
            other => bail!("--backend must be spark|hadoop, got {other:?}"),
        };
        // 0 disables the lifecycle trace rings (the default everywhere
        // except `serve`, which overrides it to feed /trace/<job>).
        cfg.scheduler.trace_capacity = self.parse_or("trace-capacity", 0usize)?;
        Ok(cfg)
    }

    fn cluster(&self) -> Result<Cluster> {
        Ok(Cluster::new(self.cluster_config()?))
    }

    fn service(&self) -> Option<XlaService> {
        let dir = self.get("artifacts").unwrap_or("artifacts");
        if !std::path::Path::new(dir).join("manifest.txt").exists() {
            return None;
        }
        match XlaService::start(dir) {
            Ok(svc) => Some(svc),
            Err(e) => {
                eprintln!("warning: XLA artifacts unavailable ({e}); using native fallback");
                None
            }
        }
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "gen" => cmd_gen(&args),
        "align" => cmd_align(&args),
        "tree" => cmd_tree(&args),
        "bench-table" => cmd_bench(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `halign2 help`)"),
    }
}

fn print_usage() {
    println!(
        "halign2 — ultra-large MSA + phylogenetic trees (HAlign-II reproduction)\n\n\
         USAGE:\n  halign2 gen --family mito|rrna|protein --count N [--length-scale F] [--seed S] --out data.fasta\n  \
         halign2 align --in data.fasta [--alphabet dna|protein] [--workers N] [--backend spark|hadoop]\n               [--artifacts DIR] [--out msa.fasta] [--tree tree.nwk]\n  \
         halign2 tree --in msa.fasta [--alphabet dna|protein] [--workers N] [--out tree.nwk]\n  \
         halign2 bench-table --table t2|t3|t4|t5|f5|f6|f6skew|f6trace [--quick true] [--scale F] [--workers N]\n  \
         halign2 serve [--addr 127.0.0.1:8080] [--workers N] [--backend spark|hadoop] [--trace-capacity N]\n  \
         halign2 info [--artifacts DIR]"
    );
}

fn cmd_gen(args: &Args) -> Result<()> {
    let family = args.get("family").unwrap_or("mito");
    let count = args.parse_or("count", 100usize)?;
    let ls = args.parse_or("length-scale", 0.1f64)?;
    let seed = args.parse_or("seed", 7u64)?;
    let out = args.get("out").context("--out required")?;
    let spec = match family {
        "mito" => DatasetSpec { count, ..DatasetSpec::mito(ls, seed) },
        "rrna" => DatasetSpec::rrna(count, ls, seed),
        "protein" => DatasetSpec::protein(count, ls, seed),
        other => bail!("--family must be mito|rrna|protein, got {other:?}"),
    };
    let seqs = spec.generate();
    fio::write_fasta_file(out, &seqs)?;
    println!("wrote {} sequences to {out}", seqs.len());
    Ok(())
}

fn cmd_align(args: &Args) -> Result<()> {
    let input = args.get("in").context("--in required")?;
    let alphabet = args.alphabet()?;
    let seqs = fio::read_fasta_file(input, alphabet)?;
    anyhow::ensure!(!seqs.is_empty(), "no sequences in {input}");
    let cluster = args.cluster()?;
    let svc = args.service();
    let sw = std::time::Instant::now();
    let msa = match alphabet {
        Alphabet::Dna => align_nucleotide(&cluster, &seqs, &CenterStarConfig::default())?,
        Alphabet::Protein => {
            align_protein(&cluster, &seqs, svc.as_ref(), &ProteinConfig::default())?
        }
    };
    let wall = sw.elapsed();
    let sp = msa.avg_sp_distributed(&cluster)?;
    let stats = cluster.stats();
    println!(
        "aligned {} sequences (width {}) in {} | avg SP {:.2} | {} workers, {} tasks, avg max mem {:.1} MB",
        msa.aligned.len(),
        msa.width,
        fmt_duration(wall),
        sp,
        stats.workers,
        stats.tasks_run,
        stats.avg_max_memory_bytes / (1 << 20) as f64
    );
    if let Some(out) = args.get("out") {
        fio::write_fasta_file(out, &msa.aligned)?;
        println!("MSA written to {out}");
    }
    if let Some(tree_out) = args.get("tree") {
        let result = build_tree(&cluster, &msa.aligned, svc.as_ref(), &TreeConfig::default())?;
        std::fs::write(tree_out, result.tree.to_newick())?;
        println!(
            "tree with {} leaves (logML {:.1}, {} clusters) written to {tree_out}",
            result.tree.num_leaves(),
            result.log_likelihood,
            result.num_clusters
        );
    }
    Ok(())
}

fn cmd_tree(args: &Args) -> Result<()> {
    let input = args.get("in").context("--in required")?;
    let alphabet = args.alphabet()?;
    let rows = fio::read_fasta_file(input, alphabet)?;
    let cluster = args.cluster()?;
    let svc = args.service();
    let sw = std::time::Instant::now();
    let result = build_tree(&cluster, &rows, svc.as_ref(), &TreeConfig::default())?;
    println!(
        "tree over {} taxa in {} | logML {:.1} | {} clusters",
        result.tree.num_leaves(),
        fmt_duration(sw.elapsed()),
        result.log_likelihood,
        result.num_clusters
    );
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, result.tree.to_newick())?;
            println!("newick written to {out}");
        }
        None => println!("{}", result.tree.to_newick()),
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let table = args.get("table").context("--table t2|t3|t4|t5|f5|f6|f6skew|f6trace required")?;
    let cfg = BenchConfig {
        workers: args.parse_or("workers", 8usize)?,
        scale: args.parse_or("scale", 1.0f64)?,
        budget: Duration::from_secs(args.parse_or("budget-secs", 120u64)?),
        quick: args.parse_or("quick", false)?,
        seed: args.parse_or("seed", 0xBEEFu64)?,
    };
    if table == "f6trace" {
        // Exported scheduler traces (both queue architectures) instead
        // of a TSV table; CI validates and archives these JSON files.
        for (label, json) in bench::fig6_trace(&cfg) {
            anyhow::ensure!(
                halign2::obs::is_json_array(&json),
                "trace {label} must be a valid JSON array"
            );
            let path = format!("trace_{label}.json");
            std::fs::write(&path, &json)?;
            println!("wrote {path} ({} bytes) — load in Perfetto / chrome://tracing", json.len());
        }
        return Ok(());
    }
    let svc = args.service();
    let (title, rows) = match table {
        "t2" => ("Table 2 — genome MSA (time + avg SP)", bench::table2_genome(&cfg)),
        "t3" => ("Table 3 — RNA MSA (time + avg SP)", bench::table3_rna(&cfg)),
        "t4" => (
            "Table 4 — protein MSA (time + avg SP)",
            bench::table4_protein(&cfg, svc.as_ref()),
        ),
        "t5" => (
            "Table 5 — tree construction (time + logML)",
            bench::table5_tree(&cfg, svc.as_ref()),
        ),
        "f5" => (
            "Figure 5 — avg max per-worker memory",
            bench::fig5_memory(&cfg, svc.as_ref()),
        ),
        "f6" => (
            "Figure 6 — scaling with worker count (steal on vs off)",
            bench::fig6_scaling(&cfg),
        ),
        "f6skew" => (
            "Figure 6b — skewed partitions (straggler scenario)",
            bench::fig6_skew(&cfg),
        ),
        other => bail!("unknown table {other:?}"),
    };
    print_table(title, &rows);
    println!("\n# {}", halign2::metrics::TSV_HEADER);
    for r in &rows {
        println!("{}", tsv_line(r));
    }
    Ok(())
}

/// The paper's web-server contribution: POST /align and /tree over HTTP.
fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = args.cluster_config()?;
    if cfg.scheduler.trace_capacity == 0 {
        // Serving defaults to traced: GET /trace/<job> needs live rings
        // (pass --trace-capacity explicitly to resize).
        cfg.scheduler.trace_capacity = 1 << 12;
    }
    let cluster = Cluster::new(cfg);
    let svc = args.service();
    let addr = args.get("addr").unwrap_or("127.0.0.1:8080").to_string();
    let server = halign2::server::Server::new(cluster, svc)?;
    let running = server.serve(&addr)?;
    println!("halign2 web server listening on {addr} (port {})", running.port);
    println!("  GET  /          status    |  GET /health  |  GET /metrics");
    println!("  GET  /trace/<job hash>    Chrome trace-event JSON");
    println!("  POST /align     FASTA in, aligned FASTA out (?alphabet=dna|protein)");
    println!("  POST /tree      aligned FASTA in, Newick out");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    match args.service() {
        None => println!("no artifacts found (run `make artifacts`)"),
        Some(svc) => {
            println!("compiled executables:");
            for name in svc.executables() {
                println!("  {name}");
            }
        }
    }
    Ok(())
}

//! Merging per-cluster NJ trees into the final evolution tree (paper
//! Fig. 4: "all phylogenetic trees are merged on clusters into the final
//! evolution tree").
//!
//! A backbone NJ tree is built over the cluster medoids; each backbone
//! leaf is then replaced by its cluster's subtree (grafted at the leaf's
//! parent with the leaf's branch length).

use anyhow::{ensure, Result};

use super::newick::Tree;
use super::nj::neighbor_joining;

/// Merge cluster subtrees given the medoid-to-medoid distance matrix.
/// `subtrees[c]` is cluster c's tree; `medoid_dist` is square over
/// clusters.
pub fn merge_cluster_trees(subtrees: &[Tree], medoid_dist: &[Vec<f64>]) -> Result<Tree> {
    ensure!(!subtrees.is_empty(), "no subtrees to merge");
    if subtrees.len() == 1 {
        return Ok(subtrees[0].clone());
    }
    ensure!(
        medoid_dist.len() == subtrees.len(),
        "medoid matrix must match cluster count"
    );
    // Backbone over pseudo-taxa "#0", "#1", ...
    let labels: Vec<String> = (0..subtrees.len()).map(|c| format!("#{c}")).collect();
    let mut backbone = neighbor_joining(&labels, medoid_dist)?;

    // Replace each backbone leaf "#c" with subtree c.
    for c in 0..subtrees.len() {
        let leaf = backbone
            .nodes
            .iter()
            .position(|n| n.children.is_empty() && n.label.as_deref() == Some(&format!("#{c}")))
            .expect("backbone leaf must exist");
        let parent = backbone.nodes[leaf].parent;
        let branch = backbone.nodes[leaf].branch;
        match parent {
            Some(p) => {
                // Drop the placeholder leaf, graft the subtree in its place.
                backbone.nodes[p].children.retain(|&ch| ch != leaf);
                backbone.nodes[leaf].label = None; // orphaned placeholder
                backbone.graft(&subtrees[c], p, branch);
            }
            None => {
                // Backbone was a single leaf (can't happen for >= 2
                // clusters, guarded above).
                unreachable!("backbone root cannot be a placeholder leaf");
            }
        }
    }
    // Orphaned placeholder nodes remain in the arena but unreachable;
    // compact the tree for cleanliness.
    let compacted = compact(&backbone)?;
    compacted.validate()?;
    Ok(compacted)
}

/// Rebuild the node arena keeping only nodes reachable from the root.
fn compact(tree: &Tree) -> Result<Tree> {
    let mut map = vec![usize::MAX; tree.nodes.len()];
    let mut order = Vec::new();
    let mut stack = vec![tree.root];
    while let Some(i) = stack.pop() {
        if map[i] != usize::MAX {
            continue;
        }
        map[i] = order.len();
        order.push(i);
        for &c in &tree.nodes[i].children {
            stack.push(c);
        }
    }
    let mut nodes = Vec::with_capacity(order.len());
    for &old in &order {
        let n = &tree.nodes[old];
        nodes.push(super::newick::TreeNode {
            parent: n.parent.and_then(|p| (map[p] != usize::MAX).then_some(map[p])),
            children: n.children.iter().map(|&c| map[c]).collect(),
            branch: n.branch,
            label: n.label.clone(),
        });
    }
    Ok(Tree { nodes, root: map[tree.root] })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merges_two_clusters_keeping_all_leaves() {
        let t1 = Tree::from_newick("(a:1,b:1);").unwrap();
        let t2 = Tree::from_newick("(c:1,(d:1,e:1):0.5);").unwrap();
        let dist = vec![vec![0.0, 2.0], vec![2.0, 0.0]];
        let merged = merge_cluster_trees(&[t1, t2], &dist).unwrap();
        merged.validate().unwrap();
        let mut leaves = merged.leaf_labels();
        leaves.sort();
        assert_eq!(leaves, vec!["a", "b", "c", "d", "e"]);
    }

    #[test]
    fn single_cluster_passthrough() {
        let t = Tree::from_newick("(a:1,b:2);").unwrap();
        let merged = merge_cluster_trees(&[t.clone()], &[vec![0.0]]).unwrap();
        assert_eq!(merged, t);
    }

    #[test]
    fn three_clusters_no_placeholders_survive() {
        let ts = vec![
            Tree::from_newick("(a:1,b:1);").unwrap(),
            Tree::from_newick("(c:1,d:1);").unwrap(),
            Tree::from_newick("(e:1,f:1);").unwrap(),
        ];
        let d = vec![
            vec![0.0, 1.0, 4.0],
            vec![1.0, 0.0, 4.0],
            vec![4.0, 4.0, 0.0],
        ];
        let merged = merge_cluster_trees(&ts, &d).unwrap();
        assert_eq!(merged.num_leaves(), 6);
        assert!(!merged.to_newick().contains('#'), "placeholders removed");
        // Close clusters (0,1) should be nearer each other than to 2.
        let ab = super::super::nj::tree_distance(&merged, "a", "c").unwrap();
        let ae = super::super::nj::tree_distance(&merged, "a", "e").unwrap();
        assert!(ab < ae);
    }
}

//! Phylogenetic tree representation + Newick serialization.

use anyhow::{bail, ensure, Result};

use crate::engine::MemSize;
use crate::util::{Decode, Encode};

/// An unrooted-tree-as-rooted-DAG: node 0..n, `root` has no parent.
/// Leaves carry taxon labels; branch lengths live on the edge to the
/// parent.
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    pub nodes: Vec<TreeNode>,
    pub root: usize,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TreeNode {
    pub parent: Option<usize>,
    pub children: Vec<usize>,
    /// Length of the edge to the parent (0 for the root).
    pub branch: f64,
    /// Leaf label (None for internal nodes).
    pub label: Option<String>,
}

impl Tree {
    /// Single-leaf tree.
    pub fn leaf(label: impl Into<String>) -> Self {
        Self {
            nodes: vec![TreeNode {
                parent: None,
                children: Vec::new(),
                branch: 0.0,
                label: Some(label.into()),
            }],
            root: 0,
        }
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.children.is_empty()).count()
    }

    pub fn leaf_labels(&self) -> Vec<&str> {
        self.nodes
            .iter()
            .filter(|n| n.children.is_empty())
            .filter_map(|n| n.label.as_deref())
            .collect()
    }

    /// Attach `child` (a whole tree) under node `at` with branch length.
    pub fn graft(&mut self, subtree: &Tree, at: usize, branch: f64) -> usize {
        let offset = self.nodes.len();
        for (i, n) in subtree.nodes.iter().enumerate() {
            let mut n = n.clone();
            n.parent = n.parent.map(|p| p + offset);
            n.children = n.children.iter().map(|c| c + offset).collect();
            if i == subtree.root {
                n.parent = Some(at);
                n.branch = branch;
            }
            self.nodes.push(n);
        }
        let new_root = subtree.root + offset;
        self.nodes[at].children.push(new_root);
        new_root
    }

    /// Sum of all branch lengths.
    pub fn total_length(&self) -> f64 {
        self.nodes.iter().map(|n| n.branch).sum()
    }

    /// Serialize to Newick (labels quoted only if needed; lengths with 6
    /// significant digits).
    pub fn to_newick(&self) -> String {
        let mut s = String::new();
        self.write_node(self.root, &mut s);
        s.push(';');
        s
    }

    fn write_node(&self, idx: usize, out: &mut String) {
        let n = &self.nodes[idx];
        if n.children.is_empty() {
            out.push_str(n.label.as_deref().unwrap_or("?"));
        } else {
            out.push('(');
            for (i, &c) in n.children.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                self.write_node(c, out);
            }
            out.push(')');
        }
        if idx != self.root {
            out.push_str(&format!(":{:.6}", n.branch));
        }
    }

    /// Parse Newick (subset: labels, branch lengths, nesting).
    pub fn from_newick(text: &str) -> Result<Self> {
        let text = text.trim().trim_end_matches(';');
        let mut nodes: Vec<TreeNode> = Vec::new();
        let chars: Vec<char> = text.chars().collect();
        let mut pos = 0usize;
        let root = parse_node(&chars, &mut pos, &mut nodes, None)?;
        ensure!(pos == chars.len(), "trailing characters at {pos}");
        Ok(Self { nodes, root })
    }

    /// Structural sanity: parent/child symmetry, single root, all
    /// reachable.
    pub fn validate(&self) -> Result<()> {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![self.root];
        ensure!(self.nodes[self.root].parent.is_none(), "root has a parent");
        while let Some(i) = stack.pop() {
            ensure!(!seen[i], "cycle at node {i}");
            seen[i] = true;
            for &c in &self.nodes[i].children {
                ensure!(self.nodes[c].parent == Some(i), "broken parent link {c}");
                stack.push(c);
            }
        }
        ensure!(seen.iter().all(|&s| s), "unreachable nodes");
        for n in &self.nodes {
            if n.children.is_empty() {
                ensure!(n.label.is_some(), "unlabeled leaf");
            }
        }
        Ok(())
    }
}

fn parse_node(
    chars: &[char],
    pos: &mut usize,
    nodes: &mut Vec<TreeNode>,
    parent: Option<usize>,
) -> Result<usize> {
    let idx = nodes.len();
    nodes.push(TreeNode { parent, children: Vec::new(), branch: 0.0, label: None });
    if *pos < chars.len() && chars[*pos] == '(' {
        *pos += 1; // consume '('
        loop {
            let child = parse_node(chars, pos, nodes, Some(idx))?;
            nodes[idx].children.push(child);
            match chars.get(*pos) {
                Some(',') => *pos += 1,
                Some(')') => {
                    *pos += 1;
                    break;
                }
                other => bail!("expected ',' or ')' at {pos}, got {other:?}"),
            }
        }
    }
    // Label.
    let start = *pos;
    while *pos < chars.len() && !matches!(chars[*pos], ',' | ')' | ':' | '(') {
        *pos += 1;
    }
    if *pos > start {
        nodes[idx].label = Some(chars[start..*pos].iter().collect());
    }
    // Branch length.
    if chars.get(*pos) == Some(&':') {
        *pos += 1;
        let start = *pos;
        while *pos < chars.len() && !matches!(chars[*pos], ',' | ')' | '(') {
            *pos += 1;
        }
        let txt: String = chars[start..*pos].iter().collect();
        nodes[idx].branch = txt.parse::<f64>().map_err(|e| anyhow::anyhow!("bad branch {txt:?}: {e}"))?;
    }
    Ok(idx)
}

impl MemSize for Tree {
    fn mem_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| {
                48 + n.children.len() * 8
                    + n.label.as_ref().map(|l| l.len()).unwrap_or(0)
            })
            .sum::<usize>()
            + 24
    }
}

impl Encode for Tree {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.root as u64).encode(out);
        (self.nodes.len() as u64).encode(out);
        for n in &self.nodes {
            n.parent.map(|p| p as u64).encode(out);
            n.children.iter().map(|&c| c as u64).collect::<Vec<_>>().encode(out);
            n.branch.encode(out);
            n.label.clone().encode(out);
        }
    }
}

impl Decode for Tree {
    fn decode(input: &mut &[u8]) -> Result<Self> {
        let root = u64::decode(input)? as usize;
        let n = u64::decode(input)? as usize;
        let mut nodes = Vec::with_capacity(n.min(1 << 20));
        for _ in 0..n {
            let parent = Option::<u64>::decode(input)?.map(|p| p as usize);
            let children = Vec::<u64>::decode(input)?.into_iter().map(|c| c as usize).collect();
            let branch = f64::decode(input)?;
            let label = Option::<String>::decode(input)?;
            nodes.push(TreeNode { parent, children, branch, label });
        }
        Ok(Self { nodes, root })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn newick_roundtrip() {
        let text = "((a:1.000000,b:2.000000):0.500000,c:3.000000);";
        let t = Tree::from_newick(text).unwrap();
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), 3);
        assert_eq!(t.to_newick(), text);
    }

    #[test]
    fn single_leaf() {
        let t = Tree::leaf("x");
        assert_eq!(t.to_newick(), "x;");
        assert_eq!(t.num_leaves(), 1);
        t.validate().unwrap();
    }

    #[test]
    fn graft_preserves_validity() {
        let mut t = Tree::from_newick("(a:1,b:1);").unwrap();
        let sub = Tree::from_newick("(c:1,d:1);").unwrap();
        t.graft(&sub, t.root, 0.7);
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), 4);
        assert!(t.to_newick().contains("(c:1.000000,d:1.000000):0.700000"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Tree::from_newick("((a,b)").is_err());
        assert!(Tree::from_newick("(a:x,b:1);").is_err());
    }

    #[test]
    fn codec_roundtrip() {
        let t = Tree::from_newick("((a:1,b:2):0.5,(c:1,d:1):0.25);").unwrap();
        let back = Tree::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn total_length_sums_branches() {
        let t = Tree::from_newick("((a:1,b:2):0.5,c:3);").unwrap();
        assert!((t.total_length() - 6.5).abs() < 1e-9);
    }
}

//! Sampling-based initial clustering (paper §NJ method): "approximately
//! 10% of all sequences are selected by random sampling for initial
//! clustering ... then sequences are clustered and labeled until all
//! sequences are identified", with rebalancing of degenerate clusters.
//!
//! Implementation: k-center (farthest-point) medoid selection over the
//! sample's k-mer distance matrix (XLA Gram kernel when available), then
//! a distributed map assigns every sequence to its nearest medoid;
//! clusters below the minimum size are merged into their nearest larger
//! cluster, clusters above the maximum are split around a secondary
//! medoid.

use anyhow::{ensure, Result};

use super::distance::{kmer_distance_matrix, kmer_profile};
use crate::distmat::{DenseF32, DistSource};
use crate::engine::Cluster as Engine;
use crate::fasta::Sequence;
use crate::runtime::XlaService;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Sampling fraction for medoid selection (paper: ~10%).
    pub sample_fraction: f64,
    /// Target number of clusters (0 = derive from max_cluster_size).
    pub num_clusters: usize,
    /// Hard cap per cluster (NJ matrix bucket size).
    pub max_cluster_size: usize,
    /// Clusters smaller than this merge into their nearest neighbour.
    pub min_cluster_size: usize,
    /// k-mer length / profile dimension for the distance signal.
    pub k: usize,
    pub profile_dim: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            sample_fraction: 0.10,
            num_clusters: 0,
            max_cluster_size: 96,
            min_cluster_size: 3,
            k: 4,
            profile_dim: 256,
        }
    }
}

/// Cluster assignment: `members[c]` = indices of sequences in cluster c.
#[derive(Debug, Clone)]
pub struct Clustering {
    pub members: Vec<Vec<usize>>,
    /// Index (into the input) of each cluster's medoid.
    pub medoids: Vec<usize>,
}

impl Clustering {
    pub fn num_clusters(&self) -> usize {
        self.members.len()
    }

    pub fn assert_partition(&self, n: usize) -> Result<()> {
        let mut seen = vec![false; n];
        for m in &self.members {
            for &i in m {
                ensure!(!seen[i], "sequence {i} in two clusters");
                seen[i] = true;
            }
        }
        ensure!(seen.iter().all(|&s| s), "not all sequences clustered");
        Ok(())
    }
}

/// Farthest-point medoid selection over any [`DistSource`] backend
/// (dense k-mer matrices today; a tiled source drops in unchanged).
/// `f32 -> f64` promotion is exact and order-preserving, so this picks
/// the same medoids the raw-f32 scan did.
fn k_center(dist: &dyn DistSource, k: usize, rng: &mut Rng) -> Result<Vec<usize>> {
    let n = dist.num_taxa();
    let k = k.min(n).max(1);
    let mut medoids = vec![rng.below(n)];
    let mut mind = Vec::with_capacity(n);
    for i in 0..n {
        mind.push(dist.dist(medoids[0], i)?);
    }
    while medoids.len() < k {
        let (far, _) = mind
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if mind[far] <= 0.0 {
            break; // no more distinct points
        }
        medoids.push(far);
        for (i, m) in mind.iter_mut().enumerate() {
            *m = m.min(dist.dist(far, i)?);
        }
    }
    Ok(medoids)
}

/// Distributed clustering of `seqs` (gaps in rows are ignored by the
/// profile, so this works on raw or aligned sequences).
pub fn cluster_sequences(
    engine: &Engine,
    seqs: &[Sequence],
    svc: Option<&XlaService>,
    cfg: &ClusterConfig,
) -> Result<Clustering> {
    let n = seqs.len();
    ensure!(n > 0, "nothing to cluster");
    let gap = seqs[0].alphabet.gap();
    let target_clusters = if cfg.num_clusters > 0 {
        cfg.num_clusters
    } else {
        n.div_ceil(cfg.max_cluster_size).max(1)
    };
    if n <= cfg.max_cluster_size.min(3) || target_clusters == 1 {
        return Ok(Clustering { members: vec![(0..n).collect()], medoids: vec![0] });
    }

    // --- Sample ~10% and pick medoids from the sample ---------------------
    let mut rng = Rng::seed_from_u64(engine.config().seed ^ 0xC1u64);
    let sample_size = ((n as f64 * cfg.sample_fraction).ceil() as usize)
        .clamp(target_clusters.min(n), 1024.min(n));
    let sample = rng.sample_indices(n, sample_size);
    let sample_profiles: Vec<Vec<f32>> = sample
        .iter()
        .map(|&i| kmer_profile(&seqs[i].codes, cfg.k, cfg.profile_dim, gap))
        .collect();
    let sample_dist = kmer_distance_matrix(&sample_profiles, svc)?;
    let medoid_sample_idx = k_center(&DenseF32(&sample_dist), target_clusters, &mut rng)?;
    let medoids: Vec<usize> = medoid_sample_idx.iter().map(|&s| sample[s]).collect();

    // --- Distributed assignment: nearest medoid per sequence --------------
    let medoid_profiles: Vec<Vec<f32>> = medoids
        .iter()
        .map(|&m| kmer_profile(&seqs[m].codes, cfg.k, cfg.profile_dim, gap))
        .collect();
    let med_bc = engine.broadcast(medoid_profiles)?;
    let med_arc = med_bc.arc();
    let (k, dim) = (cfg.k, cfg.profile_dim);
    let indexed: Vec<(u64, Sequence)> =
        seqs.iter().enumerate().map(|(i, s)| (i as u64, s.clone())).collect();
    let assignments = engine
        .parallelize(indexed, engine.config().default_partitions)
        .map(move |(idx, s)| {
            let p = kmer_profile(&s.codes, k, dim, s.alphabet.gap());
            let mut best = (0usize, f32::INFINITY);
            for (c, mp) in med_arc.iter().enumerate() {
                let d: f32 = p.iter().zip(mp).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.1 {
                    best = (c, d);
                }
            }
            (idx, best.0 as u64)
        })
        .collect()?;

    let mut members: Vec<Vec<usize>> = vec![Vec::new(); medoids.len()];
    for (idx, c) in assignments {
        members[c as usize].push(idx as usize);
    }

    // --- Rebalance ---------------------------------------------------------
    // Merge undersized clusters into the nearest medoid's cluster.
    let medoid_dist = kmer_distance_matrix(
        &medoids
            .iter()
            .map(|&m| kmer_profile(&seqs[m].codes, cfg.k, cfg.profile_dim, gap))
            .collect::<Vec<_>>(),
        svc,
    )?;
    let mut keep: Vec<bool> = members.iter().map(|m| m.len() >= cfg.min_cluster_size).collect();
    if keep.iter().all(|k| !k) {
        keep[0] = true; // degenerate: keep the first
    }
    for c in 0..members.len() {
        if keep[c] || members[c].is_empty() {
            continue;
        }
        let target = (0..members.len())
            .filter(|&o| o != c && keep[o])
            .min_by(|&a, &b| medoid_dist[c][a].partial_cmp(&medoid_dist[c][b]).unwrap())
            .unwrap_or(0);
        let moved = std::mem::take(&mut members[c]);
        members[target].extend(moved);
    }
    // Split oversized clusters round-robin (preserving medoid first).
    let mut final_members = Vec::new();
    let mut final_medoids = Vec::new();
    for (c, m) in members.into_iter().enumerate() {
        if m.is_empty() {
            continue;
        }
        if m.len() <= cfg.max_cluster_size {
            final_medoids.push(medoids[c].min(n - 1));
            final_members.push(m);
        } else {
            let chunks = m.len().div_ceil(cfg.max_cluster_size);
            let per = m.len().div_ceil(chunks);
            for chunk in m.chunks(per) {
                final_medoids.push(chunk[0]);
                final_members.push(chunk.to_vec());
            }
        }
    }
    let clustering = Clustering { members: final_members, medoids: final_medoids };
    clustering.assert_partition(n)?;
    Ok(clustering)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster as Engine, ClusterConfig as EngineConfig};

    #[test]
    fn partitions_all_sequences() {
        let seqs = DatasetSpec::rrna(60, 0.1, 3).generate();
        let engine = Engine::new(EngineConfig::spark(3));
        let c = cluster_sequences(
            &engine,
            &seqs,
            None,
            &ClusterConfig { max_cluster_size: 16, ..Default::default() },
        )
        .unwrap();
        c.assert_partition(60).unwrap();
        assert!(c.num_clusters() >= 2);
        assert!(c.members.iter().all(|m| m.len() <= 16));
    }

    #[test]
    fn small_input_single_cluster() {
        let seqs = DatasetSpec::rrna(3, 0.05, 1).generate();
        let engine = Engine::new(EngineConfig::spark(2));
        let c = cluster_sequences(&engine, &seqs, None, &ClusterConfig::default()).unwrap();
        assert_eq!(c.num_clusters(), 1);
        c.assert_partition(3).unwrap();
    }

    #[test]
    fn clusters_respect_clade_structure() {
        // Two very distinct families: mito-like and a shuffled rrna set —
        // k-mer profiles should separate them cleanly.
        let mut seqs = DatasetSpec { count: 20, ..DatasetSpec::mito(0.01, 2) }.generate();
        let other = DatasetSpec::rrna(20, 0.25, 9).generate();
        seqs.extend(other);
        let engine = Engine::new(EngineConfig::spark(3));
        let c = cluster_sequences(
            &engine,
            &seqs,
            None,
            &ClusterConfig { num_clusters: 2, max_cluster_size: 40, ..Default::default() },
        )
        .unwrap();
        c.assert_partition(40).unwrap();
        // Every cluster should be (nearly) pure: members all < 20 or all >= 20.
        for m in &c.members {
            let fam0 = m.iter().filter(|&&i| i < 20).count();
            let purity = fam0.max(m.len() - fam0) as f64 / m.len() as f64;
            assert!(purity > 0.9, "impure cluster: {purity}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let seqs = DatasetSpec::rrna(40, 0.1, 4).generate();
        let mk = || {
            let engine = Engine::new(EngineConfig::spark(2));
            cluster_sequences(
                &engine,
                &seqs,
                None,
                &ClusterConfig { max_cluster_size: 12, ..Default::default() },
            )
            .unwrap()
            .members
        };
        assert_eq!(mk(), mk());
    }
}

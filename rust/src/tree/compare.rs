//! Robinson-Foulds (symmetric bipartition) distance between trees over
//! the same taxa — the standard topology-quality oracle used by the tree
//! tests and the clustering ablation (how far the clustered-NJ tree is
//! from whole-matrix NJ).

use anyhow::{ensure, Result};

use super::newick::Tree;
use crate::util::hash::{DetHashMap, DetHashSet};

/// The set of non-trivial bipartitions, each encoded as the sorted leaf
/// set of the smaller side (canonical form, leaf names).
fn bipartitions(tree: &Tree) -> Result<DetHashSet<Vec<String>>> {
    let mut leaf_index: DetHashMap<usize, String> = DetHashMap::default();
    for (i, n) in tree.nodes.iter().enumerate() {
        if n.children.is_empty() {
            leaf_index.insert(i, n.label.clone().unwrap_or_default());
        }
    }
    let total = leaf_index.len();
    ensure!(total >= 2, "tree too small for bipartitions");

    // Post-order accumulation of leaf sets below every node.
    let mut below: Vec<Vec<String>> = vec![Vec::new(); tree.nodes.len()];
    let mut order = Vec::new();
    let mut stack = vec![(tree.root, false)];
    while let Some((i, expanded)) = stack.pop() {
        if expanded {
            order.push(i);
        } else {
            stack.push((i, true));
            for &c in &tree.nodes[i].children {
                stack.push((c, false));
            }
        }
    }
    for &i in &order {
        if tree.nodes[i].children.is_empty() {
            below[i] = vec![leaf_index[&i].clone()];
        } else {
            let mut acc = Vec::new();
            for &c in &tree.nodes[i].children {
                acc.extend(below[c].iter().cloned());
            }
            acc.sort();
            below[i] = acc;
        }
    }

    let mut all_leaves: Vec<String> = leaf_index.values().cloned().collect();
    all_leaves.sort();
    let mut out = DetHashSet::default();
    for (i, n) in tree.nodes.iter().enumerate() {
        if n.children.is_empty() || i == tree.root {
            continue; // trivial splits
        }
        let side = &below[i];
        if side.len() <= 1 || side.len() >= total - 1 {
            continue; // also trivial
        }
        // Canonical: the lexicographically smaller of (side, complement).
        let complement: Vec<String> = all_leaves
            .iter()
            .filter(|l| side.binary_search(l).is_err())
            .cloned()
            .collect();
        out.insert(if *side <= complement { side.clone() } else { complement });
    }
    Ok(out)
}

/// Robinson-Foulds distance: |A Δ B| over non-trivial bipartitions.
/// Also returns the maximum possible value (|A| + |B|) for normalizing.
pub fn robinson_foulds(a: &Tree, b: &Tree) -> Result<(usize, usize)> {
    let mut la: Vec<&str> = a.leaf_labels();
    let mut lb: Vec<&str> = b.leaf_labels();
    la.sort();
    lb.sort();
    ensure!(la == lb, "trees must share the same taxon set");
    let ba = bipartitions(a)?;
    let bb = bipartitions(b)?;
    let shared = ba.iter().filter(|s| bb.contains(*s)).count();
    Ok((ba.len() + bb.len() - 2 * shared, ba.len() + bb.len()))
}

/// Normalized RF in [0, 1] (0 = identical topology).
pub fn rf_normalized(a: &Tree, b: &Tree) -> Result<f64> {
    let (d, max) = robinson_foulds(a, b)?;
    Ok(if max == 0 { 0.0 } else { d as f64 / max as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_trees_distance_zero() {
        let t = Tree::from_newick("((a:1,b:1):1,(c:1,d:1):1);").unwrap();
        assert_eq!(robinson_foulds(&t, &t).unwrap().0, 0);
        assert_eq!(rf_normalized(&t, &t).unwrap(), 0.0);
    }

    #[test]
    fn maximally_different_quartets() {
        let t1 = Tree::from_newick("((a:1,b:1):1,(c:1,d:1):1);").unwrap();
        let t2 = Tree::from_newick("((a:1,c:1):1,(b:1,d:1):1);").unwrap();
        let (d, max) = robinson_foulds(&t1, &t2).unwrap();
        assert_eq!(d, max, "conflicting quartets share no splits");
        assert_eq!(rf_normalized(&t1, &t2).unwrap(), 1.0);
    }

    #[test]
    fn branch_lengths_do_not_matter() {
        let t1 = Tree::from_newick("((a:1,b:2):3,(c:4,d:5):6);").unwrap();
        let t2 = Tree::from_newick("((a:9,b:9):9,(c:9,d:9):9);").unwrap();
        assert_eq!(robinson_foulds(&t1, &t2).unwrap().0, 0);
    }

    #[test]
    fn different_taxa_rejected() {
        let t1 = Tree::from_newick("((a:1,b:1):1,(c:1,d:1):1);").unwrap();
        let t2 = Tree::from_newick("((a:1,b:1):1,(c:1,e:1):1);").unwrap();
        assert!(robinson_foulds(&t1, &t2).is_err());
    }

    #[test]
    fn clustered_nj_topologically_close_to_full_nj() {
        use crate::align::center_star::{align_nucleotide, CenterStarConfig};
        use crate::data::DatasetSpec;
        use crate::engine::{Cluster, ClusterConfig as EC};
        use crate::tree::{build_tree, ClusterConfig, TreeConfig};

        // Use divergent clade-structured data: on ultra-similar mito
        // genomes NJ topology is noise (all distances ~0), so RF between
        // any two methods is uninformative there.
        let seqs = DatasetSpec::rrna(24, 0.3, 51).generate();
        let engine = Cluster::new(EC::spark(3));
        let msa = align_nucleotide(
            &engine,
            &seqs,
            &CenterStarConfig { segment_len: 10, ..Default::default() },
        )
        .unwrap();
        let full = build_tree(
            &engine,
            &msa.aligned,
            None,
            &TreeConfig {
                clustering: ClusterConfig { num_clusters: 1, max_cluster_size: 999, ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap();
        let clustered = build_tree(
            &engine,
            &msa.aligned,
            None,
            &TreeConfig {
                clustering: ClusterConfig { max_cluster_size: 8, ..Default::default() },
                ..Default::default()
            },
        )
        .unwrap();
        let rf = rf_normalized(&full.tree, &clustered.tree).unwrap();
        // The clustered approximation trades fine topology for scale
        // (the paper: "our method ignores high precision for changing
        // large-scale computing power"): likelihood stays within 1% of
        // full NJ (tree::tests) while a sizable fraction of splits moves.
        // Deterministic seed -> stable value; guard the regression band.
        assert!(rf < 0.85, "clustered-vs-full RF regressed (rf = {rf})");
        assert!(rf > 0.0, "suspiciously identical trees for 3 clusters");
    }
}

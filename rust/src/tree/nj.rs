//! Neighbor-joining (Saitou & Nei 1987) — the paper's distance-based tree
//! method ("time-efficient and suitable for ultra-large sequences data").
//!
//! Classic O(n³)-time / O(n²)-space implementation with an active-node
//! list and incrementally maintained row sums (the O(n²) update the
//! HPTree line of work relies on).

use anyhow::{ensure, Result};

use super::newick::{Tree, TreeNode};

/// Build an NJ tree over `labels` with the given symmetric distance
/// matrix.  Returns a rooted binary-ish tree (the final join becomes the
/// root's children).
pub fn neighbor_joining(labels: &[String], dist: &[Vec<f64>]) -> Result<Tree> {
    let n = labels.len();
    ensure!(n > 0, "empty taxon set");
    ensure!(dist.len() == n && dist.iter().all(|r| r.len() == n), "bad matrix shape");
    if n == 1 {
        return Ok(Tree::leaf(labels[0].clone()));
    }

    // Working copy of the distance matrix; grows as joins add nodes.
    let mut d: Vec<Vec<f64>> = dist.to_vec();
    // node id of each working row (tree node indices).
    let mut nodes: Vec<TreeNode> = labels
        .iter()
        .map(|l| TreeNode {
            parent: None,
            children: Vec::new(),
            branch: 0.0,
            label: Some(l.clone()),
        })
        .collect();
    let mut active: Vec<usize> = (0..n).collect(); // indices into d/nodes

    // Row sums over active set.
    let mut rowsum: Vec<f64> = (0..n)
        .map(|i| (0..n).map(|j| d[i][j]).sum())
        .collect();

    while active.len() > 2 {
        let r = active.len() as f64;
        // Find the pair minimizing the Q criterion.
        let (mut best_q, mut bi, mut bj) = (f64::INFINITY, 0usize, 1usize);
        for (ai, &i) in active.iter().enumerate() {
            for &j in active.iter().skip(ai + 1) {
                let q = (r - 2.0) * d[i][j] - rowsum[i] - rowsum[j];
                if q < best_q {
                    best_q = q;
                    bi = i;
                    bj = j;
                }
            }
        }
        // Branch lengths to the new internal node.
        let dij = d[bi][bj];
        let li = 0.5 * dij + (rowsum[bi] - rowsum[bj]) / (2.0 * (r - 2.0));
        let li = li.clamp(0.0, dij.max(0.0));
        let lj = (dij - li).max(0.0);

        let u = nodes.len();
        nodes.push(TreeNode { parent: None, children: vec![bi, bj], branch: 0.0, label: None });
        nodes[bi].parent = Some(u);
        nodes[bi].branch = li;
        nodes[bj].parent = Some(u);
        nodes[bj].branch = lj;

        // New distance row: d(u, k) = (d(i,k) + d(j,k) - d(i,j)) / 2.
        let mut du = vec![0f64; u + 1];
        for &k in &active {
            if k == bi || k == bj {
                continue;
            }
            du[k] = ((d[bi][k] + d[bj][k] - dij) / 2.0).max(0.0);
        }
        for row in d.iter_mut() {
            row.push(0.0);
        }
        d.push(du.clone());
        for &k in &active {
            if k != bi && k != bj {
                d[k][u] = du[k];
                d[u][k] = du[k];
            }
        }
        // Update active set and row sums.
        active.retain(|&k| k != bi && k != bj);
        for &k in &active {
            rowsum[k] -= d[bi][k] + d[bj][k];
            rowsum[k] += d[u][k];
        }
        let su: f64 = active.iter().map(|&k| d[u][k]).sum();
        rowsum.push(su);
        active.push(u);
    }

    // Join the final two under a root.
    let (a, b) = (active[0], active[1]);
    let root = nodes.len();
    let dab = d[a][b].max(0.0);
    nodes.push(TreeNode { parent: None, children: vec![a, b], branch: 0.0, label: None });
    nodes[a].parent = Some(root);
    nodes[a].branch = dab / 2.0;
    nodes[b].parent = Some(root);
    nodes[b].branch = dab / 2.0;

    let tree = Tree { nodes, root };
    tree.validate()?;
    Ok(tree)
}

/// Leaf-to-leaf path distance in a tree (test helper for the 4-point
/// consistency of NJ on additive matrices).
pub fn tree_distance(tree: &Tree, a: &str, b: &str) -> Option<f64> {
    let find = |lbl: &str| {
        tree.nodes
            .iter()
            .position(|n| n.label.as_deref() == Some(lbl) && n.children.is_empty())
    };
    let (mut x, mut y) = (find(a)?, find(b)?);
    // Collect depth paths to root.
    let depth = |mut i: usize| {
        let mut d = 0;
        while let Some(p) = tree.nodes[i].parent {
            i = p;
            d += 1;
        }
        d
    };
    let (mut dx, mut dy) = (depth(x), depth(y));
    let mut total = 0.0;
    while dx > dy {
        total += tree.nodes[x].branch;
        x = tree.nodes[x].parent.unwrap();
        dx -= 1;
    }
    while dy > dx {
        total += tree.nodes[y].branch;
        y = tree.nodes[y].parent.unwrap();
        dy -= 1;
    }
    while x != y {
        total += tree.nodes[x].branch + tree.nodes[y].branch;
        x = tree.nodes[x].parent.unwrap();
        y = tree.nodes[y].parent.unwrap();
    }
    Some(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("t{i}")).collect()
    }

    #[test]
    fn two_taxa() {
        let t = neighbor_joining(&labels(2), &[vec![0.0, 1.0], vec![1.0, 0.0]]).unwrap();
        assert_eq!(t.num_leaves(), 2);
        assert!((tree_distance(&t, "t0", "t1").unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recovers_additive_tree_distances() {
        // Tree: ((A:2,B:3):1,(C:4,D:5):1) — additive matrix below.
        let d = vec![
            vec![0.0, 5.0, 7.0, 8.0],
            vec![5.0, 0.0, 8.0, 9.0],
            vec![7.0, 8.0, 0.0, 9.0],
            vec![8.0, 9.0, 9.0, 0.0],
        ];
        let lbl = vec!["A".into(), "B".into(), "C".into(), "D".into()];
        let t = neighbor_joining(&lbl, &d).unwrap();
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), 4);
        // NJ is consistent on additive matrices: path lengths match input.
        for (i, a) in ["A", "B", "C", "D"].iter().enumerate() {
            for (j, b) in ["A", "B", "C", "D"].iter().enumerate() {
                if i < j {
                    let td = tree_distance(&t, a, b).unwrap();
                    assert!(
                        (td - d[i][j]).abs() < 1e-6,
                        "d({a},{b}) = {td}, want {}",
                        d[i][j]
                    );
                }
            }
        }
    }

    #[test]
    fn correct_topology_for_clustered_taxa() {
        // Two tight pairs far apart: (A,B) and (C,D) must be siblings.
        let d = vec![
            vec![0.0, 0.1, 2.0, 2.0],
            vec![0.1, 0.0, 2.0, 2.0],
            vec![2.0, 2.0, 0.0, 0.1],
            vec![2.0, 2.0, 0.1, 0.0],
        ];
        let lbl = vec!["A".into(), "B".into(), "C".into(), "D".into()];
        let t = neighbor_joining(&lbl, &d).unwrap();
        let ab = tree_distance(&t, "A", "B").unwrap();
        let ac = tree_distance(&t, "A", "C").unwrap();
        assert!(ab < ac, "A-B ({ab}) should be closer than A-C ({ac})");
    }

    #[test]
    fn handles_moderate_sizes() {
        use crate::util::Rng;
        let n = 64;
        let mut rng = Rng::seed_from_u64(5);
        let mut d = vec![vec![0f64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.1 + rng.f64();
                d[i][j] = v;
                d[j][i] = v;
            }
        }
        let t = neighbor_joining(&labels(n), &d).unwrap();
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), n);
        assert!(t.total_length() > 0.0);
    }

    #[test]
    fn single_taxon_is_leaf() {
        let t = neighbor_joining(&labels(1), &[vec![0.0]]).unwrap();
        assert_eq!(t.num_leaves(), 1);
    }

    #[test]
    fn rejects_ragged_matrix() {
        assert!(neighbor_joining(&labels(2), &[vec![0.0, 1.0]]).is_err());
    }
}

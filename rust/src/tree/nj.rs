//! Neighbor-joining (Saitou & Nei 1987) — the paper's distance-based tree
//! method ("time-efficient and suitable for ultra-large sequences data").
//!
//! O(n³)-time implementation with an active-node list and incrementally
//! maintained row sums, generalized to consume any
//! [`DistSource`](crate::distmat::DistSource) — a dense in-memory matrix
//! or a tiled, byte-budgeted on-disk one — instead of `&[Vec<f64>]`:
//!
//! * **Leaf-leaf distances** are read through the source; tiled backends
//!   serve them from resident-or-spilled tiles.
//! * **Merged-node rows** (the working set joins create) live in a
//!   [`TileStore`] keyed past the tile range, so the whole NJ run stays
//!   inside one byte budget instead of materializing a growing O(n²)
//!   matrix.
//! * **Row-min caches** (rapid-NJ style) prune the Q-criterion scan:
//!   each node carries a stale-low lower bound on its min distance, and
//!   a row whose Q lower bound `(r-2)·dmin_i - rowsum_i - max_rowsum`
//!   cannot beat the current best is skipped without touching any
//!   (possibly spilled) tile.  The prune is *exact* — a skipped row
//!   provably contains no strictly smaller Q, and ties keep the
//!   first-scanned pair exactly as the unpruned loop would — so dense
//!   and tiled backends produce bit-identical trees (property-tested).

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::newick::{Tree, TreeNode};
use crate::distmat::{DenseView, DistSource, TileStore};

/// Working-storage knobs for [`neighbor_joining_src`].
#[derive(Clone, Default)]
pub struct NjConfig {
    /// Store for merged-node rows.  `None` = a private unbounded
    /// in-memory store (the dense-equivalent mode).  Tiled pipelines
    /// pass the tile store itself so one byte budget governs tiles and
    /// working rows together.
    pub row_store: Option<Arc<TileStore>>,
    /// First key NJ may use inside `row_store` (set it past
    /// `grid.num_tiles()` when sharing a tile store).
    pub row_key_base: u64,
}

/// Build an NJ tree over `labels` with the given symmetric dense
/// distance matrix (thin wrapper over [`neighbor_joining_src`]).
/// Returns a rooted binary-ish tree (the final join becomes the root's
/// children).
pub fn neighbor_joining(labels: &[String], dist: &[Vec<f64>]) -> Result<Tree> {
    let n = labels.len();
    ensure!(dist.len() == n && dist.iter().all(|r| r.len() == n), "bad matrix shape");
    neighbor_joining_src(labels, &DenseView(dist), &NjConfig::default())
}

/// Neighbor-joining over any [`DistSource`] backend (see module docs).
pub fn neighbor_joining_src(
    labels: &[String],
    src: &dyn DistSource,
    cfg: &NjConfig,
) -> Result<Tree> {
    let n = labels.len();
    ensure!(n > 0, "empty taxon set");
    ensure!(src.num_taxa() == n, "distance source covers {} taxa, labels {n}", src.num_taxa());
    if n == 1 {
        return Ok(Tree::leaf(labels[0].clone()));
    }

    let rows = cfg.row_store.clone().unwrap_or_else(|| Arc::new(TileStore::in_memory()));
    let key_base = cfg.row_key_base;
    // d(a, b) for any pair of node ids: leaves go through the source,
    // merged nodes through their stored row (row of the larger id holds
    // every smaller id).
    let dist_any = |a: usize, b: usize| -> Result<f64> {
        debug_assert_ne!(a, b);
        let (hi, lo) = if a > b { (a, b) } else { (b, a) };
        if hi < n {
            src.dist(hi, lo)
        } else {
            Ok(rows.get(key_base + (hi - n) as u64)?[lo])
        }
    };

    let mut nodes: Vec<TreeNode> = labels
        .iter()
        .map(|l| TreeNode {
            parent: None,
            children: Vec::new(),
            branch: 0.0,
            label: Some(l.clone()),
        })
        .collect();
    let mut active: Vec<usize> = (0..n).collect();

    // Row sums and row-min caches over the active set, seeded in one
    // pass over the source (tiled backends read each tile once here).
    // `dmin[k]` is maintained as a *lower bound*: joins can only remove
    // partners (raising the true min) or add one new distance (folded in
    // below), and a stale-low bound only weakens the prune, never its
    // exactness.
    let (mut rowsum, mut dmin) = src.row_stats()?;

    while active.len() > 2 {
        let r = active.len() as f64;
        let max_rowsum =
            active.iter().map(|&k| rowsum[k]).fold(f64::NEG_INFINITY, f64::max);
        // Find the pair minimizing the Q criterion.  Row prune: every
        // pair (i, j) satisfies q >= (r-2)·dmin_i - rowsum_i -
        // max_rowsum; once a best pair exists, rows whose bound cannot
        // *strictly* beat it are skipped — exactly the pairs the plain
        // scan would have rejected (`q < best_q` is strict), so the
        // selected pair and tie-breaking are identical to the unpruned
        // loop.
        let (mut best_q, mut bi, mut bj) = (f64::INFINITY, 0usize, 1usize);
        for (ai, &i) in active.iter().enumerate() {
            if ai + 1 == active.len() {
                break;
            }
            if best_q.is_finite()
                && (r - 2.0) * dmin[i] - rowsum[i] - max_rowsum >= best_q
            {
                continue;
            }
            for &j in active.iter().skip(ai + 1) {
                let q = (r - 2.0) * dist_any(i, j)? - rowsum[i] - rowsum[j];
                if q < best_q {
                    best_q = q;
                    bi = i;
                    bj = j;
                }
            }
        }
        // Branch lengths to the new internal node.
        let dij = dist_any(bi, bj)?;
        let li = 0.5 * dij + (rowsum[bi] - rowsum[bj]) / (2.0 * (r - 2.0));
        let li = li.clamp(0.0, dij.max(0.0));
        let lj = (dij - li).max(0.0);

        let u = nodes.len();
        nodes.push(TreeNode { parent: None, children: vec![bi, bj], branch: 0.0, label: None });
        nodes[bi].parent = Some(u);
        nodes[bi].branch = li;
        nodes[bj].parent = Some(u);
        nodes[bj].branch = lj;

        // New distance row: d(u, k) = (d(i,k) + d(j,k) - d(i,j)) / 2,
        // stored over every node id < u (inactive slots stay 0).  Row
        // sums and min caches update in the same pass — each d(bi,k) /
        // d(bj,k) is read from the (possibly spilled) store exactly once
        // per join.
        let mut du = vec![0f64; u];
        let mut dmin_u = f64::INFINITY;
        for &k in &active {
            if k == bi || k == bj {
                continue;
            }
            let d_bik = dist_any(bi, k)?;
            let d_bjk = dist_any(bj, k)?;
            du[k] = ((d_bik + d_bjk - dij) / 2.0).max(0.0);
            rowsum[k] -= d_bik + d_bjk;
            rowsum[k] += du[k];
            dmin[k] = dmin[k].min(du[k]);
            dmin_u = dmin_u.min(du[k]);
        }
        active.retain(|&k| k != bi && k != bj);
        let su: f64 = active.iter().map(|&k| du[k]).sum();
        rowsum.push(su);
        dmin.push(dmin_u);
        rows.put(key_base + (u - n) as u64, du)?;
        active.push(u);
    }

    // Join the final two under a root.
    let (a, b) = (active[0], active[1]);
    let root = nodes.len();
    let dab = dist_any(a, b)?.max(0.0);
    nodes.push(TreeNode { parent: None, children: vec![a, b], branch: 0.0, label: None });
    nodes[a].parent = Some(root);
    nodes[a].branch = dab / 2.0;
    nodes[b].parent = Some(root);
    nodes[b].branch = dab / 2.0;

    let tree = Tree { nodes, root };
    tree.validate()?;
    Ok(tree)
}

/// Leaf-to-leaf path distance in a tree (test helper for the 4-point
/// consistency of NJ on additive matrices).
pub fn tree_distance(tree: &Tree, a: &str, b: &str) -> Option<f64> {
    let find = |lbl: &str| {
        tree.nodes
            .iter()
            .position(|n| n.label.as_deref() == Some(lbl) && n.children.is_empty())
    };
    let (mut x, mut y) = (find(a)?, find(b)?);
    // Collect depth paths to root.
    let depth = |mut i: usize| {
        let mut d = 0;
        while let Some(p) = tree.nodes[i].parent {
            i = p;
            d += 1;
        }
        d
    };
    let (mut dx, mut dy) = (depth(x), depth(y));
    let mut total = 0.0;
    while dx > dy {
        total += tree.nodes[x].branch;
        x = tree.nodes[x].parent.unwrap();
        dx -= 1;
    }
    while dy > dx {
        total += tree.nodes[y].branch;
        y = tree.nodes[y].parent.unwrap();
        dy -= 1;
    }
    while x != y {
        total += tree.nodes[x].branch + tree.nodes[y].branch;
        x = tree.nodes[x].parent.unwrap();
        y = tree.nodes[y].parent.unwrap();
    }
    Some(total)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("t{i}")).collect()
    }

    #[test]
    fn two_taxa() {
        let t = neighbor_joining(&labels(2), &[vec![0.0, 1.0], vec![1.0, 0.0]]).unwrap();
        assert_eq!(t.num_leaves(), 2);
        assert!((tree_distance(&t, "t0", "t1").unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recovers_additive_tree_distances() {
        // Tree: ((A:2,B:3):1,(C:4,D:5):1) — additive matrix below.
        let d = vec![
            vec![0.0, 5.0, 7.0, 8.0],
            vec![5.0, 0.0, 8.0, 9.0],
            vec![7.0, 8.0, 0.0, 9.0],
            vec![8.0, 9.0, 9.0, 0.0],
        ];
        let lbl = vec!["A".into(), "B".into(), "C".into(), "D".into()];
        let t = neighbor_joining(&lbl, &d).unwrap();
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), 4);
        // NJ is consistent on additive matrices: path lengths match input.
        for (i, a) in ["A", "B", "C", "D"].iter().enumerate() {
            for (j, b) in ["A", "B", "C", "D"].iter().enumerate() {
                if i < j {
                    let td = tree_distance(&t, a, b).unwrap();
                    assert!(
                        (td - d[i][j]).abs() < 1e-6,
                        "d({a},{b}) = {td}, want {}",
                        d[i][j]
                    );
                }
            }
        }
    }

    #[test]
    fn correct_topology_for_clustered_taxa() {
        // Two tight pairs far apart: (A,B) and (C,D) must be siblings.
        let d = vec![
            vec![0.0, 0.1, 2.0, 2.0],
            vec![0.1, 0.0, 2.0, 2.0],
            vec![2.0, 2.0, 0.0, 0.1],
            vec![2.0, 2.0, 0.1, 0.0],
        ];
        let lbl = vec!["A".into(), "B".into(), "C".into(), "D".into()];
        let t = neighbor_joining(&lbl, &d).unwrap();
        let ab = tree_distance(&t, "A", "B").unwrap();
        let ac = tree_distance(&t, "A", "C").unwrap();
        assert!(ab < ac, "A-B ({ab}) should be closer than A-C ({ac})");
    }

    #[test]
    fn handles_moderate_sizes() {
        use crate::util::Rng;
        let n = 64;
        let mut rng = Rng::seed_from_u64(5);
        let mut d = vec![vec![0f64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.1 + rng.f64();
                d[i][j] = v;
                d[j][i] = v;
            }
        }
        let t = neighbor_joining(&labels(n), &d).unwrap();
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), n);
        assert!(t.total_length() > 0.0);
    }

    #[test]
    fn single_taxon_is_leaf() {
        let t = neighbor_joining(&labels(1), &[vec![0.0]]).unwrap();
        assert_eq!(t.num_leaves(), 1);
    }

    fn random_matrix(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let mut d = vec![vec![0f64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.05 + rng.f64();
                d[i][j] = v;
                d[j][i] = v;
            }
        }
        d
    }

    /// Feed a dense matrix through a real tiled store (tiny budget, so
    /// tiles and merged rows spill) and require the exact tree.
    #[test]
    fn tiled_source_with_spilling_rows_is_bit_identical_to_dense() {
        use crate::distmat::{TileGrid, TiledDist};
        for (n, tile_rows, seed) in [(12usize, 3usize, 1u64), (20, 7, 2), (9, 1, 3), (16, 16, 4)] {
            let d = random_matrix(n, seed);
            let lbl = labels(n);
            let dense_tree = neighbor_joining(&lbl, &d).unwrap();

            let grid = TileGrid::new(n, tile_rows);
            let dir = std::env::temp_dir().join(format!(
                "halign2-njspill-{}-{n}-{tile_rows}",
                std::process::id()
            ));
            let store = Arc::new(TileStore::spilling(dir, 256).unwrap());
            for t in 0..grid.num_tiles() {
                let tile = grid.tile(t);
                let mut entries = Vec::with_capacity(tile.num_entries());
                for i in tile.row_lo..tile.row_hi {
                    for j in tile.col_lo..tile.col_hi {
                        entries.push(d[i][j]);
                    }
                }
                store.put(t as u64, entries).unwrap();
            }
            let tiled = TiledDist::new(grid, store);
            let cfg = NjConfig {
                row_store: Some(tiled.store_arc()),
                row_key_base: tiled.row_key_base(),
            };
            let tiled_tree = neighbor_joining_src(&lbl, &tiled, &cfg).unwrap();
            assert_eq!(
                dense_tree, tiled_tree,
                "n={n} tile={tile_rows}: tiled NJ must equal dense bit for bit"
            );
            assert!(
                tiled.store_arc().spill_files_written() > 0,
                "n={n}: a 256-byte budget must have spilled"
            );
            if tiled.grid().num_row_blocks() > 1 {
                // Multi-tile grids: the resident working set stays below
                // the dense matrix (a single-tile grid's one tile *is*
                // the matrix, so the bound is vacuous there).
                assert!(
                    tiled.peak_resident_bytes() < n * n * 8,
                    "n={n}: peak {} must stay below dense {}",
                    tiled.peak_resident_bytes(),
                    n * n * 8
                );
            }
        }
    }

    /// The row-prune must be inert: an adversarial matrix with massive
    /// Q ties (all distances equal) picks the same pair as the plain
    /// scan order dictates.
    #[test]
    fn prune_preserves_tie_breaking_on_uniform_matrices() {
        let n = 10;
        let mut d = vec![vec![1.0f64; n]; n];
        for (i, row) in d.iter_mut().enumerate() {
            row[i] = 0.0;
        }
        let t = neighbor_joining(&labels(n), &d).unwrap();
        t.validate().unwrap();
        assert_eq!(t.num_leaves(), n);
        // First join under full ties must be the first-scanned pair
        // (t0, t1): node n is their parent.
        assert_eq!(t.nodes[n].children, vec![0, 1], "tie-break must match the plain scan");
    }

    #[test]
    fn rejects_ragged_matrix() {
        assert!(neighbor_joining(&labels(2), &[vec![0.0, 1.0]]).is_err());
    }
}

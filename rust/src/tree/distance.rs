//! Distance computations for clustering and neighbor-joining.
//!
//! * [`kmer_profile`] / [`kmer_distance_matrix`] — alignment-free hashed
//!   k-mer count profiles (the sampling-clustering signal).  Batched
//!   through the AOT Gram-matrix kernel when an [`XlaService`] is
//!   available, native otherwise.
//! * [`pdistance_matrix`] — p-distances over *aligned* rows (the NJ
//!   input).  The XLA path runs the match-count kernel twice — once on
//!   residue codes, once on gap indicators — and solves exactly for the
//!   residue-match and comparable-column counts (see the algebra below);
//!   the native path counts directly.  Both paths agree exactly (tested).
//!
//! Gap algebra for a pair (i, j) over width L with g_i/g_j gap columns:
//! let G = #(both gap), C = #(both non-gap), M = kernel match count over
//! codes (counts gap-gap as a match since gap is a shared code), and
//! B = kernel match count over gap indicators = G + C.  Then
//! `G = (B - L + g_i + g_j) / 2`, `C = L - g_i - g_j + G`, residue
//! matches = M - G, and p = 1 - (M - G)/C.

use anyhow::{ensure, Result};

use crate::align::myers::{pack_row, pdist_counts_packed, RowBits};
use crate::align::KernelBackend;
use crate::fasta::{Alphabet, Sequence};
use crate::runtime::{batcher, ArtifactKind, XlaService};

/// Hashed k-mer count profile of a (degapped) sequence.
pub fn kmer_profile(codes: &[u8], k: usize, dim: usize, gap: u8) -> Vec<f32> {
    let mut profile = vec![0f32; dim];
    let clean: Vec<u8> = codes.iter().copied().filter(|&c| c != gap).collect();
    if clean.len() < k {
        return profile;
    }
    for w in clean.windows(k) {
        let h = crate::util::hash::det_hash(&w);
        profile[(h % dim as u64) as usize] += 1.0;
    }
    profile
}

/// Squared-euclidean distance of one profile pair — the shared kernel
/// both the dense matrix below and the distmat k-mer tile jobs call, so
/// the two backends are bit-identical by construction.  Exactly
/// symmetric in its arguments.
pub fn kmer_sqdist_pair(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Squared-euclidean distances between k-mer profiles (native).
pub fn kmer_distance_native(profiles: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let n = profiles.len();
    let mut d = vec![vec![0f32; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let s = kmer_sqdist_pair(&profiles[i], &profiles[j]);
            d[i][j] = s;
            d[j][i] = s;
        }
    }
    d
}

/// p-distance of one aligned row pair (columns where either side is a
/// gap are skipped; an all-gap overlap counts as distance 0).  The
/// shared kernel of [`pdistance_native`] and the distmat p-distance tile
/// jobs — keeping it in one place is what makes the tiled backend
/// bit-identical to the dense path.  Exactly symmetric in its
/// arguments.
pub fn pdist_pair(a: &[u8], b: &[u8], gap: u8) -> f64 {
    debug_assert_eq!(a.len(), b.len(), "rows must be aligned");
    let (mut compared, mut mismatch) = (0u64, 0u64);
    for (x, y) in a.iter().zip(b) {
        if *x == gap || *y == gap {
            continue;
        }
        compared += 1;
        if x != y {
            mismatch += 1;
        }
    }
    if compared == 0 {
        0.0
    } else {
        mismatch as f64 / compared as f64
    }
}

/// p-distance of one bit-packed aligned row pair.  The counts come from
/// [`pdist_counts_packed`] (integer popcounts), so the ratio is
/// bit-identical to [`pdist_pair`] on the same rows.
pub fn pdist_pair_packed(a: &RowBits, b: &RowBits) -> f64 {
    let (compared, mismatch) = pdist_counts_packed(a, b);
    if compared == 0 {
        0.0
    } else {
        mismatch as f64 / compared as f64
    }
}

/// Squared-euclidean k-mer distances, XLA-batched when possible.
pub fn kmer_distance_matrix(
    profiles: &[Vec<f32>],
    svc: Option<&XlaService>,
) -> Result<Vec<Vec<f32>>> {
    if let Some(svc) = svc {
        if !profiles.is_empty()
            && svc
                .manifest()
                .kmer_bucket(profiles.len(), profiles[0].len())
                .is_some()
        {
            return batcher::kmer_sqdist(svc, profiles);
        }
    }
    Ok(kmer_distance_native(profiles))
}

/// Pairwise p-distances over aligned rows (native path, default kernel).
pub fn pdistance_native(rows: &[Sequence]) -> Result<Vec<Vec<f64>>> {
    pdistance_native_with(rows, KernelBackend::default())
}

/// Pairwise p-distances over aligned rows through the selected kernel:
/// `Scalar` runs the byte loop per pair; `BitParallel` packs every row
/// into bitplanes once and popcounts, O(n²·L/64) instead of O(n²·L).
/// Bit-identical results (integer counts either way).
pub fn pdistance_native_with(rows: &[Sequence], kernel: KernelBackend) -> Result<Vec<Vec<f64>>> {
    let n = rows.len();
    let mut d = vec![vec![0f64; n]; n];
    if n == 0 {
        return Ok(d);
    }
    let gap = rows[0].alphabet.gap();
    let width = rows[0].len();
    ensure!(rows.iter().all(|r| r.len() == width), "rows must be aligned");
    match kernel {
        KernelBackend::Scalar => {
            for i in 0..n {
                for j in (i + 1)..n {
                    let p = pdist_pair(&rows[i].codes, &rows[j].codes, gap);
                    d[i][j] = p;
                    d[j][i] = p;
                }
            }
        }
        KernelBackend::BitParallel => {
            let packed: Vec<RowBits> = rows.iter().map(|r| pack_row(&r.codes, gap)).collect();
            for i in 0..n {
                for j in (i + 1)..n {
                    let p = pdist_pair_packed(&packed[i], &packed[j]);
                    d[i][j] = p;
                    d[j][i] = p;
                }
            }
        }
    }
    Ok(d)
}

/// Extend an n×n p-distance matrix to (n+k)×(n+k) for `k` appended
/// rows, computing only the new pairs: O(k·(n+k)) kernel calls instead
/// of O((n+k)²).
///
/// `rows` is the FULL aligned union (old n rows + k new, all one
/// width).  The old block is copied bit-for-bit from `old`.  This is
/// sound even though an append may have *widened* the alignment:
/// widening inserts the same all-gap columns into every old row, and
/// [`pdist_pair`] skips any column where either side is a gap, so the
/// integer (compared, mismatch) counts between two old rows — and hence
/// their p-distance bits — are unchanged.  The result is therefore
/// bit-identical to `pdistance_native_with(rows, kernel)` from scratch
/// (pinned in tests).
pub fn pdistance_extend_with(
    old: &[Vec<f64>],
    rows: &[Sequence],
    kernel: KernelBackend,
) -> Result<Vec<Vec<f64>>> {
    let n = old.len();
    let m = rows.len();
    ensure!(m >= n, "union has fewer rows ({m}) than the old matrix ({n})");
    ensure!(old.iter().all(|r| r.len() == n), "old matrix must be square");
    let mut d = vec![vec![0f64; m]; m];
    for (i, row) in old.iter().enumerate() {
        d[i][..n].copy_from_slice(row);
    }
    if m == n {
        return Ok(d);
    }
    let gap = rows[0].alphabet.gap();
    let width = rows[0].len();
    ensure!(rows.iter().all(|r| r.len() == width), "rows must be aligned");
    match kernel {
        KernelBackend::Scalar => {
            for j in n..m {
                for i in 0..j {
                    let p = pdist_pair(&rows[i].codes, &rows[j].codes, gap);
                    d[i][j] = p;
                    d[j][i] = p;
                }
            }
        }
        KernelBackend::BitParallel => {
            let packed: Vec<RowBits> = rows.iter().map(|r| pack_row(&r.codes, gap)).collect();
            for j in n..m {
                for i in 0..j {
                    let p = pdist_pair_packed(&packed[i], &packed[j]);
                    d[i][j] = p;
                    d[j][i] = p;
                }
            }
        }
    }
    Ok(d)
}

/// Pairwise p-distances, via the XLA match-count kernel when a bucket
/// covers (rows, width); exact native fallback otherwise.
pub fn pdistance_matrix(rows: &[Sequence], svc: Option<&XlaService>) -> Result<Vec<Vec<f64>>> {
    pdistance_matrix_with(rows, svc, KernelBackend::default())
}

/// [`pdistance_matrix`] with an explicit native-kernel choice for the
/// fallback path (the XLA path is unaffected by the kernel switch).
pub fn pdistance_matrix_with(
    rows: &[Sequence],
    svc: Option<&XlaService>,
    kernel: KernelBackend,
) -> Result<Vec<Vec<f64>>> {
    let n = rows.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let alphabet = rows[0].alphabet;
    let width = rows[0].len();
    let kind = match alphabet {
        Alphabet::Dna => ArtifactKind::MatchDna,
        Alphabet::Protein => ArtifactKind::MatchProtein,
    };
    let Some(svc) = svc else { return pdistance_native_with(rows, kernel) };
    if svc.manifest().match_bucket(kind, n, width).is_none() {
        return pdistance_native_with(rows, kernel);
    }

    let gap = alphabet.gap();
    let codes: Vec<Vec<i32>> = rows
        .iter()
        .map(|r| r.codes.iter().map(|&c| c as i32).collect())
        .collect();
    // Gap indicators expressed in the same alphabet (codes 0/1 are valid
    // residue codes, so the same artifact serves).
    let indicators: Vec<Vec<i32>> = rows
        .iter()
        .map(|r| r.codes.iter().map(|&c| (c == gap) as i32).collect())
        .collect();
    let m = batcher::match_counts(svc, kind, &codes, alphabet.size())?;
    let b = batcher::match_counts(svc, kind, &indicators, alphabet.size())?;
    let gaps_per_row: Vec<f64> = rows
        .iter()
        .map(|r| r.codes.iter().filter(|&&c| c == gap).count() as f64)
        .collect();

    let l = width as f64;
    let mut d = vec![vec![0f64; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let g = (b[i][j] as f64 - l + gaps_per_row[i] + gaps_per_row[j]) / 2.0;
            let c = l - gaps_per_row[i] - gaps_per_row[j] + g;
            let matches = m[i][j] as f64 - g;
            let p = if c <= 0.0 { 0.0 } else { ((c - matches) / c).clamp(0.0, 1.0) };
            d[i][j] = p;
            d[j][i] = p;
        }
    }
    Ok(d)
}

/// Jukes-Cantor correction of a p-distance (DNA: 4 states; proteins use
/// the same family with 20 states).  Saturated distances clamp to a cap.
pub fn jc_distance(p: f64, states: usize) -> f64 {
    let b = (states as f64 - 1.0) / states as f64;
    let x = 1.0 - p / b;
    if x <= 1e-9 {
        return 5.0; // saturation cap
    }
    (-b * x.ln()).min(5.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::Alphabet;

    fn seq(id: &str, t: &str) -> Sequence {
        Sequence::from_text(id, t, Alphabet::Dna)
    }

    #[test]
    fn kmer_profile_counts_windows() {
        let s = seq("x", "ACGTACGT");
        let p = kmer_profile(&s.codes, 4, 64, Alphabet::Dna.gap());
        let total: f32 = p.iter().sum();
        assert_eq!(total, 5.0); // 8 - 4 + 1 windows
    }

    #[test]
    fn kmer_profile_ignores_gaps() {
        let a = kmer_profile(&seq("x", "AC-GT").codes, 2, 32, Alphabet::Dna.gap());
        let b = kmer_profile(&seq("x", "ACGT").codes, 2, 32, Alphabet::Dna.gap());
        assert_eq!(a, b);
    }

    #[test]
    fn identical_profiles_zero_distance() {
        let p = kmer_profile(&seq("x", "ACGTACGTAA").codes, 3, 64, 5);
        let d = kmer_distance_native(&[p.clone(), p]);
        assert_eq!(d[0][1], 0.0);
    }

    #[test]
    fn pdistance_hand_case() {
        // ACGT vs AC-T: compared cols = 3 (skip the gap), mismatches = 0.
        // ACGT vs AGGT: compared = 4, mismatch = 1 -> 0.25.
        let rows = vec![seq("a", "ACGT"), seq("b", "AC-T"), seq("c", "AGGT")];
        let d = pdistance_native(&rows).unwrap();
        assert_eq!(d[0][1], 0.0);
        assert_eq!(d[0][2], 0.25);
        assert_eq!(d[1][2], 1.0 / 3.0);
    }

    #[test]
    fn pdistance_all_gap_pair_is_zero() {
        let rows = vec![seq("a", "--"), seq("b", "--")];
        assert_eq!(pdistance_native(&rows).unwrap()[0][1], 0.0);
    }

    #[test]
    fn packed_pdistance_is_bit_identical_to_scalar() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(0xD157);
        for case in 0..20 {
            let width = 1 + rng.below(300);
            let rows: Vec<Sequence> = (0..6)
                .map(|k| {
                    let codes: Vec<u8> = (0..width)
                        .map(|_| if rng.chance(0.15) { 5 } else { rng.below(4) as u8 })
                        .collect();
                    Sequence::new(format!("r{k}"), codes, Alphabet::Dna)
                })
                .collect();
            let scalar = pdistance_native_with(&rows, KernelBackend::Scalar).unwrap();
            let packed = pdistance_native_with(&rows, KernelBackend::BitParallel).unwrap();
            assert_eq!(scalar, packed, "case {case}");
        }
    }

    #[test]
    fn extend_matches_from_scratch_bitwise_after_widening() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(0xE7E);
        for kernel in [KernelBackend::Scalar, KernelBackend::BitParallel] {
            // "Old" rows at width 40; the union widened to 46 by gap
            // columns inserted identically into the old rows.
            let old_rows: Vec<Sequence> = (0..7)
                .map(|k| {
                    let codes: Vec<u8> = (0..40)
                        .map(|_| if rng.chance(0.1) { 5 } else { rng.below(4) as u8 })
                        .collect();
                    Sequence::new(format!("o{k}"), codes, Alphabet::Dna)
                })
                .collect();
            let old = pdistance_native_with(&old_rows, kernel).unwrap();
            let gap_cols = [3usize, 17, 18, 25, 33, 39];
            let widen = |codes: &[u8]| -> Vec<u8> {
                let mut out = Vec::with_capacity(46);
                for (c, &x) in codes.iter().enumerate() {
                    if gap_cols.contains(&c) {
                        out.push(5);
                    }
                    out.push(x);
                }
                out
            };
            let mut union: Vec<Sequence> = old_rows
                .iter()
                .map(|s| Sequence::new(s.id.clone(), widen(&s.codes), Alphabet::Dna))
                .collect();
            for k in 0..3 {
                let codes: Vec<u8> = (0..46)
                    .map(|_| if rng.chance(0.2) { 5 } else { rng.below(4) as u8 })
                    .collect();
                union.push(Sequence::new(format!("n{k}"), codes, Alphabet::Dna));
            }
            let extended = pdistance_extend_with(&old, &union, kernel).unwrap();
            let scratch = pdistance_native_with(&union, kernel).unwrap();
            for i in 0..union.len() {
                for j in 0..union.len() {
                    assert_eq!(
                        extended[i][j].to_bits(),
                        scratch[i][j].to_bits(),
                        "{kernel:?} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn jc_distance_monotone_and_clamped() {
        assert_eq!(jc_distance(0.0, 4), 0.0);
        assert!(jc_distance(0.1, 4) > 0.1); // correction expands
        assert!(jc_distance(0.1, 4) < jc_distance(0.2, 4));
        assert_eq!(jc_distance(0.9, 4), 5.0); // saturated
    }
}

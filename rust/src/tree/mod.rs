//! Phylogenetic tree reconstruction (paper §NJ method, Fig. 4):
//! sampling-based clustering → per-cluster NJ trees built on the engine
//! → merge into the final evolution tree; quality evaluated as the JC69
//! log maximum-likelihood value of the result.
//!
//! Two distance backends (selected by [`TreeConfig::distmat`]):
//!
//! * [`DistBackend::Dense`] — each cluster task materializes its dense
//!   p-distance matrix locally and runs NJ over it; clusters are the
//!   parallel unit (the original HAlign-II shape).
//! * [`DistBackend::Tiled`] — each cluster's matrix is computed as
//!   engine-scheduled *tiles* ([`crate::distmat`]) consumed out-of-core;
//!   tiles are the parallel unit and resident distance-matrix memory is
//!   bounded by the byte budget, not O(n²).  Produces bit-identical
//!   trees to the dense backend (shared per-pair kernels + the same NJ
//!   code over a `DistSource`; property-tested).

pub mod cluster;
pub mod compare;
pub mod distance;
pub mod likelihood;
pub mod merge;
pub mod newick;
pub mod nj;

use anyhow::{Context as _, Result};

use crate::distmat::{self, DistBackend, DistKind, DistMatConfig};
use crate::engine::Cluster as Engine;
use crate::fasta::Sequence;
use crate::runtime::XlaService;

pub use cluster::{cluster_sequences, ClusterConfig, Clustering};
pub use newick::Tree;
pub use nj::{neighbor_joining, neighbor_joining_src, NjConfig};

/// Distance-matrix options for the tree pipeline.
#[derive(Debug, Clone, Default)]
pub struct DistMatOptions {
    pub backend: DistBackend,
}

#[derive(Debug, Clone, Default)]
pub struct TreeConfig {
    pub clustering: ClusterConfig,
    pub distmat: DistMatOptions,
    /// Native pairwise-distance kernel (scalar byte loop vs bit-packed
    /// popcount); bit-identical results either way.
    pub kernel: crate::align::KernelBackend,
}

/// Outcome of the distributed pipeline, with the stats the paper reports.
#[derive(Debug, Clone)]
pub struct TreeResult {
    pub tree: Tree,
    pub num_clusters: usize,
    /// JC69 log-likelihood of the final tree given the alignment.
    pub log_likelihood: f64,
    /// Peak resident distance-matrix bytes across cluster subtree
    /// builds: the dense backend reports its materialized matrices
    /// (O(n²) in the largest cluster), the tiled backend its store's
    /// high-water mark (bounded by the byte budget + one tile).
    pub distmat_peak_bytes: u64,
}

/// Build a phylogenetic tree from *aligned* rows (an MSA — the paper:
/// "for our HAlign-II method, we initially align multiple sequences and
/// then build phylogenetic trees").
pub fn build_tree(
    engine: &Engine,
    rows: &[Sequence],
    svc: Option<&XlaService>,
    cfg: &TreeConfig,
) -> Result<TreeResult> {
    anyhow::ensure!(!rows.is_empty(), "empty alignment");
    anyhow::ensure!(rows.len() >= 2, "need at least two taxa");

    // --- Stage 1: sampling clustering (paper Fig. 4 left) -----------------
    let clustering = cluster_sequences(engine, rows, svc, &cfg.clustering)
        .context("initial clustering")?;

    // --- Stage 2: per-cluster NJ trees -------------------------------------
    // "calculate individual phylogenetic tree based on individual
    // clusters".  Dense backend: clusters are the engine's parallel unit
    // and each task materializes its matrix locally.  Tiled backend:
    // *tiles* are the parallel unit — the driver walks clusters and each
    // cluster's tile jobs fan out over the engine, with resident
    // distance bytes bounded by the byte budget.
    let groups: Vec<(u64, Vec<Sequence>)> = clustering
        .members
        .iter()
        .enumerate()
        .map(|(c, m)| (c as u64, m.iter().map(|&i| rows[i].clone()).collect()))
        .collect();
    let (subtrees, distmat_peak_bytes) = match cfg.distmat.backend {
        DistBackend::Dense => {
            // Dense resident footprint: the largest cluster's p-distance
            // + JC matrices, both alive inside its task.
            let peak = groups
                .iter()
                .map(|(_, m)| (m.len() * m.len() * 2 * std::mem::size_of::<f64>()) as u64)
                .max()
                .unwrap_or(0);
            let svc_map = svc.cloned();
            let kernel = cfg.kernel;
            let parts = engine.config().default_partitions.min(groups.len().max(1));
            // Job boundary between the clustering job and the tree job
            // (HPTree's chained MapReduce; a no-op cache on Spark).
            let groups_rdd = engine.parallelize(groups, parts).checkpoint()?;
            // Fallible map: a failed subtree (e.g. an XLA batch error)
            // surfaces as a task error the executor retries through
            // lineage instead of panicking the worker.
            let subtrees_rdd = groups_rdd.try_map_partitions_with_index(move |_, items| {
                items
                    .into_iter()
                    .map(|(c, members)| {
                        subtree_for_cluster(&members, svc_map.as_ref(), kernel).map(|t| (c, t))
                    })
                    .collect()
            });
            let mut subtrees = subtrees_rdd.collect()?;
            subtrees.sort_by_key(|(c, _)| *c);
            (subtrees.into_iter().map(|(_, t)| t).collect::<Vec<Tree>>(), peak)
        }
        DistBackend::Tiled { tile_rows, byte_budget } => {
            let mut subtrees = Vec::with_capacity(groups.len());
            let mut peak = 0u64;
            for (_, members) in &groups {
                let (tree, cluster_peak) =
                    tiled_subtree_for_cluster(engine, members, tile_rows, byte_budget)?;
                peak = peak.max(cluster_peak);
                subtrees.push(tree);
            }
            (subtrees, peak)
        }
    };

    // --- Stage 3: merge (paper Fig. 4 right) -------------------------------
    let gap = rows[0].alphabet.gap();
    let medoid_profiles: Vec<Vec<f32>> = clustering
        .medoids
        .iter()
        .map(|&m| {
            distance::kmer_profile(
                &rows[m].codes,
                cfg.clustering.k,
                cfg.clustering.profile_dim,
                gap,
            )
        })
        .collect();
    let medoid_dist_f32 = distance::kmer_distance_matrix(&medoid_profiles, svc)?;
    // Normalize squared-euclid profile distances to a tree-scale metric.
    let norm = (rows[0].len().max(1)) as f64;
    let medoid_dist: Vec<Vec<f64>> = medoid_dist_f32
        .iter()
        .map(|r| r.iter().map(|&v| (v as f64).sqrt() / norm).collect())
        .collect();
    let tree = merge::merge_cluster_trees(&subtrees, &medoid_dist)?;

    let log_likelihood =
        likelihood::log_likelihood(&tree, rows).context("evaluating log-likelihood")?;
    Ok(TreeResult {
        tree,
        num_clusters: clustering.num_clusters(),
        log_likelihood,
        distmat_peak_bytes,
    })
}

/// NJ tree for one cluster's aligned rows (dense backend: the matrix is
/// materialized inside the cluster's task).
fn subtree_for_cluster(
    members: &[Sequence],
    svc: Option<&XlaService>,
    kernel: crate::align::KernelBackend,
) -> Result<Tree> {
    anyhow::ensure!(!members.is_empty(), "empty cluster");
    if members.len() == 1 {
        return Ok(Tree::leaf(members[0].id.clone()));
    }
    let p = distance::pdistance_matrix_with(members, svc, kernel)?;
    let states = members[0].alphabet.residues();
    let d: Vec<Vec<f64>> = p
        .iter()
        .map(|row| row.iter().map(|&x| distance::jc_distance(x, states)).collect())
        .collect();
    let labels: Vec<String> = members.iter().map(|s| s.id.clone()).collect();
    neighbor_joining(&labels, &d)
}

/// NJ tree for one cluster via the tiled distance pipeline: JC-corrected
/// p-distance tiles computed as engine jobs, NJ consuming them
/// out-of-core with its merged-row working set sharing the same
/// byte-budgeted store.  Returns the tree and the store's peak resident
/// bytes.  Bit-identical to [`subtree_for_cluster`] without an XLA
/// service (shared kernels + shared NJ); the tiled path always computes
/// natively.
fn tiled_subtree_for_cluster(
    engine: &Engine,
    members: &[Sequence],
    tile_rows: usize,
    byte_budget: usize,
) -> Result<(Tree, u64)> {
    anyhow::ensure!(!members.is_empty(), "empty cluster");
    if members.len() == 1 {
        return Ok((Tree::leaf(members[0].id.clone()), 0));
    }
    let dm_cfg = DistMatConfig {
        tile_rows,
        byte_budget,
        kind: DistKind::PDistance { jukes_cantor: true },
    };
    let tiled = distmat::distance_tiled(engine, members, &dm_cfg)?;
    let labels: Vec<String> = members.iter().map(|s| s.id.clone()).collect();
    let nj_cfg = NjConfig {
        row_store: Some(tiled.store_arc()),
        row_key_base: tiled.row_key_base(),
    };
    let tree = neighbor_joining_src(&labels, &tiled, &nj_cfg)?;
    Ok((tree, tiled.peak_resident_bytes() as u64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::center_star::{align_nucleotide, CenterStarConfig};
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster as Engine, ClusterConfig as EngineConfig};

    fn aligned_mito(count: usize, seed: u64) -> (Engine, Vec<Sequence>) {
        let spec = DatasetSpec { count, ..DatasetSpec::mito(0.015, seed) };
        let seqs = spec.generate();
        let engine = Engine::new(EngineConfig::spark(3));
        let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default()).unwrap();
        (engine, msa.aligned)
    }

    #[test]
    fn full_pipeline_produces_valid_tree() {
        let (engine, rows) = aligned_mito(30, 6);
        let cfg = TreeConfig {
            clustering: ClusterConfig { max_cluster_size: 12, ..Default::default() },
            ..Default::default()
        };
        let result = build_tree(&engine, &rows, None, &cfg).unwrap();
        result.tree.validate().unwrap();
        assert_eq!(result.tree.num_leaves(), 30);
        assert!(result.num_clusters >= 2);
        assert!(result.log_likelihood < 0.0, "logML must be negative");
        // Every input id appears exactly once.
        let mut leaves: Vec<&str> = result.tree.leaf_labels();
        leaves.sort();
        let mut ids: Vec<&str> = rows.iter().map(|r| r.id.as_str()).collect();
        ids.sort();
        assert_eq!(leaves, ids);
    }

    #[test]
    fn clustered_tree_close_to_single_nj_in_likelihood() {
        let (engine, rows) = aligned_mito(24, 7);
        // Single-cluster (plain NJ over everything).
        let single_cfg = TreeConfig {
            clustering: ClusterConfig { num_clusters: 1, max_cluster_size: 1000, ..Default::default() },
            ..Default::default()
        };
        let single = build_tree(&engine, &rows, None, &single_cfg).unwrap();
        // Multi-cluster.
        let multi_cfg = TreeConfig {
            clustering: ClusterConfig { max_cluster_size: 8, ..Default::default() },
            ..Default::default()
        };
        let multi = build_tree(&engine, &rows, None, &multi_cfg).unwrap();
        assert_eq!(single.tree.num_leaves(), multi.tree.num_leaves());
        // The clustered approximation should be within a few percent of
        // the full-NJ likelihood (both negative; larger is better).
        let rel = (multi.log_likelihood - single.log_likelihood).abs()
            / single.log_likelihood.abs();
        assert!(rel < 0.10, "clustered NJ degraded logML by {rel:.3}");
    }

    #[test]
    fn deterministic_output() {
        let (engine, rows) = aligned_mito(16, 8);
        let cfg = TreeConfig {
            clustering: ClusterConfig { max_cluster_size: 6, ..Default::default() },
            ..Default::default()
        };
        let a = build_tree(&engine, &rows, None, &cfg).unwrap();
        let b = build_tree(&engine, &rows, None, &cfg).unwrap();
        assert_eq!(a.tree.to_newick(), b.tree.to_newick());
    }

    #[test]
    fn kernel_backends_produce_identical_trees() {
        use crate::align::KernelBackend;
        let (engine, rows) = aligned_mito(20, 12);
        let clustering = ClusterConfig { max_cluster_size: 8, ..Default::default() };
        let scalar = build_tree(
            &engine,
            &rows,
            None,
            &TreeConfig {
                clustering: clustering.clone(),
                kernel: KernelBackend::Scalar,
                ..Default::default()
            },
        )
        .unwrap();
        let bitp = build_tree(
            &engine,
            &rows,
            None,
            &TreeConfig {
                clustering,
                kernel: KernelBackend::BitParallel,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(scalar.tree, bitp.tree, "kernel backends must agree exactly");
        assert_eq!(scalar.log_likelihood.to_bits(), bitp.log_likelihood.to_bits());
    }

    #[test]
    fn tiled_backend_is_bit_identical_to_dense_and_bounds_memory() {
        let (engine, rows) = aligned_mito(30, 9);
        let clustering = ClusterConfig { max_cluster_size: 12, ..Default::default() };
        let dense_cfg =
            TreeConfig { clustering: clustering.clone(), ..Default::default() };
        let byte_budget = 1 << 10; // 1 KiB, under the largest cluster's dense matrices
        let tiled_cfg = TreeConfig {
            clustering,
            distmat: DistMatOptions {
                backend: DistBackend::Tiled { tile_rows: 4, byte_budget },
            },
            ..Default::default()
        };
        let dense = build_tree(&engine, &rows, None, &dense_cfg).unwrap();
        let tiled = build_tree(&engine, &rows, None, &tiled_cfg).unwrap();
        assert_eq!(
            dense.tree, tiled.tree,
            "tiled distance backend must reproduce the dense tree bit for bit"
        );
        assert_eq!(dense.log_likelihood.to_bits(), tiled.log_likelihood.to_bits());
        assert_eq!(dense.num_clusters, tiled.num_clusters);
        // Memory story: dense reports the largest cluster's O(n²)
        // matrices; tiled stays within budget + one blob (the largest
        // single blob is either a merged-row vector of ~2·cluster_size
        // f64s, a full tile, or a cross-tile (sum,min) sidecar).
        let grid_slack = {
            let g = crate::distmat::tile::TileGrid::new(12, 4);
            g.max_tile_bytes().max(g.max_sidecar_bytes())
        };
        let blob_slack = (2 * 12 * 8).max(grid_slack);
        assert!(
            tiled.distmat_peak_bytes <= (byte_budget + blob_slack) as u64,
            "tiled peak {} must honor the byte budget {byte_budget}",
            tiled.distmat_peak_bytes
        );
        assert!(
            dense.distmat_peak_bytes > tiled.distmat_peak_bytes,
            "dense ({}) must report a larger resident matrix than tiled ({})",
            dense.distmat_peak_bytes,
            tiled.distmat_peak_bytes
        );
    }
}

//! Phylogenetic tree reconstruction (paper §NJ method, Fig. 4):
//! sampling-based clustering → per-cluster NJ trees built in parallel on
//! the engine → merge into the final evolution tree; quality evaluated as
//! the JC69 log maximum-likelihood value of the result.

pub mod cluster;
pub mod compare;
pub mod distance;
pub mod likelihood;
pub mod merge;
pub mod newick;
pub mod nj;

use anyhow::{Context as _, Result};

use crate::engine::Cluster as Engine;
use crate::fasta::Sequence;
use crate::runtime::XlaService;

pub use cluster::{cluster_sequences, ClusterConfig, Clustering};
pub use newick::Tree;
pub use nj::neighbor_joining;

#[derive(Debug, Clone, Default)]
pub struct TreeConfig {
    pub clustering: ClusterConfig,
}

/// Outcome of the distributed pipeline, with the stats the paper reports.
#[derive(Debug, Clone)]
pub struct TreeResult {
    pub tree: Tree,
    pub num_clusters: usize,
    /// JC69 log-likelihood of the final tree given the alignment.
    pub log_likelihood: f64,
}

/// Build a phylogenetic tree from *aligned* rows (an MSA — the paper:
/// "for our HAlign-II method, we initially align multiple sequences and
/// then build phylogenetic trees").
pub fn build_tree(
    engine: &Engine,
    rows: &[Sequence],
    svc: Option<&XlaService>,
    cfg: &TreeConfig,
) -> Result<TreeResult> {
    anyhow::ensure!(!rows.is_empty(), "empty alignment");
    anyhow::ensure!(rows.len() >= 2, "need at least two taxa");

    // --- Stage 1: sampling clustering (paper Fig. 4 left) -----------------
    let clustering = cluster_sequences(engine, rows, svc, &cfg.clustering)
        .context("initial clustering")?;

    // --- Stage 2: per-cluster NJ trees, in parallel ------------------------
    // Each task gets (cluster_id, member rows); computes p-distances
    // (XLA match-count kernel when a bucket covers the cluster) and runs
    // NJ locally — "calculate individual phylogenetic tree based on
    // individual clusters".
    let groups: Vec<(u64, Vec<Sequence>)> = clustering
        .members
        .iter()
        .enumerate()
        .map(|(c, m)| (c as u64, m.iter().map(|&i| rows[i].clone()).collect()))
        .collect();
    let svc_map = svc.cloned();
    let parts = engine.config().default_partitions.min(groups.len().max(1));
    // Job boundary between the clustering job and the tree job (HPTree's
    // chained MapReduce; a no-op cache on the Spark backend).
    let groups_rdd = engine.parallelize(groups, parts).checkpoint()?;
    let subtrees_rdd = groups_rdd.map(move |(c, members)| {
        let tree = subtree_for_cluster(&members, svc_map.as_ref())
            .expect("cluster subtree construction failed");
        (c, tree)
    });
    let mut subtrees = subtrees_rdd.collect()?;
    subtrees.sort_by_key(|(c, _)| *c);
    let subtrees: Vec<Tree> = subtrees.into_iter().map(|(_, t)| t).collect();

    // --- Stage 3: merge (paper Fig. 4 right) -------------------------------
    let gap = rows[0].alphabet.gap();
    let medoid_profiles: Vec<Vec<f32>> = clustering
        .medoids
        .iter()
        .map(|&m| {
            distance::kmer_profile(
                &rows[m].codes,
                cfg.clustering.k,
                cfg.clustering.profile_dim,
                gap,
            )
        })
        .collect();
    let medoid_dist_f32 = distance::kmer_distance_matrix(&medoid_profiles, svc)?;
    // Normalize squared-euclid profile distances to a tree-scale metric.
    let norm = (rows[0].len().max(1)) as f64;
    let medoid_dist: Vec<Vec<f64>> = medoid_dist_f32
        .iter()
        .map(|r| r.iter().map(|&v| (v as f64).sqrt() / norm).collect())
        .collect();
    let tree = merge::merge_cluster_trees(&subtrees, &medoid_dist)?;

    let log_likelihood =
        likelihood::log_likelihood(&tree, rows).context("evaluating log-likelihood")?;
    Ok(TreeResult { tree, num_clusters: clustering.num_clusters(), log_likelihood })
}

/// NJ tree for one cluster's aligned rows.
fn subtree_for_cluster(members: &[Sequence], svc: Option<&XlaService>) -> Result<Tree> {
    anyhow::ensure!(!members.is_empty(), "empty cluster");
    if members.len() == 1 {
        return Ok(Tree::leaf(members[0].id.clone()));
    }
    let p = distance::pdistance_matrix(members, svc)?;
    let states = members[0].alphabet.residues();
    let d: Vec<Vec<f64>> = p
        .iter()
        .map(|row| row.iter().map(|&x| distance::jc_distance(x, states)).collect())
        .collect();
    let labels: Vec<String> = members.iter().map(|s| s.id.clone()).collect();
    neighbor_joining(&labels, &d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::center_star::{align_nucleotide, CenterStarConfig};
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster as Engine, ClusterConfig as EngineConfig};

    fn aligned_mito(count: usize, seed: u64) -> (Engine, Vec<Sequence>) {
        let spec = DatasetSpec { count, ..DatasetSpec::mito(0.015, seed) };
        let seqs = spec.generate();
        let engine = Engine::new(EngineConfig::spark(3));
        let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default()).unwrap();
        (engine, msa.aligned)
    }

    #[test]
    fn full_pipeline_produces_valid_tree() {
        let (engine, rows) = aligned_mito(30, 6);
        let cfg = TreeConfig {
            clustering: ClusterConfig { max_cluster_size: 12, ..Default::default() },
        };
        let result = build_tree(&engine, &rows, None, &cfg).unwrap();
        result.tree.validate().unwrap();
        assert_eq!(result.tree.num_leaves(), 30);
        assert!(result.num_clusters >= 2);
        assert!(result.log_likelihood < 0.0, "logML must be negative");
        // Every input id appears exactly once.
        let mut leaves: Vec<&str> = result.tree.leaf_labels();
        leaves.sort();
        let mut ids: Vec<&str> = rows.iter().map(|r| r.id.as_str()).collect();
        ids.sort();
        assert_eq!(leaves, ids);
    }

    #[test]
    fn clustered_tree_close_to_single_nj_in_likelihood() {
        let (engine, rows) = aligned_mito(24, 7);
        // Single-cluster (plain NJ over everything).
        let single_cfg = TreeConfig {
            clustering: ClusterConfig { num_clusters: 1, max_cluster_size: 1000, ..Default::default() },
        };
        let single = build_tree(&engine, &rows, None, &single_cfg).unwrap();
        // Multi-cluster.
        let multi_cfg = TreeConfig {
            clustering: ClusterConfig { max_cluster_size: 8, ..Default::default() },
        };
        let multi = build_tree(&engine, &rows, None, &multi_cfg).unwrap();
        assert_eq!(single.tree.num_leaves(), multi.tree.num_leaves());
        // The clustered approximation should be within a few percent of
        // the full-NJ likelihood (both negative; larger is better).
        let rel = (multi.log_likelihood - single.log_likelihood).abs()
            / single.log_likelihood.abs();
        assert!(rel < 0.10, "clustered NJ degraded logML by {rel:.3}");
    }

    #[test]
    fn deterministic_output() {
        let (engine, rows) = aligned_mito(16, 8);
        let cfg = TreeConfig {
            clustering: ClusterConfig { max_cluster_size: 6, ..Default::default() },
        };
        let a = build_tree(&engine, &rows, None, &cfg).unwrap();
        let b = build_tree(&engine, &rows, None, &cfg).unwrap();
        assert_eq!(a.tree.to_newick(), b.tree.to_newick());
    }
}

//! Log maximum-likelihood value of a tree + alignment under JC69 — the
//! paper's tree-quality metric ("phylogenetic tree performance is
//! evaluated by maximum likelihood value under log functions"; HPTree
//! reports -21,954,385 on Φ_DNA).
//!
//! Felsenstein pruning with per-column partials; JC69 transition
//! probability `P(same) = 1/s + (1-1/s) e^{-s/(s-1) t}`, uniform base
//! frequencies, gaps treated as missing data (partial = 1 for every
//! state).  DNA uses s=4 over A/C/G/T; proteins s=20.  Branch lengths
//! come from the NJ tree; we do not re-optimize them (neither does the
//! paper's NJ pipeline — it reports the likelihood of the NJ tree).

use anyhow::{ensure, Result};

use super::newick::Tree;
use crate::fasta::Sequence;

/// JC69 probability of observing the *same* state across branch t.
#[inline]
fn p_same(t: f64, s: f64) -> f64 {
    (1.0 / s) + (1.0 - 1.0 / s) * (-s / (s - 1.0) * t).exp()
}

/// JC69 probability of a *specific different* state across branch t.
#[inline]
fn p_diff(t: f64, s: f64) -> f64 {
    (1.0 / s) * (1.0 - (-s / (s - 1.0) * t).exp())
}

/// Compute the log-likelihood of `tree` given aligned `rows` (leaf labels
/// must match row ids one-to-one).
pub fn log_likelihood(tree: &Tree, rows: &[Sequence]) -> Result<f64> {
    ensure!(!rows.is_empty(), "no rows");
    let alphabet = rows[0].alphabet;
    let states = alphabet.residues(); // 4 or 20
    let s = states as f64;
    let width = rows[0].len();
    ensure!(rows.iter().all(|r| r.len() == width), "rows must be aligned");

    // Map each leaf node to its alignment row once (O(n) lookups, not
    // O(n) per column).
    let mut by_id: crate::util::hash::DetHashMap<&str, usize> =
        crate::util::hash::DetHashMap::default();
    for (i, r) in rows.iter().enumerate() {
        by_id.insert(r.id.as_str(), i);
    }
    let mut leaf_row: Vec<Option<usize>> = vec![None; tree.nodes.len()];
    for (i, n) in tree.nodes.iter().enumerate() {
        if n.children.is_empty() {
            let l = n.label.as_deref().unwrap_or("");
            let row = by_id
                .get(l)
                .copied()
                .ok_or_else(|| anyhow::anyhow!("tree leaf {l:?} missing from alignment"))?;
            leaf_row[i] = Some(row);
        }
    }

    // Post-order traversal (children before parents).
    let mut order = Vec::with_capacity(tree.nodes.len());
    let mut stack = vec![(tree.root, false)];
    while let Some((i, expanded)) = stack.pop() {
        if expanded {
            order.push(i);
        } else {
            stack.push((i, true));
            for &c in &tree.nodes[i].children {
                stack.push((c, false));
            }
        }
    }

    // Branch-length floor: a zero branch makes identical-leaf columns
    // singular; NJ can emit zeros for identical sequences.
    const T_MIN: f64 = 1e-6;
    let gap = alphabet.gap();
    let unknown = alphabet.unknown();

    // Hoist the per-branch JC69 transition probabilities out of the
    // column loop (they depend only on branch length), and flatten the
    // per-node partials into one buffer (no per-column allocations) —
    // see EXPERIMENTS.md §Perf for the before/after.
    let probs: Vec<(f64, f64)> = tree
        .nodes
        .iter()
        .map(|n| {
            let t = n.branch.max(T_MIN);
            (p_same(t, s), p_diff(t, s))
        })
        .collect();

    let n_nodes = tree.nodes.len();
    let mut total = 0.0f64;
    let mut partials = vec![0.0f64; n_nodes * states];
    let mut child_buf = vec![0.0f64; states];
    for col in 0..width {
        for &i in &order {
            let node = &tree.nodes[i];
            let base = i * states;
            if node.children.is_empty() {
                let row = &rows[leaf_row[i].unwrap()];
                let c = row.codes[col];
                let p = &mut partials[base..base + states];
                if c == gap || c == unknown || c as usize >= states {
                    p.fill(1.0); // missing data
                } else {
                    p.fill(0.0);
                    p[c as usize] = 1.0;
                }
            } else {
                partials[base..base + states].fill(1.0);
                for &c in &node.children {
                    let (ps, pd) = probs[c];
                    let cbase = c * states;
                    child_buf.copy_from_slice(&partials[cbase..cbase + states]);
                    let child_sum: f64 = child_buf.iter().sum();
                    let parent = &mut partials[base..base + states];
                    for x in 0..states {
                        // sum_y P(x->y) * child[y]
                        //   = pd * (sum_y child[y]) + (ps - pd) * child[x]
                        parent[x] *= pd * child_sum + (ps - pd) * child_buf[x];
                    }
                }
            }
        }
        let rbase = tree.root * states;
        let root_sum: f64 = partials[rbase..rbase + states].iter().sum::<f64>() / s;
        total += root_sum.max(f64::MIN_POSITIVE).ln();
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::Alphabet;

    fn seqs(rows: &[(&str, &str)]) -> Vec<Sequence> {
        rows.iter()
            .map(|(id, t)| Sequence::from_text(*id, t, Alphabet::Dna))
            .collect()
    }

    #[test]
    fn two_identical_leaves_likelihood_matches_closed_form() {
        let rows = seqs(&[("a", "A"), ("b", "A")]);
        let tree = Tree::from_newick("(a:0.1,b:0.1);").unwrap();
        let ll = log_likelihood(&tree, &rows).unwrap();
        // L = sum_x pi_x P(x->A,0.1)^2 ; pi = 1/4.
        let s = 4.0;
        let mut expect = 0.0;
        for x in 0..4 {
            let p = if x == 0 { p_same(0.1, s) } else { p_diff(0.1, s) };
            expect += 0.25 * p * p;
        }
        assert!((ll - expect.ln()).abs() < 1e-12, "{ll} vs {}", expect.ln());
    }

    #[test]
    fn likelihood_prefers_short_branches_for_identical_data() {
        let rows = seqs(&[("a", "ACGTACGT"), ("b", "ACGTACGT")]);
        let short = Tree::from_newick("(a:0.01,b:0.01);").unwrap();
        let long = Tree::from_newick("(a:1.5,b:1.5);").unwrap();
        let ls = log_likelihood(&short, &rows).unwrap();
        let ll = log_likelihood(&long, &rows).unwrap();
        assert!(ls > ll, "identical data favours short branches");
    }

    #[test]
    fn likelihood_prefers_long_branches_for_divergent_data() {
        let rows = seqs(&[("a", "AAAAAAAA"), ("b", "CCGGTTGG")]);
        let short = Tree::from_newick("(a:0.01,b:0.01);").unwrap();
        let long = Tree::from_newick("(a:1.0,b:1.0);").unwrap();
        assert!(
            log_likelihood(&long, &rows).unwrap() > log_likelihood(&short, &rows).unwrap()
        );
    }

    #[test]
    fn gaps_are_missing_data() {
        let with_gap = seqs(&[("a", "A-"), ("b", "AC")]);
        let no_gap = seqs(&[("a", "A"), ("b", "A")]);
        let t2 = Tree::from_newick("(a:0.1,b:0.1);").unwrap();
        // Column 2 is (gap, C): with the gap marginalized out, its
        // likelihood factor is just the single observation's marginal
        // probability pi_C = 1/4.
        let ll_gap = log_likelihood(&t2, &with_gap).unwrap();
        let ll_plain = log_likelihood(&t2, &no_gap).unwrap();
        assert!((ll_gap - (ll_plain + (0.25f64).ln())).abs() < 1e-12);
    }

    #[test]
    fn four_taxon_topology_ranking() {
        // Data strongly pairs (a,b) and (c,d).
        let rows = seqs(&[
            ("a", "AAAACCCC"),
            ("b", "AAAACCCC"),
            ("c", "GGGGTTTT"),
            ("d", "GGGGTTTT"),
        ]);
        let good = Tree::from_newick("((a:0.05,b:0.05):0.5,(c:0.05,d:0.05):0.5);").unwrap();
        let bad = Tree::from_newick("((a:0.05,c:0.05):0.5,(b:0.05,d:0.05):0.5);").unwrap();
        assert!(
            log_likelihood(&good, &rows).unwrap() > log_likelihood(&bad, &rows).unwrap(),
            "correct topology must score higher"
        );
    }

    #[test]
    fn missing_leaf_errors() {
        let rows = seqs(&[("a", "A")]);
        let t = Tree::from_newick("(a:0.1,zz:0.1);").unwrap();
        assert!(log_likelihood(&t, &rows).is_err());
    }
}

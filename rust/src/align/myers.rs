//! Bit-parallel sequence kernels on `u64` lanes (dependency-free).
//!
//! * [`myers_edit_distance`] — multi-word Myers bit-parallel edit
//!   distance (Hyyrö's block formulation): 64 DP columns advance per
//!   word op, exact unit-cost Levenshtein distance in integers.  Used
//!   to seed the adaptive band width in [`super::banded`].
//! * [`RowBits`] / [`pdist_counts_packed`] — bit-plane packed aligned
//!   rows for p-distance: 5 code bitplanes plus a gap mask, so the
//!   (compared, mismatch) counts of a row pair cost O(L/64) `popcnt`s
//!   instead of an O(L) byte loop.  Integer counts, so the resulting
//!   p-distance is bit-identical to the scalar loop in
//!   [`crate::tree::distance::pdist_pair`].
//!
//! Everything here scores in integers; there is no epsilon anywhere.

/// Scalar reference edit distance (unit costs), O(m*n).  The oracle the
/// bit-parallel kernel is property-tested against.
pub fn edit_distance_dp(a: &[u8], b: &[u8]) -> usize {
    let (m, n) = (a.len(), b.len());
    let mut prev: Vec<usize> = (0..=n).collect();
    let mut cur = vec![0usize; n + 1];
    for i in 1..=m {
        cur[0] = i;
        for j in 1..=n {
            let sub = prev[j - 1] + usize::from(a[i - 1] != b[j - 1]);
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[n]
}

/// Multi-word bit-parallel Myers edit distance.  `a` is the "pattern"
/// laid out along the bit direction (one bit per row), `b` is scanned
/// column by column; each text character advances all `a.len()` rows in
/// `ceil(a.len()/64)` word operations.  Exact unit-cost edit distance.
pub fn myers_edit_distance(a: &[u8], b: &[u8]) -> usize {
    let m = a.len();
    if m == 0 {
        return b.len();
    }
    if b.is_empty() {
        return m;
    }
    let words = (m + 63) / 64;
    // peq[c * words + w]: bit i of word w set iff a[w*64 + i] == c.
    let mut peq = vec![0u64; 256 * words];
    for (i, &c) in a.iter().enumerate() {
        peq[c as usize * words + i / 64] |= 1u64 << (i % 64);
    }
    let mut pv = vec![u64::MAX; words];
    let mut mv = vec![0u64; words];
    let mut score = m;
    // Bit position of the true last row inside the last word.
    let last = (m - 1) % 64;
    for &c in b {
        let eq_base = c as usize * words;
        // Horizontal delta entering block 0 is +1 (top boundary row).
        let mut hin: i32 = 1;
        for w in 0..words {
            let mut eq = peq[eq_base + w];
            let pvw = pv[w];
            let mvw = mv[w];
            if hin < 0 {
                eq |= 1;
            }
            let xv = eq | mvw;
            let xh = (((eq & pvw).wrapping_add(pvw)) ^ pvw) | eq;
            let mut ph = mvw | !(xh | pvw);
            let mut mh = pvw & xh;
            if w == words - 1 {
                score = score.wrapping_add(((ph >> last) & 1) as usize);
                score = score.wrapping_sub(((mh >> last) & 1) as usize);
            }
            let hout: i32 = ((ph >> 63) & 1) as i32 - ((mh >> 63) & 1) as i32;
            ph <<= 1;
            mh <<= 1;
            if hin < 0 {
                mh |= 1;
            } else if hin > 0 {
                ph |= 1;
            }
            pv[w] = mh | !(xv | ph);
            mv[w] = ph & xv;
            hin = hout;
        }
    }
    score
}

/// Bit-plane packed representation of one aligned row: five code planes
/// (codes 0..32, covering `PROTEIN_ALPHA = 25`) plus a gap mask.
#[derive(Debug, Clone)]
pub struct RowBits {
    planes: [Vec<u64>; 5],
    gap: Vec<u64>,
    len: usize,
}

/// Pack a row of residue codes (values < 32) into bitplanes.
pub fn pack_row(codes: &[u8], gap_code: u8) -> RowBits {
    let words = (codes.len() + 63) / 64;
    let mut planes = [
        vec![0u64; words],
        vec![0u64; words],
        vec![0u64; words],
        vec![0u64; words],
        vec![0u64; words],
    ];
    let mut gap = vec![0u64; words];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c < 32, "code {c} exceeds 5 bitplanes");
        let (w, bit) = (i / 64, 1u64 << (i % 64));
        if c == gap_code {
            gap[w] |= bit;
        }
        for (p, plane) in planes.iter_mut().enumerate() {
            if (c >> p) & 1 == 1 {
                plane[w] |= bit;
            }
        }
    }
    RowBits { planes, gap, len: codes.len() }
}

/// (compared, mismatch) column counts of a packed row pair — the integer
/// core of the p-distance, bit-identical to the scalar byte loop.
pub fn pdist_counts_packed(a: &RowBits, b: &RowBits) -> (u64, u64) {
    debug_assert_eq!(a.len, b.len, "rows must be aligned");
    let words = a.gap.len();
    let (mut compared, mut mismatch) = (0u64, 0u64);
    for w in 0..words {
        // Mask off bits beyond the row length in the last word.
        let valid = if w == words - 1 && a.len % 64 != 0 {
            (1u64 << (a.len % 64)) - 1
        } else {
            u64::MAX
        };
        let both = !(a.gap[w] | b.gap[w]) & valid;
        let mut diff = 0u64;
        for p in 0..5 {
            diff |= a.planes[p][w] ^ b.planes[p][w];
        }
        compared += both.count_ones() as u64;
        mismatch += (diff & both).count_ones() as u64;
    }
    (compared, mismatch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn myers_matches_dp_on_hand_cases() {
        assert_eq!(myers_edit_distance(b"kitten", b"sitting"), 3);
        assert_eq!(myers_edit_distance(b"", b"abc"), 3);
        assert_eq!(myers_edit_distance(b"abc", b""), 3);
        assert_eq!(myers_edit_distance(b"abc", b"abc"), 0);
        assert_eq!(myers_edit_distance(b"a", b"b"), 1);
    }

    #[test]
    fn myers_spans_word_boundaries() {
        // Lengths straddling 64/128 exercise the multi-word carry chain.
        for &(m, n) in &[(63usize, 65usize), (64, 64), (65, 63), (128, 130), (200, 5)] {
            let mut rng = Rng::seed_from_u64((m * 1000 + n) as u64);
            let a: Vec<u8> = (0..m).map(|_| rng.below(4) as u8).collect();
            let b: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
            assert_eq!(
                myers_edit_distance(&a, &b),
                edit_distance_dp(&a, &b),
                "lengths ({m},{n})"
            );
        }
    }

    #[test]
    fn packed_counts_match_scalar_loop() {
        let mut rng = Rng::seed_from_u64(0xBEEF);
        for case in 0..40 {
            let len = 1 + rng.below(300);
            let gap = 23u8;
            let row = |rng: &mut Rng| -> Vec<u8> {
                (0..len)
                    .map(|_| if rng.chance(0.2) { gap } else { rng.below(23) as u8 })
                    .collect()
            };
            let a = row(&mut rng);
            let b = row(&mut rng);
            let (mut compared, mut mismatch) = (0u64, 0u64);
            for (x, y) in a.iter().zip(&b) {
                if *x == gap || *y == gap {
                    continue;
                }
                compared += 1;
                if x != y {
                    mismatch += 1;
                }
            }
            let pa = pack_row(&a, gap);
            let pb = pack_row(&b, gap);
            assert_eq!(pdist_counts_packed(&pa, &pb), (compared, mismatch), "case {case}");
        }
    }
}

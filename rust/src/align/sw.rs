//! Native Smith-Waterman: the same recurrence the Pallas kernel computes
//! (linear gap penalty, substitution matrix), plus traceback.
//!
//! Used three ways: as the correctness oracle for the XLA artifacts
//! (rust/tests/runtime_roundtrip.rs), as the fallback for sequences longer
//! than every artifact bucket, and as the inner aligner of the SparkSW
//! baseline.

/// Scoring parameters; `subst` is alpha x alpha row-major (see
/// [`crate::fasta::alphabet::substitution_matrix`]).
#[derive(Debug, Clone)]
pub struct SwParams {
    pub subst: Vec<f32>,
    pub alpha: usize,
    pub gap: f32,
}

impl SwParams {
    #[inline]
    pub fn score(&self, a: i32, b: i32) -> f32 {
        self.subst[a as usize * self.alpha + b as usize]
    }
}

/// Row-major H matrix `(m+1) x (n+1)` with zero boundaries — shared with
/// the runtime batcher, which fills it from the kernel's diagonal-major
/// output.
#[derive(Debug, Clone)]
pub struct HMatrix {
    pub m: usize,
    pub n: usize,
    data: Vec<f32>,
}

impl HMatrix {
    pub fn from_data(m: usize, n: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), (m + 1) * (n + 1));
        Self { m, n, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * (self.n + 1) + j]
    }

    #[inline]
    fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * (self.n + 1) + j] = v;
    }

    /// Position and value of the maximum cell (ties: largest (i, j) in
    /// row-major order, matching the batcher).
    pub fn argmax(&self) -> (usize, usize, f32) {
        let mut best = (0, 0, f32::NEG_INFINITY);
        for i in 0..=self.m {
            for j in 0..=self.n {
                let v = self.at(i, j);
                if v >= best.2 {
                    best = (i, j, v);
                }
            }
        }
        best
    }
}

/// Fill the SW matrix for query `a` vs subject `b`.
pub fn sw_matrix(a: &[i32], b: &[i32], p: &SwParams) -> HMatrix {
    let (m, n) = (a.len(), b.len());
    let mut h = HMatrix::from_data(m, n, vec![0f32; (m + 1) * (n + 1)]);
    for i in 1..=m {
        let ai = a[i - 1] as usize;
        let srow = &p.subst[ai * p.alpha..(ai + 1) * p.alpha];
        let mut left = 0f32; // H[i][j-1]
        for j in 1..=n {
            let diag = h.at(i - 1, j - 1) + srow[b[j - 1] as usize];
            let up = h.at(i - 1, j) - p.gap;
            let v = diag.max(up).max(left - p.gap).max(0.0);
            h.set(i, j, v);
            left = v;
        }
    }
    h
}

/// One step of a local alignment path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Consume one residue of each sequence (match or mismatch).
    Diag,
    /// Consume a query residue against a gap in the subject.
    Up,
    /// Consume a subject residue against a gap in the query.
    Left,
}

/// A local alignment: half-open residue ranges of the query/subject plus
/// the operation path between them.
#[derive(Debug, Clone)]
pub struct LocalAlignment {
    pub score: f32,
    /// Query range [a_start, a_end) covered by the path.
    pub a_start: usize,
    pub a_end: usize,
    /// Subject range [b_start, b_end).
    pub b_start: usize,
    pub b_end: usize,
    pub ops: Vec<Op>,
}

/// Traceback from the argmax cell, re-deriving each predecessor from H
/// (no pointer matrix — the XLA kernel only materializes H).
///
/// Predecessor selection is *exact*: each cell's value is literally one
/// of the fill loop's max() arguments, and recomputing a candidate with
/// the identical expression is bit-deterministic, so `v == candidate`
/// holds for the true predecessor and for nothing merely nearby.  An
/// epsilon here (the old `|v - cand| <= 1e-3`) mistakes sub-epsilon
/// neighbors for predecessors on long high-scoring alignments — see the
/// sub-epsilon regression test below.
pub fn traceback(h: &HMatrix, a: &[i32], b: &[i32], p: &SwParams) -> LocalAlignment {
    let (mut i, mut j, score) = h.argmax();
    let (a_end, b_end) = (i, j);
    let mut ops = Vec::new();
    while i > 0 && j > 0 && h.at(i, j) > 0.0 {
        let v = h.at(i, j);
        let diag = h.at(i - 1, j - 1) + p.score(a[i - 1], b[j - 1]);
        if v == diag {
            ops.push(Op::Diag);
            i -= 1;
            j -= 1;
        } else if v == h.at(i - 1, j) - p.gap {
            ops.push(Op::Up);
            i -= 1;
        } else {
            debug_assert_eq!(v, h.at(i, j - 1) - p.gap);
            ops.push(Op::Left);
            j -= 1;
        }
    }
    ops.reverse();
    LocalAlignment { score, a_start: i, a_end, b_start: j, b_end, ops }
}

/// Convenience: fill + traceback.
pub fn sw_align(a: &[i32], b: &[i32], p: &SwParams) -> LocalAlignment {
    traceback(&sw_matrix(a, b, p), a, b, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::{alphabet::substitution_matrix, Alphabet};

    fn dna_params() -> SwParams {
        SwParams {
            subst: substitution_matrix(Alphabet::Dna),
            alpha: Alphabet::Dna.size(),
            gap: 6.0,
        }
    }

    fn codes(s: &str) -> Vec<i32> {
        s.bytes().map(|b| Alphabet::Dna.encode(b) as i32).collect()
    }

    #[test]
    fn identical_sequences_score_full_match() {
        let p = dna_params();
        let a = codes("ACGTACGT");
        let al = sw_align(&a, &a, &p);
        assert_eq!(al.score, 40.0); // 8 * +5
        assert_eq!(al.ops.len(), 8);
        assert!(al.ops.iter().all(|&o| o == Op::Diag));
        assert_eq!((al.a_start, al.a_end), (0, 8));
    }

    #[test]
    fn local_alignment_finds_embedded_motif() {
        let p = dna_params();
        let a = codes("TTTTACGTACGTTTTT");
        let b = codes("GGGGACGTACGGGG");
        let al = sw_align(&a, &b, &p);
        // Common core ACGTACG scores 7 * 5 = 35.
        assert_eq!(al.score, 35.0);
        let aligned_a = &a[al.a_start..al.a_end];
        assert_eq!(aligned_a, &codes("ACGTACG")[..]);
    }

    #[test]
    fn gap_inserted_when_cheaper_than_mismatches() {
        let mut p = dna_params();
        p.gap = 2.0; // cheap gaps
        let a = codes("ACGTCGT"); // missing the A in the middle
        let b = codes("ACGTACGT");
        let al = sw_align(&a, &b, &p);
        assert!(al.ops.contains(&Op::Left), "expected subject-gap op: {:?}", al.ops);
        assert_eq!(al.score, 7.0 * 5.0 - 2.0);
    }

    #[test]
    fn empty_inputs_yield_zero_alignment() {
        let p = dna_params();
        let al = sw_align(&[], &codes("ACGT"), &p);
        assert_eq!(al.score, 0.0);
        assert!(al.ops.is_empty());
    }

    #[test]
    fn unrelated_sequences_score_low() {
        let p = dna_params();
        let al = sw_align(&codes("AAAAAAA"), &codes("TTTTTTT"), &p);
        assert_eq!(al.score, 0.0);
    }

    #[test]
    fn h_matrix_matches_known_small_case() {
        // Worked example: a=AC, b=AGC, match 5 / mismatch -4 / gap 6.
        let p = dna_params();
        let h = sw_matrix(&codes("AC"), &codes("AGC"), &p);
        assert_eq!(h.at(1, 1), 5.0); // A-A
        assert_eq!(h.at(1, 2), 0.0); // A-G after gap: 5-6 < 0 -> 0... max(diag -4, up/left) = 0
        assert_eq!(h.at(2, 3), 5.0); // C aligned to C after G mismatch skip
    }

    /// Regression for the epsilon-traceback bug class: with candidate
    /// spacing below the old `EPS = 1e-3` (here one dyadic unit,
    /// 2^-10 ≈ 0.00098 — the f32-ulp regime that high-scoring long
    /// alignments reach), the old `|v - diag| <= EPS` check accepted a
    /// diagonal predecessor that sits exactly one unit *below* the cell
    /// value, shearing the path onto the wrong diagonal.  All values
    /// here are exact in f32 (dyadic, small multiples of 2^-10), so the
    /// exact-equality traceback is provably right and the path rescores
    /// to the score bit-for-bit.  Under the old scheme this test fails:
    /// the traced path becomes all-Diag and rescores one unit low.
    #[test]
    fn sub_epsilon_spacing_long_alignment_traces_exactly() {
        const U: f32 = 1.0 / 1024.0; // 2^-10 < old EPS of 1e-3
        let alpha = Alphabet::Dna.size();
        let mut subst = vec![-U; alpha * alpha];
        for k in 0..alpha {
            subst[k * alpha + k] = U;
        }
        let p = SwParams { subst, alpha, gap: U };
        // a = A^n G^n, b = A^n T G^n (one T inserted): the optimal local
        // path is n A-matches, one Left (skip the T), n G-matches.  At
        // the Left cell the diag candidate is exactly one unit below the
        // cell value — old-EPS tracebacks take it and lose a unit.
        let n = 1024usize; // 2048/2049-residue pair
        let a_code = Alphabet::Dna.encode(b'A') as i32;
        let g_code = Alphabet::Dna.encode(b'G') as i32;
        let t_code = Alphabet::Dna.encode(b'T') as i32;
        let mut a = vec![a_code; n];
        a.extend(std::iter::repeat(g_code).take(n));
        let mut b = vec![a_code; n];
        b.push(t_code);
        b.extend(std::iter::repeat(g_code).take(n));

        let al = sw_align(&a, &b, &p);
        assert_eq!(al.score, (2 * n - 1) as f32 * U);
        let mut expected = vec![Op::Diag; n];
        expected.push(Op::Left);
        expected.extend(std::iter::repeat(Op::Diag).take(n));
        assert_eq!(al.ops, expected, "exact traceback must skip the inserted T via Left");
        // Path rescore is bit-exact (every term is a small dyadic).
        let (mut i, mut j, mut score) = (al.a_start, al.b_start, 0f32);
        for &op in &al.ops {
            match op {
                Op::Diag => {
                    score += p.score(a[i], b[j]);
                    i += 1;
                    j += 1;
                }
                Op::Up => {
                    score -= p.gap;
                    i += 1;
                }
                Op::Left => {
                    score -= p.gap;
                    j += 1;
                }
            }
        }
        assert_eq!((i, j), (al.a_end, al.b_end));
        assert_eq!(score, al.score, "path must rescore to the DP optimum exactly");
    }

    #[test]
    fn traceback_ops_are_consistent_with_ranges() {
        let p = dna_params();
        let a = codes("ACGGTACA");
        let b = codes("TACGTAC");
        let al = sw_align(&a, &b, &p);
        let consumed_a: usize =
            al.ops.iter().filter(|o| !matches!(o, Op::Left)).count();
        let consumed_b: usize =
            al.ops.iter().filter(|o| !matches!(o, Op::Up)).count();
        assert_eq!(consumed_a, al.a_end - al.a_start);
        assert_eq!(consumed_b, al.b_end - al.b_start);
    }
}

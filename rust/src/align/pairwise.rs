//! Pairwise query-vs-center alignment and the edit-path algebra the
//! center-star merge is built on.
//!
//! A pairwise alignment is a path of [`PathOp`]s over the *full* lengths
//! of query and center (global span).  From the path we derive the
//! "inserted space" profile — how many gap columns this pair forces
//! before each center position — which is exactly the per-pair
//! contribution reduced (element-wise max) in the paper's first
//! MapReduce stage.
//!
//! Two aligners produce paths:
//!  * [`anchored_align`] — trie-anchored: exact segment anchors from
//!    [`super::trie::SegmentTrie`], Needleman-Wunsch only between anchors
//!    (the similar-DNA/RNA fast path, linear-ish for similar sequences);
//!  * [`global_dp`] — plain Needleman-Wunsch (used for anchor gaps and as
//!    the small-input fallback).

use super::sw::Op;
use super::KernelBackend;
use crate::fasta::Alphabet;

/// Re-export the op type under the name the MSA layer uses.
pub type PathOp = Op;

/// Encode a path compactly for shuffling (one byte per op).
pub fn encode_ops(ops: &[PathOp]) -> Vec<u8> {
    ops.iter()
        .map(|o| match o {
            Op::Diag => 0u8,
            Op::Up => 1,
            Op::Left => 2,
        })
        .collect()
}

pub fn decode_ops(bytes: &[u8]) -> Vec<PathOp> {
    bytes
        .iter()
        .map(|b| match b {
            0 => Op::Diag,
            1 => Op::Up,
            _ => Op::Left,
        })
        .collect()
}

/// Validate that a path consumes exactly (query_len, center_len).
pub fn path_consumes(ops: &[PathOp]) -> (usize, usize) {
    let q = ops.iter().filter(|o| !matches!(o, Op::Left)).count();
    let c = ops.iter().filter(|o| !matches!(o, Op::Up)).count();
    (q, c)
}

/// Needleman-Wunsch global alignment (match/mismatch/linear gap), O(a*b).
/// Scores: +1 match, -1 mismatch, -2 gap (relative costs only matter).
pub fn global_dp(a: &[u8], b: &[u8]) -> Vec<PathOp> {
    let (m, n) = (a.len(), b.len());
    if m == 0 {
        return vec![Op::Left; n];
    }
    if n == 0 {
        return vec![Op::Up; m];
    }
    const GAP: i32 = -2;
    let w = n + 1;
    let mut score = vec![0i32; (m + 1) * w];
    for j in 1..=n {
        score[j] = j as i32 * GAP;
    }
    for i in 1..=m {
        score[i * w] = i as i32 * GAP;
        for j in 1..=n {
            let s = if a[i - 1] == b[j - 1] { 1 } else { -1 };
            let diag = score[(i - 1) * w + j - 1] + s;
            let up = score[(i - 1) * w + j] + GAP;
            let left = score[i * w + j - 1] + GAP;
            score[i * w + j] = diag.max(up).max(left);
        }
    }
    // Traceback.
    let mut ops = Vec::with_capacity(m + n);
    let (mut i, mut j) = (m, n);
    while i > 0 || j > 0 {
        let v = score[i * w + j];
        if i > 0 && j > 0 {
            let s = if a[i - 1] == b[j - 1] { 1 } else { -1 };
            if v == score[(i - 1) * w + j - 1] + s {
                ops.push(Op::Diag);
                i -= 1;
                j -= 1;
                continue;
            }
        }
        if i > 0 && v == score[(i - 1) * w + j] + GAP {
            ops.push(Op::Up);
            i -= 1;
        } else {
            ops.push(Op::Left);
            j -= 1;
        }
    }
    ops.reverse();
    ops
}

/// Global alignment through the selected kernel backend.  Both arms are
/// bit-identical: the banded integer kernel certifies its band against
/// the full-DP optimum before returning (see [`super::banded`]).
pub fn global_align(a: &[u8], b: &[u8], kernel: KernelBackend) -> Vec<PathOp> {
    match kernel {
        KernelBackend::Scalar => global_dp(a, b),
        KernelBackend::BitParallel => super::banded::banded_global(a, b),
    }
}

/// Trie-anchored alignment: exact anchors contribute Diag runs; the gaps
/// between anchors are closed with [`global_align`].  `query` and
/// `center` are residue codes of the same alphabet.
pub fn anchored_align_with(
    query: &[u8],
    center: &[u8],
    trie: &super::trie::SegmentTrie,
    kernel: KernelBackend,
) -> Vec<PathOp> {
    let chain = trie.chain(query);
    let mut ops = Vec::with_capacity(query.len().max(center.len()) + 16);
    let (mut q, mut c) = (0usize, 0usize);
    for a in &chain {
        // Close the unanchored region before this anchor.
        ops.extend(global_align(&query[q..a.query_pos], &center[c..a.center_pos], kernel));
        // The anchor itself: exact match run.
        ops.extend(std::iter::repeat(Op::Diag).take(a.len));
        q = a.query_pos + a.len;
        c = a.center_pos + a.len;
    }
    ops.extend(global_align(&query[q..], &center[c..], kernel));
    ops
}

/// [`anchored_align_with`] under the default kernel backend.
pub fn anchored_align(
    query: &[u8],
    center: &[u8],
    trie: &super::trie::SegmentTrie,
) -> Vec<PathOp> {
    anchored_align_with(query, center, trie, KernelBackend::default())
}

/// Number of gap columns this pair inserts before each center position:
/// `spaces[j]` counts Up ops (query residue vs center gap) occurring when
/// the center cursor is at `j` (0..=center_len).
pub fn center_space_profile(ops: &[PathOp], center_len: usize) -> Vec<u32> {
    let mut spaces = vec![0u32; center_len + 1];
    let mut c = 0usize;
    for op in ops {
        match op {
            Op::Up => spaces[c] += 1,
            _ => c += 1,
        }
    }
    debug_assert_eq!(c, center_len, "path must consume the whole center");
    spaces
}

/// Element-wise max of two space profiles (the center-star reduction).
pub fn merge_profiles(mut a: Vec<u32>, b: &[u32]) -> Vec<u32> {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x = (*x).max(*y);
    }
    a
}

/// Emit the final aligned query row under the *global* space profile.
/// Within each center gap-block, this pair's own inserted residues come
/// first, then padding gaps up to the global count (consistent across all
/// rows, so columns stay aligned).
pub fn render_query_row(
    query: &[u8],
    ops: &[PathOp],
    global_spaces: &[u32],
    own_spaces: &[u32],
    alphabet: Alphabet,
) -> Vec<u8> {
    let gap = alphabet.gap();
    let mut row = Vec::new();
    let mut qi = 0usize;
    let mut c = 0usize;
    let pad = |row: &mut Vec<u8>, c: usize| {
        let extra = (global_spaces[c] - own_spaces[c]) as usize;
        row.extend(std::iter::repeat(gap).take(extra));
    };
    let mut idx = 0usize;
    while idx < ops.len() {
        match ops[idx] {
            Op::Up => {
                // All Ups at this center position form the pair's own
                // inserted block; emit them then pad to the global count.
                while idx < ops.len() && ops[idx] == Op::Up {
                    row.push(query[qi]);
                    qi += 1;
                    idx += 1;
                }
                pad(&mut row, c);
                // The following Diag/Left (if any) handles column c.
            }
            Op::Diag => {
                if own_spaces[c] == 0 {
                    pad(&mut row, c);
                }
                row.push(query[qi]);
                qi += 1;
                c += 1;
                idx += 1;
            }
            Op::Left => {
                if own_spaces[c] == 0 {
                    pad(&mut row, c);
                }
                row.push(gap);
                c += 1;
                idx += 1;
            }
        }
    }
    // Trailing gap block at center end.
    if ops.is_empty() || own_spaces[c] == 0 {
        pad(&mut row, c);
    }
    debug_assert_eq!(qi, query.len());
    row
}

/// Emit the final aligned center row under the global space profile.
pub fn render_center_row(center: &[u8], global_spaces: &[u32], alphabet: Alphabet) -> Vec<u8> {
    let gap = alphabet.gap();
    let mut row = Vec::new();
    for (j, &ch) in center.iter().enumerate() {
        row.extend(std::iter::repeat(gap).take(global_spaces[j] as usize));
        row.push(ch);
    }
    row.extend(std::iter::repeat(gap).take(global_spaces[center.len()] as usize));
    row
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::trie::SegmentTrie;
    use crate::fasta::Alphabet;

    fn codes(s: &str) -> Vec<u8> {
        s.bytes().map(|b| Alphabet::Dna.encode(b)).collect()
    }

    fn degap(row: &[u8]) -> Vec<u8> {
        row.iter().copied().filter(|&c| c != Alphabet::Dna.gap()).collect()
    }

    #[test]
    fn global_dp_identical_is_all_diag() {
        let a = codes("ACGTACGT");
        let ops = global_dp(&a, &a);
        assert!(ops.iter().all(|o| *o == Op::Diag));
    }

    #[test]
    fn global_dp_consumes_both_fully() {
        let a = codes("ACGGT");
        let b = codes("AGT");
        let ops = global_dp(&a, &b);
        assert_eq!(path_consumes(&ops), (5, 3));
    }

    #[test]
    fn global_dp_empty_sides() {
        assert_eq!(global_dp(&[], &codes("ACG")), vec![Op::Left; 3]);
        assert_eq!(global_dp(&codes("AC"), &[]), vec![Op::Up; 2]);
        assert!(global_dp(&[], &[]).is_empty());
    }

    #[test]
    fn ops_codec_roundtrip() {
        let ops = vec![Op::Diag, Op::Up, Op::Left, Op::Diag];
        assert_eq!(decode_ops(&encode_ops(&ops)), ops);
    }

    #[test]
    fn anchored_align_consumes_both_fully() {
        let center = codes("ACGTACTTGGCATCAGGATCACGATCGA");
        let query = codes("ACGTACTTGCATCAGGATCACGTTCGA"); // del + subst
        let trie = SegmentTrie::build(&center, 5);
        let ops = anchored_align(&query, &center, &trie);
        assert_eq!(path_consumes(&ops), (query.len(), center.len()));
    }

    #[test]
    fn space_profile_counts_insertions() {
        // query=AXC vs center=AC: X inserted after center pos 1.
        let ops = vec![Op::Diag, Op::Up, Op::Diag];
        assert_eq!(center_space_profile(&ops, 2), vec![0, 1, 0]);
    }

    #[test]
    fn merge_profiles_is_elementwise_max() {
        assert_eq!(merge_profiles(vec![0, 2, 1], &[1, 1, 3]), vec![1, 2, 3]);
    }

    #[test]
    fn render_center_and_query_rows_align() {
        let center = codes("AC");
        // Pair 1: query "AXC"  (insert X after A) -> ops D U D
        // Pair 2: query "AC"   -> ops D D
        let q1 = codes("ATC"); // using T as the inserted residue
        let ops1 = vec![Op::Diag, Op::Up, Op::Diag];
        let q2 = codes("AC");
        let ops2 = vec![Op::Diag, Op::Diag];
        let p1 = center_space_profile(&ops1, 2);
        let p2 = center_space_profile(&ops2, 2);
        let global = merge_profiles(p1.clone(), &p2);
        assert_eq!(global, vec![0, 1, 0]);

        let alpha = Alphabet::Dna;
        let center_row = render_center_row(&center, &global, alpha);
        let r1 = render_query_row(&q1, &ops1, &global, &p1, alpha);
        let r2 = render_query_row(&q2, &ops2, &global, &p2, alpha);
        assert_eq!(center_row.len(), 3);
        assert_eq!(r1.len(), 3);
        assert_eq!(r2.len(), 3);
        // Center: A - C ; q1: A T C ; q2: A - C
        assert_eq!(center_row, codes("A-C"));
        assert_eq!(r1, codes("ATC"));
        assert_eq!(r2, codes("A-C"));
        assert_eq!(degap(&r1), q1);
        assert_eq!(degap(&r2), q2);
    }

    #[test]
    fn render_handles_leading_and_trailing_insertions() {
        let center = codes("GG");
        let q = codes("TTGGTT");
        // T T (before center), G G, T T (after center)
        let ops = vec![Op::Up, Op::Up, Op::Diag, Op::Diag, Op::Up, Op::Up];
        let p = center_space_profile(&ops, 2);
        assert_eq!(p, vec![2, 0, 2]);
        let global = merge_profiles(p.clone(), &[3, 1, 2]);
        let alpha = Alphabet::Dna;
        let center_row = render_center_row(&center, &global, alpha);
        let row = render_query_row(&q, &ops, &global, &p, alpha);
        assert_eq!(center_row.len(), row.len());
        assert_eq!(degap(&row), q);
        // Width = center(2) + 3 + 1 + 2 gap slots.
        assert_eq!(center_row.len(), 8);
    }

    /// Property (≥100 seeded cases): for a random pair aligned with
    /// [`global_dp`], rendering query and center rows under a merged
    /// profile yields rows of equal length, and degapping each row
    /// recovers the original sequence exactly.
    #[test]
    fn prop_degap_recovers_originals_and_rows_align() {
        use crate::util::Rng;
        let alpha = Alphabet::Dna;
        for case in 0..120u64 {
            let mut rng = Rng::seed_from_u64(0xA11E5 + case);
            let n = 1 + rng.below(60);
            let m = 1 + rng.below(60);
            let center: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
            let query: Vec<u8> = (0..m).map(|_| rng.below(4) as u8).collect();
            let ops = global_dp(&query, &center);
            assert_eq!(path_consumes(&ops), (m, n), "case {case}");

            let own = center_space_profile(&ops, n);
            // A second random pair contributes to the merged profile, as
            // in the real reduction.
            let m2 = 1 + rng.below(60);
            let query2: Vec<u8> = (0..m2).map(|_| rng.below(4) as u8).collect();
            let ops2 = global_dp(&query2, &center);
            let own2 = center_space_profile(&ops2, n);
            let global = merge_profiles(own.clone(), &own2);

            let row_q = render_query_row(&query, &ops, &global, &own, alpha);
            let row_q2 = render_query_row(&query2, &ops2, &global, &own2, alpha);
            let row_c = render_center_row(&center, &global, alpha);
            assert_eq!(row_q.len(), row_c.len(), "case {case}: aligned rows equal length");
            assert_eq!(row_q2.len(), row_c.len(), "case {case}: aligned rows equal length");

            let degap = |row: &[u8]| -> Vec<u8> {
                row.iter().copied().filter(|&c| c != alpha.gap()).collect()
            };
            assert_eq!(degap(&row_q), query, "case {case}: query round-trips");
            assert_eq!(degap(&row_q2), query2, "case {case}: query2 round-trips");
            assert_eq!(degap(&row_c), center, "case {case}: center round-trips");
        }
    }

    /// Property (≥100 seeded cases): anchored alignment consumes both
    /// sequences fully and its encoded path round-trips the codec.
    #[test]
    fn prop_anchored_align_consumes_and_encodes() {
        use crate::util::Rng;
        for case in 0..100u64 {
            let mut rng = Rng::seed_from_u64(0x7A1E + case);
            let n = 20 + rng.below(120);
            let center: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
            // Mutate a copy so anchors exist but are imperfect.
            let mut query = center.clone();
            for _ in 0..rng.below(8) {
                let k = rng.below(query.len());
                query[k] = rng.below(4) as u8;
            }
            if rng.chance(0.5) && query.len() > 2 {
                let k = rng.below(query.len() - 1);
                query.remove(k);
            }
            let trie = SegmentTrie::build(&center, 4 + rng.below(6));
            let ops = anchored_align(&query, &center, &trie);
            assert_eq!(path_consumes(&ops), (query.len(), center.len()), "case {case}");
            assert_eq!(decode_ops(&encode_ops(&ops)), ops, "case {case}");
        }
    }

    #[test]
    fn random_pairs_roundtrip_through_render() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(99);
        let alpha = Alphabet::Dna;
        for trial in 0..50 {
            let n = 1 + rng.below(30);
            let m = 1 + rng.below(30);
            let center: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
            let query: Vec<u8> = (0..m).map(|_| rng.below(4) as u8).collect();
            let ops = global_dp(&query, &center);
            assert_eq!(path_consumes(&ops), (m, n), "trial {trial}");
            let p = center_space_profile(&ops, n);
            // Global profile strictly larger in a few random slots.
            let mut global = p.clone();
            for _ in 0..3 {
                let k = rng.below(n + 1);
                global[k] += rng.below(3) as u32;
            }
            let row = render_query_row(&query, &ops, &global, &p, alpha);
            let width = n + global.iter().sum::<u32>() as usize;
            assert_eq!(row.len(), width, "trial {trial}");
            assert_eq!(degap(&row), query, "trial {trial}");
        }
    }
}

//! Keyword tree (trie) with failure links over center-sequence segments —
//! HAlign's acceleration for similar nucleotide sequences (paper §Trie
//! trees method): the center sequence is cut into fixed-length segments,
//! the segments go into a trie, and each query is scanned once (linear
//! time via failure links, Aho-Corasick style) to find exact segment
//! occurrences that anchor the pairwise alignment; DP only runs between
//! anchors.

use crate::util::hash::DetHashMap;

/// One exact match of a center segment inside a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Anchor {
    /// Start of the segment in the center sequence.
    pub center_pos: usize,
    /// Start of the occurrence in the query.
    pub query_pos: usize,
    /// Segment length (the trie's fixed segment length, except possibly
    /// a shorter final segment).
    pub len: usize,
}

#[derive(Debug, Clone)]
struct Node {
    children: DetHashMap<u8, u32>,
    fail: u32,
    /// Segment indices terminating at this node.
    outputs: Vec<u32>,
}

impl Node {
    fn new(_depth: u32) -> Self {
        Self { children: DetHashMap::default(), fail: 0, outputs: Vec::new() }
    }
}

/// Aho-Corasick automaton over the center's segments.
#[derive(Debug, Clone)]
pub struct SegmentTrie {
    nodes: Vec<Node>,
    /// (center_pos, len) per segment index.
    segments: Vec<(usize, usize)>,
    segment_len: usize,
}

impl SegmentTrie {
    /// Cut `center` into consecutive `segment_len`-length segments (the
    /// trailing partial segment is dropped — it would anchor weakly) and
    /// build the automaton.
    pub fn build(center: &[u8], segment_len: usize) -> Self {
        assert!(segment_len >= 2, "segment_len must be >= 2");
        let mut trie = Self {
            nodes: vec![Node::new(0)],
            segments: Vec::new(),
            segment_len,
        };
        let mut start = 0;
        while start + segment_len <= center.len() {
            let seg = &center[start..start + segment_len];
            let idx = trie.segments.len() as u32;
            trie.segments.push((start, segment_len));
            trie.insert(seg, idx);
            start += segment_len;
        }
        trie.build_failure_links();
        trie
    }

    fn insert(&mut self, seg: &[u8], idx: u32) {
        let mut node = 0u32;
        for (d, &c) in seg.iter().enumerate() {
            let next = match self.nodes[node as usize].children.get(&c) {
                Some(&n) => n,
                None => {
                    let n = self.nodes.len() as u32;
                    self.nodes.push(Node::new(d as u32 + 1));
                    self.nodes[node as usize].children.insert(c, n);
                    n
                }
            };
            node = next;
        }
        self.nodes[node as usize].outputs.push(idx);
    }

    /// BFS failure-link construction (classic Aho-Corasick).
    fn build_failure_links(&mut self) {
        let mut queue = std::collections::VecDeque::new();
        let root_children: Vec<u32> = self.nodes[0].children.values().copied().collect();
        for c in root_children {
            self.nodes[c as usize].fail = 0;
            queue.push_back(c);
        }
        while let Some(u) = queue.pop_front() {
            let children: Vec<(u8, u32)> =
                self.nodes[u as usize].children.iter().map(|(&c, &n)| (c, n)).collect();
            for (c, v) in children {
                // Follow fail links of u until a node with child c.
                let mut f = self.nodes[u as usize].fail;
                let fail_v = loop {
                    if let Some(&w) = self.nodes[f as usize].children.get(&c) {
                        if w != v {
                            break w;
                        }
                    }
                    if f == 0 {
                        break 0;
                    }
                    f = self.nodes[f as usize].fail;
                };
                self.nodes[v as usize].fail = fail_v;
                let inherited = self.nodes[fail_v as usize].outputs.clone();
                self.nodes[v as usize].outputs.extend(inherited);
                queue.push_back(v);
            }
        }
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    pub fn segment_len(&self) -> usize {
        self.segment_len
    }

    pub fn segment(&self, idx: usize) -> (usize, usize) {
        self.segments[idx]
    }

    /// Scan the query once, reporting every occurrence of every segment.
    pub fn scan(&self, query: &[u8]) -> Vec<Anchor> {
        let mut out = Vec::new();
        let mut node = 0u32;
        for (i, &c) in query.iter().enumerate() {
            loop {
                if let Some(&n) = self.nodes[node as usize].children.get(&c) {
                    node = n;
                    break;
                }
                if node == 0 {
                    break;
                }
                node = self.nodes[node as usize].fail;
            }
            for &seg in &self.nodes[node as usize].outputs {
                let (center_pos, len) = self.segments[seg as usize];
                out.push(Anchor { center_pos, query_pos: i + 1 - len, len });
            }
        }
        out
    }

    /// Greedy monotone chain of anchors: walk segments in center order,
    /// taking for each the query occurrence (after the previous anchor's
    /// end) that best preserves the running diagonal — i.e. minimizes the
    /// indel imbalance `|(qp - q_cursor) - (cp - c_cursor)|` — and
    /// skipping the segment entirely when even the best occurrence would
    /// imply an imbalance of a full segment length (repetitive sequence
    /// matching out of position).  Matches HAlign's "matched segments are
    /// skipped" behaviour and is linear in the number of occurrences.
    pub fn chain(&self, query: &[u8]) -> Vec<Anchor> {
        let mut occs: Vec<Vec<usize>> = vec![Vec::new(); self.segments.len()];
        let mut node = 0u32;
        for (i, &c) in query.iter().enumerate() {
            loop {
                if let Some(&n) = self.nodes[node as usize].children.get(&c) {
                    node = n;
                    break;
                }
                if node == 0 {
                    break;
                }
                node = self.nodes[node as usize].fail;
            }
            for &seg in &self.nodes[node as usize].outputs {
                let len = self.segments[seg as usize].1;
                occs[seg as usize].push(i + 1 - len);
            }
        }
        let mut chain: Vec<Anchor> = Vec::new();
        let mut q_cursor = 0usize;
        let mut c_cursor = 0usize;
        for (seg, seg_occs) in occs.iter().enumerate() {
            let (center_pos, len) = self.segments[seg];
            let best = seg_occs
                .iter()
                .filter(|&&q| q >= q_cursor)
                .map(|&qp| {
                    let dq = (qp - q_cursor) as i64;
                    let dc = (center_pos - c_cursor) as i64;
                    ((dq - dc).unsigned_abs() as usize, qp)
                })
                .min();
            if let Some((imbalance, qp)) = best {
                if imbalance >= len {
                    continue; // out-of-position repeat; let DP handle it
                }
                chain.push(Anchor { center_pos, query_pos: qp, len });
                q_cursor = qp + len;
                c_cursor = center_pos + len;
            }
        }
        chain
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::Alphabet;

    fn codes(s: &str) -> Vec<u8> {
        s.bytes().map(|b| Alphabet::Dna.encode(b)).collect()
    }

    #[test]
    fn finds_all_segment_occurrences() {
        let center = codes("ACGTACGTTTTT"); // segments (len 4): ACGT, ACGT, TTTT
        let trie = SegmentTrie::build(&center, 4);
        assert_eq!(trie.num_segments(), 3);
        let query = codes("GGACGTGG");
        let anchors = trie.scan(&query);
        // ACGT occurs once in the query but matches both segments 0 and 1.
        assert_eq!(anchors.len(), 2);
        assert!(anchors.iter().all(|a| a.query_pos == 2 && a.len == 4));
    }

    #[test]
    fn overlapping_occurrences_found_via_failure_links() {
        let center = codes("AAAA");
        let trie = SegmentTrie::build(&center, 2); // segments AA, AA
        let query = codes("AAA"); // AA occurs at 0 and 1
        let anchors = trie.scan(&query);
        let positions: Vec<usize> = anchors.iter().map(|a| a.query_pos).collect();
        assert!(positions.contains(&0) && positions.contains(&1));
    }

    #[test]
    fn identical_sequence_chains_every_segment() {
        let center = codes("ACGTTGCAACGTGGCCTTAA");
        let trie = SegmentTrie::build(&center, 5);
        let chain = trie.chain(&center);
        assert_eq!(chain.len(), trie.num_segments());
        for a in &chain {
            assert_eq!(a.center_pos, a.query_pos, "self-chain is the identity");
        }
    }

    #[test]
    fn chain_is_monotone_in_both_coordinates() {
        let center = codes("ACGTACTTGGCATCAGGATC");
        let trie = SegmentTrie::build(&center, 4);
        // Query with a deletion and a substitution relative to center.
        let query = codes("ACGTACTTGCATCAGGTC");
        let chain = trie.chain(&query);
        for w in chain.windows(2) {
            assert!(w[1].center_pos > w[0].center_pos);
            assert!(w[1].query_pos >= w[0].query_pos + w[0].len);
        }
    }

    #[test]
    fn mutated_sequence_still_anchors_most_segments() {
        use crate::data::DatasetSpec;
        let spec = DatasetSpec { count: 5, ..DatasetSpec::mito(0.05, 11) };
        let seqs = spec.generate();
        let trie = SegmentTrie::build(&seqs[0].codes, 16);
        for s in &seqs[1..] {
            let chain = trie.chain(&s.codes);
            let anchored: usize = chain.iter().map(|a| a.len).sum();
            assert!(
                anchored * 2 > seqs[0].len(),
                "similar genomes should anchor >50%: {} of {}",
                anchored,
                seqs[0].len()
            );
        }
    }

    #[test]
    fn short_center_yields_empty_trie() {
        let trie = SegmentTrie::build(&codes("ACG"), 8);
        assert_eq!(trie.num_segments(), 0);
        assert!(trie.chain(&codes("ACGTACGT")).is_empty());
    }
}

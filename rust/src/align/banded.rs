//! Banded integer DP kernels with adaptive band widening.
//!
//! All kernels here score in integers, so traceback predecessor checks
//! are exact equality — no epsilon anywhere.  The band is over diagonal
//! offsets `d = j - i ∈ [min(0,δ) - w, max(0,δ) + w]` with `δ = n - m`;
//! any path that leaves that band must spend at least `|δ| + 2(w+1)`
//! gap steps (it deviates past the corridor by more than `w` and must
//! come back), which upper-bounds every out-of-band path's score.  When
//! the banded optimum beats that bound — or the band covers the whole
//! matrix — the banded result is *provably* the full-DP optimum, and the
//! traceback (same diag→up→left check order as the full kernels) visits
//! exactly the cells full DP would, so the op path is bit-identical.
//! Otherwise the band doubles and the DP re-runs.
//!
//! * [`banded_global`] — linear-gap global alignment, bit-identical to
//!   [`super::pairwise::global_dp`] (+1 match / -1 mismatch / -2 gap),
//!   initial band seeded from the bit-parallel Myers edit distance.
//! * [`affine_full`] / [`affine_banded`] — integer affine-gap (Gotoh)
//!   global alignment, banded provably identical to the full DP.
//! * [`sw_align_i32`] — integer local Smith-Waterman replicating
//!   [`super::sw::sw_align`] (same argmax tie-break, same traceback
//!   order) for integer-valued substitution matrices.

use super::myers::myers_edit_distance;
use super::sw::{LocalAlignment, Op};
use crate::align::pairwise::{global_dp, PathOp};

/// Sentinel for out-of-band / unreachable cells.  Low enough that no
/// real score reaches it, high enough that a few additions can't wrap.
const NEG: i32 = i32::MIN / 4;

/// Linear-gap scores, matching [`global_dp`] exactly.
const GAP: i32 = -2;

/// Banded global alignment with adaptive widening; bit-identical ops to
/// [`global_dp`].  The initial band width is seeded from the Myers
/// bit-parallel edit distance (an alignment with `e` unit edits strays
/// at most `(e - |δ|)/2` beyond the corridor).
pub fn banded_global(a: &[u8], b: &[u8]) -> Vec<PathOp> {
    let (m, n) = (a.len(), b.len());
    if m == 0 {
        return vec![Op::Left; n];
    }
    if n == 0 {
        return vec![Op::Up; m];
    }
    if a == b {
        // score(i,i) == i exactly, so full-DP traceback is all Diag.
        return vec![Op::Diag; m];
    }
    let e = myers_edit_distance(a, b);
    let dd = (n as i64 - m as i64).unsigned_abs() as usize;
    let w0 = (e.saturating_sub(dd) / 2 + 1).max(8);
    banded_global_with_band(a, b, w0)
}

/// Banded global alignment starting at band width `w0`, doubling until
/// the result is provably optimal.  Exposed so tests can force the
/// adaptive re-run path with a deliberately tiny initial band.
pub fn banded_global_with_band(a: &[u8], b: &[u8], w0: usize) -> Vec<PathOp> {
    let (m, n) = (a.len(), b.len());
    if m == 0 {
        return vec![Op::Left; n];
    }
    if n == 0 {
        return vec![Op::Up; m];
    }
    let mut w = w0.max(1);
    loop {
        if let Some(ops) = banded_attempt(a, b, w) {
            return ops;
        }
        w *= 2;
    }
}

/// One banded fill + provability check + traceback.  Returns `None`
/// when the banded optimum cannot be certified as the global optimum.
fn banded_attempt(a: &[u8], b: &[u8], w: usize) -> Option<Vec<PathOp>> {
    let (m, n) = (a.len(), b.len());
    let delta = n as i64 - m as i64;
    let lo_d = delta.min(0) - w as i64;
    let hi_d = delta.max(0) + w as i64;
    let covers_full = lo_d <= -(m as i64) && hi_d >= n as i64;
    let bw = (hi_d - lo_d + 1) as usize;

    // Diagonal-band layout: cell (i, j) lives at i*bw + (j - i - lo_d).
    // Neighbors: (i-1,j-1) -> k - bw; (i-1,j) -> k - bw + 1; (i,j-1) -> k - 1.
    let mut score = vec![NEG; (m + 1) * bw];
    let idx = |i: usize, j: usize| -> usize { i * bw + (j as i64 - i as i64 - lo_d) as usize };
    score[idx(0, 0)] = 0;
    for j in 1..=n.min(hi_d as usize) {
        score[idx(0, j)] = j as i32 * GAP;
    }
    for i in 1..=m.min((-lo_d) as usize) {
        score[idx(i, 0)] = i as i32 * GAP;
    }
    for i in 1..=m {
        let ai = a[i - 1];
        let jlo = (i as i64 + lo_d).max(1) as usize;
        let jhi = (i as i64 + hi_d).min(n as i64);
        if jhi < jlo as i64 {
            continue;
        }
        for j in jlo..=jhi as usize {
            let col = (j as i64 - i as i64 - lo_d) as usize;
            let k = i * bw + col;
            let s = if ai == b[j - 1] { 1 } else { -1 };
            // The diagonal predecessor shares d, so it is always in band
            // and (by induction from the boundaries) holds a real value.
            let diag = score[k - bw] + s;
            let up = if col + 1 < bw { score[k - bw + 1] + GAP } else { NEG };
            let left = if col > 0 { score[k - 1] + GAP } else { NEG };
            score[k] = diag.max(up).max(left);
        }
    }

    let best = score[idx(m, n)];
    // Any path leaving the band spends >= |δ| + 2(w+1) gap steps; with
    // +1/-1/-2 scoring its score is <= min(m,n) - 2(|δ| + 2(w+1)).
    let out_of_band_cap =
        m.min(n) as i64 - 2 * (delta.unsigned_abs() as i64 + 2 * (w as i64 + 1));
    if !covers_full && (best as i64) <= out_of_band_cap {
        return None;
    }

    // Traceback — same check order as global_dp (diag, up, else left).
    let in_band = |i: usize, j: usize| -> bool {
        let d = j as i64 - i as i64;
        (lo_d..=hi_d).contains(&d)
    };
    let get = |i: usize, j: usize| -> i32 { if in_band(i, j) { score[idx(i, j)] } else { NEG } };
    let mut ops = Vec::with_capacity(m + n);
    let (mut i, mut j) = (m, n);
    while i > 0 || j > 0 {
        let v = get(i, j);
        if i > 0 && j > 0 {
            let s = if a[i - 1] == b[j - 1] { 1 } else { -1 };
            if v == get(i - 1, j - 1) + s {
                ops.push(Op::Diag);
                i -= 1;
                j -= 1;
                continue;
            }
        }
        if i > 0 && v == get(i - 1, j) + GAP {
            ops.push(Op::Up);
            i -= 1;
        } else {
            debug_assert!(j > 0, "banded traceback escaped the certified band");
            ops.push(Op::Left);
            j -= 1;
        }
    }
    ops.reverse();
    Some(ops)
}

// ---------------------------------------------------------------------
// Integer affine-gap (Gotoh) global alignment, full and banded.
// ---------------------------------------------------------------------

/// Integer affine-gap costs: a gap of length k costs `open + k*ext`
/// (both penalties positive), substitutions come from `subst`.
#[derive(Debug, Clone)]
pub struct AffineCosts {
    pub subst: Vec<i32>,
    pub alpha: usize,
    pub open: i32,
    pub ext: i32,
}

impl AffineCosts {
    #[inline]
    fn score(&self, a: u8, b: u8) -> i32 {
        self.subst[a as usize * self.alpha + b as usize]
    }
}

/// Full-matrix integer Gotoh global alignment: reference for
/// [`affine_banded`].  Returns (score, ops).
pub fn affine_full(a: &[u8], b: &[u8], p: &AffineCosts) -> (i32, Vec<PathOp>) {
    let (m, n) = (a.len(), b.len());
    if m == 0 {
        let s = if n == 0 { 0 } else { -p.open - n as i32 * p.ext };
        return (s, vec![Op::Left; n]);
    }
    if n == 0 {
        return (-p.open - m as i32 * p.ext, vec![Op::Up; m]);
    }
    let w = n + 1;
    let mut h = vec![NEG; (m + 1) * w];
    let mut e = vec![NEG; (m + 1) * w];
    let mut f = vec![NEG; (m + 1) * w];
    h[0] = 0;
    for j in 1..=n {
        e[j] = -p.open - j as i32 * p.ext;
        h[j] = e[j];
    }
    for i in 1..=m {
        f[i * w] = -p.open - i as i32 * p.ext;
        h[i * w] = f[i * w];
        for j in 1..=n {
            e[i * w + j] =
                (e[i * w + j - 1] - p.ext).max(h[i * w + j - 1] - p.open - p.ext).max(NEG);
            f[i * w + j] =
                (f[(i - 1) * w + j] - p.ext).max(h[(i - 1) * w + j] - p.open - p.ext).max(NEG);
            let diag = h[(i - 1) * w + j - 1] + p.score(a[i - 1], b[j - 1]);
            h[i * w + j] = diag.max(e[i * w + j]).max(f[i * w + j]);
        }
    }
    let ops = affine_traceback(
        a,
        b,
        p,
        |i, j| h[i * w + j],
        |i, j| e[i * w + j],
        |i, j| f[i * w + j],
    );
    (h[m * w + n], ops)
}

/// Banded integer Gotoh with adaptive widening; provably identical to
/// [`affine_full`] (score and ops).  Out-of-band paths spend at least
/// `|δ| + 2(w+1)` gap steps in at least one run, so they score at most
/// `max(0, min(m,n)*max_sub) - open - (|δ| + 2(w+1))*ext`; beating that
/// bound certifies the banded optimum.
pub fn affine_banded(a: &[u8], b: &[u8], p: &AffineCosts, w0: usize) -> (i32, Vec<PathOp>) {
    let (m, n) = (a.len(), b.len());
    if m == 0 || n == 0 {
        return affine_full(a, b, p);
    }
    let mut w = w0.max(1);
    loop {
        if let Some(res) = affine_banded_attempt(a, b, p, w) {
            return res;
        }
        w *= 2;
    }
}

fn affine_banded_attempt(
    a: &[u8],
    b: &[u8],
    p: &AffineCosts,
    w: usize,
) -> Option<(i32, Vec<PathOp>)> {
    let (m, n) = (a.len(), b.len());
    let delta = n as i64 - m as i64;
    let lo_d = delta.min(0) - w as i64;
    let hi_d = delta.max(0) + w as i64;
    let covers_full = lo_d <= -(m as i64) && hi_d >= n as i64;
    let bw = (hi_d - lo_d + 1) as usize;

    let mut h = vec![NEG; (m + 1) * bw];
    let mut e = vec![NEG; (m + 1) * bw];
    let mut f = vec![NEG; (m + 1) * bw];
    let idx = |i: usize, j: usize| -> usize { i * bw + (j as i64 - i as i64 - lo_d) as usize };
    h[idx(0, 0)] = 0;
    for j in 1..=n.min(hi_d as usize) {
        let k = idx(0, j);
        e[k] = -p.open - j as i32 * p.ext;
        h[k] = e[k];
    }
    for i in 1..=m.min((-lo_d) as usize) {
        let k = idx(i, 0);
        f[k] = -p.open - i as i32 * p.ext;
        h[k] = f[k];
    }
    for i in 1..=m {
        let ai = a[i - 1];
        let jlo = (i as i64 + lo_d).max(1) as usize;
        let jhi = (i as i64 + hi_d).min(n as i64);
        if jhi < jlo as i64 {
            continue;
        }
        for j in jlo..=jhi as usize {
            let col = (j as i64 - i as i64 - lo_d) as usize;
            let k = i * bw + col;
            let (el, hl) = if col > 0 { (e[k - 1], h[k - 1]) } else { (NEG, NEG) };
            let (fu, hu) =
                if col + 1 < bw { (f[k - bw + 1], h[k - bw + 1]) } else { (NEG, NEG) };
            e[k] = (el - p.ext).max(hl - p.open - p.ext).max(NEG);
            f[k] = (fu - p.ext).max(hu - p.open - p.ext).max(NEG);
            let diag = h[k - bw] + p.score(ai, b[j - 1]);
            h[k] = diag.max(e[k]).max(f[k]);
        }
    }

    let best = h[idx(m, n)];
    let max_sub = p.subst.iter().copied().max().unwrap_or(0) as i64;
    let gap_steps = delta.unsigned_abs() as i64 + 2 * (w as i64 + 1);
    let out_of_band_cap =
        (m.min(n) as i64 * max_sub).max(0) - p.open as i64 - gap_steps * p.ext as i64;
    if !covers_full && (best as i64) <= out_of_band_cap {
        return None;
    }

    let in_band = |i: usize, j: usize| -> bool {
        let d = j as i64 - i as i64;
        (lo_d..=hi_d).contains(&d)
    };
    let ops = affine_traceback(
        a,
        b,
        p,
        |i, j| if in_band(i, j) { h[idx(i, j)] } else { NEG },
        |i, j| if in_band(i, j) { e[idx(i, j)] } else { NEG },
        |i, j| if in_band(i, j) { f[idx(i, j)] } else { NEG },
    );
    Some((best, ops))
}

/// Shared three-layer traceback: exact integer equality, with the same
/// check order as [`super::gotoh::gotoh_align`] (diag, then E, then F;
/// gap runs close on the open-transition check).
fn affine_traceback(
    a: &[u8],
    b: &[u8],
    p: &AffineCosts,
    h: impl Fn(usize, usize) -> i32,
    e: impl Fn(usize, usize) -> i32,
    f: impl Fn(usize, usize) -> i32,
) -> Vec<PathOp> {
    #[derive(Clone, Copy, PartialEq)]
    enum Layer {
        H,
        E,
        F,
    }
    let (m, n) = (a.len(), b.len());
    let mut ops = Vec::with_capacity(m + n);
    let (mut i, mut j) = (m, n);
    let mut layer = Layer::H;
    while i > 0 || j > 0 {
        match layer {
            Layer::H => {
                let v = h(i, j);
                if i > 0 && j > 0 && v == h(i - 1, j - 1) + p.score(a[i - 1], b[j - 1]) {
                    ops.push(Op::Diag);
                    i -= 1;
                    j -= 1;
                } else if v == e(i, j) {
                    layer = Layer::E;
                } else {
                    debug_assert_eq!(v, f(i, j), "affine traceback lost at ({i},{j})");
                    layer = Layer::F;
                }
            }
            Layer::E => {
                let v = e(i, j);
                ops.push(Op::Left);
                let from_open = h(i, j - 1) - p.open - p.ext;
                j -= 1;
                if v == from_open {
                    layer = Layer::H;
                }
            }
            Layer::F => {
                let v = f(i, j);
                ops.push(Op::Up);
                let from_open = h(i - 1, j) - p.open - p.ext;
                i -= 1;
                if v == from_open {
                    layer = Layer::H;
                }
            }
        }
    }
    ops.reverse();
    ops
}

// ---------------------------------------------------------------------
// Integer local Smith-Waterman (exact mirror of the f32 kernel).
// ---------------------------------------------------------------------

/// Integer Smith-Waterman parameters.  Convertible from [`SwParams`]
/// whenever every matrix entry and the gap penalty are integer-valued
/// (true for all built-in matrices), in which case [`sw_align_i32`] is
/// bit-identical to [`super::sw::sw_align`]: f32 arithmetic on integer
/// values of this magnitude is exact, and both tracebacks test exact
/// equality in the same order.
#[derive(Debug, Clone)]
pub struct IntSwParams {
    pub subst: Vec<i32>,
    pub alpha: usize,
    pub gap: i32,
}

impl IntSwParams {
    /// Exact conversion; `None` if any parameter is not an f32-exact
    /// integer small enough for overflow-free i32/f32 agreement.
    pub fn from_f32(p: &super::sw::SwParams) -> Option<Self> {
        let conv = |v: f32| -> Option<i32> {
            if v.abs() > 1e7 || v != v.trunc() {
                return None;
            }
            Some(v as i32)
        };
        let mut subst = Vec::with_capacity(p.subst.len());
        for &v in &p.subst {
            subst.push(conv(v)?);
        }
        Some(Self { subst, alpha: p.alpha, gap: conv(p.gap)? })
    }

    #[inline]
    fn score(&self, a: i32, b: i32) -> i32 {
        self.subst[a as usize * self.alpha + b as usize]
    }
}

/// Integer local Smith-Waterman: same fill recurrence, same row-major
/// `v >= best` argmax tie-break, and same diag→up→left traceback as the
/// f32 kernel — but predecessor checks are exact integer equality.
pub fn sw_align_i32(a: &[i32], b: &[i32], p: &IntSwParams) -> LocalAlignment {
    let (m, n) = (a.len(), b.len());
    let w = n + 1;
    let mut h = vec![0i32; (m + 1) * w];
    for i in 1..=m {
        let ai = a[i - 1] as usize;
        let srow = &p.subst[ai * p.alpha..(ai + 1) * p.alpha];
        let mut left = 0i32;
        for j in 1..=n {
            let diag = h[(i - 1) * w + j - 1] + srow[b[j - 1] as usize];
            let up = h[(i - 1) * w + j] - p.gap;
            let v = diag.max(up).max(left - p.gap).max(0);
            h[i * w + j] = v;
            left = v;
        }
    }
    // Argmax with the same `v >= best` row-major tie-break (boundary
    // cells included) as HMatrix::argmax.
    let (mut bi, mut bj, mut best) = (0usize, 0usize, i32::MIN);
    for i in 0..=m {
        for j in 0..=n {
            let v = h[i * w + j];
            if v >= best {
                bi = i;
                bj = j;
                best = v;
            }
        }
    }
    let (a_end, b_end) = (bi, bj);
    let (mut i, mut j) = (bi, bj);
    let mut ops = Vec::new();
    while i > 0 && j > 0 && h[i * w + j] > 0 {
        let v = h[i * w + j];
        let diag = h[(i - 1) * w + j - 1] + p.score(a[i - 1], b[j - 1]);
        if v == diag {
            ops.push(Op::Diag);
            i -= 1;
            j -= 1;
        } else if v == h[(i - 1) * w + j] - p.gap {
            ops.push(Op::Up);
            i -= 1;
        } else {
            debug_assert_eq!(v, h[i * w + j - 1] - p.gap);
            ops.push(Op::Left);
            j -= 1;
        }
    }
    ops.reverse();
    LocalAlignment { score: best as f32, a_start: i, a_end, b_start: j, b_end, ops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_seq(rng: &mut Rng, len: usize, alpha: usize) -> Vec<u8> {
        (0..len).map(|_| rng.below(alpha) as u8).collect()
    }

    #[test]
    fn banded_matches_global_dp_on_hand_cases() {
        let cases: [(&[u8], &[u8]); 5] = [
            (b"ACGT", b"ACGT"),
            (b"ACGT", b""),
            (b"", b"ACGT"),
            (b"AAAA", b"TTTT"),
            (b"ACGTACGT", b"ACGGT"),
        ];
        for (a, b) in cases {
            assert_eq!(banded_global(a, b), global_dp(a, b));
        }
    }

    #[test]
    fn tiny_band_widens_to_the_same_answer() {
        let mut rng = Rng::seed_from_u64(0xBA2D);
        for case in 0..30 {
            let a = rand_seq(&mut rng, 1 + rng.below(120), 4);
            let b = rand_seq(&mut rng, 1 + rng.below(120), 4);
            // w0 = 1 forces the adaptive widening loop on most inputs.
            assert_eq!(banded_global_with_band(&a, &b, 1), global_dp(&a, &b), "case {case}");
        }
    }

    #[test]
    fn affine_banded_matches_full() {
        let p = AffineCosts {
            subst: vec![2, -3, -3, -3, -3, 2, -3, -3, -3, -3, 2, -3, -3, -3, -3, 2],
            alpha: 4,
            open: 5,
            ext: 1,
        };
        let mut rng = Rng::seed_from_u64(0xAFF1);
        for case in 0..30 {
            let a = rand_seq(&mut rng, 1 + rng.below(90), 4);
            let b = rand_seq(&mut rng, 1 + rng.below(90), 4);
            let (fs, fo) = affine_full(&a, &b, &p);
            let (bs, bo) = affine_banded(&a, &b, &p, 1);
            assert_eq!(fs, bs, "case {case} score");
            assert_eq!(fo, bo, "case {case} ops");
        }
    }

    #[test]
    fn sw_i32_matches_f32_kernel() {
        use crate::align::sw::{sw_align, SwParams};
        use crate::fasta::{alphabet::substitution_matrix, Alphabet};
        let p = SwParams {
            subst: substitution_matrix(Alphabet::Dna),
            alpha: Alphabet::Dna.size(),
            gap: 6.0,
        };
        let ip = IntSwParams::from_f32(&p).expect("DNA matrix is integer-valued");
        let mut rng = Rng::seed_from_u64(0x5117);
        for case in 0..30 {
            let a: Vec<i32> = (0..1 + rng.below(80)).map(|_| rng.below(4) as i32).collect();
            let b: Vec<i32> = (0..1 + rng.below(80)).map(|_| rng.below(4) as i32).collect();
            let sf = sw_align(&a, &b, &p);
            let si = sw_align_i32(&a, &b, &ip);
            assert_eq!(sf.score, si.score, "case {case}");
            assert_eq!(sf.ops, si.ops, "case {case}");
            assert_eq!(
                (sf.a_start, sf.a_end, sf.b_start, sf.b_end),
                (si.a_start, si.a_end, si.b_start, si.b_end),
                "case {case}"
            );
        }
    }
}

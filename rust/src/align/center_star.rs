//! Distributed center-star MSA — the paper's Figure-3 pipeline.
//!
//! Two MapReduce rounds over the engine:
//!
//! 1. **Map**: every sequence is pairwise-aligned against the broadcast
//!    center (trie-anchored for similar nucleotides); the edit path is
//!    kept, and its center-space profile extracted.
//!    **Reduce**: element-wise max of the space profiles — "the last and
//!    longest center star sequence".
//! 2. **Map**: with the merged profile broadcast, every pair renders its
//!    final aligned row.  Results are collected (the paper writes them to
//!    HDFS).
//!
//! Between the rounds, the edit paths are held per the backend: cached in
//! worker memory (Spark) or spilled through a disk checkpoint (Hadoop/
//! HAlign-v1 emulation) — the exact cost difference the paper measures.

use anyhow::{ensure, Context as _, Result};

use super::append::{ArtifactRow, MsaArtifact};
use super::pairwise::{
    anchored_align_with, center_space_profile, encode_ops, merge_profiles, render_center_row,
    render_query_row,
};
use super::trie::SegmentTrie;
use super::{KernelBackend, MsaResult};
use crate::engine::Cluster;
use crate::fasta::Sequence;

/// Tuning knobs for the nucleotide pipeline.
#[derive(Debug, Clone)]
pub struct CenterStarConfig {
    /// Trie segment length (HAlign uses short exact segments; 16 works
    /// well for >99%-similar genomes, smaller for divergent RNA).
    pub segment_len: usize,
    /// Partitions for the sequence RDD (0 = cluster default).
    pub partitions: usize,
    /// Center selection: 0/1 = first sequence (the paper's choice for
    /// similar sequences); k > 1 = sample k candidates and pick the one
    /// with the highest anchored coverage against a probe sample.
    pub center_sample: usize,
    /// When `partitions == 0`, the pipeline is repartitioned so each task
    /// holds roughly this many residues: long-sequence inputs get split
    /// into finer-grained tasks the work-stealing executor can balance
    /// (a straggler partition of long genomes no longer pins a stage to
    /// one node).
    pub target_residues_per_task: usize,
    /// Pairwise kernel backend for the inter-anchor global DP.  Both
    /// choices are bit-identical in output.
    pub kernel: KernelBackend,
}

impl Default for CenterStarConfig {
    fn default() -> Self {
        Self {
            segment_len: 16,
            partitions: 0,
            center_sample: 1,
            target_residues_per_task: 32 * 1024,
            kernel: KernelBackend::default(),
        }
    }
}

/// Residue-aware task count: enough partitions that a task holds about
/// `target` residues, at least the cluster default (capped at one task
/// per sequence so no partition is empty).  Shared with the protein
/// pipeline.
pub(crate) fn adaptive_partitions(
    seqs: &[Sequence],
    default_parts: usize,
    target: usize,
) -> usize {
    let total: usize = seqs.iter().map(Sequence::len).sum();
    let by_residues = total.div_ceil(target.max(1));
    by_residues.max(default_parts).min(seqs.len()).max(1)
}

/// Base partition count and split factor realizing the residue-aware
/// repartitioning: parallelize into the cluster-default partitions, then
/// `split_partitions(factor)` down to ~`target` residues per task.  The
/// split rides the slice-aware lineage (sources/caches/checkpoints serve
/// each slice its own range), so finer tasks cost one pass over the
/// input instead of `factor` recomputes.  `base * factor` never exceeds
/// the sequence count — the split must not reintroduce the empty tasks
/// [`adaptive_partitions`] caps away.  Shared with the protein pipeline.
pub(crate) fn repartition_plan(
    seqs: &[Sequence],
    default_parts: usize,
    target: usize,
) -> (usize, usize) {
    let n = seqs.len().max(1);
    let base = default_parts.clamp(1, n);
    let desired = adaptive_partitions(seqs, default_parts, target);
    let factor = desired.div_ceil(base).clamp(1, n / base);
    (base, factor)
}

/// Pick the center sequence index.
pub fn choose_center(seqs: &[Sequence], cfg: &CenterStarConfig, seed: u64) -> usize {
    if cfg.center_sample <= 1 || seqs.len() <= 2 {
        return 0; // "the first sequence represents the center sequence"
    }
    let mut rng = crate::util::Rng::seed_from_u64(seed);
    let candidates = rng.sample_indices(seqs.len(), cfg.center_sample.min(seqs.len()));
    let probes = rng.sample_indices(seqs.len(), 16.min(seqs.len()));
    let mut best = (candidates[0], 0usize);
    for &c in &candidates {
        let trie = SegmentTrie::build(&seqs[c].codes, cfg.segment_len);
        let coverage: usize = probes
            .iter()
            .map(|&p| trie.chain(&seqs[p].codes).iter().map(|a| a.len).sum::<usize>())
            .sum();
        if coverage > best.1 {
            best = (c, coverage);
        }
    }
    best.0
}

/// Distributed center-star MSA for similar nucleotide sequences.
pub fn align_nucleotide(
    cluster: &Cluster,
    seqs: &[Sequence],
    cfg: &CenterStarConfig,
) -> Result<MsaResult> {
    let (msa, _) = align_nucleotide_core(cluster, seqs, cfg, false)?;
    Ok(msa)
}

/// Like [`align_nucleotide`], but also retains the [`MsaArtifact`] —
/// center, merged space-profile, and per-row edit paths — that the
/// pipeline computes anyway.  The artifact is what the result cache
/// stores and what [`super::append::append_nucleotide`] extends.
pub fn align_nucleotide_with_artifact(
    cluster: &Cluster,
    seqs: &[Sequence],
    cfg: &CenterStarConfig,
) -> Result<(MsaResult, MsaArtifact)> {
    let (msa, art) = align_nucleotide_core(cluster, seqs, cfg, true)?;
    Ok((msa, art.expect("want_artifact=true always yields an artifact")))
}

fn align_nucleotide_core(
    cluster: &Cluster,
    seqs: &[Sequence],
    cfg: &CenterStarConfig,
    want_artifact: bool,
) -> Result<(MsaResult, Option<MsaArtifact>)> {
    ensure!(!seqs.is_empty(), "no sequences to align");
    let alphabet = seqs[0].alphabet;
    ensure!(
        seqs.iter().all(|s| s.alphabet == alphabet && !s.is_empty()),
        "sequences must share an alphabet and be non-empty"
    );
    if seqs.len() == 1 {
        let msa = MsaResult {
            aligned: seqs.to_vec(),
            center_index: 0,
            width: seqs[0].len(),
        };
        let art = want_artifact.then(|| MsaArtifact::single(&seqs[0], cfg));
        return Ok((msa, art));
    }

    let center_index = choose_center(seqs, cfg, cluster.config().seed);
    let center_codes = seqs[center_index].codes.clone();
    let segment_len = cfg.segment_len;
    // Residue-count repartitioning: coarse source partitions split into
    // ~target_residues_per_task tasks via the slice-aware split (each
    // slice computes only its own range of the source partition), so
    // long-sequence inputs become finer stealable tasks for free.
    let (base_parts, split_factor) = if cfg.partitions == 0 {
        repartition_plan(
            seqs,
            cluster.config().default_partitions,
            cfg.target_residues_per_task,
        )
    } else {
        (cfg.partitions, 1)
    };

    // ---- Round 1 map: pairwise align vs broadcast center ----------------
    let center_bc = cluster.broadcast(center_codes.clone())?;
    let indexed: Vec<(u64, Sequence)> = seqs
        .iter()
        .enumerate()
        .map(|(i, s)| (i as u64, s.clone()))
        .collect();
    let rdd = cluster.parallelize(indexed, base_parts).split_partitions(split_factor);
    let center_for_map = center_bc.arc();
    let kernel = cfg.kernel;
    let paths = rdd.map_partitions_with_index(move |_, items| {
        if items.is_empty() {
            return Vec::new(); // ragged tail slice: skip the trie build
        }
        // Build the trie once per partition (the broadcast is the center
        // codes; the automaton is cheap relative to alignment).
        let trie = SegmentTrie::build(&center_for_map, segment_len);
        items
            .into_iter()
            .map(|(idx, seq)| {
                let ops = anchored_align_with(&seq.codes, &center_for_map, &trie, kernel);
                (idx, seq, encode_ops(&ops))
            })
            .collect()
    });
    // Job boundary: Spark caches, Hadoop spills to disk (HAlign v1).
    let paths = paths.checkpoint().context("persisting pairwise paths")?;

    // ---- Round 1 reduce: merge space profiles ----------------------------
    let center_len = center_codes.len();
    let profiles = paths.map(move |(_, _, ops)| {
        center_space_profile(&super::pairwise::decode_ops(&ops), center_len)
    });
    let global = profiles
        .reduce(|a, b| merge_profiles(a, &b))?
        .context("at least one sequence must produce a profile")?;

    // ---- Round 2 map: render final rows under the merged profile --------
    let global_bc = cluster.broadcast(global.clone())?;
    let global_for_map = global_bc.arc();
    let rows = paths.map(move |(idx, seq, ops)| {
        let ops = super::pairwise::decode_ops(&ops);
        let own = center_space_profile(&ops, center_len);
        let row = render_query_row(&seq.codes, &ops, &global_for_map, &own, seq.alphabet);
        (idx, seq.id, row)
    });
    let mut collected = rows.collect()?;
    collected.sort_by_key(|(idx, _, _)| *idx);

    let width = center_len + global.iter().sum::<u32>() as usize;
    let mut aligned = Vec::with_capacity(seqs.len());
    for (idx, id, row) in collected {
        ensure!(
            row.len() == width,
            "row {idx} width {} != MSA width {width}",
            row.len()
        );
        aligned.push(Sequence::new(id, row, alphabet));
    }
    // Sanity: the center's own row must round-trip to the center itself.
    debug_assert_eq!(
        aligned[center_index]
            .codes
            .iter()
            .filter(|&&c| c != alphabet.gap())
            .count(),
        center_codes.len()
    );
    let _ = render_center_row(&center_codes, &global, alphabet); // (kept for parity checks)

    // The artifact reuses the checkpointed round-1 paths — a re-read of
    // already-persisted partitions, no new alignment work.
    let artifact = if want_artifact {
        let mut path_rows = paths.collect().context("collecting paths for artifact")?;
        path_rows.sort_by_key(|(idx, _, _)| *idx);
        ensure!(path_rows.len() == seqs.len(), "artifact path count mismatch");
        Some(MsaArtifact {
            alphabet,
            center_index,
            segment_len,
            kernel: cfg.kernel,
            global,
            rows: path_rows
                .into_iter()
                .map(|(_, seq, ops)| ArtifactRow { id: seq.id, codes: seq.codes, ops })
                .collect(),
        })
    } else {
        None
    };
    Ok((MsaResult { aligned, center_index, width }, artifact))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::sp_score::avg_sp;
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster, ClusterConfig};
    use crate::fasta::Alphabet;

    fn seq(id: &str, text: &str) -> Sequence {
        Sequence::from_text(id, text, Alphabet::Dna)
    }

    fn degapped(s: &Sequence) -> Vec<u8> {
        s.codes.iter().copied().filter(|&c| c != s.alphabet.gap()).collect()
    }

    fn check_msa(seqs: &[Sequence], msa: &MsaResult) {
        assert_eq!(msa.aligned.len(), seqs.len());
        for (orig, row) in seqs.iter().zip(&msa.aligned) {
            assert_eq!(row.len(), msa.width, "{}", orig.id);
            assert_eq!(degapped(row), orig.codes, "{} must round-trip", orig.id);
            assert_eq!(row.id, orig.id);
        }
    }

    #[test]
    fn identical_sequences_align_gap_free() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let seqs = vec![seq("a", "ACGTACGTACGTACGT"); 5];
        let msa = align_nucleotide(&c, &seqs, &CenterStarConfig::default()).unwrap();
        check_msa(&seqs, &msa);
        assert_eq!(msa.width, 16, "no gaps needed");
        assert_eq!(avg_sp(&msa.aligned).unwrap(), 0.0);
    }

    #[test]
    fn single_substitution_needs_no_gaps() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let seqs = vec![
            seq("a", "ACGTACGTACGTACGTACGT"),
            seq("b", "ACGTACGTACTTACGTACGT"),
        ];
        let cfg = CenterStarConfig { segment_len: 4, ..Default::default() };
        let msa = align_nucleotide(&c, &seqs, &cfg).unwrap();
        check_msa(&seqs, &msa);
        assert_eq!(msa.width, 20);
    }

    #[test]
    fn insertion_creates_one_gap_column() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let seqs = vec![
            seq("a", "ACGTACGTACGTACGTACGT"),
            seq("b", "ACGTACGTACCGTACGTACGT"), // one C inserted mid
        ];
        let cfg = CenterStarConfig { segment_len: 4, ..Default::default() };
        let msa = align_nucleotide(&c, &seqs, &cfg).unwrap();
        check_msa(&seqs, &msa);
        assert_eq!(msa.width, 21, "one inserted column");
    }

    #[test]
    fn works_on_both_backends_with_same_result() {
        let spec = DatasetSpec { count: 24, ..DatasetSpec::mito(0.01, 5) };
        let seqs = spec.generate();
        let cfg = CenterStarConfig { segment_len: 12, ..Default::default() };
        let spark = align_nucleotide(
            &Cluster::new(ClusterConfig::spark(3)),
            &seqs,
            &cfg,
        )
        .unwrap();
        let hadoop = align_nucleotide(
            &Cluster::new(ClusterConfig::hadoop(3)),
            &seqs,
            &cfg,
        )
        .unwrap();
        check_msa(&seqs, &spark);
        check_msa(&seqs, &hadoop);
        assert_eq!(spark.width, hadoop.width);
        for (a, b) in spark.aligned.iter().zip(&hadoop.aligned) {
            assert_eq!(a.codes, b.codes, "backends must agree exactly");
        }
    }

    #[test]
    fn kernel_backends_are_bit_identical() {
        let spec = DatasetSpec { count: 20, ..DatasetSpec::mito(0.02, 17) };
        let seqs = spec.generate();
        let c = Cluster::new(ClusterConfig::spark(3));
        let base = CenterStarConfig { segment_len: 12, ..Default::default() };
        let scalar = align_nucleotide(
            &c,
            &seqs,
            &CenterStarConfig { kernel: KernelBackend::Scalar, ..base.clone() },
        )
        .unwrap();
        let bitp = align_nucleotide(
            &c,
            &seqs,
            &CenterStarConfig { kernel: KernelBackend::BitParallel, ..base },
        )
        .unwrap();
        assert_eq!(scalar.width, bitp.width);
        for (a, b) in scalar.aligned.iter().zip(&bitp.aligned) {
            assert_eq!(a.codes, b.codes, "kernel backends must agree exactly");
        }
    }

    #[test]
    fn mito_msa_quality_reasonable() {
        let spec = DatasetSpec { count: 30, ..DatasetSpec::mito(0.03, 8) };
        let seqs = spec.generate();
        let c = Cluster::new(ClusterConfig::spark(4));
        let msa =
            align_nucleotide(&c, &seqs, &CenterStarConfig { segment_len: 12, ..Default::default() })
                .unwrap();
        check_msa(&seqs, &msa);
        let sp = avg_sp(&msa.aligned).unwrap();
        // ~0.2% divergence over ~500bp: a handful of penalty points/pair.
        assert!(sp > 0.0 && sp < 50.0, "avg SP {sp} out of expected band");
    }

    #[test]
    fn center_sampling_prefers_central_sequence() {
        let spec = DatasetSpec { count: 16, ..DatasetSpec::mito(0.01, 13) };
        let mut seqs = spec.generate();
        // Make sequence 0 junk so "first" would be a bad center.
        seqs[0] = seq("junk", &"T".repeat(seqs[1].len()));
        let cfg =
            CenterStarConfig { segment_len: 12, center_sample: 8, ..Default::default() };
        let picked = choose_center(&seqs, &cfg, 1);
        assert_ne!(picked, 0, "sampling should avoid the junk sequence");
    }

    #[test]
    fn adaptive_partitioning_scales_with_residues() {
        let spec = DatasetSpec { count: 64, ..DatasetSpec::mito(0.05, 11) };
        let seqs = spec.generate();
        let coarse = adaptive_partitions(&seqs, 8, 1 << 30);
        assert_eq!(coarse, 8, "huge target falls back to the cluster default");
        let fine = adaptive_partitions(&seqs, 8, 1024);
        assert!(fine > coarse, "long sequences must split finer (got {fine})");
        assert!(fine <= seqs.len(), "never more tasks than sequences");
    }

    #[test]
    fn repartition_plan_reaches_residue_granularity_via_split() {
        let seqs = DatasetSpec { count: 64, ..DatasetSpec::mito(0.05, 11) }.generate();
        let (base, factor) = repartition_plan(&seqs, 8, 1024);
        assert_eq!(base, 8, "source partitions stay at the cluster default");
        assert!(factor > 1, "fine residue target must split (factor {factor})");
        assert!(
            base * factor >= adaptive_partitions(&seqs, 8, 1024),
            "split must reach the residue-derived task count"
        );
        assert!(
            base * factor <= seqs.len(),
            "split must never create more tasks than sequences"
        );
        // A huge target needs no splitting at all.
        assert_eq!(repartition_plan(&seqs, 8, 1 << 30), (8, 1));
        // Fewer sequences than default partitions: base shrinks to fit.
        let three = &seqs[..3];
        let (b, f) = repartition_plan(three, 8, 1024);
        assert_eq!((b, f), (3, 1), "never more source partitions than sequences");
        // Sequence count barely above the default: the cap keeps the
        // plan at the coarse base rather than minting empty slices.
        let ten = &seqs[..10];
        let (b, f) = repartition_plan(ten, 8, 1);
        assert!(b * f <= 10, "10 sequences must yield at most 10 tasks (got {b}x{f})");
    }

    #[test]
    fn skewed_length_dataset_still_aligns_correctly() {
        // A few sequences 5x longer than the rest: the fine-grained
        // repartitioning plus work stealing must not change the result.
        let mut seqs = DatasetSpec { count: 12, ..DatasetSpec::mito(0.01, 21) }.generate();
        seqs.extend(DatasetSpec { count: 3, ..DatasetSpec::mito(0.05, 22) }.generate());
        let c = Cluster::new(ClusterConfig::spark(3));
        let cfg = CenterStarConfig {
            segment_len: 12,
            target_residues_per_task: 512,
            ..Default::default()
        };
        let msa = align_nucleotide(&c, &seqs, &cfg).unwrap();
        check_msa(&seqs, &msa);
    }

    #[test]
    fn fault_injection_still_produces_correct_msa() {
        use crate::engine::FaultPlan;
        let spec = DatasetSpec { count: 12, ..DatasetSpec::mito(0.01, 3) };
        let seqs = spec.generate();
        let mut cfg = ClusterConfig::spark(3);
        cfg.fault = FaultPlan::random(0.2, 77);
        cfg.max_retries = 6;
        let c = Cluster::new(cfg);
        let msa = align_nucleotide(&c, &seqs, &CenterStarConfig::default()).unwrap();
        check_msa(&seqs, &msa);
        assert!(c.stats().injected_failures > 0, "faults should have fired");
    }
}

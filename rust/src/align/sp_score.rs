//! Average sum-of-pairs (avg SP) score — the paper's MSA quality metric
//! (§Datasets): walking every pair of aligned rows, a mismatched residue
//! pair adds 1, a residue-vs-space pair adds 2, matches and space-vs-space
//! add 0; the average is over all C(n,2) pairs.  **Lower is better** (it
//! is a penalty; cf. Table 2 where MUSCLE scores 81 vs HAlign's 191).
//!
//! The naive computation is O(n² L); [`avg_sp_columnwise`] computes the
//! identical value in O(L · alpha) per column from residue counts:
//! with k residues of which count_c of residue c, and g gaps, a column
//! contributes `1·(C(k,2) − Σ_c C(count_c,2)) + 2·k·g`.  This is what
//! makes scoring the ultra-large MSAs feasible, and it distributes over
//! column blocks (each partition sums its columns).

use anyhow::{ensure, Result};

use crate::fasta::{Alphabet, Sequence};

/// Exact O(n²L) reference (tests + tiny inputs).
pub fn sp_pairwise(rows: &[Sequence]) -> f64 {
    let n = rows.len();
    if n < 2 {
        return 0.0;
    }
    let gap = rows[0].alphabet.gap();
    let mut total = 0u64;
    for i in 0..n {
        for j in (i + 1)..n {
            let (a, b) = (&rows[i].codes, &rows[j].codes);
            for k in 0..a.len() {
                let (x, y) = (a[k], b[k]);
                if x == gap && y == gap {
                    continue;
                }
                if x == gap || y == gap {
                    total += 2;
                } else if x != y {
                    total += 1;
                }
            }
        }
    }
    total as f64
}

/// Column-count SP over one column given residue counts and gap count.
#[inline]
pub fn column_sp(counts: &[u64], gaps: u64) -> u64 {
    let k: u64 = counts.iter().sum();
    let pairs = k * k.saturating_sub(1) / 2;
    let same: u64 = counts.iter().map(|&c| c * c.saturating_sub(1) / 2).sum();
    (pairs - same) + 2 * k * gaps
}

/// Exact total SP via column counts, O(L·alpha).
pub fn sp_columnwise(rows: &[Sequence]) -> Result<f64> {
    if rows.len() < 2 {
        return Ok(0.0);
    }
    let alphabet = rows[0].alphabet;
    let width = rows[0].len();
    ensure!(
        rows.iter().all(|r| r.len() == width && r.alphabet == alphabet),
        "rows must be an aligned block (equal width, same alphabet)"
    );
    let mut total = 0u64;
    let mut counts = vec![0u64; alphabet.size()];
    let gap = alphabet.gap();
    for col in 0..width {
        counts.iter_mut().for_each(|c| *c = 0);
        let mut gaps = 0u64;
        for r in rows {
            let c = r.codes[col];
            if c == gap {
                gaps += 1;
            } else {
                counts[c as usize] += 1;
            }
        }
        total += column_sp(&counts, gaps);
    }
    Ok(total as f64)
}

/// The paper's "average SP": total SP / C(n, 2).
pub fn avg_sp(rows: &[Sequence]) -> Result<f64> {
    let n = rows.len() as f64;
    if n < 2.0 {
        return Ok(0.0);
    }
    Ok(sp_columnwise(rows)? / (n * (n - 1.0) / 2.0))
}

/// Column-count contribution of a *block of columns*, as (counts per
/// column) — used by the distributed scorer in the MSA pipelines.
pub fn block_sp(rows: &[Vec<u8>], alphabet: Alphabet, col_lo: usize, col_hi: usize) -> u64 {
    let gap = alphabet.gap();
    let mut total = 0u64;
    let mut counts = vec![0u64; alphabet.size()];
    for col in col_lo..col_hi {
        counts.iter_mut().for_each(|c| *c = 0);
        let mut gaps = 0u64;
        for r in rows {
            let c = r[col];
            if c == gap {
                gaps += 1;
            } else {
                counts[c as usize] += 1;
            }
        }
        total += column_sp(&counts, gaps);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::Alphabet;

    fn rows(texts: &[&str]) -> Vec<Sequence> {
        texts
            .iter()
            .enumerate()
            .map(|(i, t)| Sequence::from_text(format!("s{i}"), t, Alphabet::Dna))
            .collect()
    }

    #[test]
    fn identical_rows_score_zero() {
        let r = rows(&["ACGT", "ACGT", "ACGT"]);
        assert_eq!(sp_columnwise(&r).unwrap(), 0.0);
    }

    #[test]
    fn known_hand_computed_case() {
        // Columns: (A,A)=0 ; (C,G)=1 ; (G,-)=2 ; (T,T)=0  => SP=3, pairs=1.
        let r = rows(&["ACGT", "AG-T"]);
        assert_eq!(sp_columnwise(&r).unwrap(), 3.0);
        assert_eq!(avg_sp(&r).unwrap(), 3.0);
    }

    #[test]
    fn columnwise_equals_pairwise_reference() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from_u64(21);
        for _ in 0..20 {
            let n = 2 + rng.below(6);
            let w = 1 + rng.below(25);
            let r: Vec<Sequence> = (0..n)
                .map(|i| {
                    let codes: Vec<u8> =
                        (0..w).map(|_| rng.below(6) as u8).collect(); // incl gaps
                    Sequence::new(format!("r{i}"), codes, Alphabet::Dna)
                })
                .collect();
            assert_eq!(sp_columnwise(&r).unwrap(), sp_pairwise(&r));
        }
    }

    /// Property (≥100 seeded cases): SP is symmetric — swapping any two
    /// sequences (indeed any permutation of the rows) leaves both the
    /// pairwise and the column-count score unchanged.
    #[test]
    fn prop_sp_symmetric_under_row_swap() {
        use crate::util::Rng;
        for case in 0..120u64 {
            let mut rng = Rng::seed_from_u64(0x5B00 + case);
            let n = 2 + rng.below(6);
            let w = 1 + rng.below(24);
            let mut r: Vec<Sequence> = (0..n)
                .map(|i| {
                    let codes: Vec<u8> = (0..w).map(|_| rng.below(6) as u8).collect();
                    Sequence::new(format!("r{i}"), codes, Alphabet::Dna)
                })
                .collect();
            let base = sp_columnwise(&r).unwrap();
            assert_eq!(sp_pairwise(&r), base, "case {case}: columnwise == pairwise");
            // Swap a random pair of rows.
            let (i, j) = (rng.below(n), rng.below(n));
            r.swap(i, j);
            assert_eq!(sp_columnwise(&r).unwrap(), base, "case {case}: swap invariant");
            // Any full permutation too.
            rng.shuffle(&mut r);
            assert_eq!(sp_columnwise(&r).unwrap(), base, "case {case}: permutation invariant");
            assert_eq!(sp_pairwise(&r), base, "case {case}");
        }
    }

    /// Property (≥100 seeded cases): block decomposition sums to the
    /// whole-alignment score at any random split point.
    #[test]
    fn prop_block_sp_splits_anywhere() {
        use crate::util::Rng;
        for case in 0..100u64 {
            let mut rng = Rng::seed_from_u64(0xB10C + case);
            let n = 2 + rng.below(5);
            let w = 2 + rng.below(30);
            let rows: Vec<Sequence> = (0..n)
                .map(|i| {
                    let codes: Vec<u8> = (0..w).map(|_| rng.below(6) as u8).collect();
                    Sequence::new(format!("r{i}"), codes, Alphabet::Dna)
                })
                .collect();
            let raw: Vec<Vec<u8>> = rows.iter().map(|s| s.codes.clone()).collect();
            let total = sp_columnwise(&rows).unwrap() as u64;
            let cut = rng.below(w + 1);
            let split = block_sp(&raw, Alphabet::Dna, 0, cut)
                + block_sp(&raw, Alphabet::Dna, cut, w);
            assert_eq!(split, total, "case {case}: cut at {cut}");
        }
    }

    #[test]
    fn gap_vs_gap_is_free() {
        let r = rows(&["A-T", "A-T"]);
        assert_eq!(sp_columnwise(&r).unwrap(), 0.0);
    }

    #[test]
    fn rejects_ragged_rows() {
        let r = rows(&["ACGT", "ACG"]);
        assert!(sp_columnwise(&r).is_err());
    }

    #[test]
    fn block_sp_partitions_total() {
        let r = rows(&["ACGTAC", "AG-TCC", "A-GTAC"]);
        let raw: Vec<Vec<u8>> = r.iter().map(|s| s.codes.clone()).collect();
        let total = sp_columnwise(&r).unwrap() as u64;
        let split = block_sp(&raw, Alphabet::Dna, 0, 3) + block_sp(&raw, Alphabet::Dna, 3, 6);
        assert_eq!(split, total);
    }
}

//! Center-star MSA: trie acceleration, pairwise DP, space-merge algebra,
//! SP scoring, and the nucleotide / protein pipelines.
//!
//! Pairwise kernels come in two interchangeable backends selected by
//! [`KernelBackend`] (same A/B discipline as `SchedulerMode` and the
//! distmat backends): `Scalar` keeps the original full-matrix f32/i32
//! DP loops, `BitParallel` (default) routes through the integer kernels
//! in [`myers`] and [`banded`] — bit-parallel edit distance, banded
//! adaptive-width global DP, packed p-distance counts, and integer SW.
//! Both backends produce bit-identical alignments and distances (the
//! property suite pins this), so the switch is purely a speed knob.
//!
//! Finished nucleotide MSAs can be summarized into a persistable
//! [`append::MsaArtifact`] (center + merged space-profile + per-row edit
//! paths); [`append::append_nucleotide`] extends such an artifact with
//! new sequences in O(k·L) while staying bit-identical to a from-scratch
//! run on the union — the serving-layer memoization path (see
//! `rust/CACHE.md`).

pub mod append;
pub mod banded;
pub mod center_star;
pub mod gotoh;
pub mod myers;
pub mod pairwise;
pub mod protein;
pub mod sp_score;
pub mod sw;
pub mod trie;

use anyhow::Result;

use crate::engine::Cluster;
use crate::fasta::{Alphabet, Sequence};

/// Which pairwise kernel implementation the pipelines use.  Both
/// backends are bit-identical in output; `BitParallel` is faster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelBackend {
    /// Original scalar full-matrix DP (f32 SW, i32 full NW).
    Scalar,
    /// Integer bit-parallel / banded kernels ([`myers`], [`banded`]).
    #[default]
    BitParallel,
}

/// A finished multiple sequence alignment: one gap-padded row per input
/// sequence (same order), all of equal `width`.
#[derive(Debug, Clone)]
pub struct MsaResult {
    pub aligned: Vec<Sequence>,
    pub center_index: usize,
    pub width: usize,
}

impl MsaResult {
    /// The paper's avg-SP metric (penalty; lower is better).
    pub fn avg_sp(&self) -> Result<f64> {
        sp_score::avg_sp(&self.aligned)
    }

    /// Distributed avg-SP: per-partition column counts reduced over the
    /// cluster, then folded column-by-column on the driver.  Exact (same
    /// value as [`sp_score::avg_sp`]) but scales over rows.
    pub fn avg_sp_distributed(&self, cluster: &Cluster) -> Result<f64> {
        let n = self.aligned.len();
        if n < 2 {
            return Ok(0.0);
        }
        let alphabet = self.aligned[0].alphabet;
        let width = self.width;
        let alpha = alphabet.size();
        let rows: Vec<Vec<u8>> = self.aligned.iter().map(|s| s.codes.clone()).collect();
        let rdd = cluster.parallelize(rows, cluster.config().default_partitions);
        // counts layout: width * (alpha + 1); the final slot per column is
        // the gap count.
        let gap = alphabet.gap();
        let partials = rdd.map_partitions_with_index(move |_, rows| {
            let mut counts = vec![0u64; width * (alpha + 1)];
            for row in &rows {
                for (col, &c) in row.iter().enumerate() {
                    let slot = if c == gap { alpha } else { c as usize };
                    counts[col * (alpha + 1) + slot] += 1;
                }
            }
            vec![counts]
        });
        let totals = partials
            .reduce(|mut a, b| {
                for (x, y) in a.iter_mut().zip(&b) {
                    *x += y;
                }
                a
            })?
            .unwrap_or_default();
        let mut total = 0u64;
        for col in 0..width {
            let base = col * (alpha + 1);
            let gaps = totals[base + alpha];
            total += sp_score::column_sp(&totals[base..base + alpha], gaps);
        }
        Ok(total as f64 / (n as f64 * (n as f64 - 1.0) / 2.0))
    }

    /// Check structural invariants against the inputs.
    pub fn validate(&self, inputs: &[Sequence]) -> Result<()> {
        anyhow::ensure!(self.aligned.len() == inputs.len(), "row count mismatch");
        for (row, orig) in self.aligned.iter().zip(inputs) {
            anyhow::ensure!(row.len() == self.width, "ragged row {}", row.id);
            let degapped: Vec<u8> = row
                .codes
                .iter()
                .copied()
                .filter(|&c| c != row.alphabet.gap())
                .collect();
            anyhow::ensure!(
                degapped == orig.codes,
                "row {} does not round-trip to its input",
                row.id
            );
        }
        Ok(())
    }
}

/// Convenience dispatcher: nucleotide sequences take the trie path,
/// proteins the Smith-Waterman path (with optional XLA service).
pub fn align_auto(
    cluster: &Cluster,
    seqs: &[Sequence],
    svc: Option<&crate::runtime::XlaService>,
) -> Result<MsaResult> {
    anyhow::ensure!(!seqs.is_empty(), "no sequences");
    match seqs[0].alphabet {
        Alphabet::Dna => {
            center_star::align_nucleotide(cluster, seqs, &center_star::CenterStarConfig::default())
        }
        Alphabet::Protein => {
            protein::align_protein(cluster, seqs, svc, &protein::ProteinConfig::default())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster, ClusterConfig};

    #[test]
    fn distributed_sp_equals_local() {
        let spec = DatasetSpec { count: 16, ..DatasetSpec::mito(0.01, 2) };
        let seqs = spec.generate();
        let c = Cluster::new(ClusterConfig::spark(3));
        let msa = center_star::align_nucleotide(
            &c,
            &seqs,
            &center_star::CenterStarConfig::default(),
        )
        .unwrap();
        let local = msa.avg_sp().unwrap();
        let dist = msa.avg_sp_distributed(&c).unwrap();
        assert!((local - dist).abs() < 1e-9, "{local} vs {dist}");
    }

    #[test]
    fn align_auto_dispatches_dna() {
        let spec = DatasetSpec { count: 6, ..DatasetSpec::mito(0.005, 4) };
        let seqs = spec.generate();
        let c = Cluster::new(ClusterConfig::spark(2));
        let msa = align_auto(&c, &seqs, None).unwrap();
        msa.validate(&seqs).unwrap();
    }
}

//! Affine-gap local alignment (Gotoh 1982) — the general "gap-scoring
//! scheme W_k" of the paper's Smith-Waterman section: W_k = open + k*ext.
//! The linear-gap kernel is the open==0 special case (W_k = ext*k);
//! this module provides the full scheme natively and is exercised by the
//! property tests against the linear DP.

use super::sw::{LocalAlignment, Op, SwParams};

#[derive(Debug, Clone)]
pub struct AffineParams {
    pub subst: Vec<f32>,
    pub alpha: usize,
    /// Penalty for opening a gap (positive).
    pub open: f32,
    /// Penalty per extended position (positive).
    pub ext: f32,
}

impl AffineParams {
    #[inline]
    fn score(&self, a: i32, b: i32) -> f32 {
        self.subst[a as usize * self.alpha + b as usize]
    }

    /// Equivalent linear-gap params: W_k = open + k*ext degenerates to
    /// the linear scheme gap*k exactly when open == 0.
    pub fn as_linear(&self) -> Option<SwParams> {
        (self.open == 0.0).then(|| SwParams {
            subst: self.subst.clone(),
            alpha: self.alpha,
            gap: self.ext,
        })
    }
}

/// Gotoh local alignment with three DP layers:
///   H(i,j) — best score ending in a match/mismatch,
///   E(i,j) — best score ending in a gap in `a` (consuming b_j),
///   F(i,j) — best score ending in a gap in `b` (consuming a_i).
pub fn gotoh_align(a: &[i32], b: &[i32], p: &AffineParams) -> LocalAlignment {
    let (m, n) = (a.len(), b.len());
    let w = n + 1;
    let neg = f32::NEG_INFINITY;
    let mut h = vec![0f32; (m + 1) * w];
    let mut e = vec![neg; (m + 1) * w];
    let mut f = vec![neg; (m + 1) * w];
    let (mut bi, mut bj, mut best) = (0usize, 0usize, 0f32);
    for i in 1..=m {
        for j in 1..=n {
            e[i * w + j] = (e[i * w + j - 1] - p.ext).max(h[i * w + j - 1] - p.open - p.ext);
            f[i * w + j] = (f[(i - 1) * w + j] - p.ext).max(h[(i - 1) * w + j] - p.open - p.ext);
            let diag = h[(i - 1) * w + j - 1] + p.score(a[i - 1], b[j - 1]);
            let v = diag.max(e[i * w + j]).max(f[i * w + j]).max(0.0);
            h[i * w + j] = v;
            if v >= best {
                best = v;
                bi = i;
                bj = j;
            }
        }
    }
    // Traceback across the three layers.
    let mut ops = Vec::new();
    let (mut i, mut j) = (bi, bj);
    #[derive(Clone, Copy, PartialEq)]
    enum Layer {
        H,
        E,
        F,
    }
    let mut layer = Layer::H;
    // Exact predecessor selection: each layer value is literally one of
    // its fill-loop max() arguments, recomputed here with the identical
    // expression, so `v == candidate` is bit-deterministic — no epsilon
    // (the old `|v - cand| <= 1e-3` misparented sub-epsilon neighbors).
    while i > 0 && j > 0 {
        match layer {
            Layer::H => {
                let v = h[i * w + j];
                if v <= 0.0 {
                    break;
                }
                let diag = h[(i - 1) * w + j - 1] + p.score(a[i - 1], b[j - 1]);
                if v == diag {
                    ops.push(Op::Diag);
                    i -= 1;
                    j -= 1;
                } else if v == e[i * w + j] {
                    layer = Layer::E;
                } else {
                    debug_assert_eq!(v, f[i * w + j]);
                    layer = Layer::F;
                }
            }
            Layer::E => {
                // Gap in `a`: consume b_j.
                let v = e[i * w + j];
                ops.push(Op::Left);
                let from_open = h[i * w + j - 1] - p.open - p.ext;
                j -= 1;
                if v == from_open {
                    layer = Layer::H;
                }
            }
            Layer::F => {
                let v = f[i * w + j];
                ops.push(Op::Up);
                let from_open = h[(i - 1) * w + j] - p.open - p.ext;
                i -= 1;
                if v == from_open {
                    layer = Layer::H;
                }
            }
        }
    }
    ops.reverse();
    LocalAlignment { score: best, a_start: i, a_end: bi, b_start: j, b_end: bj, ops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::sw::sw_align;
    use crate::fasta::{alphabet::substitution_matrix, Alphabet};
    use crate::util::Rng;

    fn params(open: f32, ext: f32) -> AffineParams {
        AffineParams {
            subst: substitution_matrix(Alphabet::Dna),
            alpha: Alphabet::Dna.size(),
            open,
            ext,
        }
    }

    fn codes(s: &str) -> Vec<i32> {
        s.bytes().map(|b| Alphabet::Dna.encode(b) as i32).collect()
    }

    #[test]
    fn identical_sequences_full_match() {
        let a = codes("ACGTACGT");
        let al = gotoh_align(&a, &a, &params(6.0, 1.0));
        assert_eq!(al.score, 40.0);
        assert!(al.ops.iter().all(|&o| o == Op::Diag));
    }

    #[test]
    fn long_gap_cheaper_than_two_short_under_affine() {
        // One 2-gap: open+2*ext = 8; two 1-gaps: 2*(open+ext) = 14.
        let p = params(6.0, 1.0);
        let a = codes("ACGTACGTCCGGAA");
        let b = codes("ACGTACGTAA"); // CCGG deleted as one block
        let al = gotoh_align(&a, &b, &p);
        // Expect a single contiguous Up run of length 4.
        let mut runs = Vec::new();
        let mut cur = 0;
        for op in &al.ops {
            if *op == Op::Up {
                cur += 1;
            } else if cur > 0 {
                runs.push(cur);
                cur = 0;
            }
        }
        if cur > 0 {
            runs.push(cur);
        }
        assert_eq!(runs, vec![4], "ops: {:?}", al.ops);
        assert_eq!(al.score, 10.0 * 5.0 - (6.0 + 4.0 * 1.0)); // 10 matches, one 4-gap
    }

    #[test]
    fn reduces_to_linear_sw_when_open_equals_ext() {
        let mut rng = Rng::seed_from_u64(77);
        for _ in 0..40 {
            let a: Vec<i32> = (0..5 + rng.below(25)).map(|_| rng.below(4) as i32).collect();
            let b: Vec<i32> = (0..5 + rng.below(25)).map(|_| rng.below(4) as i32).collect();
            let affine = params(0.0, 4.0); // W_k = 4k  <=>  linear gap 4
            assert!(affine.as_linear().is_some());
            let g = gotoh_align(&a, &b, &affine);
            let s = sw_align(
                &a,
                &b,
                &crate::align::sw::SwParams {
                    subst: affine.subst.clone(),
                    alpha: affine.alpha,
                    gap: 4.0,
                },
            );
            assert_eq!(g.score, s.score, "affine W_k=4k must equal linear gap 4");
        }
    }

    #[test]
    fn traceback_path_rescores_to_best() {
        let p = params(5.0, 2.0);
        let mut rng = Rng::seed_from_u64(88);
        for case in 0..40 {
            let a: Vec<i32> = (0..3 + rng.below(30)).map(|_| rng.below(4) as i32).collect();
            let b: Vec<i32> = (0..3 + rng.below(30)).map(|_| rng.below(4) as i32).collect();
            let al = gotoh_align(&a, &b, &p);
            // Re-score the path with affine accounting.
            let (mut i, mut j) = (al.a_start, al.b_start);
            let mut score = 0f32;
            let mut prev: Option<Op> = None;
            for &op in &al.ops {
                match op {
                    Op::Diag => {
                        score += p.score(a[i], b[j]);
                        i += 1;
                        j += 1;
                    }
                    Op::Up => {
                        score -= if prev == Some(Op::Up) { p.ext } else { p.open + p.ext };
                        i += 1;
                    }
                    Op::Left => {
                        score -= if prev == Some(Op::Left) { p.ext } else { p.open + p.ext };
                        j += 1;
                    }
                }
                prev = Some(op);
            }
            // Exact: the matrix and penalties are integer-valued, every
            // intermediate is f32-exact, and the exact-equality
            // traceback follows true predecessors only.
            assert_eq!(
                score, al.score,
                "case {case}: path rescore {score} vs {}",
                al.score
            );
        }
    }
}

//! Protein center-star MSA via Smith-Waterman (paper §Smith-Waterman
//! algorithm for protein sequences with Spark).
//!
//! Same two-round pipeline as the nucleotide path, but the pairwise step
//! is local SW against the broadcast center (proteins are too divergent
//! for exact segment anchoring).  The SW scoring matrices come from the
//! AOT XLA artifacts (batched wavefront kernel) when an [`XlaService`] is
//! supplied and a shape bucket covers the pair; otherwise the native Rust
//! DP computes the identical matrix (the runtime tests assert exact
//! agreement).  Traceback and the local→global path extension always run
//! in Rust.

use anyhow::{ensure, Context as _, Result};

use super::banded::{sw_align_i32, IntSwParams};
use super::pairwise::{
    center_space_profile, decode_ops, encode_ops, merge_profiles, render_query_row, PathOp,
};
use super::sw::{sw_align, sw_matrix, traceback, LocalAlignment, Op, SwParams};
use super::{KernelBackend, MsaResult};
use crate::engine::Cluster;
use crate::fasta::{alphabet::substitution_matrix, Alphabet, Sequence};
use crate::runtime::{batcher::SwBatcher, XlaService};

#[derive(Debug, Clone)]
pub struct ProteinConfig {
    /// Linear gap penalty (positive, subtracted).
    pub gap: f32,
    /// Partitions for the sequence RDD (0 = residue-aware adaptive).
    pub partitions: usize,
    /// Center strategy: pick the longest sequence (HAlign-II keeps the
    /// longest center so every other sequence aligns within it).
    pub center_longest: bool,
    /// When `partitions == 0`, repartition so each task holds roughly
    /// this many residues (same knob as the nucleotide path): long
    /// proteins become finer stealable tasks instead of coarse
    /// per-sequence partitions pinning a stage to one node.
    pub target_residues_per_task: usize,
    /// Pairwise kernel backend for the native SW arm.  `BitParallel`
    /// runs the integer SW kernel (bit-identical to the f32 loop for
    /// the built-in integer-valued matrices).
    pub kernel: KernelBackend,
}

impl Default for ProteinConfig {
    fn default() -> Self {
        Self {
            gap: 5.0,
            partitions: 0,
            center_longest: true,
            target_residues_per_task: 32 * 1024,
            kernel: KernelBackend::default(),
        }
    }
}

/// Extend a local SW alignment to a global edit path over the full pair:
/// unaligned flanks are emitted as unmatched runs (query flank = Up,
/// center flank = Left) — no claimed homology outside the local core.
pub fn local_to_global(
    al: &LocalAlignment,
    query_len: usize,
    center_len: usize,
) -> Vec<PathOp> {
    let mut ops = Vec::with_capacity(query_len + center_len);
    ops.extend(std::iter::repeat(Op::Up).take(al.a_start));
    ops.extend(std::iter::repeat(Op::Left).take(al.b_start));
    ops.extend(al.ops.iter().copied());
    ops.extend(std::iter::repeat(Op::Up).take(query_len - al.a_end));
    ops.extend(std::iter::repeat(Op::Left).take(center_len - al.b_end));
    ops
}

/// Pairwise-align one partition of queries against the center, via XLA
/// batches where a bucket covers them, native SW otherwise.
fn align_partition(
    queries: &[(u64, Sequence)],
    center: &[u8],
    params: &SwParams,
    svc: Option<&XlaService>,
    kernel: KernelBackend,
) -> Result<Vec<(u64, Sequence, Vec<u8>)>> {
    let center_i32: Vec<i32> = center.iter().map(|&c| c as i32).collect();
    let mut out = Vec::with_capacity(queries.len());
    // Integer SW kernel for the native arm (bit-identical to the f32
    // loop); falls back to f32 if the matrix is not integer-valued.
    let int_params = match kernel {
        KernelBackend::BitParallel => IntSwParams::from_f32(params),
        KernelBackend::Scalar => None,
    };

    // Split into XLA-coverable and fallback sets to keep batches dense.
    let mut xla_idx: Vec<usize> = Vec::new();
    let mut native_idx: Vec<usize> = Vec::new();
    let batcher = match svc {
        Some(svc) => {
            let b = SwBatcher::new(
                svc,
                center_i32.clone(),
                params.subst.clone(),
                params.alpha,
                params.gap,
            )?;
            for (k, (_, s)) in queries.iter().enumerate() {
                if b.covers(s.len()) {
                    xla_idx.push(k);
                } else {
                    native_idx.push(k);
                }
            }
            Some(b)
        }
        None => {
            native_idx.extend(0..queries.len());
            None
        }
    };

    if let Some(b) = &batcher {
        let q_codes: Vec<Vec<i32>> = xla_idx
            .iter()
            .map(|&k| queries[k].1.codes.iter().map(|&c| c as i32).collect())
            .collect();
        let hs = b.score(&q_codes).context("XLA SW batch")?;
        for ((&k, q), h) in xla_idx.iter().zip(&q_codes).zip(hs) {
            let (idx, seq) = &queries[k];
            let local = traceback(&h, q, &center_i32, params);
            let ops = local_to_global(&local, q.len(), center_i32.len());
            out.push((*idx, seq.clone(), encode_ops(&ops)));
        }
    }
    for &k in &native_idx {
        let (idx, seq) = &queries[k];
        let q: Vec<i32> = seq.codes.iter().map(|&c| c as i32).collect();
        let local = match &int_params {
            Some(ip) => sw_align_i32(&q, &center_i32, ip),
            None => sw_align(&q, &center_i32, params),
        };
        let ops = local_to_global(&local, q.len(), center_i32.len());
        out.push((*idx, seq.clone(), encode_ops(&ops)));
    }
    Ok(out)
}

/// Distributed protein center-star MSA.
pub fn align_protein(
    cluster: &Cluster,
    seqs: &[Sequence],
    svc: Option<&XlaService>,
    cfg: &ProteinConfig,
) -> Result<MsaResult> {
    ensure!(!seqs.is_empty(), "no sequences to align");
    let alphabet = seqs[0].alphabet;
    ensure!(alphabet == Alphabet::Protein, "protein pipeline needs protein sequences");
    ensure!(
        seqs.iter().all(|s| s.alphabet == alphabet && !s.is_empty()),
        "sequences must share an alphabet and be non-empty"
    );
    if seqs.len() == 1 {
        return Ok(MsaResult { aligned: seqs.to_vec(), center_index: 0, width: seqs[0].len() });
    }

    let center_index = if cfg.center_longest {
        (0..seqs.len()).max_by_key(|&i| seqs[i].len()).unwrap()
    } else {
        0
    };
    let center_codes = seqs[center_index].codes.clone();
    let center_len = center_codes.len();
    let params = SwParams {
        subst: substitution_matrix(alphabet),
        alpha: alphabet.size(),
        gap: cfg.gap,
    };
    // Residue-aware repartitioning via the slice-aware split, exactly
    // like the nucleotide path.
    let (base_parts, split_factor) = if cfg.partitions == 0 {
        super::center_star::repartition_plan(
            seqs,
            cluster.config().default_partitions,
            cfg.target_residues_per_task,
        )
    } else {
        (cfg.partitions, 1)
    };

    // Round 1 map: SW vs broadcast center (XLA-batched per partition).
    let center_bc = cluster.broadcast(center_codes.clone())?;
    let indexed: Vec<(u64, Sequence)> =
        seqs.iter().enumerate().map(|(i, s)| (i as u64, s.clone())).collect();
    let rdd = cluster.parallelize(indexed, base_parts).split_partitions(split_factor);
    let center_for_map = center_bc.arc();
    let params_map = params.clone();
    let svc_map = svc.cloned();
    let kernel = cfg.kernel;
    // Fallible map: an accelerator batch error becomes a task `Err` the
    // executor retries through lineage (and ultimately surfaces to the
    // caller) instead of panicking the worker thread.
    let paths = rdd.try_map_partitions_with_index(move |_, items| {
        align_partition(&items, &center_for_map, &params_map, svc_map.as_ref(), kernel)
    });
    let paths = paths.checkpoint().context("persisting pairwise paths")?;

    // Round 1 reduce: merged space profile.
    let global = paths
        .map(move |(_, _, ops)| center_space_profile(&decode_ops(&ops), center_len))
        .reduce(|a, b| merge_profiles(a, &b))?
        .context("empty profile reduction")?;

    // Round 2 map: render rows.
    let global_bc = cluster.broadcast(global.clone())?;
    let global_for_map = global_bc.arc();
    let rows = paths.map(move |(idx, seq, ops)| {
        let ops = decode_ops(&ops);
        let own = center_space_profile(&ops, center_len);
        let row = render_query_row(&seq.codes, &ops, &global_for_map, &own, seq.alphabet);
        (idx, seq.id, row)
    });
    let mut collected = rows.collect()?;
    collected.sort_by_key(|(idx, _, _)| *idx);

    let width = center_len + global.iter().sum::<u32>() as usize;
    let mut aligned = Vec::with_capacity(seqs.len());
    for (idx, id, row) in collected {
        ensure!(row.len() == width, "row {idx} width {} != {width}", row.len());
        aligned.push(Sequence::new(id, row, alphabet));
    }
    Ok(MsaResult { aligned, center_index, width })
}

/// Native single-pair scoring entry (used by the SparkSW baseline and by
/// benches comparing native vs XLA cells/second).
pub fn native_pair_ops(query: &Sequence, center: &[u8], params: &SwParams) -> Vec<PathOp> {
    let q: Vec<i32> = query.codes.iter().map(|&c| c as i32).collect();
    let c: Vec<i32> = center.iter().map(|&x| x as i32).collect();
    let h = sw_matrix(&q, &c, params);
    let local = traceback(&h, &q, &c, params);
    local_to_global(&local, q.len(), c.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster, ClusterConfig};

    fn degapped(s: &Sequence) -> Vec<u8> {
        s.codes.iter().copied().filter(|&c| c != s.alphabet.gap()).collect()
    }

    fn check(seqs: &[Sequence], msa: &MsaResult) {
        assert_eq!(msa.aligned.len(), seqs.len());
        for (orig, row) in seqs.iter().zip(&msa.aligned) {
            assert_eq!(row.len(), msa.width);
            assert_eq!(degapped(row), orig.codes, "{} round-trip", orig.id);
        }
    }

    fn prot(id: &str, text: &str) -> Sequence {
        Sequence::from_text(id, text, Alphabet::Protein)
    }

    #[test]
    fn local_to_global_consumes_everything() {
        let al = LocalAlignment {
            score: 10.0,
            a_start: 2,
            a_end: 5,
            b_start: 1,
            b_end: 4,
            ops: vec![Op::Diag, Op::Diag, Op::Diag],
        };
        let ops = local_to_global(&al, 7, 6);
        let q: usize = ops.iter().filter(|o| !matches!(o, Op::Left)).count();
        let c: usize = ops.iter().filter(|o| !matches!(o, Op::Up)).count();
        assert_eq!((q, c), (7, 6));
    }

    #[test]
    fn identical_proteins_align_cleanly() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let seqs = vec![prot("a", "MKVLATRSQW"); 4];
        let msa = align_protein(&c, &seqs, None, &ProteinConfig::default()).unwrap();
        check(&seqs, &msa);
        assert_eq!(msa.width, 10);
        assert_eq!(msa.avg_sp().unwrap(), 0.0);
    }

    #[test]
    fn related_proteins_produce_valid_msa() {
        let seqs = DatasetSpec::protein(24, 0.15, 7).generate();
        let c = Cluster::new(ClusterConfig::spark(3));
        let msa = align_protein(&c, &seqs, None, &ProteinConfig::default()).unwrap();
        check(&seqs, &msa);
        assert!(msa.width >= seqs.iter().map(Sequence::len).max().unwrap());
    }

    #[test]
    fn center_is_longest_sequence() {
        let seqs = vec![prot("s", "MKV"), prot("l", "MKVLATRSQWERTY"), prot("m", "MKVLAT")];
        let c = Cluster::new(ClusterConfig::spark(2));
        let msa = align_protein(&c, &seqs, None, &ProteinConfig::default()).unwrap();
        assert_eq!(msa.center_index, 1);
        check(&seqs, &msa);
    }

    #[test]
    fn kernel_backends_are_bit_identical() {
        let seqs = DatasetSpec::protein(16, 0.15, 19).generate();
        let c = Cluster::new(ClusterConfig::spark(2));
        let scalar = align_protein(
            &c,
            &seqs,
            None,
            &ProteinConfig { kernel: KernelBackend::Scalar, ..Default::default() },
        )
        .unwrap();
        let bitp = align_protein(
            &c,
            &seqs,
            None,
            &ProteinConfig { kernel: KernelBackend::BitParallel, ..Default::default() },
        )
        .unwrap();
        assert_eq!(scalar.width, bitp.width);
        for (a, b) in scalar.aligned.iter().zip(&bitp.aligned) {
            assert_eq!(a.codes, b.codes, "kernel backends must agree exactly");
        }
    }

    #[test]
    fn both_backends_agree() {
        let seqs = DatasetSpec::protein(12, 0.1, 9).generate();
        let a = align_protein(
            &Cluster::new(ClusterConfig::spark(2)),
            &seqs,
            None,
            &ProteinConfig::default(),
        )
        .unwrap();
        let b = align_protein(
            &Cluster::new(ClusterConfig::hadoop(2)),
            &seqs,
            None,
            &ProteinConfig::default(),
        )
        .unwrap();
        assert_eq!(a.width, b.width);
        for (x, y) in a.aligned.iter().zip(&b.aligned) {
            assert_eq!(x.codes, y.codes);
        }
    }
}

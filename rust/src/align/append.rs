//! Incremental center-star: persistable MSA artifacts and the
//! profile-append path that serves "same reference set + a few new
//! sequences" traffic in O(new work) instead of an O(n) recompute.
//!
//! A finished nucleotide MSA is summarized by an [`MsaArtifact`]: the
//! center choice, the merged column space-profile, and — per input row —
//! the encoded edit path against the center.  That is exactly the state
//! the two-round pipeline in [`super::center_star`] computes and then
//! throws away; retaining it makes two operations cheap:
//!
//! * [`MsaArtifact::render`] — re-materialize the full alignment locally
//!   (pure function of the artifact; no engine involved), which is what
//!   a content-hash cache hit returns.
//! * [`append_nucleotide`] — align only the `k` new sequences against
//!   the stored center, widen the global profile by an element-wise max
//!   merge, and re-render.  When no column widens the old rows are
//!   byte-identical, so a caller that still holds the parent's rendered
//!   rows can pass them in and only the `k` new rows are rendered.
//!
//! **Bit-identity certificate**: an appended result equals a from-scratch
//! run on the union set bit for bit, because (a) the default center
//! choice is index 0 and the parent's first sequence stays first in the
//! union, (b) each pairwise path depends only on (query, center,
//! segment_len, kernel) — all pinned by the artifact — (c) the profile
//! merge is an element-wise max, independent of order and grouping, and
//! (d) row rendering is a pure function of (row, path, global profile).
//! `tests/append_prop.rs` pins this across worker counts, scheduler
//! modes, kernel backends and mid-job kills.  The certificate requires
//! the parent to have used the default center selection
//! (`center_sample <= 1`); artifacts built with sampled centers render
//! and append fine but only promise *valid* output, not union
//! bit-identity.
//!
//! The on-disk form ([`MsaArtifact::to_bytes`]) is versioned
//! (magic + format version + FNV checksum) and `from_bytes` rejects
//! corrupt or foreign bytes — see `rust/CACHE.md`.

use anyhow::{bail, ensure, Context as _, Result};
use std::hash::Hasher as _;

use super::center_star::repartition_plan;
use super::pairwise::{
    anchored_align_with, center_space_profile, decode_ops, encode_ops, merge_profiles,
    path_consumes, render_query_row, PathOp,
};
use super::trie::SegmentTrie;
use super::{KernelBackend, MsaResult};
use crate::engine::Cluster;
use crate::fasta::{Alphabet, Sequence};
use crate::util::hash::FnvHasher;
use crate::util::{Decode, Encode};

/// Artifact format magic — never reuse for an incompatible layout.
const MAGIC: [u8; 4] = *b"HA2A";
/// Bump on any change to the encoded layout below.
pub const ARTIFACT_VERSION: u16 = 1;

/// One input row of a finished MSA: the original (ungapped) sequence and
/// its encoded edit path against the center.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactRow {
    pub id: String,
    pub codes: Vec<u8>,
    /// Encoded [`PathOp`]s (see [`encode_ops`]).
    pub ops: Vec<u8>,
}

/// Persistable summary of a finished center-star MSA (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MsaArtifact {
    pub alphabet: Alphabet,
    /// Index of the center row inside `rows`.
    pub center_index: usize,
    /// Trie segment length the parent run used — appends must reuse it
    /// for the bit-identity certificate.
    pub segment_len: usize,
    /// Pairwise kernel backend the parent run used (ditto).
    pub kernel: KernelBackend,
    /// Merged column space-profile, length `center_len + 1`
    /// (element `c` = gap columns inserted before center position `c`).
    pub global: Vec<u32>,
    /// One entry per input sequence, in input order.
    pub rows: Vec<ArtifactRow>,
}

impl MsaArtifact {
    /// Length of the (ungapped) center sequence.
    pub fn center_len(&self) -> usize {
        self.global.len() - 1
    }

    /// Width of the rendered alignment.
    pub fn width(&self) -> usize {
        self.center_len() + self.global.iter().sum::<u32>() as usize
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// The center's raw codes.
    pub fn center_codes(&self) -> &[u8] {
        &self.rows[self.center_index].codes
    }

    /// Reconstruct the original input sequences (input order) — what the
    /// server hashes to key the union of a parent job and an append.
    pub fn input_sequences(&self) -> Vec<Sequence> {
        self.rows
            .iter()
            .map(|r| Sequence::new(r.id.clone(), r.codes.clone(), self.alphabet))
            .collect()
    }

    /// Artifact of a single-sequence "alignment": the degenerate path is
    /// all-[`PathOp::Diag`], which is exactly what the pipeline's
    /// center-vs-center alignment produces, so appends onto it match a
    /// from-scratch union run.
    pub fn single(seq: &Sequence, cfg: &super::center_star::CenterStarConfig) -> Self {
        MsaArtifact {
            alphabet: seq.alphabet,
            center_index: 0,
            segment_len: cfg.segment_len,
            kernel: cfg.kernel,
            global: vec![0u32; seq.len() + 1],
            rows: vec![ArtifactRow {
                id: seq.id.clone(),
                codes: seq.codes.clone(),
                ops: encode_ops(&vec![PathOp::Diag; seq.len()]),
            }],
        }
    }

    fn render_row(&self, row: &ArtifactRow) -> Sequence {
        let ops = decode_ops(&row.ops);
        let own = center_space_profile(&ops, self.center_len());
        let rendered = render_query_row(&row.codes, &ops, &self.global, &own, self.alphabet);
        Sequence::new(row.id.clone(), rendered, self.alphabet)
    }

    /// Materialize the full alignment from the artifact.  Pure and local:
    /// no engine, no I/O — the cache-hit path.  Bit-identical to the
    /// `MsaResult` of the run that produced the artifact (rendering is a
    /// deterministic function of path + profile).
    pub fn render(&self) -> Result<MsaResult> {
        let width = self.width();
        let mut aligned = Vec::with_capacity(self.rows.len());
        for (i, row) in self.rows.iter().enumerate() {
            let seq = self.render_row(row);
            ensure!(
                seq.len() == width,
                "artifact row {i} renders to {} columns, expected {width}",
                seq.len()
            );
            aligned.push(seq);
        }
        Ok(MsaResult { aligned, center_index: self.center_index, width })
    }

    /// Versioned binary encoding: `MAGIC ++ version ++ payload ++
    /// fnv64(payload)`.  See `rust/CACHE.md` for the layout contract.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        (self.alphabet as u8).encode(&mut payload);
        (self.center_index as u64).encode(&mut payload);
        (self.segment_len as u64).encode(&mut payload);
        let kernel: u8 = match self.kernel {
            KernelBackend::Scalar => 0,
            KernelBackend::BitParallel => 1,
        };
        kernel.encode(&mut payload);
        self.global.encode(&mut payload);
        (self.rows.len() as u64).encode(&mut payload);
        for row in &self.rows {
            row.id.encode(&mut payload);
            row.codes.encode(&mut payload);
            row.ops.encode(&mut payload);
        }
        let mut h = FnvHasher::default();
        h.write(&payload);
        let mut out = Vec::with_capacity(payload.len() + 14);
        out.extend_from_slice(&MAGIC);
        ARTIFACT_VERSION.encode(&mut out);
        out.extend_from_slice(&payload);
        h.finish().encode(&mut out);
        out
    }

    /// Decode and *validate* an artifact: magic, format version, payload
    /// checksum, and the structural invariants rendering relies on.
    /// Corrupt or truncated bytes are rejected, never half-decoded.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        ensure!(bytes.len() >= 14, "artifact too short ({} bytes)", bytes.len());
        ensure!(bytes[..4] == MAGIC, "bad artifact magic");
        let mut hdr = &bytes[4..6];
        let version = u16::decode(&mut hdr)?;
        ensure!(
            version == ARTIFACT_VERSION,
            "artifact format v{version}, this build reads v{ARTIFACT_VERSION}"
        );
        let payload = &bytes[6..bytes.len() - 8];
        let mut tail = &bytes[bytes.len() - 8..];
        let want_sum = u64::decode(&mut tail)?;
        let mut h = FnvHasher::default();
        h.write(payload);
        ensure!(h.finish() == want_sum, "artifact checksum mismatch (corrupt bytes)");

        let mut input = payload;
        let alphabet = Alphabet::from_u8(u8::decode(&mut input)?)?;
        let center_index = u64::decode(&mut input)? as usize;
        let segment_len = u64::decode(&mut input)? as usize;
        let kernel = match u8::decode(&mut input)? {
            0 => KernelBackend::Scalar,
            1 => KernelBackend::BitParallel,
            other => bail!("bad kernel tag {other}"),
        };
        let global = Vec::<u32>::decode(&mut input)?;
        let num_rows = u64::decode(&mut input)? as usize;
        ensure!(num_rows > 0, "artifact with no rows");
        ensure!(center_index < num_rows, "center index {center_index} out of range");
        ensure!(!global.is_empty(), "empty space profile");
        let mut rows = Vec::with_capacity(num_rows.min(1 << 20));
        for i in 0..num_rows {
            let id = String::decode(&mut input).with_context(|| format!("row {i} id"))?;
            let codes = Vec::<u8>::decode(&mut input).with_context(|| format!("row {i} codes"))?;
            let ops = Vec::<u8>::decode(&mut input).with_context(|| format!("row {i} ops"))?;
            rows.push(ArtifactRow { id, codes, ops });
        }
        ensure!(input.is_empty(), "{} trailing bytes in artifact", input.len());
        let center_len = global.len() - 1;
        ensure!(
            rows[center_index].codes.len() == center_len,
            "center length {} disagrees with profile length {}",
            rows[center_index].codes.len(),
            global.len()
        );
        for (i, row) in rows.iter().enumerate() {
            let (q, c) = path_consumes(&decode_ops(&row.ops));
            ensure!(
                q == row.codes.len() && c == center_len,
                "row {i} path consumes ({q},{c}), expected ({},{center_len})",
                row.codes.len()
            );
        }
        Ok(MsaArtifact { alphabet, center_index, segment_len, kernel, global, rows })
    }
}

/// Result of an append: the union alignment, its artifact (cacheable
/// under the union's content hash), and what the fast path saved.
#[derive(Debug, Clone)]
pub struct AppendOutcome {
    pub msa: MsaResult,
    pub artifact: MsaArtifact,
    /// Did the new sequences force new gap columns?  If not, every
    /// parent row is byte-identical to its previous rendering.
    pub widened: bool,
    /// Rows actually rendered (== `k` on the no-widening fast path when
    /// the parent's rendered rows were supplied, `n + k` otherwise).
    pub rows_rendered: usize,
}

/// Append `new_seqs` onto a finished MSA: align each new sequence
/// against the stored center only (distributed over the engine — `k`
/// tasks, not `n + k`), merge its space profile into the global one, and
/// render.  O(k·L) alignment work for `k` appends.
///
/// `parent_msa` is an optional fast-path input: the parent artifact's
/// rendered rows (e.g. straight from [`MsaArtifact::render`]).  When the
/// merge widens no column those rows are reused byte-for-byte and only
/// the `k` new rows are rendered.  Correctness never depends on it —
/// rendering is pure, so the output is bit-identical either way (and
/// bit-identical to a from-scratch run on the union; see module docs).
pub fn append_nucleotide(
    cluster: &Cluster,
    parent: &MsaArtifact,
    new_seqs: &[Sequence],
    parent_msa: Option<&MsaResult>,
) -> Result<AppendOutcome> {
    ensure!(!new_seqs.is_empty(), "no sequences to append");
    ensure!(
        new_seqs.iter().all(|s| s.alphabet == parent.alphabet && !s.is_empty()),
        "appended sequences must be non-empty and share the parent's alphabet"
    );
    let center = parent.center_codes().to_vec();
    let center_len = parent.center_len();
    let segment_len = parent.segment_len;
    let kernel = parent.kernel;

    // Round-1-style map over the *new* sequences only.
    let (base_parts, split_factor) = repartition_plan(
        new_seqs,
        cluster.config().default_partitions,
        super::center_star::CenterStarConfig::default().target_residues_per_task,
    );
    let center_bc = cluster.broadcast(center)?;
    let center_for_map = center_bc.arc();
    let indexed: Vec<(u64, Sequence)> = new_seqs
        .iter()
        .enumerate()
        .map(|(i, s)| (i as u64, s.clone()))
        .collect();
    let rdd = cluster.parallelize(indexed, base_parts).split_partitions(split_factor);
    let paths = rdd.map_partitions_with_index(move |_, items| {
        if items.is_empty() {
            return Vec::new();
        }
        let trie = SegmentTrie::build(&center_for_map, segment_len);
        items
            .into_iter()
            .map(|(idx, seq)| {
                let ops = anchored_align_with(&seq.codes, &center_for_map, &trie, kernel);
                (idx, seq, encode_ops(&ops))
            })
            .collect()
    });
    let mut new_paths = paths.collect().context("aligning appended sequences")?;
    new_paths.sort_by_key(|(idx, _, _)| *idx);
    ensure!(new_paths.len() == new_seqs.len(), "append path count mismatch");

    // Merge the new space profiles into the stored global profile.  The
    // merge is an element-wise max: order- and grouping-independent, so
    // folding k profiles onto the parent's reduction equals the union's
    // single reduction exactly.
    let mut global = parent.global.clone();
    for (_, _, ops) in &new_paths {
        let own = center_space_profile(&decode_ops(ops), center_len);
        global = merge_profiles(global, &own);
    }
    let widened = global != parent.global;

    let mut rows = parent.rows.clone();
    rows.extend(new_paths.into_iter().map(|(_, seq, ops)| ArtifactRow {
        id: seq.id,
        codes: seq.codes,
        ops,
    }));
    let artifact = MsaArtifact {
        alphabet: parent.alphabet,
        center_index: parent.center_index,
        segment_len,
        kernel,
        global,
        rows,
    };

    let k = new_seqs.len();
    let reuse = match (widened, parent_msa) {
        // Only reuse rows that provably match: same row count and the
        // parent's rendering width equals the (unchanged) union width.
        (false, Some(pm)) if pm.aligned.len() == parent.rows.len() && pm.width == artifact.width() => {
            Some(pm)
        }
        _ => None,
    };
    let (msa, rows_rendered) = match reuse {
        Some(pm) => {
            let width = artifact.width();
            let mut aligned = pm.aligned.clone();
            for (i, row) in artifact.rows.iter().enumerate().skip(parent.rows.len()) {
                let seq = artifact.render_row(row);
                ensure!(
                    seq.len() == width,
                    "appended row {i} renders to {} columns, expected {width}",
                    seq.len()
                );
                aligned.push(seq);
            }
            (MsaResult { aligned, center_index: artifact.center_index, width }, k)
        }
        None => (artifact.render()?, artifact.num_rows()),
    };
    Ok(AppendOutcome { msa, artifact, widened, rows_rendered })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::center_star::{align_nucleotide_with_artifact, CenterStarConfig};
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster, ClusterConfig};

    fn mito(n: usize, seed: u64) -> Vec<Sequence> {
        DatasetSpec { count: n, ..DatasetSpec::mito(0.01, seed) }.generate()
    }

    #[test]
    fn artifact_roundtrips_through_bytes() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let seqs = mito(8, 41);
        let (_, art) =
            align_nucleotide_with_artifact(&c, &seqs, &CenterStarConfig::default()).unwrap();
        let bytes = art.to_bytes();
        let back = MsaArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(art, back);
    }

    #[test]
    fn corrupt_bytes_are_rejected() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let seqs = mito(4, 42);
        let (_, art) =
            align_nucleotide_with_artifact(&c, &seqs, &CenterStarConfig::default()).unwrap();
        let good = art.to_bytes();
        assert!(MsaArtifact::from_bytes(&good[..good.len() - 3]).is_err(), "truncation");
        assert!(MsaArtifact::from_bytes(b"HA2Anope").is_err(), "garbage");
        for pos in [0usize, 5, good.len() / 2, good.len() - 1] {
            let mut bad = good.clone();
            bad[pos] ^= 0x40;
            assert!(
                MsaArtifact::from_bytes(&bad).is_err(),
                "flipped byte at {pos} must be rejected"
            );
        }
    }

    #[test]
    fn render_matches_pipeline_output() {
        let c = Cluster::new(ClusterConfig::spark(3));
        let seqs = mito(10, 43);
        let (msa, art) =
            align_nucleotide_with_artifact(&c, &seqs, &CenterStarConfig::default()).unwrap();
        let rendered = art.render().unwrap();
        assert_eq!(rendered.width, msa.width);
        assert_eq!(rendered.center_index, msa.center_index);
        for (a, b) in rendered.aligned.iter().zip(&msa.aligned) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.codes, b.codes, "render must be bit-identical to the pipeline");
        }
    }

    #[test]
    fn append_equals_from_scratch_union() {
        let c = Cluster::new(ClusterConfig::spark(3));
        let all = mito(14, 44);
        let (base, new) = all.split_at(10);
        let cfg = CenterStarConfig::default();
        let (base_msa, art) = align_nucleotide_with_artifact(&c, base, &cfg).unwrap();
        let out = append_nucleotide(&c, &art, new, Some(&base_msa)).unwrap();
        let (scratch, scratch_art) = align_nucleotide_with_artifact(&c, &all, &cfg).unwrap();
        assert_eq!(out.msa.width, scratch.width);
        for (a, b) in out.msa.aligned.iter().zip(&scratch.aligned) {
            assert_eq!(a.codes, b.codes, "append must equal from-scratch union ({})", a.id);
        }
        assert_eq!(out.artifact, scratch_art, "artifacts must agree too");
    }

    #[test]
    fn no_widening_append_renders_only_new_rows() {
        let c = Cluster::new(ClusterConfig::spark(2));
        // Identical sequences: appends can never widen the profile.
        let seqs = vec![Sequence::from_text("a", "ACGTACGTACGTACGT", Alphabet::Dna); 6];
        let cfg = CenterStarConfig::default();
        let (msa, art) = align_nucleotide_with_artifact(&c, &seqs[..4], &cfg).unwrap();
        let out = append_nucleotide(&c, &art, &seqs[4..], Some(&msa)).unwrap();
        assert!(!out.widened);
        assert_eq!(out.rows_rendered, 2, "fast path renders only appended rows");
        assert_eq!(out.msa.aligned.len(), 6);
    }

    #[test]
    fn single_sequence_artifact_appends_like_scratch() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let all = mito(5, 45);
        let cfg = CenterStarConfig::default();
        let (_, art) = align_nucleotide_with_artifact(&c, &all[..1], &cfg).unwrap();
        let out = append_nucleotide(&c, &art, &all[1..], None).unwrap();
        let (scratch, _) = align_nucleotide_with_artifact(&c, &all, &cfg).unwrap();
        for (a, b) in out.msa.aligned.iter().zip(&scratch.aligned) {
            assert_eq!(a.codes, b.codes);
        }
    }
}

//! Byte-budgeted store for f64 blobs (distance-matrix tiles and NJ
//! merged-row working sets), with LRU spill-to-disk.
//!
//! Resident blobs live in a keyed map under a configurable byte budget;
//! inserting past the budget evicts least-recently-used blobs to disk
//! (one file per key, written with the engine's tmp+rename discipline so
//! a speculative duplicate re-writing a tile can never be observed
//! half-written).  Spill writes run *outside* the store mutex: victims
//! move to a "spilling" side map under the lock and are written after it
//! is released, so a slow disk never blocks concurrent `get`s of
//! resident tiles (readers serve in-flight victims from the side map).
//! `get` re-reads and re-admits spilled blobs.  All
//! values roundtrip bit-exactly (`f64::to_le_bytes`), which is what lets
//! the tiled NJ path promise bit-identical trees to the dense path.
//!
//! `put` *replaces* — the engine executes tile jobs at-least-once
//! (speculation, retries, lineage recovery), and a duplicate execution
//! re-putting its deterministic output must leave accounting unchanged.
//!
//! The peak-resident counter is the Fig-5-style headline: a tiled
//! pipeline's peak stays `<= budget + one blob` instead of O(n²).

use std::collections::hash_map::Entry;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure, Context as _, Result};

/// A resident blob plus the access tick the LRU eviction keys off.
struct ResidentBlob {
    data: Arc<Vec<f64>>,
    last_access: u64,
}

/// An evicted blob whose spill write has not completed yet.
struct SpillEntry {
    data: Arc<Vec<f64>>,
    version: u64,
}

/// A spill write handed out of the lock: the destination path, the
/// bytes to persist, and the key's write generation they correspond to.
/// The path is resolved at collection time (victims are only gathered
/// when a spill dir exists), so the writer needs no fallible re-lookup.
struct PendingSpill {
    key: u64,
    path: PathBuf,
    data: Arc<Vec<f64>>,
    version: u64,
}

struct StoreInner {
    resident: HashMap<u64, ResidentBlob>,
    /// Monotone access counter: `get`/`put` stamp blobs in O(1); only
    /// eviction (rare) scans for the minimum stamp.  Keeps the hot
    /// `dist(i, j)` path a hash lookup, not a queue rewrite.
    tick: u64,
    resident_bytes: usize,
    /// Keys whose *current* bytes are already on disk (skip re-spill).
    persisted: HashSet<u64>,
    /// Per-key write generation, bumped by `put`: lets a `get` that read
    /// the spill file outside the lock detect that a concurrent `put`
    /// superseded those bytes, instead of re-admitting stale data.
    versions: HashMap<u64, u64>,
    /// Evicted-but-not-yet-durable blobs.  Each entry is owned by the
    /// one thread running [`TileStore::write_spills`] for its key;
    /// readers serve from here so a slow disk write never blocks `get`,
    /// and a re-eviction of a re-put key refreshes the entry in place
    /// for that owner to pick up (never a second concurrent writer).
    spilling: HashMap<u64, SpillEntry>,
    /// Per-key `get` counter (resident hits included).  Lets tests pin
    /// access patterns — e.g. that sidecar-seeded NJ stats fault in
    /// zero tile blobs.
    get_counts: HashMap<u64, u64>,
}

impl StoreInner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Key of the least-recently-used resident blob.
    fn coldest(&self) -> Option<u64> {
        self.resident.iter().min_by_key(|(_, b)| b.last_access).map(|(&k, _)| k)
    }
}

#[cfg(test)]
type SpillHook = Box<dyn Fn(u64) + Send + Sync>;

/// Spillable keyed blob store (see module docs).
pub struct TileStore {
    inner: Mutex<StoreInner>,
    dir: Option<PathBuf>,
    budget: usize,
    peak: AtomicUsize,
    spill_files: AtomicUsize,
    spill_reads: AtomicUsize,
    /// Test-only: invoked (outside the store lock) before each spill
    /// write — lets tests stall a spill mid-flight and prove that
    /// readers of resident and spilling blobs are never blocked on it.
    #[cfg(test)]
    spill_hook: Mutex<Option<SpillHook>>,
}

fn blob_bytes(data: &[f64]) -> usize {
    data.len() * std::mem::size_of::<f64>()
}

impl TileStore {
    /// Unbounded in-memory store (never spills; the dense-equivalent
    /// working mode NJ uses when no spill directory is configured).
    pub fn in_memory() -> Self {
        Self::with_limits(None, usize::MAX)
    }

    /// Budgeted store spilling to `dir` (created if missing); the
    /// directory is removed on drop.
    pub fn spilling(dir: PathBuf, byte_budget: usize) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating tile spill dir {}", dir.display()))?;
        Ok(Self::with_limits(Some(dir), byte_budget))
    }

    fn with_limits(dir: Option<PathBuf>, budget: usize) -> Self {
        Self {
            inner: Mutex::new(StoreInner {
                resident: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
                persisted: HashSet::new(),
                versions: HashMap::new(),
                spilling: HashMap::new(),
                get_counts: HashMap::new(),
            }),
            dir,
            budget,
            peak: AtomicUsize::new(0),
            spill_files: AtomicUsize::new(0),
            spill_reads: AtomicUsize::new(0),
            #[cfg(test)]
            spill_hook: Mutex::new(None),
        }
    }

    pub fn byte_budget(&self) -> usize {
        self.budget
    }

    /// Bytes of blobs currently resident in memory.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    /// High-water mark of resident bytes — bounded by
    /// `byte_budget + largest blob`, never O(total blobs).
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Spill files written (eviction count of not-yet-persisted blobs).
    pub fn spill_files_written(&self) -> usize {
        self.spill_files.load(Ordering::Relaxed)
    }

    /// Spilled blobs re-read from disk on `get`.
    pub fn spill_reads(&self) -> usize {
        self.spill_reads.load(Ordering::Relaxed)
    }

    /// Total `get` calls (resident hits included) for keys `< bound`.
    /// With tile blobs keyed `0..num_tiles` and sidecars above, passing
    /// `num_tiles` counts exactly the tile-blob accesses.
    pub fn gets_below(&self, bound: u64) -> u64 {
        let st = self.inner.lock().unwrap();
        st.get_counts.iter().filter(|(&k, _)| k < bound).map(|(_, &c)| c).sum()
    }

    fn blob_path(&self, key: u64) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("blob-{key}.f64")))
    }

    /// Drop least-recently-used blobs until the resident set fits the
    /// budget; always keeps the most recently touched blob resident so
    /// the caller's working tile survives its own insert.  Unpersisted
    /// victims move to the `spilling` side map and are returned for the
    /// caller to write *after releasing the lock* — the disk write must
    /// never run under the store mutex, or every concurrent `get` of a
    /// resident tile stalls behind it.
    fn collect_spill_victims(&self, st: &mut StoreInner) -> Vec<PendingSpill> {
        let mut victims = Vec::new();
        if self.dir.is_none() {
            return victims; // nowhere to spill: stay resident
        }
        while st.resident_bytes > self.budget && st.resident.len() > 1 {
            let Some(key) = st.coldest() else { break };
            let Some(blob) = st.resident.remove(&key) else { break };
            st.resident_bytes -= blob_bytes(&blob.data);
            if st.persisted.contains(&key) {
                continue; // current bytes already durable on disk
            }
            let version = st.versions.get(&key).copied().unwrap_or(0);
            let Some(path) = self.blob_path(key) else { break };
            match st.spilling.entry(key) {
                Entry::Occupied(mut e) => {
                    // A writer already owns this key (the blob was
                    // re-put and re-evicted mid-write): refresh what it
                    // must persist; it re-writes until the entry
                    // matches what hit the disk.
                    *e.get_mut() = SpillEntry { data: blob.data, version };
                }
                Entry::Vacant(slot) => {
                    slot.insert(SpillEntry { data: blob.data.clone(), version });
                    victims.push(PendingSpill { key, path, data: blob.data, version });
                }
            }
        }
        victims
    }

    /// Persist evicted blobs outside the store lock.  This call owns the
    /// `spilling` entry of every victim key; if a re-eviction refreshed
    /// an entry while its write was in flight, loop and write the newer
    /// bytes until entry and file agree.  On an I/O error the entry is
    /// left in place, so the blob stays readable from memory.
    fn write_spills(&self, victims: Vec<PendingSpill>) -> Result<()> {
        for mut job in victims {
            loop {
                #[cfg(test)]
                if let Some(hook) = self.spill_hook.lock().unwrap().as_ref() {
                    hook(job.key);
                }
                let mut bytes = Vec::with_capacity(blob_bytes(&job.data));
                for v in job.data.iter() {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                crate::engine::shuffle::write_atomic(&job.path, &bytes)
                    .with_context(|| format!("spilling {}", job.path.display()))?;
                self.spill_files.fetch_add(1, Ordering::Relaxed);
                let mut st = self.inner.lock().unwrap();
                match st.spilling.get(&job.key) {
                    Some(e) if e.version != job.version => {
                        // Refreshed mid-write: go around and persist the
                        // newer bytes too.
                        job.data = e.data.clone();
                        job.version = e.version;
                    }
                    _ => {
                        if st.versions.get(&job.key).copied().unwrap_or(0) == job.version {
                            st.persisted.insert(job.key);
                        }
                        st.spilling.remove(&job.key);
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    /// Must be called with the lock held; returns victims for the caller
    /// to pass to [`Self::write_spills`] after dropping the lock.
    fn admit(&self, st: &mut StoreInner, key: u64, data: Arc<Vec<f64>>) -> Vec<PendingSpill> {
        let tick = st.next_tick();
        let blob = ResidentBlob { data: data.clone(), last_access: tick };
        if let Some(old) = st.resident.insert(key, blob) {
            st.resident_bytes -= blob_bytes(&old.data);
        }
        st.resident_bytes += blob_bytes(&data);
        self.peak.fetch_max(st.resident_bytes, Ordering::Relaxed);
        self.collect_spill_victims(st)
    }

    /// Insert (or replace) the blob for `key`.  Replacement releases the
    /// old copy's accounting first, so at-least-once producers keep the
    /// resident/peak numbers stable run to run.
    pub fn put(&self, key: u64, data: Vec<f64>) -> Result<()> {
        let victims = {
            let mut st = self.inner.lock().unwrap();
            // The new bytes supersede any spilled copy of an earlier
            // execution; it will be re-spilled on the next eviction, and
            // any in-flight disk read or spill write of the old bytes
            // sees the version bump.
            st.persisted.remove(&key);
            *st.versions.entry(key).or_insert(0) += 1;
            self.admit(&mut st, key, Arc::new(data))
        };
        self.write_spills(victims)
    }

    /// Fetch the blob for `key`, re-reading (and re-admitting) a spilled
    /// copy from disk when it is not resident.  Resident hits are O(1):
    /// one hash lookup plus an access-tick stamp.  The disk read happens
    /// outside the lock; if a concurrent `put` supersedes the key while
    /// the read is in flight (version bump), the stale bytes are
    /// discarded and the lookup retries.
    pub fn get(&self, key: u64) -> Result<Arc<Vec<f64>>> {
        let mut counted = false;
        loop {
            let seen_version = {
                let mut st = self.inner.lock().unwrap();
                if !counted {
                    *st.get_counts.entry(key).or_insert(0) += 1;
                    counted = true;
                }
                let tick = st.next_tick();
                if let Some(blob) = st.resident.get_mut(&key) {
                    blob.last_access = tick;
                    return Ok(blob.data.clone());
                }
                if let Some(e) = st.spilling.get(&key) {
                    // Evicted with its spill write still in flight:
                    // serve from the side map — never wait on the disk.
                    return Ok(e.data.clone());
                }
                st.versions.get(&key).copied().unwrap_or(0)
            };
            let path = self
                .blob_path(key)
                .ok_or_else(|| anyhow!("blob {key} missing from in-memory tile store"))?;
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading spilled blob {}", path.display()))?;
            ensure!(bytes.len() % 8 == 0, "spilled blob {key} has ragged length {}", bytes.len());
            // lint: allow(panic) chunks_exact(8) yields exactly 8-byte slices
            let data: Vec<f64> = bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().expect("chunk is 8 bytes")))
                .collect();
            self.spill_reads.fetch_add(1, Ordering::Relaxed);
            let arc = Arc::new(data);
            let victims = {
                let mut st = self.inner.lock().unwrap();
                if let Some(raced) = st.resident.get(&key) {
                    return Ok(raced.data.clone()); // another reader re-admitted it first
                }
                if let Some(e) = st.spilling.get(&key) {
                    return Ok(e.data.clone()); // at least as new as the file
                }
                if st.versions.get(&key).copied().unwrap_or(0) != seen_version {
                    continue; // a put superseded the bytes we read: retry
                }
                let victims = self.admit(&mut st, key, arc.clone());
                // The just-read bytes are exactly what is on disk.
                st.persisted.insert(key);
                victims
            };
            self.write_spills(victims)?;
            return Ok(arc);
        }
    }
}

impl Drop for TileStore {
    fn drop(&mut self) {
        if let Some(dir) = &self.dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("halign2-tilestore-{}-{tag}", std::process::id()))
    }

    #[test]
    fn in_memory_roundtrip_and_peak() {
        let s = TileStore::in_memory();
        s.put(3, vec![1.5, -2.5]).unwrap();
        s.put(9, vec![0.25]).unwrap();
        assert_eq!(*s.get(3).unwrap(), vec![1.5, -2.5]);
        assert_eq!(*s.get(9).unwrap(), vec![0.25]);
        assert_eq!(s.resident_bytes(), 24);
        assert_eq!(s.peak_resident_bytes(), 24);
        assert_eq!(s.spill_files_written(), 0);
        assert!(s.get(4).is_err(), "unknown key must error");
    }

    #[test]
    fn replacement_keeps_accounting_stable() {
        let s = TileStore::in_memory();
        for _ in 0..5 {
            s.put(7, vec![1.0; 100]).unwrap(); // at-least-once producer
        }
        assert_eq!(s.resident_bytes(), 800, "replace, don't accumulate");
        assert_eq!(s.peak_resident_bytes(), 800);
    }

    #[test]
    fn eviction_spills_and_get_rereads_bit_exact() {
        let dir = tmpdir("spill");
        let s = TileStore::spilling(dir.clone(), 3 * 80).unwrap();
        let blob = |k: u64| -> Vec<f64> {
            (0..10).map(|i| (k as f64) * 1e17 + i as f64 + 0.123).collect()
        };
        for k in 0..8u64 {
            s.put(k, blob(k)).unwrap();
        }
        assert!(s.resident_bytes() <= 3 * 80, "budget enforced");
        assert!(s.spill_files_written() >= 5, "older blobs spilled");
        assert!(s.peak_resident_bytes() <= 3 * 80 + 80, "peak <= budget + one blob");
        for k in 0..8u64 {
            let got = s.get(k).unwrap();
            let want = blob(k);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "key {k}: spill must be bit-exact");
            }
        }
        assert!(s.spill_reads() >= 5, "spilled blobs were re-read");
        drop(s);
        assert!(!dir.exists(), "spill dir removed on drop");
    }

    #[test]
    fn clean_eviction_does_not_rewrite_persisted_blobs() {
        let dir = tmpdir("clean");
        let s = TileStore::spilling(dir, 100).unwrap();
        s.put(1, vec![1.0; 10]).unwrap();
        s.put(2, vec![2.0; 10]).unwrap(); // evicts 1 (spill #1)
        let w1 = s.spill_files_written();
        s.get(1).unwrap(); // re-admit 1, evicts 2 (spill #2)
        s.get(2).unwrap(); // re-admit 2, evicts 1 again — already persisted
        assert_eq!(
            s.spill_files_written(),
            w1 + 1,
            "a clean (persisted, unmodified) blob must not be re-written"
        );
    }

    #[test]
    fn get_of_resident_tile_is_not_blocked_by_slow_spill() {
        use std::sync::mpsc;
        let s = Arc::new(TileStore::spilling(tmpdir("slowspill"), 100).unwrap());
        s.put(1, vec![1.0; 10]).unwrap(); // 80 bytes resident
        let (entered_tx, entered_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let release_rx = Mutex::new(release_rx);
        let stalled_once = std::sync::atomic::AtomicBool::new(false);
        *s.spill_hook.lock().unwrap() = Some(Box::new(move |_key| {
            // Stall only the first spill write; later spills run freely.
            if !stalled_once.swap(true, Ordering::SeqCst) {
                entered_tx.send(()).unwrap();
                release_rx.lock().unwrap().recv().unwrap();
            }
        }));
        let s2 = s.clone();
        let spiller = std::thread::spawn(move || {
            s2.put(2, vec![2.0; 10]).unwrap(); // evicts key 1 -> stalled spill
        });
        // Wait until the spill write is provably in flight (and stalled).
        entered_rx.recv().unwrap();
        // Key 2 is resident: its fetch must not wait on key 1's write.
        assert_eq!(*s.get(2).unwrap(), vec![2.0; 10]);
        // The victim itself stays readable from the spilling side map.
        assert_eq!(*s.get(1).unwrap(), vec![1.0; 10]);
        release_tx.send(()).unwrap();
        spiller.join().unwrap();
        // After the write completes, the blob round-trips from disk.
        assert_eq!(*s.get(1).unwrap(), vec![1.0; 10]);
        assert!(s.spill_files_written() >= 1);
    }

    #[test]
    fn no_spill_dir_means_budget_is_advisory() {
        let s = TileStore::with_limits(None, 8);
        s.put(1, vec![0.0; 64]).unwrap();
        assert_eq!(*s.get(1).unwrap(), vec![0.0; 64], "stays resident with nowhere to spill");
    }
}

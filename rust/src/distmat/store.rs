//! Byte-budgeted store for f64 blobs (distance-matrix tiles and NJ
//! merged-row working sets), with LRU spill-to-disk.
//!
//! Resident blobs live in a keyed map under a configurable byte budget;
//! inserting past the budget evicts least-recently-used blobs to disk
//! (one file per key, written with the engine's tmp+rename discipline so
//! a speculative duplicate re-writing a tile can never be observed
//! half-written).  `get` re-reads and re-admits spilled blobs.  All
//! values roundtrip bit-exactly (`f64::to_le_bytes`), which is what lets
//! the tiled NJ path promise bit-identical trees to the dense path.
//!
//! `put` *replaces* — the engine executes tile jobs at-least-once
//! (speculation, retries, lineage recovery), and a duplicate execution
//! re-putting its deterministic output must leave accounting unchanged.
//!
//! The peak-resident counter is the Fig-5-style headline: a tiled
//! pipeline's peak stays `<= budget + one blob` instead of O(n²).

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, ensure, Context as _, Result};

/// A resident blob plus the access tick the LRU eviction keys off.
struct ResidentBlob {
    data: Arc<Vec<f64>>,
    last_access: u64,
}

struct StoreInner {
    resident: HashMap<u64, ResidentBlob>,
    /// Monotone access counter: `get`/`put` stamp blobs in O(1); only
    /// eviction (rare) scans for the minimum stamp.  Keeps the hot
    /// `dist(i, j)` path a hash lookup, not a queue rewrite.
    tick: u64,
    resident_bytes: usize,
    /// Keys whose *current* bytes are already on disk (skip re-spill).
    persisted: HashSet<u64>,
    /// Per-key write generation, bumped by `put`: lets a `get` that read
    /// the spill file outside the lock detect that a concurrent `put`
    /// superseded those bytes, instead of re-admitting stale data.
    versions: HashMap<u64, u64>,
}

impl StoreInner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Key of the least-recently-used resident blob.
    fn coldest(&self) -> Option<u64> {
        self.resident.iter().min_by_key(|(_, b)| b.last_access).map(|(&k, _)| k)
    }
}

/// Spillable keyed blob store (see module docs).
pub struct TileStore {
    inner: Mutex<StoreInner>,
    dir: Option<PathBuf>,
    budget: usize,
    peak: AtomicUsize,
    spill_files: AtomicUsize,
    spill_reads: AtomicUsize,
}

fn blob_bytes(data: &[f64]) -> usize {
    data.len() * std::mem::size_of::<f64>()
}

impl TileStore {
    /// Unbounded in-memory store (never spills; the dense-equivalent
    /// working mode NJ uses when no spill directory is configured).
    pub fn in_memory() -> Self {
        Self::with_limits(None, usize::MAX)
    }

    /// Budgeted store spilling to `dir` (created if missing); the
    /// directory is removed on drop.
    pub fn spilling(dir: PathBuf, byte_budget: usize) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating tile spill dir {}", dir.display()))?;
        Ok(Self::with_limits(Some(dir), byte_budget))
    }

    fn with_limits(dir: Option<PathBuf>, budget: usize) -> Self {
        Self {
            inner: Mutex::new(StoreInner {
                resident: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
                persisted: HashSet::new(),
                versions: HashMap::new(),
            }),
            dir,
            budget,
            peak: AtomicUsize::new(0),
            spill_files: AtomicUsize::new(0),
            spill_reads: AtomicUsize::new(0),
        }
    }

    pub fn byte_budget(&self) -> usize {
        self.budget
    }

    /// Bytes of blobs currently resident in memory.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    /// High-water mark of resident bytes — bounded by
    /// `byte_budget + largest blob`, never O(total blobs).
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Spill files written (eviction count of not-yet-persisted blobs).
    pub fn spill_files_written(&self) -> usize {
        self.spill_files.load(Ordering::Relaxed)
    }

    /// Spilled blobs re-read from disk on `get`.
    pub fn spill_reads(&self) -> usize {
        self.spill_reads.load(Ordering::Relaxed)
    }

    fn blob_path(&self, key: u64) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("blob-{key}.f64")))
    }

    /// Drop least-recently-used blobs (spilling unpersisted ones) until
    /// the resident set fits the budget; always keeps the most recently
    /// touched blob resident so the caller's working tile survives its
    /// own insert.
    fn evict_over_budget(&self, st: &mut StoreInner) -> Result<()> {
        if self.dir.is_none() {
            return Ok(()); // nowhere to spill: stay resident
        }
        while st.resident_bytes > self.budget && st.resident.len() > 1 {
            let key = st.coldest().expect("resident non-empty");
            let blob = st.resident.remove(&key).expect("coldest key is resident");
            st.resident_bytes -= blob_bytes(&blob.data);
            if !st.persisted.contains(&key) {
                let path = self.blob_path(key).expect("spill dir checked above");
                let mut bytes = Vec::with_capacity(blob_bytes(&blob.data));
                for v in blob.data.iter() {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                crate::engine::shuffle::write_atomic(&path, &bytes)
                    .with_context(|| format!("spilling {}", path.display()))?;
                self.spill_files.fetch_add(1, Ordering::Relaxed);
                st.persisted.insert(key);
            }
        }
        Ok(())
    }

    fn admit(&self, st: &mut StoreInner, key: u64, data: Arc<Vec<f64>>) -> Result<()> {
        let tick = st.next_tick();
        let blob = ResidentBlob { data: data.clone(), last_access: tick };
        if let Some(old) = st.resident.insert(key, blob) {
            st.resident_bytes -= blob_bytes(&old.data);
        }
        st.resident_bytes += blob_bytes(&data);
        self.peak.fetch_max(st.resident_bytes, Ordering::Relaxed);
        self.evict_over_budget(st)
    }

    /// Insert (or replace) the blob for `key`.  Replacement releases the
    /// old copy's accounting first, so at-least-once producers keep the
    /// resident/peak numbers stable run to run.
    pub fn put(&self, key: u64, data: Vec<f64>) -> Result<()> {
        let mut st = self.inner.lock().unwrap();
        // The new bytes supersede any spilled copy of an earlier
        // execution; it will be re-spilled on the next eviction, and any
        // in-flight disk read of the old bytes sees the version bump.
        st.persisted.remove(&key);
        *st.versions.entry(key).or_insert(0) += 1;
        self.admit(&mut st, key, Arc::new(data))
    }

    /// Fetch the blob for `key`, re-reading (and re-admitting) a spilled
    /// copy from disk when it is not resident.  Resident hits are O(1):
    /// one hash lookup plus an access-tick stamp.  The disk read happens
    /// outside the lock; if a concurrent `put` supersedes the key while
    /// the read is in flight (version bump), the stale bytes are
    /// discarded and the lookup retries.
    pub fn get(&self, key: u64) -> Result<Arc<Vec<f64>>> {
        loop {
            let seen_version = {
                let mut st = self.inner.lock().unwrap();
                let tick = st.next_tick();
                if let Some(blob) = st.resident.get_mut(&key) {
                    blob.last_access = tick;
                    return Ok(blob.data.clone());
                }
                st.versions.get(&key).copied().unwrap_or(0)
            };
            let path = self
                .blob_path(key)
                .ok_or_else(|| anyhow!("blob {key} missing from in-memory tile store"))?;
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading spilled blob {}", path.display()))?;
            ensure!(bytes.len() % 8 == 0, "spilled blob {key} has ragged length {}", bytes.len());
            let data: Vec<f64> = bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().expect("chunk is 8 bytes")))
                .collect();
            self.spill_reads.fetch_add(1, Ordering::Relaxed);
            let arc = Arc::new(data);
            let mut st = self.inner.lock().unwrap();
            if let Some(raced) = st.resident.get(&key) {
                return Ok(raced.data.clone()); // another reader re-admitted it first
            }
            if st.versions.get(&key).copied().unwrap_or(0) != seen_version {
                continue; // a put superseded the bytes we read: retry
            }
            self.admit(&mut st, key, arc.clone())?;
            // The just-read bytes are exactly what is on disk.
            st.persisted.insert(key);
            return Ok(arc);
        }
    }
}

impl Drop for TileStore {
    fn drop(&mut self) {
        if let Some(dir) = &self.dir {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("halign2-tilestore-{}-{tag}", std::process::id()))
    }

    #[test]
    fn in_memory_roundtrip_and_peak() {
        let s = TileStore::in_memory();
        s.put(3, vec![1.5, -2.5]).unwrap();
        s.put(9, vec![0.25]).unwrap();
        assert_eq!(*s.get(3).unwrap(), vec![1.5, -2.5]);
        assert_eq!(*s.get(9).unwrap(), vec![0.25]);
        assert_eq!(s.resident_bytes(), 24);
        assert_eq!(s.peak_resident_bytes(), 24);
        assert_eq!(s.spill_files_written(), 0);
        assert!(s.get(4).is_err(), "unknown key must error");
    }

    #[test]
    fn replacement_keeps_accounting_stable() {
        let s = TileStore::in_memory();
        for _ in 0..5 {
            s.put(7, vec![1.0; 100]).unwrap(); // at-least-once producer
        }
        assert_eq!(s.resident_bytes(), 800, "replace, don't accumulate");
        assert_eq!(s.peak_resident_bytes(), 800);
    }

    #[test]
    fn eviction_spills_and_get_rereads_bit_exact() {
        let dir = tmpdir("spill");
        let s = TileStore::spilling(dir.clone(), 3 * 80).unwrap();
        let blob = |k: u64| -> Vec<f64> {
            (0..10).map(|i| (k as f64) * 1e17 + i as f64 + 0.123).collect()
        };
        for k in 0..8u64 {
            s.put(k, blob(k)).unwrap();
        }
        assert!(s.resident_bytes() <= 3 * 80, "budget enforced");
        assert!(s.spill_files_written() >= 5, "older blobs spilled");
        assert!(s.peak_resident_bytes() <= 3 * 80 + 80, "peak <= budget + one blob");
        for k in 0..8u64 {
            let got = s.get(k).unwrap();
            let want = blob(k);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "key {k}: spill must be bit-exact");
            }
        }
        assert!(s.spill_reads() >= 5, "spilled blobs were re-read");
        drop(s);
        assert!(!dir.exists(), "spill dir removed on drop");
    }

    #[test]
    fn clean_eviction_does_not_rewrite_persisted_blobs() {
        let dir = tmpdir("clean");
        let s = TileStore::spilling(dir, 100).unwrap();
        s.put(1, vec![1.0; 10]).unwrap();
        s.put(2, vec![2.0; 10]).unwrap(); // evicts 1 (spill #1)
        let w1 = s.spill_files_written();
        s.get(1).unwrap(); // re-admit 1, evicts 2 (spill #2)
        s.get(2).unwrap(); // re-admit 2, evicts 1 again — already persisted
        assert_eq!(
            s.spill_files_written(),
            w1 + 1,
            "a clean (persisted, unmodified) blob must not be re-written"
        );
    }

    #[test]
    fn no_spill_dir_means_budget_is_advisory() {
        let s = TileStore::with_limits(None, 8);
        s.put(1, vec![0.0; 64]).unwrap();
        assert_eq!(*s.get(1).unwrap(), vec![0.0; 64], "stays resident with nowhere to spill");
    }
}

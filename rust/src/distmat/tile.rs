//! Tile planner: partition the n×n lower-triangular distance matrix into
//! fixed-size rectangular tiles, one stealable engine task each.
//!
//! Row indices 0..n are cut into contiguous *row blocks*; a tile is the
//! rectangle (row block `rb`, col block `cb`) with `cb <= rb`, so the
//! tile set covers exactly the lower triangle (diagonal tiles are square
//! and store their full rectangle — both (i,j) and (j,i) — which wastes
//! under half a diagonal tile but keeps the entry layout uniform).
//!
//! Block bounds use the same chunking formula as the engine's
//! `parallelize` (`per = ceil(n / num_blocks)`), so an `Rdd` built with
//! `parallelize(rows, grid.num_row_blocks())` has partition `b` equal to
//! row block `b` — the property the tile compute pipeline relies on.

/// One tile of the lower-triangular grid: a (row block, col block) pair
/// with its element bounds.  Entries are row-major:
/// `entry[(i - row_lo) * cols + (j - col_lo)] = d(i, j)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tile {
    pub index: usize,
    pub row_block: usize,
    pub col_block: usize,
    pub row_lo: usize,
    pub row_hi: usize,
    pub col_lo: usize,
    pub col_hi: usize,
}

impl Tile {
    pub fn rows(&self) -> usize {
        self.row_hi - self.row_lo
    }

    pub fn cols(&self) -> usize {
        self.col_hi - self.col_lo
    }

    /// Number of f64 entries the tile stores.
    pub fn num_entries(&self) -> usize {
        self.rows() * self.cols()
    }

    pub fn is_diagonal(&self) -> bool {
        self.row_block == self.col_block
    }

    /// Offset of global pair (i, j) within the tile's entry vector.
    pub fn entry_offset(&self, i: usize, j: usize) -> usize {
        debug_assert!(i >= self.row_lo && i < self.row_hi);
        debug_assert!(j >= self.col_lo && j < self.col_hi);
        (i - self.row_lo) * self.cols() + (j - self.col_lo)
    }
}

use crate::util::triangle_coords;

/// Plan of the tiled lower-triangular distance matrix for `n` taxa.
#[derive(Debug, Clone)]
pub struct TileGrid {
    n: usize,
    rows_per_block: usize,
    num_blocks: usize,
}

impl TileGrid {
    /// Plan a grid over `n` taxa with roughly `tile_rows` rows per block
    /// (clamped to `1..=n`, then snapped to the engine's even-chunk
    /// formula so blocks line up with `parallelize` partitions).
    pub fn new(n: usize, tile_rows: usize) -> Self {
        assert!(n > 0, "empty taxon set has no distance matrix");
        let requested = tile_rows.clamp(1, n);
        let nb = n.div_ceil(requested);
        let rows_per_block = n.div_ceil(nb);
        // ceil-division fix point: ceil(n / ceil(n / rows_per_block))
        // equals rows_per_block, so this block count is self-consistent
        // with the per-block size (and with `Rdd::from_vec` chunking).
        let num_blocks = n.div_ceil(rows_per_block);
        debug_assert_eq!(n.div_ceil(num_blocks), rows_per_block);
        Self { n, rows_per_block, num_blocks }
    }

    pub fn num_taxa(&self) -> usize {
        self.n
    }

    pub fn rows_per_block(&self) -> usize {
        self.rows_per_block
    }

    pub fn num_row_blocks(&self) -> usize {
        self.num_blocks
    }

    /// Total tile count: `nb * (nb + 1) / 2` (lower triangle + diagonal).
    pub fn num_tiles(&self) -> usize {
        self.num_blocks * (self.num_blocks + 1) / 2
    }

    pub fn block_of(&self, i: usize) -> usize {
        debug_assert!(i < self.n);
        i / self.rows_per_block
    }

    /// Element bounds `[lo, hi)` of row block `b`.
    pub fn block_bounds(&self, b: usize) -> (usize, usize) {
        debug_assert!(b < self.num_blocks);
        (b * self.rows_per_block, ((b + 1) * self.rows_per_block).min(self.n))
    }

    /// Linear index of tile (row block, col block), `cb <= rb`.
    pub fn tile_index(&self, rb: usize, cb: usize) -> usize {
        debug_assert!(cb <= rb && rb < self.num_blocks);
        rb * (rb + 1) / 2 + cb
    }

    /// The tile holding d(i, j) for `i >= j`.
    pub fn tile_for(&self, i: usize, j: usize) -> usize {
        debug_assert!(i >= j);
        self.tile_index(self.block_of(i), self.block_of(j))
    }

    /// Decode a linear tile index into its block pair and bounds.
    pub fn tile(&self, index: usize) -> Tile {
        debug_assert!(index < self.num_tiles());
        let (rb, cb) = triangle_coords(index);
        let (row_lo, row_hi) = self.block_bounds(rb);
        let (col_lo, col_hi) = self.block_bounds(cb);
        Tile { index, row_block: rb, col_block: cb, row_lo, row_hi, col_lo, col_hi }
    }

    /// Bytes of the largest tile's entries — the granularity slack on top
    /// of a `TileStore` byte budget.
    pub fn max_tile_bytes(&self) -> usize {
        self.rows_per_block * self.rows_per_block * std::mem::size_of::<f64>()
    }

    /// Bytes of the largest per-tile `(sum, min)` sidecar blob (cross
    /// tiles carry a row *and* a mirror column section; see
    /// [`super::exact`]) — the extra store granularity sidecar-writing
    /// pipelines add on top of [`Self::max_tile_bytes`].
    pub fn max_sidecar_bytes(&self) -> usize {
        (1 + super::exact::SLOTS_PER_TAXON * (2 * self.rows_per_block))
            * std::mem::size_of::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangle_coords_roundtrip() {
        let mut idx = 0;
        for rb in 0..60 {
            for cb in 0..=rb {
                assert_eq!(triangle_coords(idx), (rb, cb), "index {idx}");
                idx += 1;
            }
        }
    }

    #[test]
    fn blocks_cover_taxa_exactly_once() {
        for n in [1usize, 2, 5, 9, 10, 17, 64, 101] {
            for tile_rows in [1usize, 2, 3, 4, 7, 64, 1000] {
                let g = TileGrid::new(n, tile_rows);
                let mut covered = vec![0usize; n];
                for b in 0..g.num_row_blocks() {
                    let (lo, hi) = g.block_bounds(b);
                    assert!(lo < hi, "n={n} tile={tile_rows}: empty block {b}");
                    for i in lo..hi {
                        covered[i] += 1;
                        assert_eq!(g.block_of(i), b);
                    }
                }
                assert!(covered.iter().all(|&c| c == 1), "n={n} tile={tile_rows}");
            }
        }
    }

    #[test]
    fn tiles_cover_lower_triangle_exactly_once() {
        let g = TileGrid::new(23, 5);
        let n = g.num_taxa();
        let mut covered = vec![vec![0usize; n]; n];
        for t in 0..g.num_tiles() {
            let tile = g.tile(t);
            assert_eq!(tile.index, t);
            for i in tile.row_lo..tile.row_hi {
                for j in tile.col_lo..tile.col_hi {
                    covered[i][j] += 1;
                }
            }
        }
        for i in 0..n {
            for j in 0..n {
                let expect = usize::from(g.block_of(i) >= g.block_of(j));
                assert_eq!(covered[i][j], expect, "({i},{j})");
            }
        }
        // Every lower-triangle pair i >= j is addressable.
        for i in 0..n {
            for j in 0..=i {
                let tile = g.tile(g.tile_for(i, j));
                let off = tile.entry_offset(i, j);
                assert!(off < tile.num_entries());
            }
        }
    }

    #[test]
    fn block_size_matches_parallelize_chunking() {
        // The engine chunks `parallelize(v, parts)` as ceil(len/parts)
        // per partition; the grid must agree for every shape.
        for n in 1..200usize {
            for tile_rows in 1..=n {
                let g = TileGrid::new(n, tile_rows);
                let per = n.div_ceil(g.num_row_blocks());
                assert_eq!(
                    per,
                    g.rows_per_block(),
                    "n={n} tile={tile_rows}: grid must match from_vec chunking"
                );
            }
        }
    }

    #[test]
    fn tile_entry_layout_is_row_major() {
        let g = TileGrid::new(10, 4);
        let t = g.tile(g.tile_for(5, 1));
        assert_eq!((t.row_block, t.col_block), (1, 0));
        assert_eq!(t.entry_offset(5, 1), (5 - t.row_lo) * t.cols() + 1);
    }
}

//! Exact row-sum accumulation for distance matrices.
//!
//! NJ branch lengths are functions of row sums, and the tree pipelines
//! promise *bit-identical* results across dense, tiled-scan, and
//! sidecar-fold backends.  f64 addition is not associative, so partial
//! sums computed per tile cannot simply be f64-folded — the grouping
//! would differ from the dense reference.  Instead, every distance is
//! lifted to a fixed-point `i128` (LSB = 2⁻⁸⁰) where addition **is**
//! associative and exact, summed, and rounded back to f64 once at the
//! end.  Any grouping of the same values then yields the same bits,
//! which is what lets per-tile `(sum, min)` sidecars seed NJ without
//! re-reading spilled tiles.
//!
//! Representability: a finite non-negative f64 lifts exactly iff its
//! ulp is ≥ 2⁻⁸⁰ — true for every real distance this codebase produces
//! (p-distances are ratios of ≤2⁶⁴ integer counts but ≥ 2⁻²⁸ for any
//! realistic length; JC distances are capped at 5.0; k-mer distances
//! are sums of integer squares).  If *any* value fails to lift (or a
//! sum overflows `i128`), every consumer falls back to the legacy
//! naive ascending-`j` f64 accumulation **globally** — validity is a
//! property of the value multiset, identical across backends, so dense
//! and tiled never disagree about which mode they are in (values are
//! non-negative, hence partial sums are monotone and overflow is
//! decided by the row total alone).

use anyhow::{ensure, Result};

use super::tile::Tile;

/// Binary point of the fixed representation: LSB = 2^-FIXED_SHIFT.
const FIXED_SHIFT: i32 = 80;

/// Lift a finite non-negative f64 into exact fixed point (LSB 2⁻⁸⁰).
/// `None` when the value is negative, non-finite, or has bits below
/// 2⁻⁸⁰ (not representable ⇒ callers fall back to naive f64 sums).
pub fn to_fixed(v: f64) -> Option<i128> {
    if !v.is_finite() || v.is_sign_negative() {
        return if v == 0.0 { Some(0) } else { None };
    }
    if v == 0.0 {
        return Some(0);
    }
    let bits = v.to_bits();
    let frac = bits & ((1u64 << 52) - 1);
    let biased = (bits >> 52) & 0x7ff;
    let (mant, e) = if biased == 0 {
        (frac, -1074i32) // subnormal
    } else {
        (frac | (1u64 << 52), biased as i32 - 1075)
    };
    let shift = e + FIXED_SHIFT;
    if !(0..=74).contains(&shift) {
        // < 0: bits below the binary point; > 74: mant << shift would
        // not fit in the non-negative range of i128 (mant < 2⁵³).
        return None;
    }
    Some((mant as i128) << shift)
}

/// Round an exact fixed-point sum back to f64.  `x as f64` rounds to
/// nearest (ties to even) and the 2⁻⁸⁰ scale is a power of two, so the
/// result is the correctly-rounded value of the exact rational sum.
pub fn fixed_to_f64(x: i128) -> f64 {
    (x as f64) * f64::from_bits(((1023 - FIXED_SHIFT as u64) << 52) as u64)
}

/// Exactly-rounded f64 sum of a value slice (test/reference helper).
/// `None` if any value fails to lift or the sum overflows.
pub fn exact_sum(values: &[f64]) -> Option<f64> {
    let mut acc: i128 = 0;
    for &v in values {
        acc = acc.checked_add(to_fixed(v)?)?;
    }
    Some(fixed_to_f64(acc))
}

/// Dual accumulator for per-row `(sum, min)` stats: exact fixed-point
/// sums alongside the legacy naive f64 sums, with one *global* validity
/// flag (see module docs).  Feed values per row in the legacy order —
/// the naive side is order-sensitive and must keep matching the old
/// dense reference when the exact side is unavailable.
pub struct RowSums {
    exact: Vec<i128>,
    naive: Vec<f64>,
    valid: bool,
}

impl RowSums {
    pub fn new(n: usize) -> Self {
        RowSums { exact: vec![0i128; n], naive: vec![0f64; n], valid: true }
    }

    pub fn add(&mut self, i: usize, v: f64) {
        self.naive[i] += v;
        if self.valid {
            match to_fixed(v).and_then(|f| self.exact[i].checked_add(f)) {
                Some(x) => self.exact[i] = x,
                None => self.valid = false,
            }
        }
    }

    /// Exact sums when every value lifted, naive sums otherwise.
    pub fn finish(self) -> Vec<f64> {
        if self.valid {
            self.exact.into_iter().map(fixed_to_f64).collect()
        } else {
            self.naive
        }
    }
}

// ---------------------------------------------------------------------
// Per-tile (sum, min) sidecars.
//
// Layout of a sidecar blob (Vec<f64>, stored in the TileStore under key
// `num_tiles + tile.index`):
//   [0]                    validity flag: 1.0 = exact sums valid
//   rows section           5 f64 per tile row  (4 u32 chunks + min)
//   cols section           5 f64 per tile col, cross tiles only
//                          (mirror credits; diagonal tiles fold both
//                          directions into the rows section)
// The i128 sums are non-negative (< 2¹²⁷), split into four u32 chunks
// stored as exact small-integer f64s — every chunk < 2³² < 2⁵³, so the
// encoding round-trips bit-exactly through the store's f64 blobs.
// ---------------------------------------------------------------------

const CHUNKS: usize = 4;
/// f64 slots per taxon in a sidecar section: 4 sum chunks + the min.
pub const SLOTS_PER_TAXON: usize = CHUNKS + 1;

fn encode_i128(x: i128, out: &mut Vec<f64>) {
    debug_assert!(x >= 0);
    let u = x as u128;
    for c in 0..CHUNKS {
        out.push(((u >> (32 * c)) & 0xffff_ffff) as u32 as f64);
    }
}

fn decode_i128(chunks: &[f64]) -> Result<i128> {
    let mut u: u128 = 0;
    for (c, &raw) in chunks.iter().enumerate().take(CHUNKS) {
        ensure!(
            raw >= 0.0 && raw <= u32::MAX as f64 && raw.fract() == 0.0,
            "corrupt sidecar sum chunk {raw}"
        );
        u |= (raw as u128) << (32 * c);
    }
    ensure!(u >> 127 == 0, "sidecar sum out of i128 range");
    Ok(u as i128)
}

/// Accumulate one taxon's side of a section.
struct SideAcc {
    sums: Vec<i128>,
    mins: Vec<f64>,
    valid: bool,
}

impl SideAcc {
    fn new(n: usize) -> Self {
        SideAcc { sums: vec![0i128; n], mins: vec![f64::INFINITY; n], valid: true }
    }

    fn add(&mut self, slot: usize, v: f64) {
        self.mins[slot] = self.mins[slot].min(v);
        if self.valid {
            match to_fixed(v).and_then(|f| self.sums[slot].checked_add(f)) {
                Some(x) => self.sums[slot] = x,
                None => self.valid = false,
            }
        }
    }

    fn write(&self, out: &mut Vec<f64>) {
        for (s, m) in self.sums.iter().zip(&self.mins) {
            encode_i128(*s, out);
            out.push(*m);
        }
    }
}

/// Build the `(sum, min)` sidecar blob for one tile's entries (same
/// `entries` vector the tile job stores: row-major over the tile
/// rectangle, diagonal cells 0.0 on diagonal tiles).
pub fn tile_sidecar(tile: &Tile, entries: &[f64]) -> Vec<f64> {
    let rows = tile.rows();
    let cols = tile.cols();
    debug_assert_eq!(entries.len(), rows * cols);
    let mut row_acc = SideAcc::new(rows);
    // Diagonal tiles credit both pair members into the rows section
    // (row and col ranges coincide); cross tiles keep a separate mirror
    // section for their columns.
    let mut col_acc = if tile.is_diagonal() { None } else { Some(SideAcc::new(cols)) };
    for i in tile.row_lo..tile.row_hi {
        for j in tile.col_lo..tile.col_hi {
            if i == j {
                continue;
            }
            let v = entries[tile.entry_offset(i, j)];
            row_acc.add(i - tile.row_lo, v);
            // Diagonal tiles store the full block square, so the mirror
            // entry (j, i) is credited by its own loop iteration; cross
            // tiles hold each pair once and need the explicit mirror.
            if let Some(acc) = &mut col_acc {
                acc.add(j - tile.col_lo, v);
            }
        }
    }
    let valid = row_acc.valid
        && match &col_acc {
            Some(a) => a.valid,
            None => true,
        };
    let mut out = Vec::with_capacity(1 + SLOTS_PER_TAXON * (rows + cols));
    out.push(if valid { 1.0 } else { 0.0 });
    row_acc.write(&mut out);
    if let Some(acc) = &col_acc {
        acc.write(&mut out);
    }
    out
}

/// One decoded sidecar: exact per-taxon partial sums and mins for the
/// tile's row range (and column range, for cross tiles).
pub struct SidecarView {
    pub valid: bool,
    /// `(taxon, exact partial sum, partial min)` triples.
    pub parts: Vec<(usize, i128, f64)>,
}

/// Decode a sidecar blob back into per-taxon contributions.
pub fn decode_sidecar(tile: &Tile, blob: &[f64]) -> Result<SidecarView> {
    let rows = tile.rows();
    let cols = tile.cols();
    let want = 1 + SLOTS_PER_TAXON * (rows + if tile.is_diagonal() { 0 } else { cols });
    ensure!(blob.len() == want, "sidecar blob len {} != {want}", blob.len());
    ensure!(blob[0] == 1.0 || blob[0] == 0.0, "corrupt sidecar flag {}", blob[0]);
    let valid = blob[0] == 1.0;
    let mut parts = Vec::with_capacity(rows + cols);
    let mut off = 1;
    for r in 0..rows {
        let sum = decode_i128(&blob[off..off + CHUNKS])?;
        parts.push((tile.row_lo + r, sum, blob[off + CHUNKS]));
        off += SLOTS_PER_TAXON;
    }
    if !tile.is_diagonal() {
        for c in 0..cols {
            let sum = decode_i128(&blob[off..off + CHUNKS])?;
            parts.push((tile.col_lo + c, sum, blob[off + CHUNKS]));
            off += SLOTS_PER_TAXON;
        }
    }
    Ok(SidecarView { valid, parts })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distmat::tile::TileGrid;

    #[test]
    fn fixed_point_roundtrips_distance_like_values() {
        for v in [0.0, 0.25, 1.0, 5.0, 0.123456789, 1.0 / 3.0, 4.999999, 1e-8, 300.5] {
            let f = to_fixed(v).unwrap();
            assert_eq!(fixed_to_f64(f).to_bits(), v.to_bits(), "{v} must round-trip");
        }
    }

    #[test]
    fn unrepresentable_values_are_rejected() {
        assert_eq!(to_fixed(-0.25), None);
        assert_eq!(to_fixed(f64::NAN), None);
        assert_eq!(to_fixed(f64::INFINITY), None);
        assert_eq!(to_fixed(1e-40), None, "bits below 2^-80");
        assert_eq!(to_fixed(f64::MAX), None, "would overflow the shift");
        assert_eq!(to_fixed(0.0), Some(0));
        assert_eq!(to_fixed(-0.0), Some(0), "negative zero is zero");
    }

    #[test]
    fn exact_sum_is_grouping_independent() {
        let mut rng = crate::util::Rng::seed_from_u64(7);
        let vals: Vec<f64> = (0..257).map(|_| 0.05 + rng.f64()).collect();
        let whole = exact_sum(&vals).unwrap();
        for chunk in [1usize, 3, 16, 64] {
            let acc = vals
                .chunks(chunk)
                .map(|c| {
                    c.iter().map(|&v| to_fixed(v).unwrap()).sum::<i128>()
                })
                .sum::<i128>();
            assert_eq!(
                fixed_to_f64(acc).to_bits(),
                whole.to_bits(),
                "chunked-by-{chunk} fold must match"
            );
        }
        // The exact result stays within rounding noise of the naive sum.
        let naive: f64 = vals.iter().sum();
        assert!((naive - whole).abs() < 1e-9);
    }

    #[test]
    fn row_sums_falls_back_globally_on_bad_values() {
        let mut rs = RowSums::new(2);
        rs.add(0, 0.5);
        rs.add(1, 1e-40); // unrepresentable: poisons the whole batch
        rs.add(0, 0.25);
        let sums = rs.finish();
        assert_eq!(sums[0].to_bits(), (0.5f64 + 0.25).to_bits(), "naive fallback");
        assert_eq!(sums[1].to_bits(), 1e-40f64.to_bits());
    }

    #[test]
    fn sidecar_roundtrip_covers_diagonal_and_cross_tiles() {
        let grid = TileGrid::new(7, 3);
        let mut rng = crate::util::Rng::seed_from_u64(11);
        for t in 0..grid.num_tiles() {
            let tile = grid.tile(t);
            let mut entries = vec![0f64; tile.num_entries()];
            for i in tile.row_lo..tile.row_hi {
                for j in tile.col_lo..tile.col_hi {
                    if i != j {
                        entries[tile.entry_offset(i, j)] = 0.05 + rng.f64();
                    }
                }
            }
            let blob = tile_sidecar(&tile, &entries);
            let view = decode_sidecar(&tile, &blob).unwrap();
            assert!(view.valid);
            // Re-derive the expected per-taxon contributions directly.
            let mut want_sum = std::collections::HashMap::new();
            let mut want_min = std::collections::HashMap::new();
            for i in tile.row_lo..tile.row_hi {
                for j in tile.col_lo..tile.col_hi {
                    if i == j {
                        continue;
                    }
                    let v = entries[tile.entry_offset(i, j)];
                    *want_sum.entry(i).or_insert(0i128) += to_fixed(v).unwrap();
                    let m = want_min.entry(i).or_insert(f64::INFINITY);
                    *m = m.min(v);
                    if !tile.is_diagonal() {
                        *want_sum.entry(j).or_insert(0i128) += to_fixed(v).unwrap();
                        let m = want_min.entry(j).or_insert(f64::INFINITY);
                        *m = m.min(v);
                    }
                }
            }
            for (taxon, sum, min) in &view.parts {
                assert_eq!(*sum, want_sum.get(taxon).copied().unwrap_or(0), "tile {t} taxon {taxon}");
                assert_eq!(
                    min.to_bits(),
                    want_min.get(taxon).copied().unwrap_or(f64::INFINITY).to_bits()
                );
            }
        }
    }

    #[test]
    fn invalid_entries_set_the_sidecar_flag() {
        let grid = TileGrid::new(4, 2);
        let tile = grid.tile(grid.tile_index(1, 0)); // cross tile
        let mut entries = vec![0.5f64; tile.num_entries()];
        entries[0] = 1e-42; // unrepresentable
        let blob = tile_sidecar(&tile, &entries);
        let view = decode_sidecar(&tile, &blob).unwrap();
        assert!(!view.valid, "bad value must mark the sidecar invalid");
    }
}

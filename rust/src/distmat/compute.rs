//! Tile jobs: compute a [`TiledDist`] on the engine, one stealable task
//! per tile.
//!
//! The input rows are partitioned into the grid's row blocks
//! (`parallelize` chunking matches [`TileGrid`] bounds by construction)
//! and the engine's `lower_triangle_blocks` primitive pairs every
//! (row block, col block) combination with `cb <= rb`; each pair is one
//! task that computes its tile's entries and `put`s them into the shared
//! [`TileStore`].  Tasks are idempotent (deterministic entries,
//! replace-on-put), so the executor's at-least-once semantics —
//! speculation, retries, worker kills with lineage recompute — apply
//! unchanged.
//!
//! The per-pair kernels are shared with [`crate::tree::distance`]
//! (`pdist_pair_packed`, `jc_distance`, `kmer_profile`,
//! `kmer_sqdist_pair`), so tiled entries are bit-identical to the dense
//! matrices the single-node path materializes.  P-distance tiles pack
//! each row block into [`crate::align::myers::RowBits`] bitplanes once
//! and popcount — same integer counts as the scalar loop, ~64× fewer
//! inner-loop iterations.
//!
//! Each tile task also stores a `(sum, min)` *sidecar* blob (key
//! `num_tiles + tile_index`, built by [`super::exact::tile_sidecar`]):
//! exact fixed-point partial row sums plus partial row minima, so NJ
//! seeding via `row_stats` folds the tiny sidecars instead of faulting
//! every spilled tile back through the byte budget.

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use super::tile::Tile;
use super::{TileGrid, TileStore, TiledDist};
use crate::align::myers::pack_row;
use crate::engine::Cluster as Engine;
use crate::fasta::Sequence;
use crate::tree::distance::{jc_distance, kmer_profile, kmer_sqdist_pair, pdist_pair_packed};

/// Which distance the tile jobs compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistKind {
    /// p-distance over aligned rows (the NJ input), optionally
    /// Jukes-Cantor corrected with the alphabet's state count.
    PDistance { jukes_cantor: bool },
    /// Squared-euclidean distance between hashed k-mer count profiles
    /// (the clustering signal; works on unaligned rows).
    KmerSq { k: usize, dim: usize },
}

/// Knobs for the tiled distance pipeline.
#[derive(Debug, Clone)]
pub struct DistMatConfig {
    /// Rows per tile block (tile ≈ `tile_rows²` f64 entries).
    pub tile_rows: usize,
    /// Resident-byte budget for the tile store; completed tiles beyond
    /// it spill to the engine scratch dir.
    pub byte_budget: usize,
    pub kind: DistKind,
}

impl Default for DistMatConfig {
    fn default() -> Self {
        Self {
            tile_rows: 64,
            byte_budget: 8 << 20,
            kind: DistKind::PDistance { jukes_cantor: true },
        }
    }
}

/// Compute the tiled pairwise distance matrix of `rows` as engine jobs.
///
/// One task per lower-triangle tile; the work-stealing executor balances
/// them and speculation/fault recovery re-run them safely.  Returns a
/// [`TiledDist`] whose resident footprint is bounded by
/// `cfg.byte_budget` plus one tile.
pub fn distance_tiled(
    engine: &Engine,
    rows: &[Sequence],
    cfg: &DistMatConfig,
) -> Result<TiledDist> {
    let n = rows.len();
    ensure!(n > 0, "no rows to measure");
    if let DistKind::PDistance { .. } = cfg.kind {
        let width = rows[0].len();
        ensure!(rows.iter().all(|r| r.len() == width), "p-distances need aligned rows");
    }
    let grid = TileGrid::new(n, cfg.tile_rows);
    let dir = engine.scratch_dir()?.join(format!("distmat-{}", engine.next_shuffle_id()));
    let store = Arc::new(TileStore::spilling(dir, cfg.byte_budget)?);

    let blocks = engine.parallelize(rows.to_vec(), grid.num_row_blocks());
    ensure!(
        blocks.num_partitions() == grid.num_row_blocks(),
        "row-block partitioning diverged from the tile grid"
    );
    let pairs = blocks.lower_triangle_blocks();
    ensure!(pairs.num_partitions() == grid.num_tiles(), "tile task count mismatch");

    let kind = cfg.kind;
    let gap = rows[0].alphabet.gap();
    let states = rows[0].alphabet.residues();
    let grid_task = grid.clone();
    let store_task = store.clone();
    pairs.run_partitions(move |part, items| {
        let ((bi, bj), (rows_i, rows_j)) = items
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("tile partition {part} produced no block pair"))?;
        let tile = grid_task.tile(part);
        ensure!(
            (tile.row_block, tile.col_block) == (bi as usize, bj as usize),
            "tile {part}: expected blocks ({},{}), got ({bi},{bj})",
            tile.row_block,
            tile.col_block
        );
        let entries = tile_entries(kind, &tile, &rows_i, &rows_j, gap, states);
        // Sidecar first: once the tile blob is visible, its stats must
        // be too (consumers only fold sidecars after distance_tiled
        // returns, but keep the ordering conservative for re-puts).
        let sidecar = super::exact::tile_sidecar(&tile, &entries);
        store_task.put((grid_task.num_tiles() + part) as u64, sidecar)?;
        store_task.put(part as u64, entries)
    })?;

    // Fold this store's spill activity into the cluster-wide registry
    // counters (the store itself stays registry-agnostic; later spills
    // during NJ row streaming are credited by the next job's fold).
    engine.io().distmat_spill_files.add(store.spill_files_written() as u64);
    engine.io().distmat_spill_reads.add(store.spill_reads() as u64);

    Ok(TiledDist::with_sidecars(grid, store))
}

/// Entries of one tile, row-major, diagonal cells zero.  Every cell is
/// computed directly (the per-pair kernels are exactly symmetric, so the
/// diagonal tile's (i,j)/(j,i) cells agree bit for bit without
/// mirroring).
fn tile_entries(
    kind: DistKind,
    tile: &Tile,
    rows_i: &[Sequence],
    rows_j: &[Sequence],
    gap: u8,
    states: usize,
) -> Vec<f64> {
    let mut out = Vec::with_capacity(tile.num_entries());
    match kind {
        DistKind::PDistance { jukes_cantor } => {
            // Pack each side once, compare with the popcount kernel:
            // O(rows·cols·L/64) instead of O(rows·cols·L).  The packed
            // counts are the same integers the scalar loop produces, so
            // the f64 ratios are bit-identical (pinned in
            // `tree::distance` tests).
            let bi: Vec<_> = rows_i.iter().map(|s| pack_row(&s.codes, gap)).collect();
            let bj: Vec<_> = rows_j.iter().map(|s| pack_row(&s.codes, gap)).collect();
            for (r, a) in bi.iter().enumerate() {
                for (c, b) in bj.iter().enumerate() {
                    if tile.row_lo + r == tile.col_lo + c {
                        out.push(0.0);
                        continue;
                    }
                    let p = pdist_pair_packed(a, b);
                    out.push(if jukes_cantor { jc_distance(p, states) } else { p });
                }
            }
        }
        DistKind::KmerSq { k, dim } => {
            let pi: Vec<Vec<f32>> =
                rows_i.iter().map(|s| kmer_profile(&s.codes, k, dim, gap)).collect();
            let pj: Vec<Vec<f32>> =
                rows_j.iter().map(|s| kmer_profile(&s.codes, k, dim, gap)).collect();
            for (r, a) in pi.iter().enumerate() {
                for (c, b) in pj.iter().enumerate() {
                    if tile.row_lo + r == tile.col_lo + c {
                        out.push(0.0);
                    } else {
                        out.push(kmer_sqdist_pair(a, b) as f64);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;
    use crate::distmat::{DenseF32, DenseView, DistSource};
    use crate::engine::{Cluster, ClusterConfig, FaultPlan};
    use crate::tree::distance::{kmer_distance_native, pdistance_native};

    fn aligned_rows(n: usize, seed: u64) -> Vec<Sequence> {
        // Raw mito rows share a length per spec, which is all the
        // p-distance kernel needs.
        let spec = DatasetSpec { count: n, ..DatasetSpec::mito(0.01, seed) };
        let rows = spec.generate();
        let w = rows.iter().map(Sequence::len).min().unwrap();
        rows.into_iter()
            .map(|mut s| {
                s.codes.truncate(w);
                s
            })
            .collect()
    }

    fn dense_jc(rows: &[Sequence]) -> Vec<Vec<f64>> {
        let p = pdistance_native(rows).unwrap();
        let states = rows[0].alphabet.residues();
        p.iter().map(|r| r.iter().map(|&x| jc_distance(x, states)).collect()).collect()
    }

    #[test]
    fn tiled_pdistance_matches_dense_bitwise() {
        let rows = aligned_rows(19, 11);
        let dense = dense_jc(&rows);
        for (tile_rows, workers) in [(1usize, 2usize), (4, 3), (7, 8), (64, 2)] {
            let engine = Cluster::new(ClusterConfig::spark(workers));
            let cfg = DistMatConfig { tile_rows, byte_budget: 1 << 12, ..Default::default() };
            let tiled = distance_tiled(&engine, &rows, &cfg).unwrap();
            for i in 0..rows.len() {
                for j in 0..rows.len() {
                    if i == j {
                        continue;
                    }
                    assert_eq!(
                        tiled.dist(i, j).unwrap().to_bits(),
                        dense[i][j].to_bits(),
                        "tile={tile_rows} w={workers} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn tiny_budget_spills_but_peak_stays_bounded() {
        let rows = aligned_rows(24, 5);
        let engine = Cluster::new(ClusterConfig::spark(4));
        let budget = 512; // far below the 24²×8 = 4.6 KB dense matrix
        let cfg = DistMatConfig { tile_rows: 4, byte_budget: budget, ..Default::default() };
        let tiled = distance_tiled(&engine, &rows, &cfg).unwrap();
        let store = tiled.store_arc();
        assert!(store.spill_files_written() > 0, "budget this small must spill");
        // Granularity slack: one blob, which may be a tile or (for small
        // tiles) the larger cross-tile sidecar.
        let blob = tiled.grid().max_tile_bytes().max(tiled.grid().max_sidecar_bytes());
        assert!(
            tiled.peak_resident_bytes() <= budget + blob,
            "peak {} must stay within budget {budget} + one blob {blob}",
            tiled.peak_resident_bytes(),
        );
        // Spilled tiles still serve bit-exact reads.
        let dense = dense_jc(&rows);
        let (sums, _) = tiled.row_stats().unwrap();
        let (dsums, _) = DenseView(&dense).row_stats().unwrap();
        for i in 0..rows.len() {
            assert_eq!(sums[i].to_bits(), dsums[i].to_bits(), "row {i}");
        }
    }

    #[test]
    fn worker_kill_mid_tile_job_recovers() {
        let rows = aligned_rows(16, 7);
        let dense = dense_jc(&rows);
        let mut ccfg = ClusterConfig::spark(3);
        ccfg.fault = FaultPlan::kill_worker_at(1, 3);
        let engine = Cluster::new(ccfg);
        let cfg = DistMatConfig { tile_rows: 3, byte_budget: 1 << 12, ..Default::default() };
        let tiled = distance_tiled(&engine, &rows, &cfg).unwrap();
        assert_eq!(engine.executor().alive_workers(), 2, "the kill must have fired");
        for i in 0..rows.len() {
            for j in 0..i {
                assert_eq!(tiled.dist(i, j).unwrap().to_bits(), dense[i][j].to_bits());
            }
        }
    }

    #[test]
    fn sidecar_seeding_reads_zero_tile_blobs() {
        let rows = aligned_rows(20, 13);
        let engine = Cluster::new(ClusterConfig::spark(3));
        let cfg = DistMatConfig { tile_rows: 4, byte_budget: 1 << 10, ..Default::default() };
        let tiled = distance_tiled(&engine, &rows, &cfg).unwrap();
        let store = tiled.store_arc();
        let num_tiles = tiled.grid().num_tiles() as u64;
        assert_eq!(tiled.row_key_base(), 2 * num_tiles, "sidecars claim a key band");
        let before = store.gets_below(num_tiles);
        let (sums, mins) = tiled.row_stats().unwrap();
        assert_eq!(
            store.gets_below(num_tiles),
            before,
            "row_stats must fold sidecars only — zero tile-blob reads"
        );
        // And the folded stats still match the dense reference bitwise.
        let dense = dense_jc(&rows);
        let (ds, dm) = DenseView(&dense).row_stats().unwrap();
        for i in 0..rows.len() {
            assert_eq!(sums[i].to_bits(), ds[i].to_bits(), "sum row {i}");
            assert_eq!(mins[i].to_bits(), dm[i].to_bits(), "min row {i}");
        }
    }

    #[test]
    fn tile_entries_packed_matches_scalar_pair_kernel() {
        use crate::tree::distance::pdist_pair;
        let rows = aligned_rows(13, 17);
        let gap = rows[0].alphabet.gap();
        let states = rows[0].alphabet.residues();
        let grid = TileGrid::new(rows.len(), 5);
        for t in 0..grid.num_tiles() {
            let tile = grid.tile(t);
            let rows_i = rows[tile.row_lo..tile.row_hi].to_vec();
            let rows_j = rows[tile.col_lo..tile.col_hi].to_vec();
            let packed = tile_entries(
                DistKind::PDistance { jukes_cantor: true },
                &tile,
                &rows_i,
                &rows_j,
                gap,
                states,
            );
            for i in tile.row_lo..tile.row_hi {
                for j in tile.col_lo..tile.col_hi {
                    let want = if i == j {
                        0.0
                    } else {
                        jc_distance(pdist_pair(&rows[i].codes, &rows[j].codes, gap), states)
                    };
                    assert_eq!(
                        packed[tile.entry_offset(i, j)].to_bits(),
                        want.to_bits(),
                        "tile {t} ({i},{j}): packed tile kernel must match scalar"
                    );
                }
            }
        }
    }

    #[test]
    fn kmer_kind_matches_native_profiles() {
        let rows = DatasetSpec::rrna(14, 0.2, 9).generate();
        let gap = rows[0].alphabet.gap();
        let profiles: Vec<Vec<f32>> =
            rows.iter().map(|s| kmer_profile(&s.codes, 4, 64, gap)).collect();
        let dense = kmer_distance_native(&profiles);
        let engine = Cluster::new(ClusterConfig::spark(2));
        let cfg = DistMatConfig {
            tile_rows: 5,
            byte_budget: 1 << 14,
            kind: DistKind::KmerSq { k: 4, dim: 64 },
        };
        let tiled = distance_tiled(&engine, &rows, &cfg).unwrap();
        let view = DenseF32(&dense);
        for i in 0..rows.len() {
            for j in 0..rows.len() {
                assert_eq!(
                    tiled.dist(i, j).unwrap().to_bits(),
                    view.dist(i, j).unwrap().to_bits(),
                    "({i},{j})"
                );
            }
        }
    }
}

//! Distributed tiled distance matrices — the subsystem behind the
//! paper's "extremely high memory efficiency" tree claim.
//!
//! The n×n pairwise distance matrix is the O(n²) object that makes
//! ultra-large tree reconstruction memory-bound.  This module stops
//! materializing it:
//!
//! * [`TileGrid`] partitions the lower triangle into fixed-size tiles;
//!   each tile is one stealable engine task (Sample-Align-D's pairwise
//!   domain decomposition), so the sharded work-stealing/speculation
//!   machinery from `engine/` applies unchanged.
//! * [`TileStore`] keeps completed tiles resident under a byte budget
//!   and spills the rest to disk (tmp+rename, bit-exact roundtrip);
//!   peak resident bytes stay `<= budget + one tile`, not O(n²).
//! * [`DistSource`] abstracts "something that answers d(i, j)" so
//!   consumers ([`crate::tree::nj`], [`crate::tree::cluster`]) are
//!   backend-agnostic: [`DenseView`] / [`DenseF32`] wrap in-memory
//!   matrices, [`TiledDist`] serves tiles out-of-core.
//! * [`compute::distance_tiled`] runs the tile jobs on the engine
//!   (p-distance + optional Jukes-Cantor, or k-mer-profile distances).
//!
//! Bit-identity contract: every backend must return the *same f64 bits*
//! for d(i, j) as the dense single-node path.  `row_stats` sums are
//! computed in exact fixed-point arithmetic ([`exact::RowSums`]) —
//! grouping-independent, so the dense pass, the tiled scan, and the
//! per-tile sidecar fold all round to identical f64 bits; when a value
//! is not fixed-point representable, *every* backend falls back to the
//! legacy naive ascending-j f64 accumulation together.  The tile
//! kernels share the per-pair code with `tree::distance`, and the NJ
//! property tests pin the end-to-end guarantee across tile sizes,
//! worker counts and fault plans.
//!
//! Sidecars: tile jobs also store a per-tile `(sum, min)` sidecar blob
//! (key `num_tiles + tile_index`), so [`TiledDist::row_stats`] can seed
//! NJ by folding `num_tiles` tiny sidecars instead of faulting every
//! spilled tile back through the byte budget — zero tile-blob reads,
//! pinned by a test in [`compute`].
//!
//! At-least-once interaction: tile jobs may run more than once under
//! speculation/retry; `TileStore::put` replaces (accounting released
//! first) and tile contents are deterministic, so duplicates are
//! harmless — the same discipline as the shuffle spill path.

pub mod compute;
pub mod exact;
pub mod store;
pub mod tile;

use anyhow::{ensure, Result};

pub use compute::{distance_tiled, DistKind, DistMatConfig};
pub use store::TileStore;
pub use tile::{Tile, TileGrid};

use std::sync::Arc;

/// Which distance backend a tree pipeline should use (threaded through
/// [`crate::tree::TreeConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DistBackend {
    /// Materialize dense `Vec<Vec<f64>>` matrices per cluster (the
    /// single-node path; resident memory is O(n²) per cluster).
    #[default]
    Dense,
    /// Compute tiles as engine jobs and consume them out-of-core with
    /// resident memory bounded by `byte_budget` (+ one tile).
    Tiled { tile_rows: usize, byte_budget: usize },
}

/// Read access to a symmetric pairwise distance matrix, independent of
/// how (or whether) it is materialized.
///
/// Contract: `dist(i, j) == dist(j, i)`, `dist(i, i) == 0.0`, and all
/// methods return identical f64 bits across backends for the same
/// underlying distances.  `row_stats`/`stream_row` must visit `j` in
/// ascending order so floating-point accumulation matches the dense
/// reference exactly.
pub trait DistSource: Send + Sync {
    /// Number of taxa (matrix side length).
    fn num_taxa(&self) -> usize;

    /// Distance between taxa `i` and `j` (fallible: tiled backends may
    /// touch disk).
    fn dist(&self, i: usize, j: usize) -> Result<f64>;

    /// Visit `(j, d(i, j))` for every `j != i`, in ascending `j` order.
    fn stream_row(&self, i: usize, f: &mut dyn FnMut(usize, f64)) -> Result<()> {
        for j in 0..self.num_taxa() {
            if j != i {
                f(j, self.dist(i, j)?);
            }
        }
        Ok(())
    }

    /// `(row_sums, row_mins)` over `j != i` — the NJ seed data, computed
    /// in one pass so a tiled backend reads each spilled tile once
    /// instead of once per row.  Sums are exact fixed-point
    /// ([`exact::RowSums`]) with a naive-f64 fallback, so every backend
    /// produces identical bits (see the module docs).
    fn row_stats(&self) -> Result<(Vec<f64>, Vec<f64>)> {
        let n = self.num_taxa();
        let mut sums = exact::RowSums::new(n);
        let mut mins = vec![f64::INFINITY; n];
        for i in 0..n {
            self.stream_row(i, &mut |_, v| {
                sums.add(i, v);
                mins[i] = mins[i].min(v);
            })?;
        }
        Ok((sums.finish(), mins))
    }

    /// Per-row minima (rapid-NJ seed caches); see [`row_stats`].
    ///
    /// [`row_stats`]: DistSource::row_stats
    fn row_mins(&self) -> Result<Vec<f64>> {
        Ok(self.row_stats()?.1)
    }
}

/// Borrowed dense f64 matrix as a [`DistSource`] (the single-node path).
pub struct DenseView<'a>(pub &'a [Vec<f64>]);

impl DistSource for DenseView<'_> {
    fn num_taxa(&self) -> usize {
        self.0.len()
    }

    fn dist(&self, i: usize, j: usize) -> Result<f64> {
        Ok(self.0[i][j])
    }
}

/// Borrowed dense f32 matrix (k-mer profile distances) as a
/// [`DistSource`]; `f32 -> f64` is exact and order-preserving, so
/// consumers see the same comparisons as raw-f32 code did.
pub struct DenseF32<'a>(pub &'a [Vec<f32>]);

impl DistSource for DenseF32<'_> {
    fn num_taxa(&self) -> usize {
        self.0.len()
    }

    fn dist(&self, i: usize, j: usize) -> Result<f64> {
        Ok(self.0[i][j] as f64)
    }
}

/// Tiled, byte-budgeted distance matrix: entries live in a [`TileStore`]
/// keyed by tile index (resident or spilled), planned by a [`TileGrid`].
/// Built by [`compute::distance_tiled`].
pub struct TiledDist {
    grid: TileGrid,
    store: Arc<TileStore>,
    /// Whether the producer also stored `(sum, min)` sidecar blobs under
    /// keys `num_tiles + t` (see [`exact::tile_sidecar`]).  Manually
    /// populated stores (tests) default to no sidecars and take the
    /// scan path in [`DistSource::row_stats`].
    has_sidecars: bool,
}

impl TiledDist {
    pub fn new(grid: TileGrid, store: Arc<TileStore>) -> Self {
        Self { grid, store, has_sidecars: false }
    }

    /// A tiled matrix whose store also holds per-tile sidecar blobs
    /// (written by [`compute::distance_tiled`]).
    pub fn with_sidecars(grid: TileGrid, store: Arc<TileStore>) -> Self {
        Self { grid, store, has_sidecars: true }
    }

    pub fn grid(&self) -> &TileGrid {
        &self.grid
    }

    /// Shared handle to the backing store — NJ reuses it (with keys
    /// offset past [`Self::row_key_base`]) for its merged-row working
    /// set so one byte budget governs the whole tree build.
    pub fn store_arc(&self) -> Arc<TileStore> {
        self.store.clone()
    }

    /// First store key free for consumers: tile blobs occupy
    /// `0..num_tiles` and sidecars (when present) the next `num_tiles`.
    pub fn row_key_base(&self) -> u64 {
        self.grid.num_tiles() as u64 * if self.has_sidecars { 2 } else { 1 }
    }

    pub fn peak_resident_bytes(&self) -> usize {
        self.store.peak_resident_bytes()
    }

    /// Fold the per-tile sidecars into `(sums, mins)` without touching
    /// any tile blob.  `None` when any sidecar is marked invalid or the
    /// exact fold overflows — callers fall back to the tile scan, which
    /// lands in the identical naive mode (global-validity argument in
    /// [`exact`]'s module docs).
    fn row_stats_from_sidecars(&self) -> Result<Option<(Vec<f64>, Vec<f64>)>> {
        let n = self.num_taxa();
        let num_tiles = self.grid.num_tiles();
        let mut sums = vec![0i128; n];
        let mut mins = vec![f64::INFINITY; n];
        for t in 0..num_tiles {
            let tile = self.grid.tile(t);
            let blob = self.store.get((num_tiles + t) as u64)?;
            let view = exact::decode_sidecar(&tile, &blob)?;
            if !view.valid {
                return Ok(None);
            }
            for (taxon, sum, min) in view.parts {
                match sums[taxon].checked_add(sum) {
                    Some(x) => sums[taxon] = x,
                    None => return Ok(None),
                }
                mins[taxon] = mins[taxon].min(min);
            }
        }
        Ok(Some((sums.into_iter().map(exact::fixed_to_f64).collect(), mins)))
    }
}

impl DistSource for TiledDist {
    fn num_taxa(&self) -> usize {
        self.grid.num_taxa()
    }

    fn dist(&self, i: usize, j: usize) -> Result<f64> {
        ensure!(i < self.num_taxa() && j < self.num_taxa(), "taxon out of range");
        if i == j {
            return Ok(0.0);
        }
        let (hi, lo) = if i > j { (i, j) } else { (j, i) };
        let tile = self.grid.tile(self.grid.tile_for(hi, lo));
        let data = self.store.get(tile.index as u64)?;
        Ok(data[tile.entry_offset(hi, lo)])
    }

    fn stream_row(&self, i: usize, f: &mut dyn FnMut(usize, f64)) -> Result<()> {
        ensure!(i < self.num_taxa(), "taxon out of range");
        let rb = self.grid.block_of(i);
        // j < end of i's block: row-side entries of tiles (rb, 0..=rb),
        // ascending cb = ascending j (the diagonal tile stores its full
        // rectangle, covering in-block j on both sides of i).
        for cb in 0..=rb {
            let tile = self.grid.tile(self.grid.tile_index(rb, cb));
            let data = self.store.get(tile.index as u64)?;
            for j in tile.col_lo..tile.col_hi {
                if j != i {
                    f(j, data[tile.entry_offset(i, j)]);
                }
            }
        }
        // j in later blocks: i is a *column* of tiles (rb2, rb),
        // ascending rb2 = ascending j.
        for rb2 in rb + 1..self.grid.num_row_blocks() {
            let tile = self.grid.tile(self.grid.tile_index(rb2, rb));
            let data = self.store.get(tile.index as u64)?;
            for j in tile.row_lo..tile.row_hi {
                f(j, data[tile.entry_offset(j, i)]);
            }
        }
        Ok(())
    }

    fn row_stats(&self) -> Result<(Vec<f64>, Vec<f64>)> {
        // Sidecar fast path: fold num_tiles tiny (sum, min) blobs — no
        // tile blob is faulted back through the byte budget.  Exact
        // fixed-point sums make the fold bit-identical to the dense
        // reference regardless of grouping.
        if self.has_sidecars {
            if let Some(stats) = self.row_stats_from_sidecars()? {
                return Ok(stats);
            }
        }
        // Scan path: one pass over tiles in index order.  For any row i
        // this visits its entries in ascending-j order (row-side tiles
        // (rb, cb) come in ascending cb, then column-side tiles
        // (rb2, rb) in ascending rb2), so the naive-fallback f64 row
        // sums match the dense reference bit for bit.
        let n = self.num_taxa();
        let mut sums = exact::RowSums::new(n);
        let mut mins = vec![f64::INFINITY; n];
        for t in 0..self.grid.num_tiles() {
            let tile = self.grid.tile(t);
            let data = self.store.get(t as u64)?;
            for i in tile.row_lo..tile.row_hi {
                for j in tile.col_lo..tile.col_hi {
                    if i == j {
                        continue;
                    }
                    let v = data[tile.entry_offset(i, j)];
                    sums.add(i, v);
                    mins[i] = mins[i].min(v);
                    if !tile.is_diagonal() {
                        // Cross tiles hold each pair once; credit the
                        // column row's mirror entry here.
                        sums.add(j, v);
                        mins[j] = mins[j].min(v);
                    }
                }
            }
        }
        Ok((sums.finish(), mins))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = crate::util::Rng::seed_from_u64(seed);
        let mut d = vec![vec![0f64; n]; n];
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.05 + rng.f64();
                d[i][j] = v;
                d[j][i] = v;
            }
        }
        d
    }

    fn tiled_from_dense(d: &[Vec<f64>], tile_rows: usize) -> TiledDist {
        let grid = TileGrid::new(d.len(), tile_rows);
        let store = Arc::new(TileStore::in_memory());
        for t in 0..grid.num_tiles() {
            let tile = grid.tile(t);
            let mut entries = Vec::with_capacity(tile.num_entries());
            for i in tile.row_lo..tile.row_hi {
                for j in tile.col_lo..tile.col_hi {
                    entries.push(d[i][j]);
                }
            }
            store.put(t as u64, entries).unwrap();
        }
        TiledDist::new(grid, store)
    }

    #[test]
    fn dense_view_basics() {
        let d = dense(6, 1);
        let v = DenseView(&d);
        assert_eq!(v.num_taxa(), 6);
        assert_eq!(v.dist(2, 5).unwrap(), d[2][5]);
        let (sums, mins) = v.row_stats().unwrap();
        let row: Vec<f64> = (0..6).filter(|&j| j != 3).map(|j| d[3][j]).collect();
        let want = exact::exact_sum(&row).unwrap();
        assert_eq!(sums[3].to_bits(), want.to_bits(), "exact row sum");
        let naive: f64 = row.iter().sum();
        assert!((sums[3] - naive).abs() < 1e-9, "within rounding of the naive sum");
        assert!(mins.iter().all(|m| m.is_finite()));
    }

    #[test]
    fn tiled_matches_dense_bitwise_across_tile_sizes() {
        let d = dense(17, 2);
        for tile_rows in [1usize, 2, 3, 5, 17, 100] {
            let t = tiled_from_dense(&d, tile_rows);
            let v = DenseView(&d);
            for i in 0..17 {
                for j in 0..17 {
                    assert_eq!(
                        t.dist(i, j).unwrap().to_bits(),
                        v.dist(i, j).unwrap().to_bits(),
                        "tile={tile_rows} ({i},{j})"
                    );
                }
            }
            let (ts, tm) = t.row_stats().unwrap();
            let (ds, dm) = v.row_stats().unwrap();
            for i in 0..17 {
                assert_eq!(ts[i].to_bits(), ds[i].to_bits(), "tile={tile_rows} sum row {i}");
                assert_eq!(tm[i].to_bits(), dm[i].to_bits(), "tile={tile_rows} min row {i}");
            }
        }
    }

    #[test]
    fn sidecar_fold_matches_dense_row_stats_bitwise() {
        let d = dense(13, 4);
        for tile_rows in [1usize, 2, 5, 13] {
            let grid = TileGrid::new(d.len(), tile_rows);
            let store = Arc::new(TileStore::in_memory());
            for t in 0..grid.num_tiles() {
                let tile = grid.tile(t);
                let mut entries = Vec::with_capacity(tile.num_entries());
                for i in tile.row_lo..tile.row_hi {
                    for j in tile.col_lo..tile.col_hi {
                        entries.push(d[i][j]);
                    }
                }
                store.put((grid.num_tiles() + t) as u64, exact::tile_sidecar(&tile, &entries)).unwrap();
                store.put(t as u64, entries).unwrap();
            }
            let td = TiledDist::with_sidecars(grid, store);
            assert_eq!(td.row_key_base(), 2 * td.grid().num_tiles() as u64);
            let (ts, tm) = td.row_stats().unwrap();
            let (ds, dm) = DenseView(&d).row_stats().unwrap();
            for i in 0..d.len() {
                assert_eq!(ts[i].to_bits(), ds[i].to_bits(), "tile={tile_rows} sum row {i}");
                assert_eq!(tm[i].to_bits(), dm[i].to_bits(), "tile={tile_rows} min row {i}");
            }
        }
    }

    #[test]
    fn stream_row_ascending_and_complete() {
        let d = dense(11, 3);
        let t = tiled_from_dense(&d, 4);
        for i in 0..11 {
            let mut seen = Vec::new();
            t.stream_row(i, &mut |j, v| seen.push((j, v))).unwrap();
            let want: Vec<(usize, f64)> =
                (0..11).filter(|&j| j != i).map(|j| (j, d[i][j])).collect();
            assert_eq!(seen, want, "row {i} must stream ascending and complete");
        }
    }
}

//! SparkSW (Zhao et al. 2015) emulation: Smith-Waterman on Spark, the
//! load-balanced but *unspecialized* design point — no trie acceleration
//! for similar sequences, no XLA batching, full O(mn) native DP per pair
//! against the center.  Works for both alphabets (the real SparkSW
//! targeted proteins; the paper notes it "cannot achieve peer performance
//! on nucleotide sequences").

use anyhow::{ensure, Context as _, Result};

use crate::align::pairwise::{
    center_space_profile, decode_ops, encode_ops, merge_profiles, render_query_row,
};
use crate::align::protein::native_pair_ops;
use crate::align::sw::SwParams;
use crate::align::MsaResult;
use crate::engine::{Cluster, ClusterConfig};
use crate::fasta::{alphabet::substitution_matrix, Sequence};

/// SparkSW-style center-star MSA on an in-memory engine; returns the MSA
/// and the engine (for stats).
pub fn sparksw_msa(workers: usize, seqs: &[Sequence], gap: f32) -> Result<(MsaResult, Cluster)> {
    ensure!(!seqs.is_empty(), "no sequences");
    let engine = Cluster::new(ClusterConfig::spark(workers));
    let alphabet = seqs[0].alphabet;
    let params =
        SwParams { subst: substitution_matrix(alphabet), alpha: alphabet.size(), gap };

    // Center: longest sequence (SparkSW aligns all against a reference).
    let center_index = (0..seqs.len()).max_by_key(|&i| seqs[i].len()).unwrap();
    let center = seqs[center_index].codes.clone();
    let center_len = center.len();
    let center_bc = engine.broadcast(center)?;
    let center_arc = center_bc.arc();

    let indexed: Vec<(u64, Sequence)> =
        seqs.iter().enumerate().map(|(i, s)| (i as u64, s.clone())).collect();
    // No inter-job caching: SparkSW is a pure pairwise-SW engine, not an
    // MSA system — wrapping it into center-star means each downstream
    // job re-derives the pairwise alignments (full-matrix DP again).
    // HAlign-II's cached/checkpointed paths are exactly the design
    // difference the paper credits for its speedup.
    let params_map = params.clone();
    let paths = engine
        .parallelize(indexed, engine.config().default_partitions)
        .map(move |(idx, s)| {
            // Full-matrix SW per pair — the cost SparkSW pays everywhere
            // (native_pair_ops fills the whole H matrix then globalizes
            // the local path).
            let ops = native_pair_ops(&s, &center_arc, &params_map);
            (idx, s, encode_ops(&ops))
        });

    let global = paths
        .map(move |(_, _, ops)| center_space_profile(&decode_ops(&ops), center_len))
        .reduce(|a, b| merge_profiles(a, &b))?
        .context("empty reduction")?;
    let global_bc = engine.broadcast(global.clone())?;
    let global_arc = global_bc.arc();
    let rows = paths.map(move |(idx, s, ops)| {
        let ops = decode_ops(&ops);
        let own = center_space_profile(&ops, center_len);
        let row = render_query_row(&s.codes, &ops, &global_arc, &own, s.alphabet);
        (idx, s.id, row)
    });
    let mut collected = rows.collect()?;
    collected.sort_by_key(|(i, _, _)| *i);

    let width = center_len + global.iter().sum::<u32>() as usize;
    let aligned = collected
        .into_iter()
        .map(|(_, id, row)| Sequence::new(id, row, alphabet))
        .collect();
    Ok((MsaResult { aligned, center_index, width }, engine))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;
    use crate::fasta::Alphabet;

    #[test]
    fn valid_protein_msa() {
        let seqs = DatasetSpec::protein(10, 0.1, 4).generate();
        let (msa, _) = sparksw_msa(2, &seqs, 5.0).unwrap();
        msa.validate(&seqs).unwrap();
    }

    #[test]
    fn works_on_dna_but_is_the_slow_path() {
        let seqs = DatasetSpec { count: 8, ..DatasetSpec::mito(0.005, 5) }.generate();
        let (msa, _) = sparksw_msa(2, &seqs, 6.0).unwrap();
        msa.validate(&seqs).unwrap();
        assert_eq!(msa.aligned[0].alphabet, Alphabet::Dna);
    }
}

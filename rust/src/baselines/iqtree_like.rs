//! IQ-TREE-like single-node maximum-likelihood tree search: NJ starting
//! tree, then rounds of nearest-neighbour-interchange (NNI) hill-climbing
//! scored by the JC69 log-likelihood of the full alignment.  Every NNI
//! candidate pays a full Felsenstein pass — the cost structure that makes
//! ML search the slow, accurate column of Table 5.

use anyhow::Result;

use crate::fasta::Sequence;
use crate::tree::distance::{jc_distance, pdistance_native};
use crate::tree::likelihood::log_likelihood;
use crate::tree::newick::Tree;
use crate::tree::nj::neighbor_joining;

#[derive(Debug, Clone)]
pub struct IqTreeConfig {
    /// Maximum NNI sweeps over all internal edges.
    pub max_rounds: usize,
    /// Stop when a full sweep improves logML by less than this.
    pub min_improvement: f64,
}

impl Default for IqTreeConfig {
    fn default() -> Self {
        Self { max_rounds: 4, min_improvement: 1e-3 }
    }
}

/// Result: tree + its logML + search statistics.
#[derive(Debug, Clone)]
pub struct MlSearchResult {
    pub tree: Tree,
    pub log_likelihood: f64,
    pub nni_accepted: usize,
    pub nni_evaluated: usize,
}

/// One NNI move: internal edge (parent u, child v with children a,b) and
/// sibling s of v; swapping s<->a (or s<->b) re-roots the quartet.
fn nni_candidates(tree: &Tree) -> Vec<(usize, usize, usize)> {
    // (v, child_of_v_to_swap, sibling s)
    let mut out = Vec::new();
    for (v, node) in tree.nodes.iter().enumerate() {
        if node.children.len() < 2 {
            continue;
        }
        let Some(u) = node.parent else { continue };
        for &s in &tree.nodes[u].children {
            if s == v {
                continue;
            }
            for &c in &node.children {
                out.push((v, c, s));
            }
        }
    }
    out
}

/// Apply the swap (child c of v exchanged with sibling s under v's
/// parent) on a clone.
fn apply_nni(tree: &Tree, v: usize, c: usize, s: usize) -> Tree {
    let mut t = tree.clone();
    let u = t.nodes[v].parent.unwrap();
    // c moves under u; s moves under v.
    t.nodes[v].children.retain(|&x| x != c);
    t.nodes[u].children.retain(|&x| x != s);
    t.nodes[v].children.push(s);
    t.nodes[u].children.push(c);
    t.nodes[c].parent = Some(u);
    t.nodes[s].parent = Some(v);
    t
}

/// Run the ML search over aligned rows.
pub fn iqtree_like_search(rows: &[Sequence], cfg: &IqTreeConfig) -> Result<MlSearchResult> {
    anyhow::ensure!(rows.len() >= 3, "ML search needs >= 3 taxa");
    // NJ start from JC-corrected p-distances.
    let p = pdistance_native(rows)?;
    let states = rows[0].alphabet.residues();
    let d: Vec<Vec<f64>> = p
        .iter()
        .map(|r| r.iter().map(|&x| jc_distance(x, states)).collect())
        .collect();
    let labels: Vec<String> = rows.iter().map(|r| r.id.clone()).collect();
    let mut tree = neighbor_joining(&labels, &d)?;
    let mut best_ll = log_likelihood(&tree, rows)?;

    let mut accepted = 0usize;
    let mut evaluated = 0usize;
    for _round in 0..cfg.max_rounds {
        let round_start = best_ll;
        for (v, c, s) in nni_candidates(&tree) {
            // Indices may be stale after an accepted move; re-validate.
            if v >= tree.nodes.len() || c >= tree.nodes.len() || s >= tree.nodes.len() {
                continue;
            }
            let pv = tree.nodes[v].parent;
            if pv.is_none()
                || !tree.nodes[v].children.contains(&c)
                || !tree.nodes[pv.unwrap()].children.contains(&s)
                || s == v
            {
                continue;
            }
            let candidate = apply_nni(&tree, v, c, s);
            if candidate.validate().is_err() {
                continue;
            }
            evaluated += 1;
            let ll = log_likelihood(&candidate, rows)?;
            if ll > best_ll + 1e-12 {
                best_ll = ll;
                tree = candidate;
                accepted += 1;
            }
        }
        if best_ll - round_start < cfg.min_improvement {
            break;
        }
    }
    Ok(MlSearchResult { tree, log_likelihood: best_ll, nni_accepted: accepted, nni_evaluated: evaluated })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::Alphabet;

    fn seqs(rows: &[(&str, &str)]) -> Vec<Sequence> {
        rows.iter()
            .map(|(id, t)| Sequence::from_text(*id, t, Alphabet::Dna))
            .collect()
    }

    #[test]
    fn search_never_decreases_likelihood() {
        let rows = seqs(&[
            ("a", "ACGTACGTACGTACGT"),
            ("b", "ACGTACGTACGTACGA"),
            ("c", "TGCATGCATGCATGCA"),
            ("d", "TGCATGCATGCATGCC"),
            ("e", "ACGTACGAACGTACGA"),
        ]);
        let p = pdistance_native(&rows).unwrap();
        let d: Vec<Vec<f64>> = p
            .iter()
            .map(|r| r.iter().map(|&x| jc_distance(x, 4)).collect())
            .collect();
        let labels: Vec<String> = rows.iter().map(|r| r.id.clone()).collect();
        let nj = neighbor_joining(&labels, &d).unwrap();
        let nj_ll = log_likelihood(&nj, &rows).unwrap();

        let result = iqtree_like_search(&rows, &IqTreeConfig::default()).unwrap();
        result.tree.validate().unwrap();
        assert!(result.log_likelihood >= nj_ll - 1e-9);
        assert_eq!(result.tree.num_leaves(), 5);
        assert!(result.nni_evaluated > 0);
    }

    #[test]
    fn recovers_obvious_pairs() {
        let rows = seqs(&[
            ("a1", "AAAAAAAACCCCCCCC"),
            ("a2", "AAAAAAAACCCCCCCG"),
            ("b1", "GGGGGGGGTTTTTTTT"),
            ("b2", "GGGGGGGGTTTTTTTA"),
        ]);
        let result = iqtree_like_search(&rows, &IqTreeConfig::default()).unwrap();
        let d_same = crate::tree::nj::tree_distance(&result.tree, "a1", "a2").unwrap();
        let d_cross = crate::tree::nj::tree_distance(&result.tree, "a1", "b1").unwrap();
        assert!(d_same < d_cross);
    }

    #[test]
    fn too_few_taxa_errors() {
        let rows = seqs(&[("a", "ACGT"), ("b", "ACGT")]);
        assert!(iqtree_like_search(&rows, &IqTreeConfig::default()).is_err());
    }
}

//! MUSCLE/MAFFT-like single-node progressive MSA.
//!
//! The classic recipe: alignment-free k-mer distances → UPGMA guide tree →
//! profile-profile Needleman-Wunsch merges up the tree.  More accurate
//! than center-star on divergent families (better avg SP), but:
//! an O(n²) distance matrix and O(L²·alpha) profile DP make it a single-
//! machine tool — the configurable [`ProgressiveConfig::memory_budget`]
//! reproduces the paper's observed behaviour that "MUSCLE ... eventually
//! reports an out-of-memory message with ultra-large datasets" (Tables
//! 2-4's `-` entries).

use anyhow::{bail, ensure, Result};

use crate::align::MsaResult;
use crate::fasta::{alphabet::substitution_matrix, Sequence};
use crate::tree::distance::{kmer_distance_native, kmer_profile};

#[derive(Debug, Clone)]
pub struct ProgressiveConfig {
    /// Simulated per-process memory budget in bytes; the run aborts with
    /// an OOM error when the distance matrix + working profiles exceed it
    /// (default 2 GiB — generous for 1x datasets, fatal at 100x, like the
    /// paper's single-node tools on 384 GB boxes at 100x file sizes).
    pub memory_budget: usize,
    pub gap: f32,
    pub k: usize,
    pub profile_dim: usize,
}

impl Default for ProgressiveConfig {
    fn default() -> Self {
        Self { memory_budget: 2 << 30, gap: 4.0, k: 4, profile_dim: 128 }
    }
}

/// Estimated resident bytes for `n` sequences of max length `lmax`.
pub fn estimated_bytes(n: usize, lmax: usize, alpha: usize, cfg: &ProgressiveConfig) -> usize {
    let matrix = n * n * 8;
    let profiles = n * cfg.profile_dim * 4;
    // Two working profile blocks + DP rows for the deepest merge.
    let blocks = 2 * n * lmax * 2; // rows held as u8 with gaps, double
    let dp = 3 * lmax * alpha * 8 + lmax * lmax; // freq rows + traceback bytes
    matrix + profiles + blocks + dp
}

/// A partial alignment block: equal-width gap-padded rows.
struct Block {
    rows: Vec<(usize, Vec<u8>)>, // (original index, row)
    width: usize,
}

/// Column frequency profile of a block (alpha+1 slots; last = gap).
fn block_profile(block: &Block, alpha: usize, gap: u8) -> Vec<f32> {
    let mut p = vec![0f32; block.width * (alpha + 1)];
    for (_, row) in &block.rows {
        for (c, &code) in row.iter().enumerate() {
            let slot = if code == gap { alpha } else { code as usize };
            p[c * (alpha + 1) + slot] += 1.0;
        }
    }
    let nrows = block.rows.len() as f32;
    p.iter_mut().for_each(|x| *x /= nrows);
    p
}

/// Profile-profile global DP: returns per-column ops (0 diag, 1 up = gap
/// in b, 2 left = gap in a).
fn profile_dp(
    pa: &[f32],
    wa: usize,
    pb: &[f32],
    wb: usize,
    subst: &[f32],
    alpha_full: usize,
    alpha: usize,
    gap_pen: f32,
) -> Vec<u8> {
    let score_col = |ca: usize, cb: usize| -> f32 {
        let a = &pa[ca * (alpha + 1)..(ca + 1) * (alpha + 1)];
        let b = &pb[cb * (alpha + 1)..(cb + 1) * (alpha + 1)];
        let mut s = 0f32;
        for (x, &fa) in a.iter().take(alpha).enumerate() {
            if fa == 0.0 {
                continue;
            }
            for (y, &fb) in b.iter().take(alpha).enumerate() {
                if fb == 0.0 {
                    continue;
                }
                s += fa * fb * subst[x * alpha_full + y];
            }
        }
        // Gap fractions pay a partial penalty against residues.
        s -= (a[alpha] * (1.0 - b[alpha]) + b[alpha] * (1.0 - a[alpha])) * gap_pen * 0.5;
        s
    };
    let w = wb + 1;
    let mut dp = vec![f32::NEG_INFINITY; (wa + 1) * w];
    let mut tb = vec![0u8; (wa + 1) * w];
    dp[0] = 0.0;
    for j in 1..=wb {
        dp[j] = dp[j - 1] - gap_pen;
        tb[j] = 2;
    }
    for i in 1..=wa {
        dp[i * w] = dp[(i - 1) * w] - gap_pen;
        tb[i * w] = 1;
        for j in 1..=wb {
            let diag = dp[(i - 1) * w + j - 1] + score_col(i - 1, j - 1);
            let up = dp[(i - 1) * w + j] - gap_pen;
            let left = dp[i * w + j - 1] - gap_pen;
            let (best, t) = if diag >= up && diag >= left {
                (diag, 0)
            } else if up >= left {
                (up, 1)
            } else {
                (left, 2)
            };
            dp[i * w + j] = best;
            tb[i * w + j] = t;
        }
    }
    let mut ops = Vec::with_capacity(wa + wb);
    let (mut i, mut j) = (wa, wb);
    while i > 0 || j > 0 {
        let t = tb[i * w + j];
        ops.push(t);
        match t {
            0 => {
                i -= 1;
                j -= 1;
            }
            1 => i -= 1,
            _ => j -= 1,
        }
    }
    ops.reverse();
    ops
}

/// Merge two blocks along a profile-DP path.
fn merge_blocks(a: Block, b: Block, ops: &[u8], gap: u8) -> Block {
    let width = ops.len();
    let mut rows = Vec::with_capacity(a.rows.len() + b.rows.len());
    for (idx, row) in &a.rows {
        let mut out = Vec::with_capacity(width);
        let mut c = 0usize;
        for &op in ops {
            match op {
                0 | 1 => {
                    out.push(row[c]);
                    c += 1;
                }
                _ => out.push(gap),
            }
        }
        rows.push((*idx, out));
    }
    for (idx, row) in &b.rows {
        let mut out = Vec::with_capacity(width);
        let mut c = 0usize;
        for &op in ops {
            match op {
                0 | 2 => {
                    out.push(row[c]);
                    c += 1;
                }
                _ => out.push(gap),
            }
        }
        rows.push((*idx, out));
    }
    Block { rows, width }
}

/// Single-node progressive MSA.
pub fn progressive_msa(seqs: &[Sequence], cfg: &ProgressiveConfig) -> Result<MsaResult> {
    ensure!(!seqs.is_empty(), "no sequences");
    let alphabet = seqs[0].alphabet;
    let alpha = alphabet.residues();
    let alpha_full = alphabet.size();
    let gap = alphabet.gap();
    let n = seqs.len();
    let lmax = seqs.iter().map(Sequence::len).max().unwrap();

    let need = estimated_bytes(n, lmax, alpha, cfg);
    if need > cfg.memory_budget {
        bail!(
            "simulated OOM: progressive alignment needs ~{} MB (> budget {} MB)",
            need >> 20,
            cfg.memory_budget >> 20
        );
    }

    // Guide order: UPGMA over k-mer distances.
    let profiles: Vec<Vec<f32>> = seqs
        .iter()
        .map(|s| kmer_profile(&s.codes, cfg.k, cfg.profile_dim, gap))
        .collect();
    let d = kmer_distance_native(&profiles);
    let mut dist: Vec<Vec<f64>> = d
        .iter()
        .map(|r| r.iter().map(|&x| x as f64).collect())
        .collect();

    let subst = substitution_matrix(alphabet);
    let mut blocks: Vec<Option<(Block, usize)>> = seqs
        .iter()
        .enumerate()
        .map(|(i, s)| Some((Block { rows: vec![(i, s.codes.clone())], width: s.len() }, 1usize)))
        .collect();
    let mut active: Vec<usize> = (0..n).collect();

    while active.len() > 1 {
        // Closest pair (UPGMA / average linkage).
        let (mut bi, mut bj, mut best) = (active[0], active[1], f64::INFINITY);
        for (x, &i) in active.iter().enumerate() {
            for &j in active.iter().skip(x + 1) {
                if dist[i][j] < best {
                    best = dist[i][j];
                    bi = i;
                    bj = j;
                }
            }
        }
        let (block_a, na) = blocks[bi].take().unwrap();
        let (block_b, nb) = blocks[bj].take().unwrap();
        let pa = block_profile(&block_a, alpha, gap);
        let pb = block_profile(&block_b, alpha, gap);
        let ops = profile_dp(
            &pa,
            block_a.width,
            &pb,
            block_b.width,
            &subst,
            alpha_full,
            alpha,
            cfg.gap,
        );
        let merged = merge_blocks(block_a, block_b, &ops, gap);
        // Average-linkage distance update into slot bi.
        for &k in &active {
            if k != bi && k != bj {
                let v = (dist[bi][k] * na as f64 + dist[bj][k] * nb as f64)
                    / (na + nb) as f64;
                dist[bi][k] = v;
                dist[k][bi] = v;
            }
        }
        blocks[bi] = Some((merged, na + nb));
        active.retain(|&k| k != bj);
    }

    let (final_block, _) = blocks[active[0]].take().unwrap();
    let width = final_block.width;
    let mut rows = final_block.rows;
    rows.sort_by_key(|(i, _)| *i);
    let aligned = rows
        .into_iter()
        .map(|(i, row)| Sequence::new(seqs[i].id.clone(), row, alphabet))
        .collect();
    Ok(MsaResult { aligned, center_index: 0, width })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::Alphabet;
    use crate::data::DatasetSpec;

    #[test]
    fn aligns_small_protein_family() {
        let seqs = DatasetSpec::protein(10, 0.12, 3).generate();
        let msa = progressive_msa(&seqs, &ProgressiveConfig::default()).unwrap();
        msa.validate(&seqs).unwrap();
    }

    #[test]
    fn oom_budget_aborts_large_inputs() {
        let seqs = DatasetSpec::protein(40, 0.1, 4).generate();
        let cfg = ProgressiveConfig { memory_budget: 1 << 16, ..Default::default() };
        let err = progressive_msa(&seqs, &cfg).unwrap_err();
        assert!(format!("{err}").contains("OOM"), "{err}");
    }

    #[test]
    fn more_accurate_than_center_star_on_divergent_rna() {
        use crate::align::center_star::{align_nucleotide, CenterStarConfig};
        use crate::engine::{Cluster, ClusterConfig};
        let seqs = DatasetSpec::rrna(16, 0.15, 6).generate();
        let prog = progressive_msa(&seqs, &ProgressiveConfig::default()).unwrap();
        let engine = Cluster::new(ClusterConfig::spark(2));
        let cs = align_nucleotide(
            &engine,
            &seqs,
            &CenterStarConfig { segment_len: 10, ..Default::default() },
        )
        .unwrap();
        prog.validate(&seqs).unwrap();
        let sp_prog = prog.avg_sp().unwrap();
        let sp_cs = cs.avg_sp().unwrap();
        // The paper's Table 3 shape: the accurate single-node tool beats
        // center-star on avg SP (lower penalty), at much higher cost.
        assert!(
            sp_prog < sp_cs * 1.25,
            "progressive ({sp_prog:.1}) should be competitive with center-star ({sp_cs:.1})"
        );
    }

    #[test]
    fn identical_sequences_trivial() {
        let seqs = vec![
            Sequence::from_text("a", "MKVLAT", Alphabet::Protein),
            Sequence::from_text("b", "MKVLAT", Alphabet::Protein),
        ];
        let msa = progressive_msa(&seqs, &ProgressiveConfig::default()).unwrap();
        assert_eq!(msa.width, 6);
        assert_eq!(msa.avg_sp().unwrap(), 0.0);
    }
}

//! Comparator implementations for the paper's evaluation (DESIGN.md §3):
//!
//! * [`halign_v1`]   — HAlign (Hadoop): the same center-star code path on
//!                     the DiskKv engine — every stage boundary pays the
//!                     serialize/spill/read tax.
//! * [`sparksw`]     — SparkSW: Smith-Waterman-only center star on the
//!                     in-memory engine, no trie, per-pair full-matrix
//!                     native DP (no XLA batching), no map-side combine.
//! * [`progressive`] — MUSCLE/MAFFT-like single-node progressive MSA
//!                     (k-mer guide tree + profile-profile alignment)
//!                     with a memory budget that aborts like the paper's
//!                     observed OOMs.
//! * [`iqtree_like`] — single-node ML tree search (NJ start + NNI
//!                     hill-climbing under JC69).
//! * HPTree           — the paper's Hadoop tree pipeline: reuse
//!                     [`crate::tree::build_tree`] on a DiskKv engine
//!                     (see [`hptree_build`]).

pub mod halign_v1;
pub mod iqtree_like;
pub mod progressive;
pub mod sparksw;

use anyhow::Result;

use crate::engine::{Cluster, ClusterConfig};
use crate::fasta::Sequence;
use crate::tree::{TreeConfig, TreeResult};

/// HPTree emulation: the clustered-NJ pipeline on a Hadoop-style engine.
/// (HPTree predates HAlign-II and does not support proteins — Table 5's
/// "not supported" entries.)
pub fn hptree_build(
    workers: usize,
    rows: &[Sequence],
    cfg: &TreeConfig,
) -> Result<(TreeResult, Cluster)> {
    anyhow::ensure!(
        rows[0].alphabet == crate::fasta::Alphabet::Dna,
        "HPTree does not support protein sequences"
    );
    let engine = Cluster::new(ClusterConfig::hadoop(workers));
    let result = crate::tree::build_tree(&engine, rows, None, cfg)?;
    Ok((result, engine))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::center_star::{align_nucleotide, CenterStarConfig};
    use crate::data::DatasetSpec;
    use crate::engine::{Cluster, ClusterConfig};

    #[test]
    fn hptree_runs_on_hadoop_engine_and_rejects_proteins() {
        let seqs = DatasetSpec { count: 12, ..DatasetSpec::mito(0.01, 3) }.generate();
        let engine = Cluster::new(ClusterConfig::spark(2));
        let msa = align_nucleotide(&engine, &seqs, &CenterStarConfig::default()).unwrap();
        let (result, hadoop) = hptree_build(2, &msa.aligned, &TreeConfig::default()).unwrap();
        assert_eq!(result.tree.num_leaves(), 12);
        assert!(
            hadoop.stats().shuffle_bytes_written > 0 || hadoop.stats().shuffle_bytes_read > 0,
            "hadoop engine must touch disk"
        );

        let prots = DatasetSpec::protein(4, 0.1, 1).generate();
        assert!(hptree_build(2, &prots, &TreeConfig::default()).is_err());
    }
}

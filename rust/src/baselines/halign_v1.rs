//! HAlign v1 (Zou et al. 2015) emulation: the identical trie-accelerated
//! center-star algorithm, but executed the Hadoop way — DiskKv shuffle
//! backend, so the inter-job data (edit paths) round-trips through
//! serialized spill files, and broadcasts go through the distributed
//! cache.  The *algorithmic* work is shared with
//! [`crate::align::center_star`]; only the engine configuration differs,
//! which is precisely the paper's claim about where HAlign v1 loses time
//! and memory.

use anyhow::Result;

use crate::align::center_star::{align_nucleotide, CenterStarConfig};
use crate::align::MsaResult;
use crate::engine::{Cluster, ClusterConfig};
use crate::fasta::Sequence;

/// Run HAlign-v1-style MSA: returns the result plus the Hadoop engine so
/// callers can read its time/memory/IO stats.
pub fn halign_v1_msa(
    workers: usize,
    seqs: &[Sequence],
    cfg: &CenterStarConfig,
) -> Result<(MsaResult, Cluster)> {
    let engine = Cluster::new(ClusterConfig::hadoop(workers));
    let msa = align_nucleotide(&engine, seqs, cfg)?;
    Ok((msa, engine))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetSpec;

    #[test]
    fn produces_identical_msa_to_spark_mode() {
        let seqs = DatasetSpec { count: 16, ..DatasetSpec::mito(0.01, 7) }.generate();
        let cfg = CenterStarConfig::default();
        let (hadoop_msa, hadoop_engine) = halign_v1_msa(3, &seqs, &cfg).unwrap();
        let spark_engine = Cluster::new(ClusterConfig::spark(3));
        let spark_msa = align_nucleotide(&spark_engine, &seqs, &cfg).unwrap();
        assert_eq!(hadoop_msa.width, spark_msa.width);
        for (a, b) in hadoop_msa.aligned.iter().zip(&spark_msa.aligned) {
            assert_eq!(a.codes, b.codes);
        }
        // The point of the baseline: it hits disk where Spark does not.
        assert!(hadoop_engine.stats().shuffle_bytes_written > 0);
        assert_eq!(spark_engine.stats().shuffle_bytes_written, 0);
    }
}

//! Parsers for the markdown files `pallas-lint` treats as config:
//! `rust/LOCKS.md` (the declared lock hierarchy, the helper functions
//! that acquire or return locks, and the atomics that pair with the
//! executor's wake-epoch condvar) and `rust/OBSERVABILITY.md` (the
//! declared metric family names, rule W8).
//!
//! Both files are ordinary markdown; `pallas-lint` only reads specific
//! sections.  From `LOCKS.md`, three (matched case-insensitively on
//! their headings):
//!
//! * a heading containing **"hierarchy"**: numbered list items whose
//!   first backticked token is a lock name, outermost first
//!   (`1. \`kill_lock\` — …`);
//! * a heading containing **"helper"**: bullet items of the form
//!   `- \`name\` returns \`lock\`` (the call yields a guard the caller
//!   holds) or `- \`name\` acquires \`lock\`` (the lock is taken and
//!   released inside the call);
//! * a heading containing **"atomic"**: bullet items naming the
//!   condvar-paired atomics (`- \`shutdown\` — …`).
//!
//! From `OBSERVABILITY.md`, one: a heading containing **"famil"**
//! (e.g. *Metric families*), whose table rows / bullet items declare
//! one backticked family name each (`| \`halign_tasks_run_total\` | …`).
//!
//! Unknown lines are ignored, so the prose around the lists can grow
//! freely without breaking the parsers.

/// How a declared helper interacts with its lock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HelperKind {
    /// The helper returns a `MutexGuard` the caller goes on holding.
    ReturnsGuard,
    /// The helper locks and unlocks internally; calling it while holding
    /// another lock still creates an ordering edge.
    AcquiresInternally,
}

/// One declared helper function.
#[derive(Debug, Clone)]
pub struct HelperLock {
    pub name: String,
    pub lock: String,
    pub kind: HelperKind,
}

/// Parsed `LOCKS.md` contents.
#[derive(Debug, Clone, Default)]
pub struct LintConfig {
    /// Lock names, outermost first.  Index = rank; lower rank must be
    /// acquired first.
    pub hierarchy: Vec<String>,
    pub helpers: Vec<HelperLock>,
    /// Atomics that participate in the executor sleep/wake handshake;
    /// `Ordering::Relaxed` on these is rule W5.
    pub condvar_atomics: Vec<String>,
    /// Metric family names declared in `rust/OBSERVABILITY.md`;
    /// registering an undeclared (or duplicate) family is rule W8.
    /// Empty when the file is absent, which leaves W8 inert.
    pub metric_names: Vec<String>,
    /// Bench scenarios with committed baselines at the repo root:
    /// `(scenario, declared keys)` parsed from each
    /// `BENCH_<scenario>.baseline.json`.  A `write_bench_json` call
    /// whose scenario or keys are undeclared is rule W9.  Empty when no
    /// baselines exist, which leaves W9 inert.
    pub bench_baseline_keys: Vec<(String, Vec<String>)>,
}

#[derive(Clone, Copy, PartialEq)]
enum Section {
    None,
    Hierarchy,
    Helpers,
    Atomics,
}

impl LintConfig {
    /// Rank of a lock name in the hierarchy, if declared.
    pub fn rank(&self, name: &str) -> Option<usize> {
        self.hierarchy.iter().position(|h| h == name)
    }

    pub fn helper(&self, name: &str) -> Option<&HelperLock> {
        self.helpers.iter().find(|h| h.name == name)
    }

    /// Parse the markdown text of `LOCKS.md`.
    pub fn parse_locks_md(text: &str) -> LintConfig {
        let mut cfg = LintConfig::default();
        let mut section = Section::None;
        for line in text.lines() {
            let trimmed = line.trim();
            if trimmed.starts_with('#') {
                let lower = trimmed.to_ascii_lowercase();
                section = if lower.contains("hierarchy") {
                    Section::Hierarchy
                } else if lower.contains("helper") {
                    Section::Helpers
                } else if lower.contains("atomic") {
                    Section::Atomics
                } else {
                    Section::None
                };
                continue;
            }
            match section {
                Section::Hierarchy => {
                    if starts_with_number_dot(trimmed) {
                        if let Some(name) = first_backticked(trimmed) {
                            cfg.hierarchy.push(name);
                        }
                    }
                }
                Section::Helpers => {
                    if trimmed.starts_with('-') {
                        let ticks = all_backticked(trimmed);
                        if ticks.len() >= 2 {
                            let kind = if trimmed.contains(" returns ") {
                                Some(HelperKind::ReturnsGuard)
                            } else if trimmed.contains(" acquires ") {
                                Some(HelperKind::AcquiresInternally)
                            } else {
                                None
                            };
                            if let Some(kind) = kind {
                                cfg.helpers.push(HelperLock {
                                    name: ticks[0].clone(),
                                    lock: ticks[1].clone(),
                                    kind,
                                });
                            }
                        }
                    }
                }
                Section::Atomics => {
                    if trimmed.starts_with('-') {
                        if let Some(name) = first_backticked(trimmed) {
                            cfg.condvar_atomics.push(name);
                        }
                    }
                }
                Section::None => {}
            }
        }
        cfg
    }

    /// Parse the markdown text of `rust/OBSERVABILITY.md` into the list
    /// of declared metric family names.  Only sections whose heading
    /// contains "famil" (case-insensitive) are read; inside one, every
    /// table row (`| \`name\` | …`) or bullet (`- \`name\` — …`) whose
    /// first backticked token exists declares a family.  Header and
    /// separator rows carry no backticks and are skipped naturally.
    pub fn parse_observability_md(text: &str) -> Vec<String> {
        let mut names = Vec::new();
        let mut in_families = false;
        for line in text.lines() {
            let trimmed = line.trim();
            if trimmed.starts_with('#') {
                in_families = trimmed.to_ascii_lowercase().contains("famil");
                continue;
            }
            if in_families && (trimmed.starts_with('|') || trimmed.starts_with('-')) {
                if let Some(name) = first_backticked(trimmed) {
                    if !names.contains(&name) {
                        names.push(name);
                    }
                }
            }
        }
        names
    }

    /// Extract the top-level keys of a `BENCH_<scenario>.baseline.json`
    /// file.  The scan is lexical, not a JSON parse: every quoted
    /// string directly followed (after whitespace) by a `:` is a key.
    /// That is exact for the flat objects the baselines are — and for
    /// W9's purpose a nested key is still a declared key.
    pub fn parse_bench_baseline(text: &str) -> Vec<String> {
        let bytes = text.as_bytes();
        let mut keys: Vec<String> = Vec::new();
        let mut i = 0usize;
        while i < bytes.len() {
            if bytes[i] != b'"' {
                i += 1;
                continue;
            }
            let start = i + 1;
            let mut j = start;
            while j < bytes.len() && bytes[j] != b'"' {
                if bytes[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            if j >= bytes.len() {
                break;
            }
            let lit = &text[start..j];
            let mut k = j + 1;
            while k < bytes.len() && (bytes[k] as char).is_ascii_whitespace() {
                k += 1;
            }
            if k < bytes.len() && bytes[k] == b':' && !keys.iter().any(|s| s == lit) {
                keys.push(lit.to_string());
            }
            i = j + 1;
        }
        keys
    }
}

fn starts_with_number_dot(s: &str) -> bool {
    let digits: String = s.chars().take_while(|c| c.is_ascii_digit()).collect();
    !digits.is_empty() && s[digits.len()..].starts_with('.')
}

fn first_backticked(s: &str) -> Option<String> {
    all_backticked(s).into_iter().next()
}

fn all_backticked(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = s;
    while let Some(open) = rest.find('`') {
        let after = &rest[open + 1..];
        match after.find('`') {
            Some(close) => {
                out.push(after[..close].to_string());
                rest = &after[close + 1..];
            }
            None => break,
        }
    }
    out
}

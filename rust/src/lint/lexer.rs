//! Lexer-level scrubber: the first stage of every `pallas-lint` rule.
//!
//! Rules must never fire on text inside string literals or comments (a
//! doc comment *describing* the old `EPS` bug is not a finding), and
//! suppressions live *in* comments — so the scrubber walks the source
//! once, byte by byte, and produces:
//!
//! * a **scrubbed** copy of the source, byte-for-byte the same length,
//!   with the contents of every comment and string/char literal blanked
//!   to spaces (newlines preserved, so byte offsets and line numbers are
//!   identical to the original);
//! * the list of comments (for `// lint: allow(...)` parsing);
//! * the list of string literals with their raw (escapes-unexpanded)
//!   contents (for the metrics-arity rule, which counts `\t` columns and
//!   `{}` placeholders as written in the source).
//!
//! Handled syntax: line comments, nested block comments, `"…"` /
//! `b"…"` strings with escapes, raw strings `r"…"` / `br#"…"#` with any
//! hash depth, char and byte-char literals, and lifetimes (`'a` is not a
//! char literal).  This is the same no-external-deps discipline as
//! `server/http.rs`: a small exact scanner instead of a parser crate.

/// One comment in the source (either form), with its 1-based start line
/// and the raw text *after* the comment opener, trimmed.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// One string literal: 1-based line, byte offset of its opening quote in
/// the (scrubbed or original) source, and the raw contents between the
/// quotes with escape sequences left unexpanded (`\t` is two bytes).
#[derive(Debug, Clone)]
pub struct StrLit {
    pub line: usize,
    pub offset: usize,
    pub raw: String,
}

/// Scrubber output: see module docs.
#[derive(Debug)]
pub struct Scrubbed {
    pub text: String,
    pub comments: Vec<Comment>,
    pub strings: Vec<StrLit>,
    /// Byte offset of the first byte of each line (line N is index N-1).
    pub line_starts: Vec<usize>,
}

impl Scrubbed {
    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i, // offset is inside line i (1-based)
        }
    }
}

pub(crate) fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Scrub `source` (see module docs).  Operates on bytes; multi-byte
/// UTF-8 sequences inside comments/strings blank to one space per byte,
/// which keeps every offset stable.
pub fn scrub(source: &str) -> Scrubbed {
    let src = source.as_bytes();
    let mut out = src.to_vec();
    let mut comments = Vec::new();
    let mut strings = Vec::new();

    let blank = |out: &mut [u8], from: usize, to: usize| {
        for b in out.iter_mut().take(to).skip(from) {
            if *b != b'\n' {
                *b = b' ';
            }
        }
    };

    let mut i = 0usize;
    let n = src.len();
    while i < n {
        let b = src[i];
        // Line comment.
        if b == b'/' && i + 1 < n && src[i + 1] == b'/' {
            let start = i;
            while i < n && src[i] != b'\n' {
                i += 1;
            }
            let text = source[start + 2..i].trim().to_string();
            comments.push(Comment { line: line_at(src, start), text });
            blank(&mut out, start, i);
            continue;
        }
        // Block comment (nesting).
        if b == b'/' && i + 1 < n && src[i + 1] == b'*' {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if src[i] == b'/' && i + 1 < n && src[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if src[i] == b'*' && i + 1 < n && src[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            let inner_end = i.saturating_sub(2).max(start + 2);
            let text = source[start + 2..inner_end].trim().to_string();
            comments.push(Comment { line: line_at(src, start), text });
            blank(&mut out, start, i);
            continue;
        }
        // Raw strings: r"…", r#"…"#, br#"…"# etc.
        if (b == b'r' || b == b'b') && !prev_is_ident(src, i) {
            if let Some((open_quote, hashes)) = raw_string_open(src, i) {
                let start = i;
                let body_start = open_quote + 1;
                let mut j = body_start;
                let closer_len = 1 + hashes;
                loop {
                    if j >= n {
                        break; // unterminated: blank to EOF
                    }
                    if src[j] == b'"' && has_hashes(src, j + 1, hashes) {
                        break;
                    }
                    j += 1;
                }
                let body_end = j.min(n);
                strings.push(StrLit {
                    line: line_at(src, start),
                    offset: start,
                    raw: source[body_start..body_end].to_string(),
                });
                let end = (body_end + closer_len).min(n);
                // Keep the delimiting quotes so scans still see a
                // string boundary; blank everything else.
                blank(&mut out, start, end);
                out[open_quote] = b'"';
                if body_end < n {
                    out[body_end] = b'"';
                }
                i = end;
                continue;
            }
        }
        // Normal strings: "…" and b"…".
        if b == b'"' || (b == b'b' && i + 1 < n && src[i + 1] == b'"' && !prev_is_ident(src, i)) {
            let start = i;
            let quote = if b == b'"' { i } else { i + 1 };
            let mut j = quote + 1;
            while j < n {
                match src[j] {
                    b'\\' => j += 2,
                    b'"' => break,
                    _ => j += 1,
                }
            }
            let body_end = j.min(n);
            strings.push(StrLit {
                line: line_at(src, start),
                offset: start,
                raw: source[(quote + 1).min(n)..body_end].to_string(),
            });
            let end = (body_end + 1).min(n);
            blank(&mut out, start, end);
            out[quote] = b'"';
            if body_end < n {
                out[body_end] = b'"';
            }
            i = end;
            continue;
        }
        // Char / byte-char literal vs lifetime.
        if b == b'\'' || (b == b'b' && i + 1 < n && src[i + 1] == b'\'' && !prev_is_ident(src, i))
        {
            let quote = if b == b'\'' { i } else { i + 1 };
            if b == b'\'' && looks_like_lifetime(src, quote) {
                i += 1;
                continue;
            }
            let start = i;
            let mut j = quote + 1;
            if j < n && src[j] == b'\\' {
                j += 2; // escape + escaped byte
                while j < n && src[j] != b'\'' {
                    j += 1; // \u{…} and friends
                }
            } else {
                // One UTF-8 scalar: advance to the closing quote.
                j += 1;
                while j < n && src[j] != b'\'' && j - quote < 6 {
                    j += 1;
                }
            }
            let end = (j + 1).min(n);
            blank(&mut out, start, end);
            i = end;
            continue;
        }
        i += 1;
    }

    let text = String::from_utf8(out).unwrap_or_else(|e| {
        // Only comment/string bytes were rewritten (to ASCII spaces), so
        // this cannot happen on valid UTF-8 input; degrade lossily
        // rather than abort the whole lint run.
        String::from_utf8_lossy(e.as_bytes()).into_owned()
    });
    let mut line_starts = vec![0usize];
    for (pos, byte) in text.bytes().enumerate() {
        if byte == b'\n' {
            line_starts.push(pos + 1);
        }
    }
    Scrubbed { text, comments, strings, line_starts }
}

fn prev_is_ident(src: &[u8], i: usize) -> bool {
    i > 0 && is_ident(src[i - 1])
}

fn line_at(src: &[u8], offset: usize) -> usize {
    1 + src[..offset].iter().filter(|&&b| b == b'\n').count()
}

/// If `i` starts a raw-string opener (`r`/`br` + hashes + quote), return
/// (offset of the opening quote, hash count).
fn raw_string_open(src: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if src[j] == b'b' {
        j += 1;
    }
    if j >= src.len() || src[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < src.len() && src[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j < src.len() && src[j] == b'"' {
        Some((j, hashes))
    } else {
        None
    }
}

fn has_hashes(src: &[u8], from: usize, hashes: usize) -> bool {
    if from + hashes > src.len() {
        return false;
    }
    src[from..from + hashes].iter().all(|&b| b == b'#')
}

/// `'ident` not followed by a closing quote is a lifetime, not a char.
fn looks_like_lifetime(src: &[u8], quote: usize) -> bool {
    let mut j = quote + 1;
    if j >= src.len() || !(src[j].is_ascii_alphabetic() || src[j] == b'_') {
        return false;
    }
    while j < src.len() && is_ident(src[j]) {
        j += 1;
    }
    // 'a' is a char; 'a followed by anything else is a lifetime.
    !(j < src.len() && src[j] == b'\'' && j == quote + 2)
}

/// Per-line `#[cfg(test)]` coverage: true for every line inside a
/// `#[cfg(test)]`-gated item, statement, or field.  The region runs from
/// the attribute to the end of the next balanced `{…}` block, or to the
/// first `;`/`,` at bracket depth zero when the gated thing has no block
/// (a field, a `type` alias, a struct-literal field).
pub fn test_line_mask(scrubbed: &Scrubbed) -> Vec<bool> {
    let text = scrubbed.text.as_bytes();
    let num_lines = scrubbed.line_starts.len();
    let mut mask = vec![false; num_lines];
    let needle = b"#[cfg(test)]";
    let mut i = 0usize;
    while let Some(pos) = find_from(text, needle, i) {
        let start_line = scrubbed.line_of(pos);
        let mut j = pos + needle.len();
        // Skip whitespace and any further attributes.
        loop {
            while j < text.len() && (text[j] as char).is_whitespace() {
                j += 1;
            }
            if j + 1 < text.len() && text[j] == b'#' && text[j + 1] == b'[' {
                let mut depth = 0i32;
                while j < text.len() {
                    match text[j] {
                        b'[' => depth += 1,
                        b']' => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
            } else {
                break;
            }
        }
        // Scan to the item's end.
        let mut depth = 0i32;
        let mut saw_brace = false;
        while j < text.len() {
            match text[j] {
                b'{' | b'(' | b'[' => {
                    if text[j] == b'{' {
                        saw_brace = true;
                    }
                    depth += 1;
                }
                b'}' | b')' | b']' => {
                    depth -= 1;
                    if depth == 0 && text[j] == b'}' && saw_brace {
                        break;
                    }
                }
                b';' | b',' if depth == 0 && !saw_brace => break,
                _ => {}
            }
            j += 1;
        }
        let end_line = scrubbed.line_of(j.min(text.len().saturating_sub(1)));
        for line in start_line..=end_line.min(num_lines) {
            mask[line - 1] = true;
        }
        i = j.max(pos + 1);
    }
    mask
}

/// First occurrence of `needle` in `hay` at or after `from`.
pub fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if needle.is_empty() || from >= hay.len() || hay.len() - from < needle.len() {
        return None;
    }
    let last = hay.len() - needle.len();
    (from..=last).find(|&i| &hay[i..i + needle.len()] == needle)
}

//! `pallas-lint` — project-native static analysis for the invariants
//! the compiler and clippy cannot see.
//!
//! Every PR so far has fixed a *class* of bug by hand: worker panics
//! that defeat fault recovery, spill I/O performed while holding the
//! `TileStore` mutex, float-EPS traceback drift, TSV header/row arity
//! skew.  This module is the gate that keeps those classes from coming
//! back.  It is deliberately dependency-free (the `server/http.rs`
//! discipline): a byte-level scrubber ([`lexer`]), a markdown config
//! parser for the declared lock hierarchy ([`config`]), per-rule
//! lexical passes ([`rules`]), and a hand-rolled JSON report
//! ([`report`]).
//!
//! Rules (details and rationale in `rust/LINTS.md`):
//!
//! | rule | key                | what it catches |
//! |------|--------------------|-----------------|
//! | W1   | `panic`            | `.unwrap()`/`.expect(`/`panic!` in worker-reachable code (`engine/`, `distmat/`, `server/`) |
//! | W2   | `lock-across-io`   | a `MutexGuard` binding live across `fs::`/`File::`/`write_atomic`/`TcpStream` calls |
//! | W3   | `lock-order`       | nested `lock()` against the hierarchy declared in `rust/LOCKS.md` |
//! | W4   | `float-tolerance`  | `EPS`/`.abs() <` comparisons in `align/` outside tests |
//! | W5   | `relaxed-handshake`| `Ordering::Relaxed` on the condvar-paired executor atomics |
//! | W6   | `metrics-arity`    | TSV row-writer field count vs header column count |
//! | W7   | `cache-atomic-write`| direct `fs::write`/`fs::rename`/`File::create`/`OpenOptions` in `cache/` bypassing `write_atomic` |
//! | W8   | `metric-name-registry` | metric families registered with names undeclared in `rust/OBSERVABILITY.md`, non-snake_case, or registered twice |
//! | W9   | `bench-json-schema`    | `write_bench_json` calls whose scenario lacks a committed `BENCH_<scenario>.baseline.json` or whose keys are undeclared in it |
//!
//! Suppression: `// lint: allow(<key>) <reason>` on the offending line
//! or the line above.  A missing reason is itself a finding (W0), so
//! every escape hatch in the tree carries its justification.
//!
//! The binary front-end is `src/bin/pallas_lint.rs`
//! (`cargo run --bin pallas_lint -- --deny`); CI runs it as a required
//! step and archives `LINT_REPORT.json`.

pub mod config;
pub mod lexer;
pub mod report;
pub mod rules;

pub use config::LintConfig;
pub use report::{Finding, Report, Rule};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// One parsed `// lint: allow(key) reason` comment.
struct Allow {
    /// Inclusive line range the suppression applies to: the comment's
    /// own line when it trails code, otherwise the next code line
    /// through the end of that statement (so a multi-line builder chain
    /// is covered by one comment above it).
    first_line: usize,
    last_line: usize,
    key: String,
    reason: String,
}

/// Lint a single file's source text.  `path` is only used for scoping
/// (W1/W4 look at directory components) and for finding output; it does
/// not need to exist on disk — fixture tests pass synthetic paths like
/// `rust/src/engine/fixture.rs`.
pub fn lint_source(path: &str, source: &str, cfg: &LintConfig) -> Vec<Finding> {
    let scrubbed = lexer::scrub(source);
    let test_mask = lexer::test_line_mask(&scrubbed);
    let ctx = rules::FileContext { path, scrubbed: &scrubbed, test_mask: &test_mask, cfg };
    let mut findings = rules::run_all(&ctx);

    let (allows, mut syntax_findings) = collect_allows(path, &scrubbed);
    for f in &mut findings {
        let covered = allows.iter().find(|a| {
            a.key == f.rule.allow_key() && (a.first_line..=a.last_line).contains(&f.line)
        });
        if let Some(a) = covered {
            f.suppressed = true;
            f.allow_reason = Some(a.reason.clone());
        }
    }
    findings.append(&mut syntax_findings);
    findings.sort_by(|a, b| (a.line, a.rule.id()).cmp(&(b.line, b.rule.id())));
    findings
}

/// Parse every `lint: allow(...)` comment; malformed ones (unknown key,
/// missing reason) become W0 findings that cannot themselves be
/// suppressed.
fn collect_allows(path: &str, scrubbed: &lexer::Scrubbed) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut findings = Vec::new();
    for c in &scrubbed.comments {
        let Some(rest) = c.text.strip_prefix("lint:") else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix("allow(") else {
            findings.push(Finding::new(
                path,
                c.line,
                Rule::AllowSyntax,
                "malformed lint comment; expected `lint: allow(<key>) <reason>`".to_string(),
            ));
            continue;
        };
        let Some(close) = rest.find(')') else {
            findings.push(Finding::new(
                path,
                c.line,
                Rule::AllowSyntax,
                "unclosed `lint: allow(` comment".to_string(),
            ));
            continue;
        };
        let key = rest[..close].trim().to_string();
        let reason = rest[close + 1..].trim().to_string();
        if Rule::from_allow_key(&key).is_none() {
            findings.push(Finding::new(
                path,
                c.line,
                Rule::AllowSyntax,
                format!("unknown lint key `{key}` in allow comment"),
            ));
            continue;
        }
        if reason.is_empty() {
            findings.push(Finding::new(
                path,
                c.line,
                Rule::AllowSyntax,
                format!("`lint: allow({key})` needs a justification after the closing paren"),
            ));
            continue;
        }
        let (first_line, last_line) = allow_target_range(scrubbed, c.line);
        allows.push(Allow { first_line, last_line, key, reason });
    }
    (allows, findings)
}

/// A trailing comment suppresses its own line; a standalone comment
/// suppresses the next line that has code (comment-only and blank lines
/// in between are blank in the scrubbed text and skipped) through the
/// end of the statement starting there — the first `;` or block-opening
/// `{` at bracket depth zero — so one comment covers a multi-line call
/// chain.
fn allow_target_range(scrubbed: &lexer::Scrubbed, comment_line: usize) -> (usize, usize) {
    if line_has_code(scrubbed, comment_line) {
        return (comment_line, comment_line);
    }
    let total = scrubbed.line_starts.len();
    for line in comment_line + 1..=total {
        if line_has_code(scrubbed, line) {
            return (line, statement_end_line(scrubbed, line));
        }
    }
    (comment_line, comment_line)
}

fn statement_end_line(scrubbed: &lexer::Scrubbed, line: usize) -> usize {
    let text = scrubbed.text.as_bytes();
    let Some(&start) = scrubbed.line_starts.get(line - 1) else {
        return line;
    };
    let mut depth = 0i32;
    let mut j = start;
    while j < text.len() {
        match text[j] {
            b'(' | b'[' => depth += 1,
            b')' | b']' => depth -= 1,
            b';' | b'{' if depth == 0 => return scrubbed.line_of(j),
            b'}' if depth == 0 => return scrubbed.line_of(j),
            _ => {}
        }
        j += 1;
    }
    line
}

fn line_has_code(scrubbed: &lexer::Scrubbed, line: usize) -> bool {
    let text = scrubbed.text.as_bytes();
    let start = match scrubbed.line_starts.get(line - 1) {
        Some(&s) => s,
        None => return false,
    };
    let end = scrubbed.line_starts.get(line).copied().unwrap_or(text.len());
    text[start..end].iter().any(|&b| !(b as char).is_whitespace())
}

/// Lint every `.rs` file under `<root>/rust/src`, deterministically
/// ordered.  Paths in findings are repo-relative with forward slashes.
///
/// On top of the per-file passes, this is where the cross-file half of
/// W8 runs: a metric family must have exactly one registration site in
/// the whole tree, so a family registered in two *different* files is a
/// finding even though each file looks clean in isolation.  Like W0,
/// these structural findings cannot be suppressed — there is no single
/// line an allow comment could bless.
pub fn lint_tree(root: &Path, cfg: &LintConfig) -> io::Result<Report> {
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs_files(&src, &mut files)?;
    files.sort();
    let mut report = Report::default();
    let mut metric_sites: Vec<(String, String, usize)> = Vec::new(); // (family, file, line)
    for file in &files {
        let source = fs::read_to_string(file)?;
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        report.findings.extend(lint_source(&rel, &source, cfg));
        if !cfg.metric_names.is_empty() {
            let scrubbed = lexer::scrub(&source);
            let test_mask = lexer::test_line_mask(&scrubbed);
            let ctx = rules::FileContext {
                path: &rel,
                scrubbed: &scrubbed,
                test_mask: &test_mask,
                cfg,
            };
            for (name, line) in rules::metric_registrations(&ctx) {
                metric_sites.push((name, rel.clone(), line));
            }
        }
        report.files_scanned += 1;
    }
    // Sorted by (family, file, line): the first site for each family is
    // canonical, and every site in a *different* file is flagged.
    metric_sites.sort();
    for i in 0..metric_sites.len() {
        let (name, file, line) = &metric_sites[i];
        let first = metric_sites.iter().find(|(n, _, _)| n == name).expect("name is present");
        if &first.1 != file {
            report.findings.push(Finding::new(
                file,
                *line,
                Rule::MetricNameRegistry,
                format!(
                    "metric family `{name}` is also registered in {}:{}; each family \
                     has exactly one registration site in the tree",
                    first.1, first.2
                ),
            ));
        }
    }
    Ok(report)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Load `rust/LOCKS.md` (required), `rust/OBSERVABILITY.md` (optional —
/// when absent, no metric names are declared and W8 stays inert rather
/// than failing the run), and the committed `BENCH_*.baseline.json`
/// files at the repo root (optional the same way — none present leaves
/// W9 inert).
pub fn load_config(root: &Path) -> io::Result<LintConfig> {
    let text = fs::read_to_string(root.join("rust").join("LOCKS.md"))?;
    let mut cfg = LintConfig::parse_locks_md(&text);
    if let Ok(obs) = fs::read_to_string(root.join("rust").join("OBSERVABILITY.md")) {
        cfg.metric_names = LintConfig::parse_observability_md(&obs);
    }
    if let Ok(entries) = fs::read_dir(root) {
        let mut found = Vec::new();
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            let Some(scenario) =
                name.strip_prefix("BENCH_").and_then(|n| n.strip_suffix(".baseline.json"))
            else {
                continue;
            };
            if let Ok(text) = fs::read_to_string(entry.path()) {
                found.push((scenario.to_string(), LintConfig::parse_bench_baseline(&text)));
            }
        }
        found.sort();
        cfg.bench_baseline_keys = found;
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scrubber_preserves_offsets_and_collects_strings() {
        let src = "let a = \"x\\ty\"; // trailing\nlet b = 'c';\n";
        let s = lexer::scrub(src);
        assert_eq!(s.text.len(), src.len());
        assert_eq!(s.strings.len(), 1);
        assert_eq!(s.strings[0].raw, "x\\ty");
        assert_eq!(s.comments.len(), 1);
        assert_eq!(s.comments[0].text, "trailing");
        assert!(!s.text.contains("trailing"));
    }

    #[test]
    fn test_mask_covers_mod_and_field() {
        let src = "struct S {\n    a: u32,\n    #[cfg(test)]\n    hook: u8,\n}\n\
                   #[cfg(test)]\nmod tests {\n    fn f() {}\n}\n";
        let s = lexer::scrub(src);
        let mask = lexer::test_line_mask(&s);
        assert!(!mask[0]); // struct S {
        assert!(!mask[1]); // a: u32,
        assert!(mask[2]); // #[cfg(test)]
        assert!(mask[3]); // hook: u8,
        assert!(!mask[4]); // }
        assert!(mask[5] && mask[6] && mask[7] && mask[8]); // test mod
    }

    #[test]
    fn locks_md_parser_reads_all_sections() {
        let md = "# Locks\n## Hierarchy\n1. `kill_lock` — outermost\n2. `deque`\n\
                  \n## Helper lock acquisitions\n- `bump_epoch` acquires `epoch`\n\
                  - `lock_state` returns `state`\n## Condvar-paired atomics\n- `shutdown` — flag\n";
        let cfg = LintConfig::parse_locks_md(md);
        assert_eq!(cfg.hierarchy, vec!["kill_lock", "deque"]);
        assert_eq!(cfg.helpers.len(), 2);
        assert_eq!(cfg.helpers[0].name, "bump_epoch");
        assert_eq!(cfg.condvar_atomics, vec!["shutdown"]);
    }

    #[test]
    fn observability_md_parser_reads_family_table() {
        let md = "# Observability\nprose with `halign_stray` backticks\n\
                  ## Metric families\n| family | kind |\n|---|---|\n\
                  | `halign_tasks_run_total` | counter |\n\
                  | `halign_request_seconds` | histogram |\n\
                  - `halign_workers` — gauge bullet form\n\
                  ## The /metrics endpoint\n- `curl /metrics` is not a family\n";
        let names = LintConfig::parse_observability_md(md);
        assert_eq!(
            names,
            vec!["halign_tasks_run_total", "halign_request_seconds", "halign_workers"]
        );
    }

    #[test]
    fn allow_comment_suppresses_and_requires_reason() {
        let cfg = LintConfig::default();
        let src = "fn f(x: Option<u32>) -> u32 {\n    \
                   // lint: allow(panic) checked by caller\n    x.unwrap()\n}\n";
        let findings = lint_source("rust/src/engine/fx.rs", src, &cfg);
        assert_eq!(findings.len(), 1);
        assert!(findings[0].suppressed);
        let bare = "fn f(x: Option<u32>) -> u32 {\n    // lint: allow(panic)\n    x.unwrap()\n}\n";
        let findings = lint_source("rust/src/engine/fx.rs", bare, &cfg);
        assert!(findings.iter().any(|f| f.rule == Rule::AllowSyntax));
        assert!(findings.iter().any(|f| f.rule == Rule::PanicInWorker && !f.suppressed));
    }

    #[test]
    fn report_json_is_well_formed_enough() {
        let mut report = Report { files_scanned: 2, ..Default::default() };
        report.findings.push(Finding::new(
            "rust/src/engine/a.rs",
            3,
            Rule::PanicInWorker,
            "say \"no\" to panics".to_string(),
        ));
        let json = report.to_json();
        assert!(json.contains("\"files_scanned\": 2"));
        assert!(json.contains("\\\"no\\\""));
        assert!(json.contains("\"rule\": \"W1\""));
    }
}

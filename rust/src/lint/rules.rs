//! The per-rule passes (W1–W8).  Every pass works on the scrubbed
//! source (comments and string contents blanked, offsets stable) and
//! skips lines covered by the `#[cfg(test)]` mask.
//!
//! These are lexical analyses, not type-checked ones; the known
//! heuristic limits are documented per rule in `rust/LINTS.md`
//! (poison-unwrap carve-out, intraprocedural lock tracking plus the
//! helper declarations in `rust/LOCKS.md`, `let`-binding-only guard
//! liveness).

use super::config::{HelperKind, LintConfig};
use super::lexer::{find_from, is_ident, Scrubbed};
use super::report::{Finding, Rule};
use std::collections::HashMap;

/// Everything a rule pass needs to look at one file.
pub struct FileContext<'a> {
    /// Repo-relative path with forward slashes, e.g.
    /// `rust/src/engine/executor.rs`.
    pub path: &'a str,
    pub scrubbed: &'a Scrubbed,
    /// `test_mask[line-1]` is true inside `#[cfg(test)]` regions.
    pub test_mask: &'a [bool],
    pub cfg: &'a LintConfig,
}

impl FileContext<'_> {
    fn in_test(&self, line: usize) -> bool {
        self.test_mask.get(line.saturating_sub(1)).copied().unwrap_or(false)
    }

    fn line_of(&self, offset: usize) -> usize {
        self.scrubbed.line_of(offset)
    }
}

/// Run every rule on one file.
pub fn run_all(ctx: &FileContext<'_>) -> Vec<Finding> {
    let mut findings = Vec::new();
    check_panic_in_worker(ctx, &mut findings);
    check_locks(ctx, &mut findings);
    check_float_tolerance(ctx, &mut findings);
    check_relaxed_handshake(ctx, &mut findings);
    check_metrics_arity(ctx, &mut findings);
    check_cache_atomic_write(ctx, &mut findings);
    check_metric_names(ctx, &mut findings);
    check_bench_json_schema(ctx, &mut findings);
    findings
}

// ---------------------------------------------------------------- W1 --

/// Methods whose `.unwrap()`/`.expect(...)` only fires on a *poisoned*
/// lock — i.e. after another thread already panicked.  The executor's
/// `catch_unwind` turns worker panics into task errors, so propagating
/// poison is the correct response, not a new panic path; these calls are
/// carved out of W1 (documented in LINTS.md).
const POISON_METHODS: &[&str] = &[
    "lock",
    "try_lock",
    "read",
    "write",
    "wait",
    "wait_timeout",
    "wait_timeout_while",
    "wait_while",
];

fn w1_in_scope(path: &str) -> bool {
    ["engine/", "distmat/", "server/"].iter().any(|d| path.contains(d))
}

fn check_panic_in_worker(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    if !w1_in_scope(ctx.path) {
        return;
    }
    let text = ctx.scrubbed.text.as_bytes();
    let probes = [(&b".unwrap()"[..], "`.unwrap()`"), (&b".expect("[..], "`.expect(...)`")];
    for (needle, what) in probes {
        let mut from = 0usize;
        while let Some(p) = find_from(text, needle, from) {
            from = p + 1;
            let line = ctx.line_of(p);
            if ctx.in_test(line) || poison_carved(text, p) {
                continue;
            }
            out.push(Finding::new(
                ctx.path,
                line,
                Rule::PanicInWorker,
                format!(
                    "{what} in worker-reachable code can panic and defeat fault recovery; \
                     return an error or justify with `// lint: allow(panic) <reason>`"
                ),
            ));
        }
    }
    for mac in ["panic!", "todo!", "unimplemented!"] {
        let needle = mac.as_bytes();
        let mut from = 0usize;
        while let Some(p) = find_from(text, needle, from) {
            from = p + 1;
            if p > 0 && is_ident(text[p - 1]) {
                continue;
            }
            let line = ctx.line_of(p);
            if ctx.in_test(line) {
                continue;
            }
            out.push(Finding::new(
                ctx.path,
                line,
                Rule::PanicInWorker,
                format!(
                    "`{mac}` in worker-reachable code defeats fault recovery; \
                     return an error or justify with `// lint: allow(panic) <reason>`"
                ),
            ));
        }
    }
}

/// True when the call preceding `.unwrap()`/`.expect(` at `dot` is one
/// of the poison-only methods (`x.lock().unwrap()` and friends).
/// Whitespace between the call and the `.unwrap()` is skipped so a
/// chain rustfmt broke across lines is still recognised.
fn poison_carved(text: &[u8], dot: usize) -> bool {
    let mut dot = dot;
    while dot > 0 && (text[dot - 1] as char).is_whitespace() {
        dot -= 1;
    }
    if dot == 0 || text[dot - 1] != b')' {
        return false;
    }
    let mut depth = 0i32;
    let mut j = dot - 1;
    loop {
        match text[j] {
            b')' => depth += 1,
            b'(' => depth -= 1,
            _ => {}
        }
        if depth == 0 {
            break;
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
    let end = j;
    let mut start = j;
    while start > 0 && is_ident(text[start - 1]) {
        start -= 1;
    }
    let name = &text[start..end];
    POISON_METHODS.iter().any(|m| m.as_bytes() == name)
}

// ----------------------------------------------------------- W2 + W3 --

/// Calls that touch the filesystem or network; a live `MutexGuard`
/// across any of these is W2.
const IO_MARKERS: &[&str] = &[
    "fs::",
    "File::",
    "OpenOptions::",
    "write_atomic(",
    "TcpStream",
    "TcpListener",
    ".read_to_end(",
    ".read_exact(",
    ".write_all(",
    ".sync_all(",
    ".seek(",
    ".flush(",
];

struct Guard {
    /// Lock name (`inner`, `deque`, …), from the receiver of `.lock()`
    /// or the declared helper.
    lock: String,
    /// Binding variable, for `drop(var)` tracking.
    var: String,
    /// Brace depth at the `let`; the guard dies when the scope closes.
    depth: usize,
    /// Byte offset after which the guard is held (end of its `let`
    /// statement) — events inside the initializer itself see only
    /// *previously* held guards.
    active_from: usize,
}

/// One linear walk handling both W2 (lock across I/O) and W3 (lock
/// ordering).  Tracks `let`-bound guards per brace scope; every
/// `.lock()` occurrence and declared-helper call is an acquisition
/// event checked against the guards currently held.
fn check_locks(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    let text = ctx.scrubbed.text.as_bytes();
    let n = text.len();

    // Pre-locate I/O markers and helper calls so the main walk is a
    // cheap per-byte dispatch.
    let mut io_at: HashMap<usize, &str> = HashMap::new();
    for marker in IO_MARKERS {
        let needle = marker.as_bytes();
        let mut from = 0usize;
        while let Some(p) = find_from(text, needle, from) {
            from = p + 1;
            if needle[0] != b'.' && p > 0 && is_ident(text[p - 1]) {
                continue;
            }
            io_at.entry(p).or_insert(marker);
        }
    }
    let mut helper_at: HashMap<usize, (&str, &str, HelperKind)> = HashMap::new();
    for h in &ctx.cfg.helpers {
        let needle = h.name.as_bytes();
        let mut from = 0usize;
        while let Some(p) = find_from(text, needle, from) {
            from = p + 1;
            if p > 0 && is_ident(text[p - 1]) {
                continue;
            }
            let after = p + needle.len();
            if after >= n || text[after] != b'(' {
                continue;
            }
            // Skip the definition site (`fn name(`): preceded by `fn `.
            if is_fn_def(text, p) {
                continue;
            }
            helper_at.insert(p, (h.name.as_str(), h.lock.as_str(), h.kind));
        }
    }

    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0usize;
    let mut last_io_line = 0usize;
    let mut i = 0usize;
    while i < n {
        let b = text[i];
        match b {
            b'{' => depth += 1,
            b'}' => {
                depth = depth.saturating_sub(1);
                guards.retain(|g| g.depth <= depth);
            }
            b'.' if slice_is(text, i, b".lock()") => {
                lock_event(ctx, i, &receiver_name(text, i), &guards, out);
            }
            b'l' if word_is(text, i, b"let") && !prev_word_is(text, i, &[b"if", b"while"]) => {
                if let Some(g) = parse_guard_binding(ctx, text, i, depth) {
                    guards.push(g);
                }
            }
            b'd' if word_is(text, i, b"drop") => {
                if let Some(var) = drop_target(text, i) {
                    guards.retain(|g| g.var != var);
                }
            }
            _ => {}
        }
        if let Some((_, lock, _)) = helper_at.get(&i) {
            lock_event(ctx, i, lock, &guards, out);
        }
        if io_at.contains_key(&i) {
            let line = ctx.line_of(i);
            if !ctx.in_test(line) && line != last_io_line {
                let live: Vec<&str> = guards
                    .iter()
                    .filter(|g| g.active_from <= i)
                    .map(|g| g.lock.as_str())
                    .collect();
                if !live.is_empty() {
                    last_io_line = line;
                    out.push(Finding::new(
                        ctx.path,
                        line,
                        Rule::LockAcrossIo,
                        format!(
                            "I/O call while holding MutexGuard(s) `{}`; \
                             move the I/O outside the critical section",
                            live.join("`, `")
                        ),
                    ));
                }
            }
        }
        i += 1;
    }
}

/// W3 check at one acquisition event (`.lock()` or a declared helper).
fn lock_event(
    ctx: &FileContext<'_>,
    offset: usize,
    inner: &str,
    guards: &[Guard],
    out: &mut Vec<Finding>,
) {
    let line = ctx.line_of(offset);
    if ctx.in_test(line) {
        return;
    }
    for g in guards.iter().filter(|g| g.active_from <= offset) {
        if g.lock == inner {
            out.push(Finding::new(
                ctx.path,
                line,
                Rule::LockOrder,
                format!("re-acquiring `{inner}` while a guard on it is held (self-deadlock)"),
            ));
            continue;
        }
        match (ctx.cfg.rank(&g.lock), ctx.cfg.rank(inner)) {
            (Some(outer_rank), Some(inner_rank)) => {
                if outer_rank >= inner_rank {
                    out.push(Finding::new(
                        ctx.path,
                        line,
                        Rule::LockOrder,
                        format!(
                            "acquiring `{inner}` while holding `{}` inverts the declared \
                             hierarchy in rust/LOCKS.md",
                            g.lock
                        ),
                    ));
                }
            }
            _ => {
                let undeclared = if ctx.cfg.rank(&g.lock).is_none() { &g.lock } else { inner };
                out.push(Finding::new(
                    ctx.path,
                    line,
                    Rule::LockOrder,
                    format!(
                        "nested lock acquisition involves `{undeclared}`, which is not \
                         declared in rust/LOCKS.md"
                    ),
                ));
            }
        }
    }
}

/// Parse `let [mut] name = <rhs>;` at `i` (which points at `let`) and
/// return a `Guard` when the RHS yields a `MutexGuard`: it ends in
/// `.lock()` / `.lock()?` / `.lock().unwrap()` / `.lock().expect(…)`,
/// or is a call to a declared `returns`-kind helper.  Patterns
/// (`let (a, b) = …`), `let _ = …`, and deref/borrow RHSes are not
/// guards.  `if let` / `while let` scrutinees are excluded by the
/// caller; their temporaries die at the end of the condition.
fn parse_guard_binding(
    ctx: &FileContext<'_>,
    text: &[u8],
    i: usize,
    depth: usize,
) -> Option<Guard> {
    let n = text.len();
    let line = ctx.line_of(i);
    if ctx.in_test(line) {
        return None;
    }
    let mut j = i + 3;
    j = skip_ws(text, j);
    if word_is(text, j, b"mut") {
        j = skip_ws(text, j + 3);
    }
    if j >= n || !(text[j].is_ascii_alphabetic() || text[j] == b'_') {
        return None; // pattern binding, not a simple ident
    }
    let var_start = j;
    while j < n && is_ident(text[j]) {
        j += 1;
    }
    let var = std::str::from_utf8(&text[var_start..j]).ok()?.to_string();
    if var == "_" {
        return None; // dropped immediately
    }
    j = skip_ws(text, j);
    // Optional `: Type` up to the `=` at bracket depth 0.
    let mut bdepth = 0i32;
    let mut eq = None;
    let mut k = j;
    while k < n {
        match text[k] {
            b'(' | b'[' | b'<' => bdepth += 1,
            b')' | b']' | b'>' => bdepth -= 1,
            b'=' if bdepth <= 0 && (k + 1 >= n || text[k + 1] != b'=') => {
                eq = Some(k);
                break;
            }
            b';' | b'{' => break,
            _ => {}
        }
        k += 1;
    }
    let eq = eq?;
    let stmt_end = find_stmt_end(text, eq + 1);
    let rhs_start = skip_ws(text, eq + 1);
    let rhs = &text[rhs_start..stmt_end.min(n)];
    let rhs_trim = trim_bytes(rhs);
    if rhs_trim.first() == Some(&b'*') || rhs_trim.first() == Some(&b'&') {
        return None; // deref/borrow of an existing guard, not a new one
    }
    // Case 1: …lock() [? | .unwrap() | .expect(…)] at the very end.
    if let Some(lp) = rfind(rhs_trim, b".lock()") {
        let tail = &rhs_trim[lp + b".lock()".len()..];
        if guard_tail_ok(tail) {
            let lock = receiver_name(rhs_trim, lp);
            if !lock.is_empty() {
                return Some(Guard { lock, var, depth, active_from: stmt_end });
            }
        }
    }
    // Case 2: call to a declared `returns`-guard helper.
    if rhs_trim.last() == Some(&b')') {
        let mut pd = 0i32;
        let mut p = rhs_trim.len() - 1;
        loop {
            match rhs_trim[p] {
                b')' => pd += 1,
                b'(' => pd -= 1,
                _ => {}
            }
            if pd == 0 {
                break;
            }
            if p == 0 {
                return None;
            }
            p -= 1;
        }
        let end = p;
        let mut start = p;
        while start > 0 && is_ident(rhs_trim[start - 1]) {
            start -= 1;
        }
        let method = std::str::from_utf8(&rhs_trim[start..end]).ok()?;
        if let Some(h) = ctx.cfg.helper(method) {
            if h.kind == HelperKind::ReturnsGuard {
                return Some(Guard {
                    lock: h.lock.clone(),
                    var,
                    depth,
                    active_from: stmt_end,
                });
            }
        }
    }
    None
}

fn guard_tail_ok(tail: &[u8]) -> bool {
    // Normalise away whitespace so multi-line chains still match.
    let t: Vec<u8> = tail.iter().copied().filter(|&b| !(b as char).is_whitespace()).collect();
    if t.is_empty() || t == b"?" || t == b".unwrap()" {
        return true;
    }
    t.starts_with(b".expect(") && t.last() == Some(&b')')
}

/// Receiver name of `.lock()` at `dot`: the field/variable segment just
/// before the dot, with one `[…]` index stripped
/// (`self.shards[v].deque.lock()` → `deque`, `self.slots[p].lock()` →
/// `slots`).
fn receiver_name(text: &[u8], dot: usize) -> String {
    let mut k = dot;
    while k > 0 && text[k - 1] == b']' {
        let mut depth = 0i32;
        let mut j = k - 1;
        loop {
            match text[j] {
                b']' => depth += 1,
                b'[' => depth -= 1,
                _ => {}
            }
            if depth == 0 || j == 0 {
                break;
            }
            j -= 1;
        }
        k = j;
    }
    let end = k;
    let mut start = k;
    while start > 0 && is_ident(text[start - 1]) {
        start -= 1;
    }
    String::from_utf8_lossy(&text[start..end]).into_owned()
}

fn drop_target(text: &[u8], i: usize) -> Option<String> {
    let mut j = skip_ws(text, i + 4);
    if j >= text.len() || text[j] != b'(' {
        return None;
    }
    j = skip_ws(text, j + 1);
    let start = j;
    while j < text.len() && is_ident(text[j]) {
        j += 1;
    }
    let end = j;
    j = skip_ws(text, j);
    if j >= text.len() || text[j] != b')' || start == end {
        return None;
    }
    Some(String::from_utf8_lossy(&text[start..end]).into_owned())
}

/// `fn name(` — the definition of a helper, not a call to it.
fn is_fn_def(text: &[u8], name_pos: usize) -> bool {
    let mut j = name_pos;
    while j > 0 && (text[j - 1] as char).is_whitespace() {
        j -= 1;
    }
    j >= 2 && &text[j - 2..j] == b"fn" && (j == 2 || !is_ident(text[j - 3]))
}

// ---------------------------------------------------------------- W4 --

fn check_float_tolerance(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    if !ctx.path.contains("align/") {
        return;
    }
    let text = ctx.scrubbed.text.as_bytes();
    let mut from = 0usize;
    while let Some(p) = find_from(text, b"EPS", from) {
        from = p + 1;
        let before_ok = p == 0 || !is_ident(text[p - 1]);
        let after = p + 3;
        let after_ok = after >= text.len() || !is_ident(text[after]);
        if !(before_ok && after_ok) {
            continue;
        }
        let line = ctx.line_of(p);
        if ctx.in_test(line) {
            continue;
        }
        out.push(Finding::new(
            ctx.path,
            line,
            Rule::FloatTolerance,
            "`EPS` tolerance in alignment code; kernels must compare exactly \
             (the float-EPS traceback bug class removed by the integer kernels)"
                .to_string(),
        ));
    }
    from = 0;
    while let Some(p) = find_from(text, b".abs()", from) {
        from = p + 1;
        let mut j = skip_ws(text, p + b".abs()".len());
        if j < text.len() && text[j] == b'<' {
            j += 1;
            if j < text.len() && text[j] == b'<' {
                continue; // shift, not comparison
            }
            let line = ctx.line_of(p);
            if ctx.in_test(line) {
                continue;
            }
            out.push(Finding::new(
                ctx.path,
                line,
                Rule::FloatTolerance,
                "`.abs() < …` tolerance comparison in alignment code; \
                 compare exactly or move the tolerance out of the kernel"
                    .to_string(),
            ));
        }
    }
}

// ---------------------------------------------------------------- W5 --

const ATOMIC_OPS: &[&str] = &["load(", "store(", "swap(", "fetch_", "compare_"];

fn check_relaxed_handshake(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    if ctx.cfg.condvar_atomics.is_empty() {
        return;
    }
    let text = ctx.scrubbed.text.as_bytes();
    let mut from = 0usize;
    while let Some(p) = find_from(text, b"Ordering::Relaxed", from) {
        from = p + 1;
        let line = ctx.line_of(p);
        if ctx.in_test(line) {
            continue;
        }
        let start = stmt_start(text, p);
        let span = &text[start..p];
        for name in &ctx.cfg.condvar_atomics {
            if atomic_op_in(span, name) {
                out.push(Finding::new(
                    ctx.path,
                    line,
                    Rule::RelaxedHandshake,
                    format!(
                        "`Ordering::Relaxed` on condvar-paired atomic `{name}`; the \
                         sleep/wake handshake needs SeqCst (see rust/LOCKS.md)"
                    ),
                ));
                break;
            }
        }
    }
}

fn atomic_op_in(span: &[u8], name: &str) -> bool {
    let needle = name.as_bytes();
    let mut from = 0usize;
    while let Some(p) = find_from(span, needle, from) {
        from = p + 1;
        if p > 0 && is_ident(span[p - 1]) {
            continue;
        }
        let mut q = p + needle.len();
        if q < span.len() && span[q] == b'[' {
            let mut depth = 0i32;
            while q < span.len() {
                match span[q] {
                    b'[' => depth += 1,
                    b']' => {
                        depth -= 1;
                        if depth == 0 {
                            q += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                q += 1;
            }
        } else if q < span.len() && is_ident(span[q]) {
            continue;
        }
        if q < span.len() && span[q] == b'.' {
            let rest = &span[q + 1..];
            if ATOMIC_OPS.iter().any(|op| rest.starts_with(op.as_bytes())) {
                return true;
            }
        }
    }
    false
}

// ---------------------------------------------------------------- W6 --

fn check_metrics_arity(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    let text = ctx.scrubbed.text.as_bytes();
    // Find `const <NAME-containing-HEADER>` and its string literal.
    let mut header: Option<(usize, usize)> = None; // (offset, columns)
    let mut from = 0usize;
    while let Some(p) = find_from(text, b"const", from) {
        from = p + 1;
        if (p > 0 && is_ident(text[p - 1])) || (p + 5 < text.len() && is_ident(text[p + 5])) {
            continue;
        }
        let mut j = skip_ws(text, p + 5);
        let start = j;
        while j < text.len() && is_ident(text[j]) {
            j += 1;
        }
        if !contains_sub(&text[start..j], b"HEADER") {
            continue;
        }
        let semi = find_from(text, b";", j).unwrap_or(text.len());
        if let Some(lit) = ctx
            .scrubbed
            .strings
            .iter()
            .find(|s| s.offset > p && s.offset < semi && tab_count(&s.raw) > 0)
        {
            header = Some((lit.offset, tab_count(&lit.raw) + 1));
            break;
        }
    }
    let Some((header_offset, columns)) = header else {
        return;
    };
    for lit in &ctx.scrubbed.strings {
        if lit.offset == header_offset || ctx.in_test(lit.line) {
            continue;
        }
        let tabs = tab_count(&lit.raw);
        if tabs < 2 || placeholder_count(&lit.raw) == 0 {
            continue;
        }
        let fields = tabs + 1;
        if fields != columns {
            out.push(Finding::new(
                ctx.path,
                lit.line,
                Rule::MetricsArity,
                format!(
                    "row writer has {fields} tab-separated fields but the TSV header \
                     in this file declares {columns} columns"
                ),
            ));
        }
    }
}

/// Occurrences of the two-byte escape `\t` as written in the source.
fn tab_count(raw: &str) -> usize {
    raw.as_bytes().windows(2).filter(|w| *w == b"\\t").count()
}

/// `{…}` placeholders, skipping the `{{` escape.
fn placeholder_count(raw: &str) -> usize {
    let b = raw.as_bytes();
    let mut i = 0usize;
    let mut count = 0usize;
    while i < b.len() {
        if b[i] == b'{' {
            if i + 1 < b.len() && b[i + 1] == b'{' {
                i += 2;
                continue;
            }
            count += 1;
        }
        i += 1;
    }
    count
}

// ---------------------------------------------------------------- W7 --

/// Mutating filesystem calls that bypass the tmp+rename discipline.
/// Read-side and directory-lifecycle calls (`fs::read`,
/// `fs::create_dir_all`, `fs::remove_*`) are fine; blob *writes* must go
/// through `write_atomic` so a crash mid-write can never leave a
/// half-written artifact that a later `get` serves as cached truth.
const DIRECT_WRITE_MARKERS: &[&str] =
    &["fs::write(", "fs::rename(", "fs::copy(", "File::create(", "OpenOptions::"];

fn check_cache_atomic_write(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    if !ctx.path.contains("cache/") {
        return;
    }
    let text = ctx.scrubbed.text.as_bytes();
    for marker in DIRECT_WRITE_MARKERS {
        let needle = marker.as_bytes();
        let mut from = 0usize;
        while let Some(p) = find_from(text, needle, from) {
            from = p + 1;
            if p > 0 && is_ident(text[p - 1]) {
                continue;
            }
            let line = ctx.line_of(p);
            if ctx.in_test(line) {
                continue;
            }
            out.push(Finding::new(
                ctx.path,
                line,
                Rule::CacheAtomicWrite,
                format!(
                    "`{}` in the artifact cache bypasses `write_atomic`; write blobs \
                     via tmp+rename or justify with `// lint: allow(cache-atomic-write) <reason>`",
                    marker.trim_end_matches('(')
                ),
            ));
        }
    }
}

// ---------------------------------------------------------------- W8 --

/// The `Registry` methods that mint a new metric family.  Each marker
/// carries its trailing `(` so `register_counter(` never matches inside
/// `register_counter_labeled(`.
const REGISTER_MARKERS: &[&str] = &[
    "register_counter(",
    "register_counter_labeled(",
    "register_gauge(",
    "register_histogram(",
    "register_histogram_labeled(",
];

/// Metric family registrations in one file: `(family name, line)` for
/// every non-test call to a `REGISTER_MARKERS` method whose first
/// argument is a string literal.  Calls passing a variable name (the
/// registry's own `register_counter` → `register_counter_labeled`
/// delegation) and `fn` definition sites have no literal after the
/// paren and fall out naturally.  Shared by [`check_metric_names`]
/// (in-file checks) and `lint_tree` (the cross-file exactly-once check).
pub fn metric_registrations(ctx: &FileContext<'_>) -> Vec<(String, usize)> {
    let text = ctx.scrubbed.text.as_bytes();
    let mut sites = Vec::new();
    for marker in REGISTER_MARKERS {
        let needle = marker.as_bytes();
        let mut from = 0usize;
        while let Some(p) = find_from(text, needle, from) {
            from = p + 1;
            if p > 0 && is_ident(text[p - 1]) {
                continue;
            }
            let q = skip_ws(text, p + needle.len());
            if q >= text.len() || text[q] != b'"' {
                continue;
            }
            let line = ctx.line_of(p);
            if ctx.in_test(line) {
                continue;
            }
            // The scrubbed text keeps the delimiting quotes; the raw
            // (unblanked) name lives in the string table at this offset.
            if let Some(lit) = ctx.scrubbed.strings.iter().find(|s| s.offset == q) {
                sites.push((lit.raw.clone(), line));
            }
        }
    }
    sites.sort_by(|a, b| a.1.cmp(&b.1));
    sites
}

fn is_snake_case(name: &str) -> bool {
    name.starts_with(|c: char| c.is_ascii_lowercase())
        && name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// W8: every registered family name must be declared in
/// `rust/OBSERVABILITY.md`, be snake_case, and be registered at exactly
/// one site per file (labeled instances reuse the one site inside a
/// loop).  Inert when no names are declared (the file is absent).
fn check_metric_names(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    if ctx.cfg.metric_names.is_empty() {
        return;
    }
    let mut first_seen: HashMap<String, usize> = HashMap::new();
    for (name, line) in metric_registrations(ctx) {
        if !is_snake_case(&name) {
            out.push(Finding::new(
                ctx.path,
                line,
                Rule::MetricNameRegistry,
                format!(
                    "metric family `{name}` is not snake_case; the naming contract in \
                     rust/OBSERVABILITY.md requires `[a-z][a-z0-9_]*`"
                ),
            ));
        } else if !ctx.cfg.metric_names.iter().any(|n| n == &name) {
            out.push(Finding::new(
                ctx.path,
                line,
                Rule::MetricNameRegistry,
                format!(
                    "metric family `{name}` is not declared in rust/OBSERVABILITY.md; \
                     add it to the family table (or fix the name)"
                ),
            ));
        }
        match first_seen.get(&name) {
            Some(&first) => out.push(Finding::new(
                ctx.path,
                line,
                Rule::MetricNameRegistry,
                format!(
                    "metric family `{name}` is registered more than once in this file \
                     (first at line {first}); register once and share the handle"
                ),
            )),
            None => {
                first_seen.insert(name, line);
            }
        }
    }
}

// ---------------------------------------------------------------- W9 --

/// W9: every `write_bench_json("<scenario>", ...)` call must target a
/// scenario with a committed `BENCH_<scenario>.baseline.json` at the
/// repo root, and every snake_case string literal inside the call (the
/// field keys, per the writer's literal-key contract) must be declared
/// in that baseline — so the CI gate in `scripts/bench_compare.py`
/// never meets a key it has no floor or ceiling for.  Inert when no
/// baselines exist.  Heuristic limit (LINTS.md): any snake_case literal
/// inside the statement is treated as a key, so value expressions must
/// not contain snake_case string literals.
fn check_bench_json_schema(ctx: &FileContext<'_>, out: &mut Vec<Finding>) {
    if ctx.cfg.bench_baseline_keys.is_empty() {
        return;
    }
    let text = ctx.scrubbed.text.as_bytes();
    let needle = b"write_bench_json(";
    let mut from = 0usize;
    while let Some(p) = find_from(text, needle, from) {
        from = p + 1;
        if p > 0 && is_ident(text[p - 1]) {
            continue;
        }
        // The scenario must be a string literal right after the paren;
        // the writer's own `fn` definition (`scenario: &str`) and any
        // pass-through call have an identifier there instead.
        let q = skip_ws(text, p + needle.len());
        if q >= text.len() || text[q] != b'"' {
            continue;
        }
        let line = ctx.line_of(p);
        if ctx.in_test(line) {
            continue;
        }
        let Some(scenario) = ctx.scrubbed.strings.iter().find(|s| s.offset == q) else {
            continue;
        };
        let stmt_end = find_stmt_end(text, p);
        match ctx.cfg.bench_baseline_keys.iter().find(|(s, _)| s == &scenario.raw) {
            None => out.push(Finding::new(
                ctx.path,
                line,
                Rule::BenchJsonSchema,
                format!(
                    "bench scenario `{0}` has no committed BENCH_{0}.baseline.json at the \
                     repo root; commit the baseline with the gate knobs (or fix the name)",
                    scenario.raw
                ),
            )),
            Some((_, declared)) => {
                for lit in &ctx.scrubbed.strings {
                    if lit.offset <= q || lit.offset >= stmt_end || !is_snake_case(&lit.raw) {
                        continue;
                    }
                    if !declared.iter().any(|k| k == &lit.raw) {
                        out.push(Finding::new(
                            ctx.path,
                            lit.line,
                            Rule::BenchJsonSchema,
                            format!(
                                "bench JSON key `{}` is not declared in \
                                 BENCH_{}.baseline.json; add the baseline row in the same \
                                 commit (or fix the key)",
                                lit.raw, scenario.raw
                            ),
                        ));
                    }
                }
            }
        }
    }
}

// ----------------------------------------------------------- shared --

fn skip_ws(text: &[u8], mut i: usize) -> usize {
    while i < text.len() && (text[i] as char).is_whitespace() {
        i += 1;
    }
    i
}

fn trim_bytes(b: &[u8]) -> &[u8] {
    let mut s = 0usize;
    let mut e = b.len();
    while s < e && (b[s] as char).is_whitespace() {
        s += 1;
    }
    while e > s && (b[e - 1] as char).is_whitespace() {
        e -= 1;
    }
    &b[s..e]
}

fn slice_is(text: &[u8], i: usize, pat: &[u8]) -> bool {
    text.len() >= i + pat.len() && &text[i..i + pat.len()] == pat
}

/// `pat` starts at `i` as a whole word.
fn word_is(text: &[u8], i: usize, pat: &[u8]) -> bool {
    slice_is(text, i, pat)
        && (i == 0 || !is_ident(text[i - 1]))
        && (i + pat.len() >= text.len() || !is_ident(text[i + pat.len()]))
}

/// The word immediately before position `i` (skipping whitespace) is
/// one of `words` — used to exclude `if let` / `while let`.
fn prev_word_is(text: &[u8], i: usize, words: &[&[u8]]) -> bool {
    let mut j = i;
    while j > 0 && (text[j - 1] as char).is_whitespace() {
        j -= 1;
    }
    let end = j;
    let mut start = j;
    while start > 0 && is_ident(text[start - 1]) {
        start -= 1;
    }
    let w = &text[start..end];
    words.iter().any(|p| *p == w)
}

/// End of the statement starting at `from`: the first `;` at combined
/// bracket depth 0, or the position where the enclosing block closes.
fn find_stmt_end(text: &[u8], from: usize) -> usize {
    let mut depth = 0i32;
    let mut j = from;
    while j < text.len() {
        match text[j] {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' => depth -= 1,
            b'}' => {
                depth -= 1;
                if depth < 0 {
                    return j;
                }
            }
            b';' if depth == 0 => return j,
            _ => {}
        }
        j += 1;
    }
    text.len()
}

/// Statement start for W5: scan back to the nearest `;`, `{`, or `}`.
fn stmt_start(text: &[u8], pos: usize) -> usize {
    let mut j = pos;
    while j > 0 {
        match text[j - 1] {
            b';' | b'{' | b'}' => break,
            _ => j -= 1,
        }
    }
    j
}

fn rfind(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (0..=hay.len() - needle.len()).rev().find(|&i| &hay[i..i + needle.len()] == needle)
}

fn contains_sub(hay: &[u8], needle: &[u8]) -> bool {
    find_from(hay, needle, 0).is_some()
}

//! Finding type, rule identifiers, text rendering, and the
//! hand-rolled `LINT_REPORT.json` writer (no serde in this tree).

use std::fmt;

/// Every rule `pallas-lint` enforces.  `W0` is the linter checking its
/// own escape hatch: a malformed or reasonless `// lint: allow(...)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Rule {
    AllowSyntax,
    PanicInWorker,
    LockAcrossIo,
    LockOrder,
    FloatTolerance,
    RelaxedHandshake,
    MetricsArity,
    CacheAtomicWrite,
    MetricNameRegistry,
    BenchJsonSchema,
}

impl Rule {
    /// Short ID printed in findings (`W1`…`W9`, `W0` for allow syntax).
    pub fn id(self) -> &'static str {
        match self {
            Rule::AllowSyntax => "W0",
            Rule::PanicInWorker => "W1",
            Rule::LockAcrossIo => "W2",
            Rule::LockOrder => "W3",
            Rule::FloatTolerance => "W4",
            Rule::RelaxedHandshake => "W5",
            Rule::MetricsArity => "W6",
            Rule::CacheAtomicWrite => "W7",
            Rule::MetricNameRegistry => "W8",
            Rule::BenchJsonSchema => "W9",
        }
    }

    /// Key accepted inside `// lint: allow(<key>)`.
    pub fn allow_key(self) -> &'static str {
        match self {
            Rule::AllowSyntax => "allow-syntax",
            Rule::PanicInWorker => "panic",
            Rule::LockAcrossIo => "lock-across-io",
            Rule::LockOrder => "lock-order",
            Rule::FloatTolerance => "float-tolerance",
            Rule::RelaxedHandshake => "relaxed-handshake",
            Rule::MetricsArity => "metrics-arity",
            Rule::CacheAtomicWrite => "cache-atomic-write",
            Rule::MetricNameRegistry => "metric-name-registry",
            Rule::BenchJsonSchema => "bench-json-schema",
        }
    }

    pub fn from_allow_key(key: &str) -> Option<Rule> {
        [
            Rule::PanicInWorker,
            Rule::LockAcrossIo,
            Rule::LockOrder,
            Rule::FloatTolerance,
            Rule::RelaxedHandshake,
            Rule::MetricsArity,
            Rule::CacheAtomicWrite,
            Rule::MetricNameRegistry,
            Rule::BenchJsonSchema,
        ]
        .into_iter()
        .find(|r| r.allow_key() == key)
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}", self.id(), self.allow_key())
    }
}

/// One lint finding, before or after suppression matching.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
    /// Set when a `// lint: allow(...)` with a reason covers this line.
    pub suppressed: bool,
    pub allow_reason: Option<String>,
}

impl Finding {
    pub fn new(file: &str, line: usize, rule: Rule, message: String) -> Finding {
        let file = file.to_string();
        Finding { file, line, rule, message, suppressed: false, allow_reason: None }
    }

    /// The `file:line rule message` line the CLI prints.
    pub fn render(&self) -> String {
        format!("{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// Full run output, serialized to `LINT_REPORT.json`.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    pub fn unsuppressed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.suppressed)
    }

    pub fn unsuppressed_count(&self) -> usize {
        self.unsuppressed().count()
    }

    pub fn suppressed_count(&self) -> usize {
        self.findings.len() - self.unsuppressed_count()
    }

    /// Machine-readable report.  Schema:
    /// `{"files_scanned":N,"unsuppressed":N,"suppressed":N,"findings":[...]}`
    /// with each finding carrying `file,line,rule,key,message,suppressed`
    /// and `allow_reason` when present.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        out.push_str(&format!("  \"unsuppressed\": {},\n", self.unsuppressed_count()));
        out.push_str(&format!("  \"suppressed\": {},\n", self.suppressed_count()));
        out.push_str("  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n    {");
            out.push_str(&format!("\"file\": {}, ", json_str(&f.file)));
            out.push_str(&format!("\"line\": {}, ", f.line));
            out.push_str(&format!("\"rule\": {}, ", json_str(f.rule.id())));
            out.push_str(&format!("\"key\": {}, ", json_str(f.rule.allow_key())));
            out.push_str(&format!("\"message\": {}, ", json_str(&f.message)));
            out.push_str(&format!("\"suppressed\": {}", f.suppressed));
            if let Some(reason) = &f.allow_reason {
                out.push_str(&format!(", \"allow_reason\": {}", json_str(reason)));
            }
            out.push('}');
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

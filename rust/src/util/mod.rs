//! Std-only substitutes for the usual crate ecosystem (offline build):
//! deterministic PRNG, binary codec for the disk shuffle, and a tiny
//! stopwatch.

pub mod codec;
pub mod hash;
pub mod rng;
pub mod timer;

pub use codec::{Decode, Encode};
pub use rng::Rng;
pub use timer::Stopwatch;

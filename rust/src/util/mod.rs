//! Std-only substitutes for the usual crate ecosystem (offline build):
//! deterministic PRNG, binary codec for the disk shuffle, and a tiny
//! stopwatch.

pub mod codec;
pub mod hash;
pub mod rng;
pub mod timer;

pub use codec::{Decode, Encode};
pub use rng::Rng;
pub use timer::Stopwatch;

/// Row/column of a linear lower-triangle index: the unique `(r, c)` with
/// `c <= r` and `r(r+1)/2 + c == index`.  Shared by the engine's
/// `lower_triangle_blocks` pairing and the distmat tile grid, which must
/// agree on the enumeration order.
pub fn triangle_coords(index: usize) -> (usize, usize) {
    // Float sqrt gets within one of the answer; correct with integer
    // steps so the result is exact for any index we can hold.
    let mut r = (((8.0 * index as f64 + 1.0).sqrt() as usize).saturating_sub(1)) / 2;
    while (r + 1) * (r + 2) / 2 <= index {
        r += 1;
    }
    while r * (r + 1) / 2 > index {
        r -= 1;
    }
    (r, index - r * (r + 1) / 2)
}

#[cfg(test)]
mod triangle_tests {
    #[test]
    fn triangle_coords_roundtrip() {
        let mut idx = 0;
        for r in 0..80 {
            for c in 0..=r {
                assert_eq!(super::triangle_coords(idx), (r, c), "index {idx}");
                idx += 1;
            }
        }
    }
}

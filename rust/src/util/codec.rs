//! Tiny binary codec for the disk (Hadoop-mode) shuffle and broadcast
//! spill files — the offline stand-in for serde/bincode.
//!
//! Little-endian, length-prefixed, no schema evolution (spill files never
//! outlive a job). The engine requires `Encode + Decode` on any element
//! type that crosses a DiskKv stage boundary, which is exactly the
//! serialization tax Hadoop pays and Spark's in-memory cache avoids — the
//! mechanism behind the paper's Tables 2-4 speedups.

use anyhow::{bail, Context, Result};

pub trait Encode {
    fn encode(&self, out: &mut Vec<u8>);

    fn to_bytes(&self) -> Vec<u8> {
        let mut v = Vec::new();
        self.encode(&mut v);
        v
    }
}

pub trait Decode: Sized {
    fn decode(input: &mut &[u8]) -> Result<Self>;

    fn from_bytes(mut bytes: &[u8]) -> Result<Self> {
        let v = Self::decode(&mut bytes)?;
        if !bytes.is_empty() {
            bail!("{} trailing bytes after decode", bytes.len());
        }
        Ok(v)
    }
}

fn take<'a>(input: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if input.len() < n {
        bail!("codec underrun: need {n} bytes, have {}", input.len());
    }
    let (head, tail) = input.split_at(n);
    *input = tail;
    Ok(head)
}

macro_rules! impl_prim {
    ($($t:ty),*) => {$(
        impl Encode for $t {
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
        }
        impl Decode for $t {
            fn decode(input: &mut &[u8]) -> Result<Self> {
                let bytes = take(input, std::mem::size_of::<$t>())?;
                Ok(<$t>::from_le_bytes(bytes.try_into().unwrap()))
            }
        }
    )*};
}

impl_prim!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64, usize, isize);

impl Encode for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(*self as u8);
    }
}

impl Decode for bool {
    fn decode(input: &mut &[u8]) -> Result<Self> {
        Ok(take(input, 1)?[0] != 0)
    }
}

impl Encode for String {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        out.extend_from_slice(self.as_bytes());
    }
}

impl Decode for String {
    fn decode(input: &mut &[u8]) -> Result<Self> {
        let len = u64::decode(input)? as usize;
        let bytes = take(input, len)?;
        String::from_utf8(bytes.to_vec()).context("invalid utf-8 in codec")
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        for item in self {
            item.encode(out);
        }
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(input: &mut &[u8]) -> Result<Self> {
        let len = u64::decode(input)? as usize;
        // Guard absurd lengths so corrupt files fail fast, not OOM.
        if len > input.len() + (1 << 24) {
            bail!("codec: implausible vec length {len}");
        }
        let mut v = Vec::with_capacity(len.min(1 << 20));
        for _ in 0..len {
            v.push(T::decode(input)?);
        }
        Ok(v)
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                v.encode(out);
            }
        }
    }
}

impl<T: Decode> Decode for Option<T> {
    fn decode(input: &mut &[u8]) -> Result<Self> {
        match take(input, 1)?[0] {
            0 => Ok(None),
            1 => Ok(Some(T::decode(input)?)),
            other => bail!("codec: bad Option tag {other}"),
        }
    }
}

macro_rules! impl_tuple {
    ($($name:ident : $idx:tt),+) => {
        impl<$($name: Encode),+> Encode for ($($name,)+) {
            fn encode(&self, out: &mut Vec<u8>) {
                $(self.$idx.encode(out);)+
            }
        }
        impl<$($name: Decode),+> Decode for ($($name,)+) {
            fn decode(input: &mut &[u8]) -> Result<Self> {
                Ok(($($name::decode(input)?,)+))
            }
        }
    };
}

impl_tuple!(A: 0);
impl_tuple!(A: 0, B: 1);
impl_tuple!(A: 0, B: 1, C: 2);
impl_tuple!(A: 0, B: 1, C: 2, D: 3);

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = v.to_bytes();
        assert_eq!(T::from_bytes(&bytes).unwrap(), v);
    }

    #[test]
    fn primitives() {
        roundtrip(42u8);
        roundtrip(-7i64);
        roundtrip(3.25f64);
        roundtrip(usize::MAX);
        roundtrip(true);
    }

    #[test]
    fn strings_and_vecs() {
        roundtrip(String::from("ACGT-N ≈ ülträ"));
        roundtrip(vec![1u32, 2, 3]);
        roundtrip(Vec::<String>::new());
        roundtrip(vec![vec![1u8, 2], vec![], vec![3]]);
    }

    #[test]
    fn tuples_and_options() {
        roundtrip((1u32, String::from("x"), vec![2u64]));
        roundtrip(Option::<u32>::None);
        roundtrip(Some(vec![(1u8, 2u8)]));
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = 5u32.to_bytes();
        bytes.push(0);
        assert!(u32::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_underrun_and_bad_tags() {
        assert!(u64::from_bytes(&[1, 2, 3]).is_err());
        assert!(Option::<u8>::from_bytes(&[9]).is_err());
        let huge = (u64::MAX).to_bytes();
        assert!(Vec::<u8>::from_bytes(&huge).is_err());
    }
}

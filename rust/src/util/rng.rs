//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component (dataset generation, sampling clustering,
//! fault injection, property tests) threads an explicit `Rng` so runs are
//! reproducible from a single seed — required for the paper-table benches
//! to be comparable across backends.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        // SplitMix64 expansion, as recommended by the xoshiro authors.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-partition / per-worker rngs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, bound) without modulo bias (Lemire reduction).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from 0..n (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Pick per categorical weights (unnormalized, non-negative).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if t < *w {
                return i;
            }
            t -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::seed_from_u64(4);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(6);
        let s = r.sample_indices(100, 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..200 {
            assert_ne!(r.weighted(&[1.0, 0.0, 3.0]), 1);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::seed_from_u64(8);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}

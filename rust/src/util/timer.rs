//! Minimal stopwatch + duration formatting in the paper's "1 h 25 m" style.

use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now(), laps: Vec::new() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Record a named lap measured from the previous lap (or start).
    pub fn lap(&mut self, name: &str) -> Duration {
        let total: Duration = self.laps.iter().map(|(_, d)| *d).sum();
        let d = self.elapsed().saturating_sub(total);
        self.laps.push((name.to_string(), d));
        d
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Format like the paper's tables: "14 s", "10 m 24 s", "1 h 25 m".
pub fn fmt_duration(d: Duration) -> String {
    let secs = d.as_secs_f64();
    if secs < 1.0 {
        format!("{:.0} ms", secs * 1e3)
    } else if secs < 60.0 {
        format!("{secs:.1} s")
    } else if secs < 3600.0 {
        format!("{} m {} s", (secs as u64) / 60, (secs as u64) % 60)
    } else {
        format!("{} h {} m", (secs as u64) / 3600, ((secs as u64) % 3600) / 60)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_paper_style() {
        assert_eq!(fmt_duration(Duration::from_millis(250)), "250 ms");
        assert_eq!(fmt_duration(Duration::from_secs(14)), "14.0 s");
        assert_eq!(fmt_duration(Duration::from_secs(624)), "10 m 24 s");
        assert_eq!(fmt_duration(Duration::from_secs(5100)), "1 h 25 m");
    }

    #[test]
    fn laps_partition_elapsed() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(5));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(5));
        sw.lap("b");
        let total: Duration = sw.laps().iter().map(|(_, d)| *d).sum();
        assert!(total <= sw.elapsed());
        assert_eq!(sw.laps().len(), 2);
    }
}

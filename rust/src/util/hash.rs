//! Deterministic hashing for shuffle partitioning and grouping maps.
//!
//! `std::collections::HashMap`'s default `RandomState` is seeded per
//! process, which would make partition contents (and therefore task
//! timings and spill sizes) non-reproducible across runs; every map the
//! engine uses for keyed data is a [`DetHashMap`] instead (FNV-1a, fixed
//! offset basis).

use std::hash::{BuildHasherDefault, Hash, Hasher};

/// FNV-1a 64-bit.
#[derive(Debug, Default, Clone)]
pub struct FnvHasher(u64);

const OFFSET: u64 = 0xcbf29ce484222325;
const PRIME: u64 = 0x100000001b3;

impl Hasher for FnvHasher {
    fn finish(&self) -> u64 {
        if self.0 == 0 {
            OFFSET
        } else {
            self.0
        }
    }

    fn write(&mut self, bytes: &[u8]) {
        let mut h = if self.0 == 0 { OFFSET } else { self.0 };
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
        self.0 = h;
    }
}

pub type DetState = BuildHasherDefault<FnvHasher>;
pub type DetHashMap<K, V> = std::collections::HashMap<K, V, DetState>;
pub type DetHashSet<K> = std::collections::HashSet<K, DetState>;

/// Deterministic 64-bit hash of any `Hash` value.
pub fn det_hash<T: Hash>(value: &T) -> u64 {
    let mut h = FnvHasher::default();
    value.hash(&mut h);
    h.finish()
}

/// Stable reduce-partition assignment for a key.
pub fn partition_for<T: Hash>(key: &T, num_partitions: usize) -> usize {
    (det_hash(key) % num_partitions as u64) as usize
}

/// Canonical SplitMix64 step: cheap, deterministic, well-mixed — the
/// hash behind the executor's sampled victim picks.  (The PRNG in
/// `util::rng` and the fault plan use seed-pinned variants of the same
/// mix; their exact bit streams are locked by seeded tests, so they stay
/// inlined.)
pub fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_across_calls() {
        assert_eq!(det_hash(&"abc"), det_hash(&"abc"));
        assert_ne!(det_hash(&"abc"), det_hash(&"abd"));
    }

    #[test]
    fn partitions_in_range_and_spread() {
        let mut counts = vec![0usize; 7];
        for i in 0..700u64 {
            counts[partition_for(&i, 7)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 50), "skewed: {counts:?}");
    }

    #[test]
    fn det_map_is_usable() {
        let mut m: DetHashMap<String, u32> = DetHashMap::default();
        m.insert("x".into(), 1);
        assert_eq!(m["x"], 1);
    }
}

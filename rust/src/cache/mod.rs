//! Content-hash result memoization for the serving layer.
//!
//! Two pieces (see `rust/CACHE.md` for the full contract):
//!
//! * [`canonical_digest`] / [`DigestBuilder`] — a canonical FNV-1a
//!   digest over a FASTA submission.  Whitespace, line wrapping, header
//!   comments and residue case are already normalized away by the FASTA
//!   parser, so the digest is computed over parsed `Sequence`s: two
//!   submissions that differ only in formatting hash identically.
//!   Sequence *order* is deliberately part of the hash — center-star
//!   output depends on it (the center is picked from the input order),
//!   so reordered submissions are different jobs with different (equally
//!   correct) artifacts.
//! * [`ArtifactStore`] — a byte-budgeted, LRU, spill-to-disk blob store
//!   keyed by digest, holding encoded [`crate::align::append::MsaArtifact`]s.
//!   Same discipline as the distmat `TileStore`: spill writes are atomic
//!   (tmp+rename via `write_atomic`) and run outside the store mutex;
//!   resident peak stays ≤ budget + one artifact.  Unlike `TileStore`,
//!   a missing key is a normal cache miss (`Ok(None)`), not an error,
//!   and hit/miss counters feed the server status page and
//!   `BENCH_serve.json`.
//!
//! The cache serves three traffic shapes in `POST /align`: exact
//! resubmissions (digest hit → render the stored artifact locally,
//! engine untouched), appends (`?parent=<hash>` → extend the parent
//! artifact in O(new work)), and fresh jobs (miss → full run, artifact
//! stored under the submission digest).

pub mod store;

pub use store::ArtifactStore;

use std::hash::Hasher as _;

use crate::fasta::Sequence;
use crate::util::hash::FnvHasher;

/// Bump when the digest layout below changes — old cache entries must
/// not be served to a new hashing scheme.
pub const DIGEST_VERSION: u8 = 1;

/// Streaming canonical digest over parsed sequence records.  Records can
/// be fed from a slice ([`canonical_digest`]) or incrementally — the
/// append path digests `parent rows ++ new sequences` without
/// materializing the union.
#[derive(Debug, Clone)]
pub struct DigestBuilder {
    h: FnvHasher,
    records: u64,
}

impl DigestBuilder {
    pub fn new() -> Self {
        let mut h = FnvHasher::default();
        h.write(b"halign2-fasta-digest");
        h.write(&[DIGEST_VERSION]);
        DigestBuilder { h, records: 0 }
    }

    /// Fold one record.  `0xFF` never occurs in UTF-8, so it terminates
    /// the id unambiguously; codes get a length prefix so record
    /// boundaries cannot alias (`("ab", "c")` vs `("a", "bc")`).
    pub fn record(&mut self, id: &str, codes: &[u8], alphabet: crate::fasta::Alphabet) {
        self.h.write(id.as_bytes());
        self.h.write(&[0xFF]);
        self.h.write(&(codes.len() as u64).to_le_bytes());
        self.h.write(codes);
        self.h.write(&[alphabet as u8]);
        self.records += 1;
    }

    pub fn push(&mut self, seq: &Sequence) {
        self.record(&seq.id, &seq.codes, seq.alphabet);
    }

    pub fn finish(mut self) -> u64 {
        self.h.write(&self.records.to_le_bytes());
        self.h.finish()
    }
}

impl Default for DigestBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// Canonical content hash of a submission (see module docs for what is
/// and is not normalized).
pub fn canonical_digest(seqs: &[Sequence]) -> u64 {
    let mut b = DigestBuilder::new();
    for s in seqs {
        b.push(s);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fasta::{read_fasta, Alphabet};

    fn parse(text: &str) -> Vec<Sequence> {
        read_fasta(text.as_bytes(), Alphabet::Dna).unwrap()
    }

    #[test]
    fn formatting_does_not_change_the_digest() {
        let a = parse(">s1 extra words\nACGTACGT\n>s2\nTTTTACGT\n");
        let b = parse(">s1\tother comment\r\nacgt\r\nACGT\r\n>s2\ntttt\nACGT\n\n");
        assert_eq!(canonical_digest(&a), canonical_digest(&b));
    }

    #[test]
    fn order_content_and_boundaries_all_matter() {
        let d = canonical_digest(&parse(">a\nACGT\n>b\nTTTT\n"));
        assert_ne!(
            d,
            canonical_digest(&parse(">b\nTTTT\n>a\nACGT\n")),
            "order is part of the job identity"
        );
        assert_ne!(d, canonical_digest(&parse(">a\nACGA\n>b\nTTTT\n")));
        assert_ne!(d, canonical_digest(&parse(">a2\nACGT\n>b\nTTTT\n")));
        // Residues must not slide across record boundaries.
        assert_ne!(d, canonical_digest(&parse(">a\nACGTT\n>b\nTTT\n")));
    }

    #[test]
    fn incremental_builder_equals_slice_digest() {
        let seqs = parse(">a\nACGT\n>b\nTTTT\n>c\nGGGG\n");
        let whole = canonical_digest(&seqs);
        let mut b = DigestBuilder::new();
        for s in &seqs[..2] {
            b.push(s);
        }
        b.record(&seqs[2].id, &seqs[2].codes, Alphabet::Dna);
        assert_eq!(b.finish(), whole, "union digest must be buildable incrementally");
    }
}

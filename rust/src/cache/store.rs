//! Byte-budgeted artifact store: digest-keyed `Vec<u8>` blobs with LRU
//! spill-to-disk — the `TileStore` discipline (see `distmat/store.rs`)
//! applied to whole-job alignment artifacts.
//!
//! Same invariants as the tile store: spill writes are atomic
//! (tmp+rename via `write_atomic` — pallas-lint rule W7 forbids anything
//! else in this module) and run *outside* the store mutex via a
//! versioned "spilling" side map, so a slow disk never blocks concurrent
//! hits on resident artifacts; `put` replaces and keeps accounting
//! stable under at-least-once producers; the resident peak stays
//! `<= budget + one artifact`.
//!
//! Differences from the tile store, both because this is a *cache* and
//! not a materialized working set:
//!
//! * a missing key is a normal miss — `get` returns `Ok(None)`, never an
//!   error — and hits/misses are counted for the status page and the
//!   serve bench;
//! * the store always has a spill directory: artifacts must survive
//!   eviction or a "cached" job would silently recompute.

use std::collections::hash_map::Entry;
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context as _, Result};

struct ResidentBlob {
    data: Arc<Vec<u8>>,
    last_access: u64,
}

struct SpillEntry {
    data: Arc<Vec<u8>>,
    version: u64,
}

struct PendingSpill {
    key: u64,
    path: PathBuf,
    data: Arc<Vec<u8>>,
    version: u64,
}

struct StoreInner {
    resident: HashMap<u64, ResidentBlob>,
    /// Monotone access counter: `get`/`put` stamp blobs in O(1); only
    /// eviction (rare) scans for the minimum stamp.
    tick: u64,
    resident_bytes: usize,
    /// Keys whose *current* bytes are already on disk (skip re-spill).
    persisted: HashSet<u64>,
    /// Per-key write generation, bumped by `put`: lets a `get` that read
    /// the spill file outside the lock detect a concurrent supersede.
    versions: HashMap<u64, u64>,
    /// Evicted-but-not-yet-durable blobs (see `TileStore::spilling`).
    spilling: HashMap<u64, SpillEntry>,
    /// Every key ever stored — distinguishes "spilled to disk" from
    /// "never seen" without touching the filesystem on a miss.
    known: HashSet<u64>,
}

impl StoreInner {
    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn coldest(&self) -> Option<u64> {
        self.resident.iter().min_by_key(|(_, b)| b.last_access).map(|(&k, _)| k)
    }
}

/// Digest-keyed artifact cache (see module docs).
pub struct ArtifactStore {
    inner: Mutex<StoreInner>,
    dir: PathBuf,
    budget: usize,
    peak: AtomicUsize,
    spill_files: AtomicUsize,
    spill_reads: AtomicUsize,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl ArtifactStore {
    /// Budgeted cache spilling to `dir` (created if missing); the
    /// directory is removed on drop.
    pub fn new(dir: PathBuf, byte_budget: usize) -> Result<Self> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating artifact cache dir {}", dir.display()))?;
        Ok(Self {
            inner: Mutex::new(StoreInner {
                resident: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
                persisted: HashSet::new(),
                versions: HashMap::new(),
                spilling: HashMap::new(),
                known: HashSet::new(),
            }),
            dir,
            budget: byte_budget,
            peak: AtomicUsize::new(0),
            spill_files: AtomicUsize::new(0),
            spill_reads: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        })
    }

    pub fn byte_budget(&self) -> usize {
        self.budget
    }

    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().resident_bytes
    }

    /// High-water mark of resident bytes — bounded by
    /// `byte_budget + largest artifact`, never O(all artifacts).
    pub fn peak_resident_bytes(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn spill_files_written(&self) -> usize {
        self.spill_files.load(Ordering::Relaxed)
    }

    pub fn spill_reads(&self) -> usize {
        self.spill_reads.load(Ordering::Relaxed)
    }

    /// `get` calls that found an artifact (resident or spilled).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// `get` calls for keys never stored.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Artifacts currently stored (resident or spilled).
    pub fn entries(&self) -> usize {
        self.inner.lock().unwrap().known.len()
    }

    fn blob_path(&self, key: u64) -> PathBuf {
        self.dir.join(format!("artifact-{key:016x}.bin"))
    }

    /// Evict LRU blobs past the budget; keep the most recently touched
    /// blob resident; hand unpersisted victims back for the caller to
    /// write after releasing the lock (W2/W7: no I/O under the mutex,
    /// all writes through `write_atomic`).
    fn collect_spill_victims(&self, st: &mut StoreInner) -> Vec<PendingSpill> {
        let mut victims = Vec::new();
        while st.resident_bytes > self.budget && st.resident.len() > 1 {
            let Some(key) = st.coldest() else { break };
            let Some(blob) = st.resident.remove(&key) else { break };
            st.resident_bytes -= blob.data.len();
            if st.persisted.contains(&key) {
                continue;
            }
            let version = st.versions.get(&key).copied().unwrap_or(0);
            let path = self.blob_path(key);
            match st.spilling.entry(key) {
                Entry::Occupied(mut e) => {
                    *e.get_mut() = SpillEntry { data: blob.data, version };
                }
                Entry::Vacant(slot) => {
                    slot.insert(SpillEntry { data: blob.data.clone(), version });
                    victims.push(PendingSpill { key, path, data: blob.data, version });
                }
            }
        }
        victims
    }

    /// Persist evicted blobs outside the store lock; identical protocol
    /// to `TileStore::write_spills` (re-write until the spilling entry
    /// and the file agree).
    fn write_spills(&self, victims: Vec<PendingSpill>) -> Result<()> {
        for mut job in victims {
            loop {
                crate::engine::shuffle::write_atomic(&job.path, &job.data)
                    .with_context(|| format!("spilling artifact {}", job.path.display()))?;
                self.spill_files.fetch_add(1, Ordering::Relaxed);
                let mut st = self.inner.lock().unwrap();
                match st.spilling.get(&job.key) {
                    Some(e) if e.version != job.version => {
                        job.data = e.data.clone();
                        job.version = e.version;
                    }
                    _ => {
                        if st.versions.get(&job.key).copied().unwrap_or(0) == job.version {
                            st.persisted.insert(job.key);
                        }
                        st.spilling.remove(&job.key);
                        break;
                    }
                }
            }
        }
        Ok(())
    }

    fn admit(&self, st: &mut StoreInner, key: u64, data: Arc<Vec<u8>>) -> Vec<PendingSpill> {
        let tick = st.next_tick();
        let blob = ResidentBlob { data: data.clone(), last_access: tick };
        if let Some(old) = st.resident.insert(key, blob) {
            st.resident_bytes -= old.data.len();
        }
        st.resident_bytes += data.len();
        self.peak.fetch_max(st.resident_bytes, Ordering::Relaxed);
        self.collect_spill_victims(st)
    }

    /// Insert (or replace) the artifact for `key`.
    pub fn put(&self, key: u64, data: Vec<u8>) -> Result<()> {
        let victims = {
            let mut st = self.inner.lock().unwrap();
            st.known.insert(key);
            st.persisted.remove(&key);
            *st.versions.entry(key).or_insert(0) += 1;
            self.admit(&mut st, key, Arc::new(data))
        };
        self.write_spills(victims)
    }

    /// Look up the artifact for `key`.  `Ok(None)` is a cache miss;
    /// spilled entries are re-read from disk (outside the lock, with the
    /// same version-race retry as `TileStore::get`) and re-admitted.
    pub fn get(&self, key: u64) -> Result<Option<Arc<Vec<u8>>>> {
        let mut counted = false;
        loop {
            let seen_version = {
                let mut st = self.inner.lock().unwrap();
                if !st.known.contains(&key) {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return Ok(None);
                }
                if !counted {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    counted = true;
                }
                let tick = st.next_tick();
                if let Some(blob) = st.resident.get_mut(&key) {
                    blob.last_access = tick;
                    return Ok(Some(blob.data.clone()));
                }
                if let Some(e) = st.spilling.get(&key) {
                    return Ok(Some(e.data.clone()));
                }
                st.versions.get(&key).copied().unwrap_or(0)
            };
            let path = self.blob_path(key);
            let data = std::fs::read(&path)
                .with_context(|| format!("reading spilled artifact {}", path.display()))?;
            self.spill_reads.fetch_add(1, Ordering::Relaxed);
            let arc = Arc::new(data);
            let victims = {
                let mut st = self.inner.lock().unwrap();
                if let Some(raced) = st.resident.get(&key) {
                    return Ok(Some(raced.data.clone()));
                }
                if let Some(e) = st.spilling.get(&key) {
                    return Ok(Some(e.data.clone()));
                }
                if st.versions.get(&key).copied().unwrap_or(0) != seen_version {
                    continue;
                }
                let victims = self.admit(&mut st, key, arc.clone());
                st.persisted.insert(key);
                victims
            };
            self.write_spills(victims)?;
            return Ok(Some(arc));
        }
    }
}

impl Drop for ArtifactStore {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("halign2-artifacts-{}-{tag}", std::process::id()))
    }

    #[test]
    fn miss_then_hit_and_counters() {
        let s = ArtifactStore::new(tmpdir("hits"), 1 << 20).unwrap();
        assert!(s.get(1).unwrap().is_none());
        assert_eq!((s.hits(), s.misses()), (0, 1));
        s.put(1, vec![7u8; 100]).unwrap();
        assert_eq!(s.get(1).unwrap().unwrap().as_slice(), &[7u8; 100][..]);
        assert_eq!((s.hits(), s.misses()), (1, 1));
        assert_eq!(s.entries(), 1);
    }

    #[test]
    fn eviction_under_budget_spills_and_rereads_bit_exact() {
        let budget = 300;
        let s = ArtifactStore::new(tmpdir("evict"), budget).unwrap();
        let blob = |k: u64| -> Vec<u8> { (0..120).map(|i| (k as u8).wrapping_mul(31).wrapping_add(i)).collect() };
        for k in 0..8u64 {
            s.put(k, blob(k)).unwrap();
        }
        assert!(s.resident_bytes() <= budget, "budget enforced");
        assert!(s.spill_files_written() >= 5, "older artifacts spilled");
        assert!(
            s.peak_resident_bytes() <= budget + 120,
            "peak {} must stay <= budget + one artifact",
            s.peak_resident_bytes()
        );
        for k in 0..8u64 {
            assert_eq!(
                s.get(k).unwrap().unwrap().as_slice(),
                blob(k).as_slice(),
                "key {k}: spill must round-trip bit-exactly"
            );
        }
        assert!(s.spill_reads() >= 5);
    }

    #[test]
    fn replacement_keeps_accounting_stable() {
        let s = ArtifactStore::new(tmpdir("replace"), 1 << 20).unwrap();
        for _ in 0..5 {
            s.put(9, vec![1u8; 400]).unwrap();
        }
        assert_eq!(s.resident_bytes(), 400, "replace, don't accumulate");
        assert_eq!(s.entries(), 1);
    }

    #[test]
    fn drop_removes_the_cache_dir() {
        let dir = tmpdir("drop");
        let s = ArtifactStore::new(dir.clone(), 64).unwrap();
        s.put(1, vec![0u8; 256]).unwrap();
        s.put(2, vec![0u8; 256]).unwrap();
        drop(s);
        assert!(!dir.exists());
    }
}

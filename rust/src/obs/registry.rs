//! Process-wide metrics registry: named counters, gauges and
//! log2-bucketed latency histograms behind `Arc` handles.
//!
//! Design constraints (see `rust/OBSERVABILITY.md` for the contract):
//!
//! * **Lock-free on the record path.**  A [`Counter`] increment is one
//!   `fetch_add`; a [`Histogram::record`] is three `fetch_add`s plus a
//!   conditional `fetch_max` — no mutex is ever taken while recording,
//!   so instrumented code (including the executor's worker hot path)
//!   cannot block on observability.  The registry's own mutex guards
//!   only registration and scrape-time enumeration, both cold paths.
//! * **Exact merge.**  Histograms are plain per-bucket counts, so two
//!   snapshots merge by integer addition with no approximation beyond
//!   the bucketing itself.
//! * **Dependency-free rendering.**  [`Registry::render_prometheus`]
//!   emits the Prometheus text exposition format by hand (the
//!   `server/http.rs` discipline): `# HELP`/`# TYPE` preambles,
//!   `family{labels} value` samples, and cumulative `_bucket`/`_sum`/
//!   `_count` series for histograms.
//!
//! Unit convention: histograms record **nanoseconds** and their family
//! names end in `_seconds`; rendering divides by 1e9 so scrapes see
//! base-unit seconds, while in-process percentile math stays integer.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic counter.  Also exposes `AtomicU64`-shaped shims
/// (`fetch_add`/`fetch_sub`/`load`) so a struct field that used to be a
/// bare atomic can become a registered counter without touching every
/// call site; the shims ignore the caller's ordering and use `Relaxed`
/// (counters are statistics, not synchronization).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    /// `AtomicU64` compatibility shim (ordering ignored, always Relaxed).
    pub fn fetch_add(&self, n: u64, _order: Ordering) -> u64 {
        self.v.fetch_add(n, Ordering::Relaxed)
    }

    /// `AtomicU64` compatibility shim; used by the shuffle re-put
    /// correction, which retracts a duplicate map task's bytes before
    /// crediting the fresh ones.
    pub fn fetch_sub(&self, n: u64, _order: Ordering) -> u64 {
        self.v.fetch_sub(n, Ordering::Relaxed)
    }

    /// `AtomicU64` compatibility shim (ordering ignored, always Relaxed).
    pub fn load(&self, _order: Ordering) -> u64 {
        self.get()
    }
}

/// Last-write-wins instantaneous value (resident bytes, worker count).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    pub fn set(&self, n: u64) {
        self.v.store(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Bucket count: one per power of two plus the zero bucket —
/// `bucket_index` maps 0 → 0 and v ∈ [2^(k-1), 2^k) → k, so index 64
/// catches values in the top half of the u64 range.
pub const NUM_BUCKETS: usize = 65;

/// Which bucket a recorded value lands in (0 for 0, else
/// `64 - leading_zeros`, i.e. one past the highest set bit).
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Inclusive lower bound of bucket `i` (0 for the zero bucket).
pub fn bucket_lower_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the top bucket).
pub fn bucket_upper_bound(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Log2-bucketed histogram.  Recording is a few relaxed atomic RMWs on
/// per-bucket counters — safe from any number of threads concurrently,
/// never blocking.  Reads go through [`Histogram::snapshot`].
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>, // NUM_BUCKETS slots
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation (by convention, nanoseconds).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Point-in-time copy.  The count is derived from the bucket counts
    /// themselves, so `count == buckets.sum()` holds by construction
    /// even under concurrent recording (sum/max may lag by in-flight
    /// records; bucket counts are individually exact).
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = buckets.iter().sum();
        HistSnapshot {
            buckets,
            count,
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    /// Shorthand: percentile of a fresh snapshot, in milliseconds
    /// (recording convention is nanoseconds).
    pub fn percentile_ms(&self, q: f64) -> f64 {
        self.snapshot().percentile(q) as f64 / 1e6
    }
}

/// Frozen histogram state: mergeable and queryable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistSnapshot {
    pub fn empty() -> Self {
        Self { buckets: vec![0; NUM_BUCKETS], count: 0, sum: 0, max: 0 }
    }

    /// Exact merge: integer addition per bucket (associative and
    /// commutative — the property the obs_prop suite pins).
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        let buckets: Vec<u64> = (0..NUM_BUCKETS)
            .map(|i| {
                self.buckets.get(i).copied().unwrap_or(0)
                    + other.buckets.get(i).copied().unwrap_or(0)
            })
            .collect();
        let count = buckets.iter().sum();
        HistSnapshot {
            buckets,
            count,
            sum: self.sum + other.sum,
            max: self.max.max(other.max),
        }
    }

    /// Value at quantile `q` in [0, 1]: the upper bound of the bucket
    /// where the cumulative count crosses `ceil(q * count)`, capped at
    /// the recorded max so tail quantiles never exceed any observation.
    /// Returns 0 for an empty snapshot.  Monotone in `q` by cumulative
    /// construction.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_upper_bound(i).min(self.max);
            }
        }
        self.max
    }
}

/// What a family holds; a family's kind is fixed by its first
/// registration.
#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

#[derive(Debug)]
struct Instance {
    labels: Vec<(String, String)>,
    metric: Metric,
}

#[derive(Debug)]
struct Family {
    help: String,
    instances: Vec<Instance>,
}

/// Named metric families, each holding one instance per distinct label
/// set.  Registration is idempotent: re-registering the same
/// (family, labels) pair returns the existing handle, so a lazy
/// register-on-use call site stays cheap and never double-counts.
#[derive(Debug, Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn register_counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.register_counter_labeled(name, help, &[])
    }

    pub fn register_counter_labeled(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Counter> {
        let fresh = Metric::Counter(Arc::new(Counter::default()));
        match self.intern(name, help, labels, fresh) {
            Metric::Counter(c) => c,
            // Kind clash with an existing family: hand back a live but
            // unregistered counter rather than corrupting the family
            // (pallas-lint W8 keeps registrations single-sited, so this
            // arm is a programming-error escape hatch, not a code path).
            _ => Arc::new(Counter::default()),
        }
    }

    pub fn register_gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let fresh = Metric::Gauge(Arc::new(Gauge::default()));
        match self.intern(name, help, &[], fresh) {
            Metric::Gauge(g) => g,
            _ => Arc::new(Gauge::default()),
        }
    }

    pub fn register_histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        self.register_histogram_labeled(name, help, &[])
    }

    pub fn register_histogram_labeled(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
    ) -> Arc<Histogram> {
        let fresh = Metric::Histogram(Arc::new(Histogram::default()));
        match self.intern(name, help, labels, fresh) {
            Metric::Histogram(h) => h,
            _ => Arc::new(Histogram::default()),
        }
    }

    fn intern(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        fresh: Metric,
    ) -> Metric {
        let labels: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        let mut fams = self.families.lock().unwrap();
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            instances: Vec::new(),
        });
        if let Some(inst) = fam.instances.iter().find(|i| i.labels == labels) {
            return inst.metric.clone();
        }
        if let Some(first) = fam.instances.first() {
            if first.metric.kind() != fresh.kind() {
                return fresh; // kind clash: caller gets an unregistered handle
            }
        }
        fam.instances.push(Instance { labels, metric: fresh.clone() });
        fresh
    }

    /// Every registered family name, sorted (drives the W8 fixture
    /// assertions and the status page).
    pub fn family_names(&self) -> Vec<String> {
        self.families.lock().unwrap().keys().cloned().collect()
    }

    /// All instances of a histogram family as (rendered label set,
    /// handle) pairs — the status page's per-route percentile source.
    pub fn histograms(&self, family: &str) -> Vec<(String, Arc<Histogram>)> {
        let fams = self.families.lock().unwrap();
        let Some(fam) = fams.get(family) else {
            return Vec::new();
        };
        fam.instances
            .iter()
            .filter_map(|i| match &i.metric {
                Metric::Histogram(h) => Some((label_str(&i.labels), h.clone())),
                _ => None,
            })
            .collect()
    }

    /// Prometheus text exposition format (version 0.0.4), hand-rolled.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let fams = self.families.lock().unwrap();
        for (name, fam) in fams.iter() {
            let Some(kind) = fam.instances.first().map(|i| i.metric.kind()) else {
                continue;
            };
            out.push_str(&format!("# HELP {name} {}\n", fam.help));
            out.push_str(&format!("# TYPE {name} {kind}\n"));
            for inst in &fam.instances {
                let labels = label_str(&inst.labels);
                match &inst.metric {
                    Metric::Counter(c) => {
                        out.push_str(&sample(name, "", &labels, &c.get().to_string()));
                    }
                    Metric::Gauge(g) => {
                        out.push_str(&sample(name, "", &labels, &g.get().to_string()));
                    }
                    Metric::Histogram(h) => {
                        render_histogram(&mut out, name, &labels, &h.snapshot());
                    }
                }
            }
        }
        out
    }
}

/// One exposition line: `name[_suffix]{labels} value`.
fn sample(name: &str, suffix: &str, labels: &str, value: &str) -> String {
    if labels.is_empty() {
        format!("{name}{suffix} {value}\n")
    } else {
        format!("{name}{suffix}{{{labels}}} {value}\n")
    }
}

/// Cumulative `_bucket` series over the non-empty log2 buckets, plus
/// the mandatory `+Inf` bucket and `_sum`/`_count`.  `le` bounds and
/// `_sum` are converted from recorded nanoseconds to seconds (the
/// `_seconds` naming convention).
fn render_histogram(out: &mut String, name: &str, labels: &str, snap: &HistSnapshot) {
    let mut cum = 0u64;
    for (i, &c) in snap.buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        let le = bucket_upper_bound(i) as f64 / 1e9;
        let with_le = if labels.is_empty() {
            format!("le=\"{le}\"")
        } else {
            format!("{labels},le=\"{le}\"")
        };
        out.push_str(&sample(name, "_bucket", &with_le, &cum.to_string()));
    }
    let inf = if labels.is_empty() {
        "le=\"+Inf\"".to_string()
    } else {
        format!("{labels},le=\"+Inf\"")
    };
    out.push_str(&sample(name, "_bucket", &inf, &snap.count.to_string()));
    out.push_str(&sample(name, "_sum", labels, &format!("{}", snap.sum as f64 / 1e9)));
    out.push_str(&sample(name, "_count", labels, &snap.count.to_string()));
}

fn label_str(labels: &[(String, String)]) -> String {
    labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_bracket_their_values() {
        for v in [0u64, 1, 2, 3, 7, 8, 1023, 1024, u64::MAX / 2, u64::MAX] {
            let i = bucket_index(v);
            assert!(bucket_lower_bound(i) <= v, "lower({i}) <= {v}");
            assert!(v <= bucket_upper_bound(i), "{v} <= upper({i})");
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
    }

    #[test]
    fn percentiles_are_monotone_and_capped_at_max() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 1000, 5000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        let mut prev = 0;
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let p = s.percentile(q);
            assert!(p >= prev, "percentile must be monotone in q");
            assert!(p <= s.max, "percentile can never exceed the recorded max");
            prev = p;
        }
        assert_eq!(s.percentile(1.0), 5000, "p100 of this set is its max");
        assert_eq!(HistSnapshot::empty().percentile(0.5), 0);
    }

    #[test]
    fn merge_is_exact_and_associative() {
        let mk = |vals: &[u64]| {
            let h = Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h.snapshot()
        };
        let a = mk(&[1, 2, 3]);
        let b = mk(&[100, 200]);
        let c = mk(&[7]);
        assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
        assert_eq!(a.merge(&b).count, 5);
        assert_eq!(a.merge(&b).sum, 306);
        assert_eq!(a.merge(&b).max, 200);
        assert_eq!(a.merge(&b), b.merge(&a), "merge is commutative");
    }

    #[test]
    fn registration_is_idempotent_per_label_set() {
        let r = Registry::new();
        let c1 = r.register_counter("requests_total", "requests");
        let c2 = r.register_counter("requests_total", "requests");
        c1.inc();
        assert_eq!(c2.get(), 1, "same family+labels must share one counter");
        let l1 = r.register_counter_labeled("requests_total", "requests", &[("route", "a")]);
        l1.add(5);
        assert_eq!(c1.get(), 1, "labeled instance is distinct");
        assert_eq!(r.family_names(), vec!["requests_total".to_string()]);
    }

    #[test]
    fn prometheus_rendering_has_types_samples_and_buckets() {
        let r = Registry::new();
        r.register_counter("jobs_total", "jobs").add(3);
        r.register_gauge("resident_bytes", "bytes").set(42);
        let h = r.register_histogram_labeled(
            "req_seconds",
            "latency",
            &[("route", "align")],
        );
        h.record(1_000_000); // 1ms
        h.record(2_000_000);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE jobs_total counter"));
        assert!(text.contains("jobs_total 3"));
        assert!(text.contains("# TYPE resident_bytes gauge"));
        assert!(text.contains("resident_bytes 42"));
        assert!(text.contains("# TYPE req_seconds histogram"));
        assert!(text.contains("req_seconds_bucket{route=\"align\",le=\"+Inf\"} 2"));
        assert!(text.contains("req_seconds_count{route=\"align\"} 2"));
        assert!(text.contains("req_seconds_sum{route=\"align\"}"));
    }

    #[test]
    fn histogram_family_enumeration_feeds_the_status_page() {
        let r = Registry::new();
        r.register_histogram_labeled("req_seconds", "latency", &[("route", "a")])
            .record(5);
        r.register_histogram_labeled("req_seconds", "latency", &[("route", "b")])
            .record(7);
        let all = r.histograms("req_seconds");
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].0, "route=\"a\"");
        assert!(r.histograms("nope").is_empty());
    }
}

//! Unified observability: one metrics registry, one trace sink.
//!
//! Before this module, runtime statistics lived in five disconnected
//! places — `WorkerMetrics`, `ClusterStats`, the cache counters, the
//! TileStore spill counters, and `IoCounters` — none of them
//! percentile-aware and none machine-scrapeable.  `obs` is the
//! substrate they all register into:
//!
//! * [`registry`] — named atomic [`Counter`]s, [`Gauge`]s, and
//!   log2-bucketed [`Histogram`]s behind a process-wide [`Registry`]
//!   that renders the Prometheus text exposition format for the
//!   server's `GET /metrics`.
//! * [`trace`] — bounded per-lane ring buffers recording task
//!   lifecycle events, drained into Chrome trace-event JSON so a fig6
//!   run renders as a worker×time Gantt chart in Perfetto.
//! * [`profile`] — post-hoc analysis over drained traces: per-lane ×
//!   per-stage self-time (collapsed-stack flamegraph export),
//!   scheduler gap classification (idle / steal-wait / drain-wait),
//!   and critical-path extraction with `critical_path_frac`.
//!
//! Everything is `std`-only and lock-free on the record path; the
//! naming contract and the machine-parsed family table live in
//! `rust/OBSERVABILITY.md` (enforced by pallas-lint W8).

pub mod profile;
pub mod registry;
pub mod trace;

use std::sync::Arc;

pub use profile::Profile;
pub use registry::{Counter, Gauge, HistSnapshot, Histogram, Registry};
pub use trace::{
    chrome_trace_json, is_json_array, is_json_object, TraceEvent, TraceKind, TraceSink,
};

/// The executor's registered instruments, created once per cluster in
/// `Executor::with_options` and shared (via `Arc`) with both scheduler
/// backends.  This is the single registration site for the engine
/// metric families (pallas-lint W8 pins that).
#[derive(Debug)]
pub struct EngineObs {
    /// Tasks whose closures ran to completion (either attempt).
    pub tasks_run: Arc<Counter>,
    /// Task closures that panicked or were failed by fault injection.
    pub task_failures: Arc<Counter>,
    /// Jobs moved between workers by steals (sum of batch sizes).
    pub tasks_stolen: Arc<Counter>,
    /// Steal operations (each moving one or more jobs).
    pub steal_batches: Arc<Counter>,
    /// `try_lock` misses on the scheduler locks (sharded: shard deques;
    /// global: the single state lock).
    pub lock_contention: Arc<Counter>,
    /// Speculative re-launches of straggler tasks.
    pub speculative_launches: Arc<Counter>,
    /// Worker-side task execution latency, recorded in nanoseconds.
    pub task_exec: Arc<Histogram>,
    /// Worker thread count for this cluster.
    pub workers: Arc<Gauge>,
    /// Lifecycle trace rings (capacity 0 = tracing disabled).
    pub trace: Arc<TraceSink>,
}

impl EngineObs {
    pub fn register(
        registry: &Registry,
        num_workers: usize,
        trace_capacity: usize,
    ) -> Arc<EngineObs> {
        let trace_dropped = registry.register_counter(
            "halign_trace_dropped_total",
            "Trace events dropped to ring-buffer overflow",
        );
        let workers = registry.register_gauge(
            "halign_workers",
            "Worker threads in the executor pool",
        );
        workers.set(num_workers as u64);
        Arc::new(EngineObs {
            tasks_run: registry.register_counter(
                "halign_tasks_run_total",
                "Task closures executed to completion",
            ),
            task_failures: registry.register_counter(
                "halign_task_failures_total",
                "Task closures that panicked or were fault-injected",
            ),
            tasks_stolen: registry.register_counter(
                "halign_tasks_stolen_total",
                "Jobs migrated between workers by work-stealing",
            ),
            steal_batches: registry.register_counter(
                "halign_steal_batches_total",
                "Steal operations (each moves a batch of jobs)",
            ),
            lock_contention: registry.register_counter(
                "halign_lock_contention_total",
                "Scheduler lock try_lock misses",
            ),
            speculative_launches: registry.register_counter(
                "halign_speculative_launches_total",
                "Straggler tasks re-launched speculatively",
            ),
            task_exec: registry.register_histogram(
                "halign_task_exec_seconds",
                "Worker-side task execution latency",
            ),
            workers,
            // Driver gets its own lane after the workers.
            trace: TraceSink::new(num_workers + 1, trace_capacity, trace_dropped),
        })
    }
}

//! Post-hoc profiling over the trace rings: turns a drained event list
//! into the three views a perf investigation needs.
//!
//! * **Span aggregation** — `Start`/`Finish` pairs fold into per-lane ×
//!   per-stage self-time / count / max tables, exported in collapsed-
//!   stack format (`worker0;stage1;task 420`) so any flamegraph tool
//!   (inferno, flamegraph.pl, speedscope) renders them directly.
//! * **Scheduler gap analysis** — the gaps between consecutive spans on
//!   a worker lane partition its wall-clock into self / steal-wait /
//!   drain-wait / idle exactly (integer nanos, no residue), and the
//!   `Enqueue` instants yield the enqueue→start queueing delay.
//! * **Critical-path extraction** — `run_tasks` is a barrier, so the
//!   stages of a job form a sequential dependency chain (the
//!   `ClusterStats::stage_edges` the engine exports).  Within a stage
//!   the *winning attempt* of each task (earliest `Finish`, which is
//!   what unblocks the barrier under speculation) is selected, and the
//!   longest winner per stage is the stage's critical task; the path is
//!   the chain of those, with `critical_path_frac = path / wall_clock`
//!   as the headline number.  Winner spans of successive stages are
//!   time-disjoint (a stage's winners all end before the next stage
//!   submits), so the path never exceeds the wall-clock by
//!   construction.
//!
//! Everything here runs on already-drained `Vec<TraceEvent>` — no locks,
//! no interaction with live rings — so the server can profile a retained
//! trace long after the job finished.

use std::collections::BTreeMap;

use super::trace::{TraceEvent, TraceKind};

/// One aggregate row: everything lane `lane` spent executing tasks of
/// stage `stage`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageRow {
    pub lane: usize,
    pub stage: u64,
    /// Completed spans folded into this row.
    pub count: u64,
    /// Total execution nanos (the flamegraph weight).
    pub self_nanos: u64,
    /// Longest single span in the row.
    pub max_nanos: u64,
}

/// Exact partition of one worker lane's wall-clock: task execution plus
/// classified gaps.  `self + steal_wait + drain_wait + idle` equals the
/// job wall-clock exactly (integer nanos).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneGaps {
    pub lane: usize,
    pub self_nanos: u64,
    /// Gap containing a `Steal` instant on this lane: the worker was
    /// out of local work and went stealing.
    pub steal_wait_nanos: u64,
    /// Gap containing a `KillDrain` instant (any lane): the scheduler
    /// was redistributing a dead worker's deque.
    pub drain_wait_nanos: u64,
    /// Everything else: parked with no work available.
    pub idle_nanos: u64,
}

/// Enqueue→start queueing delay, aggregated over every task whose
/// `Enqueue` instant and first `Start` both appear in the trace.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QueueDelay {
    pub samples: u64,
    pub total_nanos: u64,
    pub max_nanos: u64,
}

/// One link of the critical path: the stage's slowest winning task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PathEntry {
    pub stage: u64,
    pub task: u64,
    pub dur_nanos: u64,
}

/// The full post-hoc profile of one drained trace.
#[derive(Debug, Clone)]
pub struct Profile {
    /// Last event minus first event across all lanes (0 when the trace
    /// holds fewer than two events).
    pub wall_nanos: u64,
    pub num_lanes: usize,
    /// Per-lane × per-stage self-time table, sorted by (lane, stage).
    pub aggregate: Vec<StageRow>,
    /// Gap analysis per worker lane (the driver lane runs no spans and
    /// is omitted).
    pub lanes: Vec<LaneGaps>,
    pub queue: QueueDelay,
    /// Stage chain, ascending; one entry per stage with completed spans.
    pub critical_path: Vec<PathEntry>,
    /// Sum of the path entries' durations.
    pub critical_path_nanos: u64,
    /// `critical_path_nanos / wall_nanos`; in `(0, 1]` whenever the
    /// trace holds at least one completed span, else 0.
    pub critical_path_frac: f64,
}

/// A completed execution span recovered from a `Start`/`Finish` pair.
#[derive(Debug, Clone, Copy)]
struct Span {
    lane: usize,
    stage: u64,
    task: u64,
    start: u64,
    end: u64,
}

impl Span {
    fn dur(&self) -> u64 {
        self.end - self.start
    }
}

/// Human lane label matching the Chrome export (`worker N` / `driver`).
pub fn lane_label(lane: usize, num_lanes: usize) -> String {
    if lane + 1 == num_lanes && num_lanes > 1 {
        "driver".to_string()
    } else {
        format!("worker{lane}")
    }
}

impl Profile {
    /// Build the profile from a drained event list (the output of
    /// `TraceSink::drain_new`; any order is accepted, events are
    /// re-sorted).  `num_lanes` follows the sink's convention: lanes
    /// `0..num_lanes-1` are workers, the last lane is the driver.
    pub fn from_events(events: &[TraceEvent], num_lanes: usize) -> Profile {
        let mut evs: Vec<TraceEvent> =
            events.iter().filter(|e| e.lane < num_lanes).copied().collect();
        evs.sort_by_key(|e| (e.nanos, e.lane));
        let wall_lo = evs.first().map(|e| e.nanos).unwrap_or(0);
        let wall_hi = evs.last().map(|e| e.nanos).unwrap_or(0);
        let wall_nanos = wall_hi - wall_lo;

        // ---- Span pairing: a worker runs one task at a time, so each
        // lane carries at most one open span; a Start whose Finish was
        // lost (ring overflow, killed worker) is superseded by the next
        // Start and dropped.
        let mut pending: Vec<Option<(u64, u64)>> = vec![None; num_lanes];
        let mut spans: Vec<Span> = Vec::new();
        let mut steal_times: Vec<Vec<u64>> = vec![Vec::new(); num_lanes];
        let mut drain_times: Vec<u64> = Vec::new();
        let mut enqueue_at: BTreeMap<u64, u64> = BTreeMap::new();
        let mut first_start_at: BTreeMap<u64, u64> = BTreeMap::new();
        for e in &evs {
            match e.kind {
                TraceKind::Start => {
                    pending[e.lane] = Some((e.payload, e.nanos));
                    first_start_at.entry(e.payload).or_insert(e.nanos);
                }
                TraceKind::Finish => {
                    if let Some((payload, start)) = pending[e.lane] {
                        if payload == e.payload {
                            spans.push(Span {
                                lane: e.lane,
                                stage: payload >> 32,
                                task: payload & 0xffff_ffff,
                                start,
                                end: e.nanos.max(start),
                            });
                            pending[e.lane] = None;
                        }
                    }
                }
                TraceKind::Steal => steal_times[e.lane].push(e.nanos),
                TraceKind::KillDrain => drain_times.push(e.nanos),
                TraceKind::Enqueue => {
                    enqueue_at.entry(e.payload).or_insert(e.nanos);
                }
                _ => {}
            }
        }

        // ---- Aggregation per (lane, stage).
        let mut agg: BTreeMap<(usize, u64), (u64, u64, u64)> = BTreeMap::new();
        for sp in &spans {
            let row = agg.entry((sp.lane, sp.stage)).or_insert((0, 0, 0));
            row.0 += 1;
            row.1 += sp.dur();
            row.2 = row.2.max(sp.dur());
        }
        let aggregate: Vec<StageRow> = agg
            .into_iter()
            .map(|((lane, stage), (count, self_nanos, max_nanos))| StageRow {
                lane,
                stage,
                count,
                self_nanos,
                max_nanos,
            })
            .collect();

        // ---- Gap analysis: walk each worker lane's timeline from
        // wall_lo to wall_hi; spans and classified gaps partition it
        // exactly.  A gap is steal-wait if a Steal instant on this lane
        // falls inside it, else drain-wait if any KillDrain does, else
        // idle.
        let worker_lanes = if num_lanes > 1 { num_lanes - 1 } else { num_lanes };
        let mut lanes_out: Vec<LaneGaps> = Vec::with_capacity(worker_lanes);
        for lane in 0..worker_lanes {
            let mut lane_spans: Vec<&Span> = spans.iter().filter(|s| s.lane == lane).collect();
            lane_spans.sort_by_key(|s| s.start);
            let mut g = LaneGaps {
                lane,
                self_nanos: 0,
                steal_wait_nanos: 0,
                drain_wait_nanos: 0,
                idle_nanos: 0,
            };
            let in_window = |ts: &[u64], lo: u64, hi: u64| ts.iter().any(|&t| t >= lo && t < hi);
            let mut classify = |lo: u64, hi: u64| {
                let dur = hi - lo;
                if in_window(&steal_times[lane], lo, hi) {
                    g.steal_wait_nanos += dur;
                } else if in_window(&drain_times, lo, hi) {
                    g.drain_wait_nanos += dur;
                } else {
                    g.idle_nanos += dur;
                }
            };
            let mut cursor = wall_lo;
            for sp in lane_spans {
                let start = sp.start.max(cursor);
                classify(cursor, start);
                g.self_nanos += sp.end.saturating_sub(start);
                cursor = cursor.max(sp.end);
            }
            classify(cursor, wall_hi.max(cursor));
            lanes_out.push(g);
        }

        // ---- Queue delay: enqueue instant → first start, per payload.
        let mut queue = QueueDelay::default();
        for (payload, &enq) in &enqueue_at {
            if let Some(&start) = first_start_at.get(payload) {
                if start >= enq {
                    let d = start - enq;
                    queue.samples += 1;
                    queue.total_nanos += d;
                    queue.max_nanos = queue.max_nanos.max(d);
                }
            }
        }

        // ---- Critical path: winning attempt (earliest Finish) per
        // (stage, task), then the longest winner per stage, chained in
        // stage order.
        let mut winners: BTreeMap<(u64, u64), (u64, u64)> = BTreeMap::new(); // (end, dur)
        for sp in &spans {
            let w = winners.entry((sp.stage, sp.task)).or_insert((sp.end, sp.dur()));
            if sp.end < w.0 {
                *w = (sp.end, sp.dur());
            }
        }
        let mut per_stage: BTreeMap<u64, (u64, u64)> = BTreeMap::new(); // stage -> (task, dur)
        for (&(stage, task), &(_, dur)) in &winners {
            let e = per_stage.entry(stage).or_insert((task, dur));
            if dur > e.1 {
                *e = (task, dur);
            }
        }
        let critical_path: Vec<PathEntry> = per_stage
            .into_iter()
            .map(|(stage, (task, dur_nanos))| PathEntry { stage, task, dur_nanos })
            .collect();
        let critical_path_nanos: u64 = critical_path.iter().map(|p| p.dur_nanos).sum();
        let critical_path_frac = if wall_nanos == 0 {
            // A degenerate trace (all events share one timestamp) still
            // counts as fully on-path when it ran anything at all.
            if critical_path.is_empty() {
                0.0
            } else {
                1.0
            }
        } else {
            // Zero-duration winner spans can make the sum 0 while work
            // clearly happened; clamp into (0, 1] whenever a span
            // completed so the headline stays an honest fraction.
            let raw = critical_path_nanos as f64 / wall_nanos as f64;
            if critical_path.is_empty() {
                0.0
            } else {
                raw.clamp(f64::MIN_POSITIVE, 1.0)
            }
        };

        Profile {
            wall_nanos,
            num_lanes,
            aggregate,
            lanes: lanes_out,
            queue,
            critical_path,
            critical_path_nanos,
            critical_path_frac,
        }
    }

    /// Collapsed-stack flamegraph lines: one per aggregate row,
    /// `<lane>;stage<stage>;task <weight-micros>`, weight floored at 1
    /// so every line carries a positive integer weight.
    pub fn collapsed_stack(&self) -> String {
        let mut out = String::new();
        for row in &self.aggregate {
            let micros = ((row.self_nanos + 500) / 1000).max(1);
            out.push_str(&format!(
                "{};stage{};task {micros}\n",
                lane_label(row.lane, self.num_lanes),
                row.stage
            ));
        }
        out
    }

    /// The `k` stages with the most total self-time across lanes,
    /// descending: `(stage, self_nanos)`.
    pub fn top_self_stages(&self, k: usize) -> Vec<(u64, u64)> {
        let mut per_stage: BTreeMap<u64, u64> = BTreeMap::new();
        for row in &self.aggregate {
            *per_stage.entry(row.stage).or_insert(0) += row.self_nanos;
        }
        let mut v: Vec<(u64, u64)> = per_stage.into_iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// The profile as one JSON object (hand-rolled, std-only — same
    /// policy as the Chrome export and the bench JSON writers).
    pub fn to_json(&self) -> String {
        let aggregate: Vec<String> = self
            .aggregate
            .iter()
            .map(|r| {
                format!(
                    "{{\"lane\":\"{}\",\"stage\":{},\"count\":{},\
                     \"self_nanos\":{},\"max_nanos\":{}}}",
                    lane_label(r.lane, self.num_lanes),
                    r.stage,
                    r.count,
                    r.self_nanos,
                    r.max_nanos
                )
            })
            .collect();
        let lanes: Vec<String> = self
            .lanes
            .iter()
            .map(|g| {
                format!(
                    "{{\"lane\":\"{}\",\"self_nanos\":{},\"steal_wait_nanos\":{},\
                     \"drain_wait_nanos\":{},\"idle_nanos\":{}}}",
                    lane_label(g.lane, self.num_lanes),
                    g.self_nanos,
                    g.steal_wait_nanos,
                    g.drain_wait_nanos,
                    g.idle_nanos
                )
            })
            .collect();
        let path: Vec<String> = self
            .critical_path
            .iter()
            .map(|p| {
                format!(
                    "{{\"stage\":{},\"task\":{},\"dur_nanos\":{}}}",
                    p.stage, p.task, p.dur_nanos
                )
            })
            .collect();
        let avg_queue = if self.queue.samples == 0 {
            0
        } else {
            self.queue.total_nanos / self.queue.samples
        };
        format!(
            "{{\"wall_nanos\":{},\"num_lanes\":{},\"aggregate\":[{}],\
             \"lanes\":[{}],\
             \"queue\":{{\"samples\":{},\"avg_nanos\":{avg_queue},\"max_nanos\":{}}},\
             \"critical_path\":[{}],\"critical_path_nanos\":{},\
             \"critical_path_frac\":{:.6}}}",
            self.wall_nanos,
            self.num_lanes,
            aggregate.join(","),
            lanes.join(","),
            self.queue.samples,
            self.queue.max_nanos,
            path.join(","),
            self.critical_path_nanos,
            self.critical_path_frac
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::is_json_object;

    fn ev(nanos: u64, lane: usize, kind: TraceKind, payload: u64) -> TraceEvent {
        TraceEvent { nanos, lane, kind, payload }
    }

    fn pack(stage: u64, task: u64) -> u64 {
        (stage << 32) | task
    }

    #[test]
    fn empty_trace_profiles_to_zeroes() {
        let p = Profile::from_events(&[], 3);
        assert_eq!(p.wall_nanos, 0);
        assert!(p.aggregate.is_empty());
        assert!(p.critical_path.is_empty());
        assert_eq!(p.critical_path_frac, 0.0);
        assert!(p.collapsed_stack().is_empty());
    }

    #[test]
    fn spans_aggregate_per_lane_and_stage() {
        // Lane 0 runs two stage-1 tasks (10ns, 30ns); lane 1 one
        // stage-2 task (50ns).  Driver is lane 2.
        let events = [
            ev(0, 0, TraceKind::Start, pack(1, 0)),
            ev(10, 0, TraceKind::Finish, pack(1, 0)),
            ev(20, 0, TraceKind::Start, pack(1, 1)),
            ev(50, 0, TraceKind::Finish, pack(1, 1)),
            ev(60, 1, TraceKind::Start, pack(2, 0)),
            ev(110, 1, TraceKind::Finish, pack(2, 0)),
        ];
        let p = Profile::from_events(&events, 3);
        assert_eq!(p.wall_nanos, 110);
        assert_eq!(p.aggregate.len(), 2);
        let r0 = &p.aggregate[0];
        assert_eq!((r0.lane, r0.stage, r0.count, r0.self_nanos, r0.max_nanos), (0, 1, 2, 40, 30));
        let r1 = &p.aggregate[1];
        assert_eq!((r1.lane, r1.stage, r1.count, r1.self_nanos, r1.max_nanos), (1, 2, 1, 50, 50));
        // Collapsed stack: arity 3 on ';', positive integer weight.
        let stack = p.collapsed_stack();
        for line in stack.lines() {
            let (frames, weight) = line.rsplit_once(' ').unwrap();
            assert_eq!(frames.split(';').count(), 3, "{line}");
            assert!(weight.parse::<u64>().unwrap() >= 1, "{line}");
        }
        assert!(stack.contains("worker0;stage1;task"), "{stack}");
        assert!(is_json_object(&p.to_json()), "{}", p.to_json());
    }

    #[test]
    fn gap_classification_partitions_the_lane_exactly() {
        // Lane 0: span [0,10), gap [10,40) containing a steal at 20,
        // span [40,60), gap [60,100) containing a kill-drain (driver
        // lane) at 70.  Wall = 100.
        let events = [
            ev(0, 0, TraceKind::Start, pack(1, 0)),
            ev(10, 0, TraceKind::Finish, pack(1, 0)),
            ev(20, 0, TraceKind::Steal, 2),
            ev(40, 0, TraceKind::Start, pack(1, 1)),
            ev(60, 0, TraceKind::Finish, pack(1, 1)),
            ev(70, 1, TraceKind::KillDrain, 3),
            ev(100, 1, TraceKind::CacheMiss, 0),
        ];
        let p = Profile::from_events(&events, 2);
        let g = &p.lanes[0];
        assert_eq!(g.self_nanos, 30);
        assert_eq!(g.steal_wait_nanos, 30, "steal instant claims its gap");
        assert_eq!(g.drain_wait_nanos, 40, "kill-drain claims the tail gap");
        assert_eq!(g.idle_nanos, 0);
        assert_eq!(
            g.self_nanos + g.steal_wait_nanos + g.drain_wait_nanos + g.idle_nanos,
            p.wall_nanos,
            "partition must be exact"
        );
    }

    #[test]
    fn critical_path_picks_winning_attempts_per_stage() {
        // Stage 1, task 0 runs twice (speculation): the slow original
        // [0,100) loses to the duplicate [10,30) — winner dur 20.
        // Stage 1, task 1: [5,50), dur 45 → stage-1 critical task.
        // Stage 2, task 0: [120,160), dur 40.
        let events = [
            ev(0, 0, TraceKind::Start, pack(1, 0)),
            ev(5, 1, TraceKind::Start, pack(1, 1)),
            ev(10, 2, TraceKind::Start, pack(1, 0)),
            ev(30, 2, TraceKind::Finish, pack(1, 0)),
            ev(50, 1, TraceKind::Finish, pack(1, 1)),
            ev(100, 0, TraceKind::Finish, pack(1, 0)),
            ev(120, 0, TraceKind::Start, pack(2, 0)),
            ev(160, 0, TraceKind::Finish, pack(2, 0)),
        ];
        let p = Profile::from_events(&events, 4);
        assert_eq!(p.critical_path.len(), 2);
        assert_eq!(
            (p.critical_path[0].stage, p.critical_path[0].task, p.critical_path[0].dur_nanos),
            (1, 1, 45),
            "stage 1's critical task is the longest WINNER, not the zombie original"
        );
        assert_eq!(
            (p.critical_path[1].stage, p.critical_path[1].task, p.critical_path[1].dur_nanos),
            (2, 0, 40)
        );
        assert_eq!(p.critical_path_nanos, 85);
        assert!(p.critical_path_frac > 0.0 && p.critical_path_frac <= 1.0);
        assert!((p.critical_path_frac - 85.0 / 160.0).abs() < 1e-9);
    }

    #[test]
    fn queue_delay_matches_enqueue_to_first_start() {
        let events = [
            ev(0, 2, TraceKind::Enqueue, pack(1, 0)),
            ev(7, 2, TraceKind::Enqueue, pack(1, 1)),
            ev(10, 0, TraceKind::Start, pack(1, 0)),
            ev(12, 1, TraceKind::Start, pack(1, 1)),
            ev(20, 0, TraceKind::Finish, pack(1, 0)),
            ev(22, 1, TraceKind::Finish, pack(1, 1)),
        ];
        let p = Profile::from_events(&events, 3);
        assert_eq!(p.queue.samples, 2);
        assert_eq!(p.queue.total_nanos, 10 + 5);
        assert_eq!(p.queue.max_nanos, 10);
    }

    #[test]
    fn top_self_stages_ranks_by_total_self_time() {
        let events = [
            ev(0, 0, TraceKind::Start, pack(1, 0)),
            ev(10, 0, TraceKind::Finish, pack(1, 0)),
            ev(20, 0, TraceKind::Start, pack(2, 0)),
            ev(100, 0, TraceKind::Finish, pack(2, 0)),
            ev(110, 1, TraceKind::Start, pack(2, 1)),
            ev(130, 1, TraceKind::Finish, pack(2, 1)),
        ];
        let p = Profile::from_events(&events, 3);
        let top = p.top_self_stages(3);
        assert_eq!(top, vec![(2, 100), (1, 10)]);
        assert_eq!(p.top_self_stages(1).len(), 1);
    }
}

//! Task-lifecycle tracing: bounded per-lane ring buffers drained into
//! Chrome trace-event JSON (loadable in Perfetto / `chrome://tracing`).
//!
//! The recording path is built for the executor's worker hot loop:
//!
//! * **Never blocks.**  [`TraceSink::emit`] claims a slot with one
//!   `fetch_add` on the lane's head counter and writes three atomic
//!   words — no mutex, no allocation, no syscall.  When the ring is
//!   full the claim simply wraps, dropping the oldest event and
//!   bumping the shared `trace_dropped` counter; a slow drainer can
//!   lose history but can never stall a worker.
//! * **Compiles to a cheap no-op when disabled.**  A sink built with
//!   capacity 0 returns from `emit` after a single field load, so
//!   un-traced runs (the default) pay essentially nothing.
//! * **Tear-resistant drain.**  Each slot is three `AtomicU64` words;
//!   the writer stores the kind word last with `Release` and the
//!   drainer reads it first with `Acquire`, then discards any slot
//!   whose absolute index could have been overwritten while copying
//!   (`index + capacity < head_after`).  A drain that races a burst of
//!   writes may miss a bounded number of in-flight events; it never
//!   yields a torn one and is exact once writers quiesce (the normal
//!   case: traces are drained at job boundaries).
//!
//! Lane convention: lanes `0..num_workers` are worker threads, lane
//! `num_workers` is the driver.  Timestamps are nanoseconds since sink
//! creation, rendered as microsecond `ts` values in the trace JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::registry::Counter;

/// Task lifecycle event kinds.  Discriminants start at 1 so a zeroed
/// (never-written) slot word can be recognized and skipped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// Task handed to a queue; payload = task ordinal.
    Enqueue = 1,
    /// Thief moved work from a victim; payload = batch size.
    Steal = 2,
    /// Straggler re-launched; payload = task ordinal.
    SpeculativeLaunch = 3,
    /// Worker began executing; payload = task ordinal.
    Start = 4,
    /// Worker finished executing; payload = task ordinal.
    Finish = 5,
    /// Killed worker's deque drained back to the pool; payload =
    /// number of drained jobs.
    KillDrain = 6,
    /// Tile or artifact spilled to disk; payload = bytes.
    Spill = 7,
    /// Artifact cache hit; payload = 0.
    CacheHit = 8,
    /// Artifact cache profile-append; payload = 0.
    CacheAppend = 9,
    /// Artifact cache miss (full recompute); payload = 0.
    CacheMiss = 10,
}

impl TraceKind {
    fn from_u64(v: u64) -> Option<TraceKind> {
        use TraceKind::*;
        Some(match v {
            1 => Enqueue,
            2 => Steal,
            3 => SpeculativeLaunch,
            4 => Start,
            5 => Finish,
            6 => KillDrain,
            7 => Spill,
            8 => CacheHit,
            9 => CacheAppend,
            10 => CacheMiss,
            _ => return None,
        })
    }

    /// Event name in the exported trace.
    pub fn name(self) -> &'static str {
        use TraceKind::*;
        match self {
            Enqueue => "enqueue",
            Steal => "steal",
            SpeculativeLaunch => "speculative_launch",
            Start => "task",
            Finish => "task",
            KillDrain => "kill_drain",
            Spill => "spill",
            CacheHit => "cache_hit",
            CacheAppend => "cache_append",
            CacheMiss => "cache_miss",
        }
    }
}

/// One drained event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub nanos: u64,
    pub lane: usize,
    pub kind: TraceKind,
    pub payload: u64,
}

/// Fixed-capacity multi-writer ring.  Slots are claimed by a
/// `fetch_add` on `head` (every claim gets a unique absolute index, so
/// concurrent writers never share a slot); claims past capacity wrap
/// and overwrite the oldest slot.
struct TraceRing {
    /// 3 words per slot: nanos, kind, payload.  Kind is written last
    /// (Release) and read first (Acquire) so a non-zero kind implies
    /// the other two words are from the same event.
    slots: Vec<AtomicU64>,
    head: AtomicU64,
    capacity: usize,
}

impl TraceRing {
    fn new(capacity: usize) -> Self {
        Self {
            slots: (0..capacity * 3).map(|_| AtomicU64::new(0)).collect(),
            head: AtomicU64::new(0),
            capacity,
        }
    }

    /// Returns true if this push displaced an older event.
    fn push(&self, nanos: u64, kind: TraceKind, payload: u64) -> bool {
        let h = self.head.fetch_add(1, Ordering::Relaxed);
        let base = (h as usize % self.capacity) * 3;
        self.slots[base].store(nanos, Ordering::Relaxed);
        self.slots[base + 2].store(payload, Ordering::Relaxed);
        self.slots[base + 1].store(kind as u64, Ordering::Release);
        h as usize >= self.capacity
    }

    /// Copy out events with absolute index in `[since, head)`, skipping
    /// overwritten and in-flight slots.  Returns (events tagged with
    /// their absolute index, head at drain time).
    fn drain_since(&self, lane: usize, since: u64) -> (Vec<(u64, TraceEvent)>, u64) {
        let head_before = self.head.load(Ordering::Acquire);
        let lo = since.max(head_before.saturating_sub(self.capacity as u64));
        let mut out = Vec::new();
        for idx in lo..head_before {
            let base = (idx as usize % self.capacity) * 3;
            let kind_word = self.slots[base + 1].load(Ordering::Acquire);
            let Some(kind) = TraceKind::from_u64(kind_word) else {
                continue; // claimed but not yet fully written
            };
            let nanos = self.slots[base].load(Ordering::Relaxed);
            let payload = self.slots[base + 2].load(Ordering::Relaxed);
            out.push((idx, TraceEvent { nanos, lane, kind, payload }));
        }
        // Any slot whose index could have been reclaimed while we were
        // copying may hold a mix of old and new words: discard it.
        let head_after = self.head.load(Ordering::Acquire);
        out.retain(|(idx, _)| idx + self.capacity as u64 >= head_after);
        (out, head_before)
    }
}

/// Per-lane trace rings plus the shared drop counter.  Cheaply
/// shareable (`Arc`) between the executor, the driver, and the server.
pub struct TraceSink {
    rings: Vec<TraceRing>,
    origin: Instant,
    dropped: Arc<Counter>,
    /// Per-lane absolute index of the last `drain_new` high-water mark.
    /// Cold path only (job boundaries); never taken while emitting.
    watermarks: Mutex<Vec<u64>>,
    capacity: usize,
    lanes: usize,
}

impl TraceSink {
    /// `capacity` is per lane; 0 disables tracing entirely.
    pub fn new(num_lanes: usize, capacity: usize, dropped: Arc<Counter>) -> Arc<Self> {
        let rings = if capacity == 0 {
            Vec::new()
        } else {
            (0..num_lanes).map(|_| TraceRing::new(capacity)).collect()
        };
        Arc::new(Self {
            rings,
            origin: Instant::now(),
            dropped,
            watermarks: Mutex::new(vec![0; num_lanes]),
            capacity,
            lanes: num_lanes,
        })
    }

    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn num_lanes(&self) -> usize {
        self.lanes
    }

    /// Record one event.  No-op when disabled or the lane is out of
    /// range; never blocks.
    pub fn emit(&self, lane: usize, kind: TraceKind, payload: u64) {
        if self.capacity == 0 {
            return;
        }
        let Some(ring) = self.rings.get(lane) else {
            return;
        };
        let nanos = self.origin.elapsed().as_nanos() as u64;
        if ring.push(nanos, kind, payload) {
            self.dropped.inc();
        }
    }

    /// Drain every event recorded since the previous `drain_new` call,
    /// across all lanes, sorted by timestamp.  Intended for job
    /// boundaries: each job's trace is the delta since the last drain.
    pub fn drain_new(&self) -> Vec<TraceEvent> {
        let mut marks = self.watermarks.lock().unwrap();
        let mut events = Vec::new();
        for (lane, ring) in self.rings.iter().enumerate() {
            let (mut chunk, head) = ring.drain_since(lane, marks[lane]);
            marks[lane] = head;
            events.extend(chunk.drain(..).map(|(_, e)| e));
        }
        events.sort_by_key(|e| (e.nanos, e.lane));
        events
    }

    /// Total events dropped to overflow across all lanes.
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink")
            .field("lanes", &self.rings.len())
            .field("capacity", &self.capacity)
            .field("dropped", &self.dropped.get())
            .finish()
    }
}

/// Render drained events as a Chrome trace-event JSON array.
///
/// * `Start`/`Finish` pairs become `B`/`E` duration events (a worker
///   runs one task at a time, so they nest correctly per thread).
/// * Everything else becomes a thread-scoped instant event (`"i"`).
/// * Lane `n` maps to `tid` `n + 1`; the last lane is named `driver`,
///   the rest `worker <n>`, via `thread_name` metadata events.
/// * `ts` is microseconds (float) since the sink's origin, the unit
///   the trace-event spec expects.
pub fn chrome_trace_json(events: &[TraceEvent], num_lanes: usize) -> String {
    let mut parts: Vec<String> = Vec::with_capacity(events.len() + num_lanes);
    for lane in 0..num_lanes {
        let name = if lane + 1 == num_lanes && num_lanes > 1 {
            "driver".to_string()
        } else {
            format!("worker {lane}")
        };
        parts.push(format!(
            "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"{name}\"}}}}",
            lane + 1
        ));
    }
    for e in events {
        let ts = e.nanos as f64 / 1000.0;
        let tid = e.lane + 1;
        let name = e.kind.name();
        let part = match e.kind {
            // Task payloads pack `(stage << 32) | task` (see the
            // executor); decode both halves so Perfetto shows which
            // stage a span belongs to.
            TraceKind::Start => format!(
                "{{\"ph\":\"B\",\"name\":\"{name}\",\"cat\":\"task\",\
                 \"pid\":1,\"tid\":{tid},\"ts\":{ts},\
                 \"args\":{{\"stage\":{},\"ordinal\":{}}}}}",
                e.payload >> 32,
                e.payload & 0xffff_ffff
            ),
            TraceKind::Finish => format!(
                "{{\"ph\":\"E\",\"name\":\"{name}\",\"cat\":\"task\",\
                 \"pid\":1,\"tid\":{tid},\"ts\":{ts},\
                 \"args\":{{\"stage\":{},\"ordinal\":{}}}}}",
                e.payload >> 32,
                e.payload & 0xffff_ffff
            ),
            _ => format!(
                "{{\"ph\":\"i\",\"name\":\"{name}\",\"cat\":\"sched\",\
                 \"pid\":1,\"tid\":{tid},\"ts\":{ts},\"s\":\"t\",\
                 \"args\":{{\"payload\":{}}}}}",
                e.payload
            ),
        };
        parts.push(part);
    }
    format!("[{}]", parts.join(","))
}

/// Minimal JSON validator: true iff `text` is one syntactically valid
/// JSON array (the Chrome trace-event container format).  Used by the
/// fig6 trace test and the serve bench to verify exports in-tree
/// without a JSON dependency.
pub fn is_json_array(text: &str) -> bool {
    let b = text.as_bytes();
    let mut pos = 0usize;
    skip_ws(b, &mut pos);
    if pos >= b.len() || b[pos] != b'[' {
        return false;
    }
    if !parse_value(b, &mut pos) {
        return false;
    }
    skip_ws(b, &mut pos);
    pos == b.len()
}

/// Companion validator: true iff `text` is one syntactically valid JSON
/// object (the `/profile/<hash>` response shape).
pub fn is_json_object(text: &str) -> bool {
    let b = text.as_bytes();
    let mut pos = 0usize;
    skip_ws(b, &mut pos);
    if pos >= b.len() || b[pos] != b'{' {
        return false;
    }
    if !parse_value(b, &mut pos) {
        return false;
    }
    skip_ws(b, &mut pos);
    pos == b.len()
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> bool {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return false;
    };
    match c {
        b'[' => parse_seq(b, pos, b']', |b, pos| parse_value(b, pos)),
        b'{' => parse_seq(b, pos, b'}', |b, pos| {
            skip_ws(b, pos);
            if !parse_string(b, pos) {
                return false;
            }
            skip_ws(b, pos);
            if b.get(*pos) != Some(&b':') {
                return false;
            }
            *pos += 1;
            parse_value(b, pos)
        }),
        b'"' => parse_string(b, pos),
        b't' => eat(b, pos, b"true"),
        b'f' => eat(b, pos, b"false"),
        b'n' => eat(b, pos, b"null"),
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        _ => false,
    }
}

fn parse_seq(
    b: &[u8],
    pos: &mut usize,
    close: u8,
    mut item: impl FnMut(&[u8], &mut usize) -> bool,
) -> bool {
    *pos += 1; // opening bracket/brace
    skip_ws(b, pos);
    if b.get(*pos) == Some(&close) {
        *pos += 1;
        return true;
    }
    loop {
        if !item(b, pos) {
            return false;
        }
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => {
                *pos += 1;
            }
            Some(&c) if c == close => {
                *pos += 1;
                return true;
            }
            _ => return false,
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> bool {
    if b.get(*pos) != Some(&b'"') {
        return false;
    }
    *pos += 1;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return true;
            }
            b'\\' => *pos += 2,
            _ => *pos += 1,
        }
    }
    false
}

fn parse_number(b: &[u8], pos: &mut usize) -> bool {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let digits_start = *pos;
    while matches!(b.get(*pos), Some(b'0'..=b'9')) {
        *pos += 1;
    }
    if *pos == digits_start {
        return false;
    }
    if b.get(*pos) == Some(&b'.') {
        *pos += 1;
        while matches!(b.get(*pos), Some(b'0'..=b'9')) {
            *pos += 1;
        }
    }
    if matches!(b.get(*pos), Some(b'e') | Some(b'E')) {
        *pos += 1;
        if matches!(b.get(*pos), Some(b'+') | Some(b'-')) {
            *pos += 1;
        }
        let exp_start = *pos;
        while matches!(b.get(*pos), Some(b'0'..=b'9')) {
            *pos += 1;
        }
        if *pos == exp_start {
            return false;
        }
    }
    *pos > start
}

fn eat(b: &[u8], pos: &mut usize, word: &[u8]) -> bool {
    if b.len() >= *pos + word.len() && &b[*pos..*pos + word.len()] == word {
        *pos += word.len();
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink(lanes: usize, cap: usize) -> Arc<TraceSink> {
        TraceSink::new(lanes, cap, Arc::new(Counter::default()))
    }

    #[test]
    fn disabled_sink_is_a_noop() {
        let s = sink(2, 0);
        assert!(!s.enabled());
        s.emit(0, TraceKind::Start, 1);
        assert!(s.drain_new().is_empty());
        assert_eq!(s.dropped(), 0);
    }

    #[test]
    fn events_round_trip_in_timestamp_order() {
        let s = sink(3, 64);
        s.emit(2, TraceKind::Enqueue, 7);
        s.emit(0, TraceKind::Start, 7);
        s.emit(0, TraceKind::Finish, 7);
        let ev = s.drain_new();
        assert_eq!(ev.len(), 3);
        assert!(ev.windows(2).all(|w| w[0].nanos <= w[1].nanos));
        assert_eq!(ev[0].kind, TraceKind::Enqueue);
        assert_eq!(ev[0].lane, 2);
        assert_eq!(ev[0].payload, 7);
        assert!(s.drain_new().is_empty(), "second drain sees only new events");
        s.emit(1, TraceKind::Steal, 4);
        assert_eq!(s.drain_new().len(), 1, "delta drain picks up the new event");
    }

    #[test]
    fn overflow_drops_oldest_and_counts_exactly() {
        let s = sink(1, 8);
        for i in 0..20u64 {
            s.emit(0, TraceKind::Enqueue, i);
        }
        assert_eq!(s.dropped(), 12, "drops = pushes - capacity, exactly");
        let ev = s.drain_new();
        assert_eq!(ev.len(), 8, "ring retains exactly its capacity");
        let payloads: Vec<u64> = ev.iter().map(|e| e.payload).collect();
        assert_eq!(payloads, (12..20).collect::<Vec<u64>>(), "oldest were dropped");
    }

    #[test]
    fn wrap_does_not_corrupt_events() {
        let s = sink(1, 4);
        // Push 3 full wraps of distinguishable events; after each wave
        // the drained payload/kind pairs must be internally consistent.
        for wave in 0..3u64 {
            for i in 0..4u64 {
                let kind = if i % 2 == 0 { TraceKind::Start } else { TraceKind::Finish };
                s.emit(0, kind, wave * 100 + i);
            }
            for e in s.drain_new() {
                let expect = if e.payload % 2 == 0 { TraceKind::Start } else { TraceKind::Finish };
                assert_eq!(e.kind, expect, "kind/payload pairing survives wrap");
            }
        }
    }

    #[test]
    fn chrome_export_is_a_valid_trace_array() {
        let s = sink(2, 16);
        s.emit(1, TraceKind::Enqueue, 0);
        s.emit(0, TraceKind::Start, 0);
        s.emit(0, TraceKind::Steal, 3);
        s.emit(0, TraceKind::Finish, 0);
        let json = chrome_trace_json(&s.drain_new(), 2);
        assert!(is_json_array(&json), "export must parse as a JSON array");
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("\"driver\""));
        assert!(json.contains("\"ph\":\"B\""));
        assert!(json.contains("\"ph\":\"E\""));
        assert!(json.contains("\"steal\""));
    }

    #[test]
    fn task_spans_decode_packed_stage_and_ordinal() {
        let s = sink(1, 16);
        s.emit(0, TraceKind::Start, (3 << 32) | 9);
        s.emit(0, TraceKind::Finish, (3 << 32) | 9);
        let json = chrome_trace_json(&s.drain_new(), 1);
        assert!(is_json_array(&json), "{json}");
        assert!(json.contains("\"stage\":3,\"ordinal\":9"), "{json}");
    }

    #[test]
    fn object_validator_accepts_and_rejects() {
        assert!(is_json_object("{}"));
        assert!(is_json_object("{\"a\":{\"b\":[1,2]},\"c\":0.5}"));
        assert!(!is_json_object("[]"));
        assert!(!is_json_object("{\"a\":}"));
        assert!(!is_json_object("{} trailing"));
    }

    #[test]
    fn json_validator_accepts_and_rejects() {
        for good in [
            "[]",
            "[1,2,3]",
            "[{\"a\":1},{\"b\":[true,null,\"x\"]}]",
            " [ {\"ts\": 1.5e3, \"s\": \"t\"} ] ",
            "[-0.5]",
        ] {
            assert!(is_json_array(good), "should accept {good:?}");
        }
        for bad in [
            "",
            "{}",
            "[1,",
            "[1,]",
            "[01x]",
            "[\"unterminated]",
            "[1] trailing",
            "[{\"a\" 1}]",
        ] {
            assert!(!is_json_array(bad), "should reject {bad:?}");
        }
    }
}

//! `pallas-lint` CLI: walk `rust/src/**`, enforce the project
//! invariants (W1–W8, see `rust/LINTS.md`), print findings as
//! `file:line rule message`, and write `LINT_REPORT.json` at the repo
//! root.
//!
//! Usage:
//!   pallas_lint [--deny] [--root <repo-root>] [--report <path>]
//!
//! `--deny` exits 1 when any unsuppressed finding remains — the CI
//! gate.  Exit 2 means the run itself failed (bad args, missing
//! `rust/LOCKS.md`, unreadable tree).

use halign2::lint;
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    deny: bool,
    root: Option<PathBuf>,
    report: Option<PathBuf>,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args { deny: false, root: None, report: None };
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--deny" => args.deny = true,
            "--root" => {
                let v = it.next().ok_or("--root needs a path")?;
                args.root = Some(PathBuf::from(v));
            }
            "--report" => {
                let v = it.next().ok_or("--report needs a path")?;
                args.report = Some(PathBuf::from(v));
            }
            "--help" | "-h" => {
                return Err("usage: pallas_lint [--deny] [--root <dir>] [--report <path>]"
                    .to_string())
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

/// The repo root is the nearest ancestor of the current directory that
/// contains `rust/src` (so the tool works from the repo root, from
/// `rust/`, or from anywhere inside it).
fn find_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("pallas-lint: {msg}");
            return ExitCode::from(2);
        }
    };
    let Some(root) = args.root.or_else(find_root) else {
        eprintln!("pallas-lint: could not locate a repo root containing rust/src; use --root");
        return ExitCode::from(2);
    };
    let cfg = match lint::load_config(&root) {
        Ok(cfg) => cfg,
        Err(e) => {
            eprintln!(
                "pallas-lint: cannot read {}: {e} (the lock hierarchy is required)",
                root.join("rust/LOCKS.md").display()
            );
            return ExitCode::from(2);
        }
    };
    let report = match lint::lint_tree(&root, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-lint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };
    for finding in report.unsuppressed() {
        println!("{}", finding.render());
    }
    let unsuppressed = report.unsuppressed_count();
    println!(
        "pallas-lint: {} finding(s) ({} suppressed) across {} file(s)",
        unsuppressed,
        report.suppressed_count(),
        report.files_scanned
    );
    let report_path = args.report.unwrap_or_else(|| root.join("LINT_REPORT.json"));
    if let Err(e) = std::fs::write(&report_path, report.to_json()) {
        eprintln!("pallas-lint: cannot write {}: {e}", report_path.display());
        return ExitCode::from(2);
    }
    if args.deny && unsuppressed > 0 {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}

//! The mini-Spark substrate: lazy RDDs with slice-aware lineage and
//! pairwise block-job primitives (`cartesian_blocks` /
//! `lower_triangle_blocks`, the distmat tile scheduler), a DAG-cut
//! scheduler, a sharded work-stealing worker executor (per-worker
//! deques, steal-half batching, sampled two-choice victim picks at high
//! worker counts, control-block coordination — plus a global-mutex
//! baseline for A/B) with variance-deadline speculative straggler
//! re-execution, swappable shuffle backends (in-memory Spark vs disk
//! key-value Hadoop) and offset-indexed checkpoint files (slices seek,
//! not prefix-decode), broadcast variables, per-worker memory
//! accounting, and deterministic fault injection (task failures and
//! worker kills, which drain the dead node's deque back into the steal
//! pool).
//!
//! See DESIGN.md §4 for how each piece maps onto the paper's system.

pub mod broadcast;
pub mod context;
pub mod executor;
pub mod fault;
pub mod memory;
pub mod pair;
pub mod rdd;
pub mod shuffle;

pub use broadcast::Broadcast;
pub use context::{stage_dependency_edges, Cluster, ClusterConfig, ClusterStats};
pub use executor::{ExecutorOptions, SchedulerMode, WorkerMetrics};
pub use fault::FaultPlan;
pub use memory::{MemSize, MemoryTracker};
pub use rdd::{Data, Rdd};
pub use shuffle::Backend;

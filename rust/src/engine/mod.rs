//! The mini-Spark substrate: lazy RDDs with slice-aware lineage, a
//! DAG-cut scheduler, a sharded work-stealing worker executor (per-worker
//! deques, steal-half batching, control-block coordination — plus a
//! global-mutex baseline for A/B) with speculative straggler
//! re-execution, swappable shuffle backends (in-memory Spark vs disk
//! key-value Hadoop), broadcast variables, per-worker memory accounting,
//! and deterministic fault injection (task failures and worker kills,
//! which drain the dead node's deque back into the steal pool).
//!
//! See DESIGN.md §4 for how each piece maps onto the paper's system.

pub mod broadcast;
pub mod context;
pub mod executor;
pub mod fault;
pub mod memory;
pub mod pair;
pub mod rdd;
pub mod shuffle;

pub use broadcast::Broadcast;
pub use context::{Cluster, ClusterConfig, ClusterStats};
pub use executor::{ExecutorOptions, SchedulerMode, WorkerMetrics};
pub use fault::FaultPlan;
pub use memory::{MemSize, MemoryTracker};
pub use rdd::{Data, Rdd};
pub use shuffle::Backend;

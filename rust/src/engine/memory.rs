//! Per-worker memory accounting — the instrumentation behind Figure 5
//! ("average maximum memory usage of each machine on the cluster").
//!
//! Real RSS is meaningless here (every simulated worker shares one
//! process), so the engine accounts *logical* resident bytes the way a
//! cluster scheduler would: cached partitions, in-flight task buffers,
//! shuffle map-output buffers, and broadcast replicas are charged to the
//! owning worker when created and released when dropped/spilled.  The
//! in-memory (Spark) backend keeps shuffle buffers resident until the
//! consuming stage ends; the DiskKv (Hadoop) backend spills them and
//! charges only transient serialization buffers — exactly the trade the
//! paper measures.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Approximate deep size of a value, used for accounting.  Implemented for
/// every element type that flows through the engine.
pub trait MemSize {
    fn mem_bytes(&self) -> usize;
}

macro_rules! impl_memsize_prim {
    ($($t:ty),*) => {$(
        impl MemSize for $t {
            fn mem_bytes(&self) -> usize {
                std::mem::size_of::<$t>()
            }
        }
    )*};
}

impl_memsize_prim!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64, usize, isize, bool, char);

impl MemSize for String {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<String>() + self.capacity()
    }
}

impl<T: MemSize> MemSize for Vec<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Vec<T>>() + self.iter().map(MemSize::mem_bytes).sum::<usize>()
    }
}

impl<T: MemSize> MemSize for Option<T> {
    fn mem_bytes(&self) -> usize {
        std::mem::size_of::<Option<T>>()
            + self.as_ref().map(MemSize::mem_bytes).unwrap_or(0)
    }
}

impl<T: MemSize> MemSize for Arc<T> {
    fn mem_bytes(&self) -> usize {
        // Shared data: charge the full payload to each accounting site; this
        // over-approximates like Spark's block manager does for replicas.
        std::mem::size_of::<Arc<T>>() + (**self).mem_bytes()
    }
}

impl<A: MemSize, B: MemSize> MemSize for (A, B) {
    fn mem_bytes(&self) -> usize {
        self.0.mem_bytes() + self.1.mem_bytes()
    }
}

impl<A: MemSize, B: MemSize, C: MemSize> MemSize for (A, B, C) {
    fn mem_bytes(&self) -> usize {
        self.0.mem_bytes() + self.1.mem_bytes() + self.2.mem_bytes()
    }
}

impl MemSize for crate::fasta::Sequence {
    fn mem_bytes(&self) -> usize {
        self.approx_bytes()
    }
}

/// Deep size of a slice of elements (helper for partitions).
pub fn slice_bytes<T: MemSize>(xs: &[T]) -> usize {
    xs.iter().map(MemSize::mem_bytes).sum()
}

/// Lock-free current/peak counters for one worker.
#[derive(Debug, Default)]
pub struct WorkerMemory {
    current: AtomicUsize,
    peak: AtomicUsize,
}

impl WorkerMemory {
    pub fn acquire(&self, bytes: usize) {
        let now = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }

    pub fn release(&self, bytes: usize) {
        // Saturating: release of an over-estimated buffer must not wrap.
        let mut cur = self.current.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(bytes);
            match self.current.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(v) => cur = v,
            }
        }
    }

    pub fn current(&self) -> usize {
        self.current.load(Ordering::Relaxed)
    }

    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn reset_peak(&self) {
        self.peak.store(self.current(), Ordering::Relaxed);
    }
}

/// Cluster-wide tracker: one [`WorkerMemory`] per simulated worker.
#[derive(Debug)]
pub struct MemoryTracker {
    workers: Vec<WorkerMemory>,
}

impl MemoryTracker {
    pub fn new(workers: usize) -> Self {
        Self { workers: (0..workers).map(|_| WorkerMemory::default()).collect() }
    }

    pub fn worker(&self, w: usize) -> &WorkerMemory {
        &self.workers[w % self.workers.len()]
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Figure-5 metric: mean over workers of each worker's peak bytes.
    pub fn avg_max_bytes(&self) -> f64 {
        let total: usize = self.workers.iter().map(WorkerMemory::peak).sum();
        total as f64 / self.workers.len() as f64
    }

    pub fn max_peak_bytes(&self) -> usize {
        self.workers.iter().map(WorkerMemory::peak).max().unwrap_or(0)
    }

    pub fn total_current(&self) -> usize {
        self.workers.iter().map(WorkerMemory::current).sum()
    }

    pub fn reset_peaks(&self) {
        for w in &self.workers {
            w.reset_peak();
        }
    }
}

/// RAII charge against a worker's accounting.
pub struct MemCharge<'a> {
    mem: &'a WorkerMemory,
    bytes: usize,
}

impl<'a> MemCharge<'a> {
    pub fn new(mem: &'a WorkerMemory, bytes: usize) -> Self {
        mem.acquire(bytes);
        Self { mem, bytes }
    }
}

impl Drop for MemCharge<'_> {
    fn drop(&mut self) {
        self.mem.release(self.bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water_mark() {
        let m = WorkerMemory::default();
        m.acquire(100);
        m.acquire(50);
        m.release(120);
        m.acquire(10);
        assert_eq!(m.peak(), 150);
        assert_eq!(m.current(), 40);
    }

    #[test]
    fn release_saturates() {
        let m = WorkerMemory::default();
        m.acquire(10);
        m.release(1000);
        assert_eq!(m.current(), 0);
    }

    #[test]
    fn avg_max_over_workers() {
        let t = MemoryTracker::new(4);
        t.worker(0).acquire(100);
        t.worker(1).acquire(300);
        t.worker(0).release(100);
        assert_eq!(t.avg_max_bytes(), 100.0);
        assert_eq!(t.max_peak_bytes(), 300);
    }

    #[test]
    fn charge_is_raii() {
        let m = WorkerMemory::default();
        {
            let _c = MemCharge::new(&m, 64);
            assert_eq!(m.current(), 64);
        }
        assert_eq!(m.current(), 0);
        assert_eq!(m.peak(), 64);
    }

    #[test]
    fn memsize_composes() {
        let v = vec![String::from("abcd"), String::from("ef")];
        assert!(v.mem_bytes() >= 4 + 2 + 2 * std::mem::size_of::<String>());
        let pair = (1u64, vec![1u8, 2, 3]);
        assert!(pair.mem_bytes() >= 8 + 3);
    }
}

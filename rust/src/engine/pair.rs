//! Keyed (wide) operations: `group_by_key`, `reduce_by_key`,
//! `count_by_key`, `join` — each introduces a shuffle stage boundary.
//!
//! The map stage hash-partitions every parent partition's pairs into
//! reduce buckets through the cluster's [`ShuffleStore`] (in-memory or
//! disk per backend); `reduce_by_key` additionally map-side combines,
//! Spark's combiner optimization, which is what keeps the center-star
//! space-matrix reduction cheap.
//!
//! Lineage recovery: a reduce task first checks that every map partition's
//! outputs are present; missing ones (lost worker) are recomputed inline
//! from the parent lineage before reading — the "RDDs will be recomputed
//! after data loss" behaviour of the paper.

use std::hash::Hash;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use super::context::Cluster;
use super::rdd::{Data, PartSrc, Rdd, ShuffleNode};
use super::shuffle::ShuffleStore;
use crate::util::hash::{partition_for, DetHashMap};
use crate::util::{Decode, Encode};

/// Key/value bounds for shuffled data (must cross the serialization
/// boundary in DiskKv mode).
pub trait KeyBound: Data + Hash + Eq + Encode + Decode {}
impl<T: Data + Hash + Eq + Encode + Decode> KeyBound for T {}

pub trait ValBound: Data + Encode + Decode {}
impl<T: Data + Encode + Decode> ValBound for T {}

/// Shared shuffle machinery for both keyed nodes.
struct ShuffleStage<K: KeyBound, V: ValBound> {
    ctx: Cluster,
    parent: Arc<dyn PartSrc<(K, V)>>,
    num_reduce: usize,
    /// Map-side combiner (reduce_by_key); None groups raw pairs.
    combiner: Option<Arc<dyn Fn(V, V) -> V + Send + Sync>>,
    store: OnceLock<Arc<ShuffleStore<(K, V)>>>,
    done: Mutex<bool>,
    /// One recovery guard per map partition: when a node's outputs are
    /// lost, every reduce task notices at once — without the guard all
    /// `num_reduce` of them recompute the same map partition.  The first
    /// to take the lock recomputes; the rest re-probe and skip.
    recovery: Vec<Mutex<()>>,
}

impl<K: KeyBound, V: ValBound> ShuffleStage<K, V> {
    fn new(
        ctx: Cluster,
        parent: Arc<dyn PartSrc<(K, V)>>,
        num_reduce: usize,
        combiner: Option<Arc<dyn Fn(V, V) -> V + Send + Sync>>,
    ) -> Self {
        let recovery = (0..parent.num_parts()).map(|_| Mutex::new(())).collect();
        Self {
            ctx,
            parent,
            num_reduce,
            combiner,
            store: OnceLock::new(),
            done: Mutex::new(false),
            recovery,
        }
    }

    fn store(&self) -> Result<&Arc<ShuffleStore<(K, V)>>> {
        if let Some(s) = self.store.get() {
            return Ok(s);
        }
        let s = Arc::new(ShuffleStore::new(&self.ctx, self.num_reduce)?);
        // If another thread initialized concurrently, ours is dropped.
        Ok(self.store.get_or_init(|| s))
    }

    fn materialize(&self) -> Result<()> {
        let mut done = self.done.lock().unwrap();
        if *done {
            return Ok(());
        }
        for dep in self.parent.shuffle_deps() {
            dep.ensure_materialized()?;
        }
        self.store()?; // create before tasks race to it
        let num_map = self.parent.num_parts();
        // Tasks need 'static captures: clone the stage pieces individually.
        let parent = self.parent.clone();
        let store = self.store()?.clone();
        let num_reduce = self.num_reduce;
        let combiner = self.combiner.clone();
        self.ctx.executor().run_tasks(
            num_map,
            self.ctx.config().max_retries,
            move |p| map_task(&parent, &store, num_reduce, &combiner, p),
        )?;
        *done = true;
        Ok(())
    }

    /// Reduce-side read with lineage recovery for missing map outputs.
    /// Recovery is double-checked under a per-map-partition mutex so a
    /// lost node costs **one** recompute, not `num_reduce` concurrent
    /// ones racing each other.
    fn read_with_recovery(&self, reduce_part: usize) -> Result<Vec<(K, V)>> {
        let store = self.store()?;
        let num_map = self.parent.num_parts();
        for m in 0..num_map {
            if store.map_part_present(m) {
                continue;
            }
            let _one_recovers = self.recovery[m].lock().unwrap();
            // Another reduce task may have recomputed while we waited.
            if !store.map_part_present(m) {
                // Lost output: recompute map task m from lineage, inline.
                map_task(&self.parent, store, self.num_reduce, &self.combiner, m)?;
            }
        }
        store.read_reduce(reduce_part, num_map)
    }
}

/// Free-function map task so both `materialize` and recovery share it.
fn map_task<K: KeyBound, V: ValBound>(
    parent: &Arc<dyn PartSrc<(K, V)>>,
    store: &Arc<ShuffleStore<(K, V)>>,
    num_reduce: usize,
    combiner: &Option<Arc<dyn Fn(V, V) -> V + Send + Sync>>,
    p: usize,
) -> Result<()> {
    let data = parent.compute(p)?;
    let mut buckets: Vec<Vec<(K, V)>> = (0..num_reduce).map(|_| Vec::new()).collect();
    match combiner {
        None => {
            for (k, v) in data {
                let r = partition_for(&k, num_reduce);
                buckets[r].push((k, v));
            }
        }
        Some(f) => {
            let mut combined: DetHashMap<K, V> = DetHashMap::default();
            for (k, v) in data {
                match combined.remove(&k) {
                    None => {
                        combined.insert(k, v);
                    }
                    Some(prev) => {
                        combined.insert(k, f(prev, v));
                    }
                }
            }
            for (k, v) in combined {
                let r = partition_for(&k, num_reduce);
                buckets[r].push((k, v));
            }
        }
    }
    for (r, bucket) in buckets.into_iter().enumerate() {
        store.put(p, r, bucket)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// group_by_key
// ---------------------------------------------------------------------------

struct GroupByNode<K: KeyBound, V: ValBound> {
    stage: ShuffleStage<K, V>,
    self_arc: OnceLock<Arc<dyn ShuffleNode>>,
}

impl<K: KeyBound, V: ValBound> PartSrc<(K, Vec<V>)> for GroupByNode<K, V> {
    fn num_parts(&self) -> usize {
        self.stage.num_reduce
    }

    fn compute(&self, part: usize) -> Result<Vec<(K, Vec<V>)>> {
        let pairs = self.stage.read_with_recovery(part)?;
        let mut groups: DetHashMap<K, Vec<V>> = DetHashMap::default();
        for (k, v) in pairs {
            groups.entry(k).or_default().push(v);
        }
        Ok(groups.into_iter().collect())
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        // lint: allow(panic) OnceLock is filled in group_by_key before any task runs
        vec![self.self_arc.get().expect("node registered").clone()]
    }
}

// A node must hand out an Arc of itself as a ShuffleNode; OnceLock filled
// right after construction (see group_by_key).
impl<K: KeyBound, V: ValBound> GroupByNode<K, V> {
    fn new(stage: ShuffleStage<K, V>) -> Arc<Self> {
        let node = Arc::new(Self { stage, self_arc: OnceLock::new() });
        let _ = node.self_arc.set(node.clone() as Arc<dyn ShuffleNode>);
        node
    }
}

impl<K: KeyBound, V: ValBound> ShuffleNode for GroupByNode<K, V> {
    fn ensure_materialized(&self) -> Result<()> {
        self.stage.materialize()
    }
}

// ---------------------------------------------------------------------------
// reduce_by_key
// ---------------------------------------------------------------------------

struct ReduceByNode<K: KeyBound, V: ValBound> {
    stage: ShuffleStage<K, V>,
    f: Arc<dyn Fn(V, V) -> V + Send + Sync>,
    self_arc: OnceLock<Arc<dyn ShuffleNode>>,
}

impl<K: KeyBound, V: ValBound> ReduceByNode<K, V> {
    fn new(stage: ShuffleStage<K, V>, f: Arc<dyn Fn(V, V) -> V + Send + Sync>) -> Arc<Self> {
        let node = Arc::new(Self { stage, f, self_arc: OnceLock::new() });
        let _ = node.self_arc.set(node.clone() as Arc<dyn ShuffleNode>);
        node
    }
}

impl<K: KeyBound, V: ValBound> PartSrc<(K, V)> for ReduceByNode<K, V> {
    fn num_parts(&self) -> usize {
        self.stage.num_reduce
    }

    fn compute(&self, part: usize) -> Result<Vec<(K, V)>> {
        let pairs = self.stage.read_with_recovery(part)?;
        let mut acc: DetHashMap<K, V> = DetHashMap::default();
        for (k, v) in pairs {
            match acc.remove(&k) {
                None => {
                    acc.insert(k, v);
                }
                Some(prev) => {
                    acc.insert(k, (self.f)(prev, v));
                }
            }
        }
        Ok(acc.into_iter().collect())
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        // lint: allow(panic) OnceLock is filled in reduce_by_key before any task runs
        vec![self.self_arc.get().expect("node registered").clone()]
    }
}

impl<K: KeyBound, V: ValBound> ShuffleNode for ReduceByNode<K, V> {
    fn ensure_materialized(&self) -> Result<()> {
        self.stage.materialize()
    }
}

// ---------------------------------------------------------------------------
// Public pair API
// ---------------------------------------------------------------------------

impl<K: KeyBound, V: ValBound> Rdd<(K, V)> {
    /// Hash-shuffle into `num_reduce` partitions, grouping values per key.
    pub fn group_by_key(&self, num_reduce: usize) -> Rdd<(K, Vec<V>)> {
        let stage = ShuffleStage::new(self.ctx.clone(), self.src.clone(), num_reduce.max(1), None);
        let node = GroupByNode::new(stage);
        Rdd::from_src(self.ctx.clone(), node)
    }

    /// Shuffle with map-side combining, then reduce per key.
    pub fn reduce_by_key(
        &self,
        num_reduce: usize,
        f: impl Fn(V, V) -> V + Send + Sync + 'static,
    ) -> Rdd<(K, V)> {
        let f: Arc<dyn Fn(V, V) -> V + Send + Sync> = Arc::new(f);
        let stage = ShuffleStage::new(
            self.ctx.clone(),
            self.src.clone(),
            num_reduce.max(1),
            Some(f.clone()),
        );
        let node = ReduceByNode::new(stage, f);
        Rdd::from_src(self.ctx.clone(), node)
    }

    pub fn count_by_key(&self, num_reduce: usize) -> Result<Vec<(K, usize)>> {
        self.map(|(k, _)| (k, 1usize))
            .reduce_by_key(num_reduce, |a, b| a + b)
            .collect()
    }

    /// Inner hash join (both sides shuffled to `num_reduce` partitions).
    pub fn join<W: ValBound>(&self, other: &Rdd<(K, W)>, num_reduce: usize) -> Rdd<(K, (V, W))> {
        let left = self.group_by_key(num_reduce);
        let right = other.group_by_key(num_reduce);
        // Zip matching reduce partitions: same hash partitioner => same
        // keys land in the same partition index on both sides.
        let rs = right.src.clone();
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(JoinNode { left: left.src.clone(), right: rs }),
        )
    }
}

struct JoinNode<K: KeyBound, V: ValBound, W: ValBound> {
    left: Arc<dyn PartSrc<(K, Vec<V>)>>,
    right: Arc<dyn PartSrc<(K, Vec<W>)>>,
}

impl<K: KeyBound, V: ValBound, W: ValBound> PartSrc<(K, (V, W))> for JoinNode<K, V, W> {
    fn num_parts(&self) -> usize {
        self.left.num_parts()
    }

    fn compute(&self, part: usize) -> Result<Vec<(K, (V, W))>> {
        let mut rights: DetHashMap<K, Vec<W>> = DetHashMap::default();
        for (k, ws) in self.right.compute(part)? {
            rights.insert(k, ws);
        }
        let mut out = Vec::new();
        for (k, vs) in self.left.compute(part)? {
            if let Some(ws) = rights.get(&k) {
                for v in &vs {
                    for w in ws {
                        out.push((k.clone(), (v.clone(), w.clone())));
                    }
                }
            }
        }
        Ok(out)
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        let mut deps = self.left.shuffle_deps();
        deps.extend(self.right.shuffle_deps());
        deps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::{Cluster, ClusterConfig};
    use crate::engine::shuffle::Backend;

    fn both_backends() -> Vec<Cluster> {
        vec![
            Cluster::new(ClusterConfig::spark(3)),
            Cluster::new(ClusterConfig::hadoop(3)),
        ]
    }

    #[test]
    fn group_by_key_collects_all_values() {
        for c in both_backends() {
            let pairs: Vec<(u32, u32)> = (0..60).map(|i| (i % 5, i)).collect();
            let mut groups = c.parallelize(pairs, 4).group_by_key(3).collect().unwrap();
            groups.sort_by_key(|(k, _)| *k);
            assert_eq!(groups.len(), 5);
            for (k, vs) in groups {
                assert_eq!(vs.len(), 12, "key {k}");
                assert!(vs.iter().all(|v| v % 5 == k));
            }
        }
    }

    #[test]
    fn reduce_by_key_sums() {
        for c in both_backends() {
            let pairs: Vec<(String, u64)> =
                (0..100).map(|i| (format!("k{}", i % 3), i)).collect();
            let mut out = c.parallelize(pairs, 5).reduce_by_key(2, |a, b| a + b).collect().unwrap();
            out.sort();
            let expect = |r: u64| (0..100).filter(|i| i % 3 == r).sum::<u64>();
            assert_eq!(
                out,
                vec![
                    ("k0".to_string(), expect(0)),
                    ("k1".to_string(), expect(1)),
                    ("k2".to_string(), expect(2)),
                ]
            );
        }
    }

    #[test]
    fn count_by_key_counts() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let pairs: Vec<(u8, u8)> = vec![(1, 0), (2, 0), (1, 0), (1, 0)];
        let mut out = c.parallelize(pairs, 2).count_by_key(2).unwrap();
        out.sort();
        assert_eq!(out, vec![(1, 3), (2, 1)]);
    }

    #[test]
    fn join_matches_keys() {
        let c = Cluster::new(ClusterConfig::spark(3));
        let left = c.parallelize(vec![(1u32, "a".to_string()), (2, "b".into()), (1, "c".into())], 2);
        let right = c.parallelize(vec![(1u32, 10u32), (3, 30)], 2);
        let mut out = left.join(&right, 2).collect().unwrap();
        out.sort();
        assert_eq!(
            out,
            vec![(1, ("a".to_string(), 10)), (1, ("c".to_string(), 10))]
        );
    }

    #[test]
    fn chained_shuffles_materialize_in_order() {
        for c in both_backends() {
            let pairs: Vec<(u32, u32)> = (0..40).map(|i| (i % 8, i)).collect();
            // shuffle -> narrow -> shuffle
            let out = c
                .parallelize(pairs, 4)
                .reduce_by_key(3, |a, b| a + b)
                .map(|(k, v)| (k % 2, v))
                .reduce_by_key(2, |a, b| a + b)
                .collect()
                .unwrap();
            let total: u32 = out.iter().map(|(_, v)| v).sum();
            assert_eq!(total, (0..40).sum());
        }
    }

    #[test]
    fn shuffle_is_lazy_until_action() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let pairs: Vec<(u32, u32)> = (0..10).map(|i| (i, i)).collect();
        let _grouped = c.parallelize(pairs, 2).group_by_key(2);
        assert_eq!(c.stats().shuffles_executed, 0, "no action, no shuffle");
    }

    #[test]
    fn shuffle_materializes_once_across_actions() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let pairs: Vec<(u32, u32)> = (0..10).map(|i| (i % 2, i)).collect();
        let grouped = c.parallelize(pairs, 2).group_by_key(2);
        grouped.collect().unwrap();
        grouped.count().unwrap();
        assert_eq!(c.stats().shuffles_executed, 1);
    }

    #[test]
    fn lost_map_outputs_recomputed_once_not_per_reduce() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for c in both_backends() {
            let calls = Arc::new(AtomicUsize::new(0));
            let k = calls.clone();
            let pairs: Vec<(u32, u32)> = (0..40).map(|i| (i % 8, i)).collect();
            let parent = c.parallelize(pairs, 4).map(move |kv| {
                k.fetch_add(1, Ordering::SeqCst);
                kv
            });
            let num_reduce = 6;
            let stage = ShuffleStage::new(c.clone(), parent.src.clone(), num_reduce, None);
            stage.materialize().unwrap();
            assert_eq!(calls.load(Ordering::SeqCst), 40, "map stage ran once");

            // Lose worker 0's outputs (3 workers: it owns map parts 0, 3).
            stage.store().unwrap().drop_worker_outputs(0, 4);

            // All reduce tasks race into recovery at once; the per-map-
            // partition guard must hold the recompute to one per lost
            // partition: 2 lost partitions x 10 elements = +20 calls,
            // not +20 per reduce task.
            std::thread::scope(|s| {
                for r in 0..num_reduce {
                    let stage = &stage;
                    s.spawn(move || {
                        let got = stage.read_with_recovery(r).unwrap();
                        for (key, _) in got {
                            assert_eq!(partition_for(&key, num_reduce), r);
                        }
                    });
                }
            });
            assert_eq!(
                calls.load(Ordering::SeqCst),
                60,
                "a lost node costs one recompute per lost partition, not num_reduce"
            );
        }
    }

    #[test]
    fn diskkv_recovery_keeps_write_counters_stable() {
        // The recovery re-put writes the same bytes into the same slots;
        // with the replace-and-release accounting the job's IO counters
        // must be identical before and after a loss + recovery cycle.
        let c = Cluster::new(ClusterConfig::hadoop(3));
        let pairs: Vec<(u32, u32)> = (0..60).map(|i| (i % 5, i)).collect();
        let stage = ShuffleStage::new(
            c.clone(),
            c.parallelize(pairs, 4).src.clone(),
            3,
            None,
        );
        stage.materialize().unwrap();
        let before = c.stats();
        stage.store().unwrap().drop_worker_outputs(1, 4);
        for r in 0..3 {
            stage.read_with_recovery(r).unwrap();
        }
        let after = c.stats();
        assert_eq!(
            after.shuffle_bytes_written, before.shuffle_bytes_written,
            "recovery re-puts replace their accounting slots"
        );
    }

    #[test]
    fn diskkv_shuffle_writes_and_reads_bytes() {
        let c = Cluster::new(ClusterConfig::hadoop(2));
        let pairs: Vec<(u32, u32)> = (0..50).map(|i| (i % 4, i)).collect();
        c.parallelize(pairs, 3).group_by_key(2).collect().unwrap();
        let st = c.stats();
        assert!(st.shuffle_bytes_written > 0);
        // Writes include the HDFS-style replication copies.
        assert!(
            st.shuffle_bytes_written
                >= st.shuffle_bytes_read * c.config().disk_replication as u64
        );
    }

    #[test]
    fn inmemory_shuffle_holds_memory_diskkv_does_not() {
        let make_pairs =
            || -> Vec<(u32, Vec<u8>)> { (0..64).map(|i| (i % 4, vec![0u8; 4096])).collect() };
        let spark = Cluster::new(ClusterConfig::spark(2));
        let grouped = spark.parallelize(make_pairs(), 4).group_by_key(2);
        grouped.collect().unwrap();
        let spark_peak = spark.memory().max_peak_bytes();

        let hadoop = Cluster::new(ClusterConfig::hadoop(2));
        let grouped = hadoop.parallelize(make_pairs(), 4).group_by_key(2);
        grouped.collect().unwrap();
        let _ = hadoop.memory().max_peak_bytes();
        // Spark's resident shuffle buffers must show up as extra peak
        // memory relative to its own baseline input.
        assert!(
            spark_peak > 64 * 4096,
            "spark peak {spark_peak} should include shuffle buffers"
        );
    }
}

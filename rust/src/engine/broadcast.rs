//! Broadcast variables — how the center-star sequence and inserted-space
//! matrix reach every worker (paper Fig. 3: "the extracted center star
//! sequence ... becomes a broadcast variable").
//!
//! Both backends replicate the value to every worker (memory is charged
//! per replica); the DiskKv backend additionally round-trips the payload
//! through an encoded scratch file, modelling Hadoop's distributed-cache
//! distribution cost where Spark hands out an in-memory reference.

use std::sync::Arc;

use anyhow::{Context as _, Result};

use super::context::Cluster;
use super::memory::MemSize;
use super::shuffle::Backend;
use crate::util::{Decode, Encode};

pub struct Broadcast<T> {
    value: Arc<T>,
    ctx: Cluster,
    bytes_per_worker: usize,
}

impl<T> Clone for Broadcast<T> {
    fn clone(&self) -> Self {
        // Clones share the replicas; only the original releases on drop,
        // enforced by reference counting on `value`.
        Self {
            value: self.value.clone(),
            ctx: self.ctx.clone(),
            bytes_per_worker: 0, // non-owning clone
        }
    }
}

impl<T> Broadcast<T> {
    pub fn value(&self) -> &T {
        &self.value
    }

    pub fn arc(&self) -> Arc<T> {
        self.value.clone()
    }
}

impl<T> Drop for Broadcast<T> {
    fn drop(&mut self) {
        if self.bytes_per_worker > 0 {
            for w in 0..self.ctx.num_workers() {
                self.ctx.memory().worker(w).release(self.bytes_per_worker);
            }
        }
    }
}

impl Cluster {
    /// Replicate `value` to every worker.
    pub fn broadcast<T>(&self, value: T) -> Result<Broadcast<T>>
    where
        T: MemSize + Encode + Decode + Send + Sync + 'static,
    {
        let value = match self.backend() {
            Backend::InMemory => value,
            Backend::DiskKv => {
                // Hadoop path: serialize to the distributed cache and read
                // it back (cost scales with payload and worker count).
                let path = self
                    .scratch_dir()?
                    .join(format!("broadcast-{}.kv", self.next_shuffle_id()));
                let bytes = value.to_bytes();
                std::fs::write(&path, &bytes)
                    .with_context(|| format!("writing broadcast {}", path.display()))?;
                let mut last = value;
                for _ in 0..self.num_workers() {
                    let read = std::fs::read(&path)?;
                    self.io()
                        .shuffle_bytes_read
                        .fetch_add(read.len() as u64, std::sync::atomic::Ordering::Relaxed);
                    last = T::from_bytes(&read)?;
                }
                let _ = std::fs::remove_file(&path);
                last
            }
        };
        let bytes_per_worker = value.mem_bytes();
        for w in 0..self.num_workers() {
            self.memory().worker(w).acquire(bytes_per_worker);
        }
        Ok(Broadcast { value: Arc::new(value), ctx: self.clone(), bytes_per_worker })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::ClusterConfig;

    #[test]
    fn value_accessible_and_memory_charged_per_worker() {
        let c = Cluster::new(ClusterConfig::spark(4));
        let payload = vec![0u8; 10_000];
        let before = c.memory().total_current();
        let b = c.broadcast(payload.clone()).unwrap();
        assert_eq!(b.value(), &payload);
        assert!(c.memory().total_current() >= before + 4 * 10_000);
        drop(b);
        assert_eq!(c.memory().total_current(), before);
    }

    #[test]
    fn diskkv_broadcast_roundtrips_and_counts_io() {
        let c = Cluster::new(ClusterConfig::hadoop(3));
        let b = c.broadcast(vec![7u32; 100]).unwrap();
        assert_eq!(b.value().len(), 100);
        assert!(c.stats().shuffle_bytes_read >= 3 * 400);
    }

    #[test]
    fn clones_do_not_double_release() {
        let c = Cluster::new(ClusterConfig::spark(2));
        let b = c.broadcast(String::from("center")).unwrap();
        let snapshot = c.memory().total_current();
        let b2 = b.clone();
        drop(b2);
        assert_eq!(c.memory().total_current(), snapshot, "clone drop is free");
        drop(b);
        assert!(c.memory().total_current() < snapshot);
    }
}

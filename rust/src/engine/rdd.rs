//! Lazy, lineage-tracked RDDs (paper §Overview of Apache Spark).
//!
//! *Transformations* (`map`, `filter`, `flat_map`, `map_partitions`,
//! `sample`, `union`, keyed ops in [`super::pair`]) only build the lineage
//! graph; *actions* (`collect`, `count`, `reduce`, ...) materialize
//! upstream shuffle stages first (wide dependencies = stage boundaries,
//! exactly Spark's DAG scheduler cut) and then run the final narrow stage
//! as one task set.  Narrow chains fuse: a task computes its partition by
//! recursing through its parents in a single pass, which is Spark's
//! pipelined-stage execution.
//!
//! Fault tolerance is lineage-based: a failed task retries by recomputing
//! its parent partitions; lost shuffle map outputs are detected by reduce
//! tasks and recomputed from the parent lineage (see `pair.rs`).

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::context::Cluster;
use super::memory::{slice_bytes, MemSize};
use super::shuffle::Backend;
use crate::util::{Decode, Encode, Rng};

/// Element bound for everything that flows through the engine.
pub trait Data: Clone + Send + Sync + MemSize + 'static {}
impl<T: Clone + Send + Sync + MemSize + 'static> Data for T {}

/// Clamp a requested element range to a partition of `n` elements:
/// `lo <= hi <= n` on return.  Single source of truth for every
/// `compute_slice` implementation.
fn clamp_range(n: usize, lo: usize, hi: usize) -> (usize, usize) {
    let lo = lo.min(n);
    (lo, hi.clamp(lo, n))
}

/// A node that can produce the contents of one partition.
pub trait PartSrc<T: Data>: Send + Sync {
    fn num_parts(&self) -> usize;
    fn compute(&self, part: usize) -> Result<Vec<T>>;
    /// Element count of `part` when knowable without running the full
    /// lineage (sources, caches, checkpoints).  `None` makes
    /// slice-requesting callers fall back to a full [`compute`].
    ///
    /// [`compute`]: PartSrc::compute
    fn part_len(&self, _part: usize) -> Result<Option<usize>> {
        Ok(None)
    }
    /// Compute only elements `lo..hi` of `part` (bounds clamped to the
    /// partition length).  Nodes that can slice cheaply — sources, filled
    /// caches, checkpoint files — override this so `split_partitions(f)`
    /// costs one pass over the parent instead of `f` recomputes; the
    /// default recomputes the whole partition and slices locally.
    fn compute_slice(&self, part: usize, lo: usize, hi: usize) -> Result<Vec<T>> {
        let data = self.compute(part)?;
        let (lo, hi) = clamp_range(data.len(), lo, hi);
        Ok(data.into_iter().skip(lo).take(hi - lo).collect())
    }
    /// Wide dependencies that must be materialized before this node's
    /// partitions can be computed (transitively closed by recursion).
    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>>;
}

/// Object-safe view of a shuffle stage for the pre-action scheduler walk.
pub trait ShuffleNode: Send + Sync {
    /// Run the map stage if not already done (idempotent, thread-safe);
    /// materializes upstream shuffles first.
    fn ensure_materialized(&self) -> Result<()>;
}

/// A distributed dataset handle.
pub struct Rdd<T: Data> {
    pub(crate) ctx: Cluster,
    pub(crate) src: Arc<dyn PartSrc<T>>,
}

impl<T: Data> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Self { ctx: self.ctx.clone(), src: self.src.clone() }
    }
}

// ---------------------------------------------------------------------------
// Source node
// ---------------------------------------------------------------------------

struct SourceNode<T: Data> {
    ctx: Cluster,
    parts: Vec<Arc<Vec<T>>>,
    charged: Vec<(usize, usize)>, // (worker, bytes) released on drop
}

impl<T: Data> PartSrc<T> for SourceNode<T> {
    fn num_parts(&self) -> usize {
        self.parts.len()
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        Ok(self.parts[part].as_ref().clone())
    }

    fn part_len(&self, part: usize) -> Result<Option<usize>> {
        Ok(Some(self.parts[part].len()))
    }

    fn compute_slice(&self, part: usize, lo: usize, hi: usize) -> Result<Vec<T>> {
        let data = self.parts[part].as_ref();
        let (lo, hi) = clamp_range(data.len(), lo, hi);
        Ok(data[lo..hi].to_vec())
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        Vec::new()
    }
}

impl<T: Data> Drop for SourceNode<T> {
    fn drop(&mut self) {
        for &(w, b) in &self.charged {
            self.ctx.memory().worker(w).release(b);
        }
    }
}

// ---------------------------------------------------------------------------
// Narrow transformation nodes
// ---------------------------------------------------------------------------

/// map_partitions_with_index — the one narrow primitive every other narrow
/// op lowers to (matching Spark's `MapPartitionsRDD`).
struct MapPartsNode<U: Data, T: Data> {
    parent: Arc<dyn PartSrc<U>>,
    #[allow(clippy::type_complexity)]
    f: Arc<dyn Fn(usize, Vec<U>) -> Vec<T> + Send + Sync>,
}

impl<U: Data, T: Data> PartSrc<T> for MapPartsNode<U, T> {
    fn num_parts(&self) -> usize {
        self.parent.num_parts()
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        Ok((self.f)(part, self.parent.compute(part)?))
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        self.parent.shuffle_deps()
    }
}

/// Fallible variant of [`MapPartsNode`]: the closure returns `Result`, so
/// a partition-level failure (an XLA batch error, a poisoned resource)
/// surfaces as a task error the executor retries through lineage instead
/// of panicking the worker.
struct TryMapPartsNode<U: Data, T: Data> {
    parent: Arc<dyn PartSrc<U>>,
    #[allow(clippy::type_complexity)]
    f: Arc<dyn Fn(usize, Vec<U>) -> Result<Vec<T>> + Send + Sync>,
}

impl<U: Data, T: Data> PartSrc<T> for TryMapPartsNode<U, T> {
    fn num_parts(&self) -> usize {
        self.parent.num_parts()
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        (self.f)(part, self.parent.compute(part)?)
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        self.parent.shuffle_deps()
    }
}

/// Contiguous element bounds of slice `slice` when a partition of `n`
/// elements is split `factor` ways.
fn slice_bounds(n: usize, factor: usize, slice: usize) -> (usize, usize) {
    let per = n.div_ceil(factor).max(1);
    ((slice * per).min(n), ((slice + 1) * per).min(n))
}

/// Split every parent partition into `factor` contiguous slices — a
/// narrow repartitioning that multiplies the task count so the
/// work-stealing executor has finer-grained units to balance.
///
/// Slice-aware lineage: when the parent can report its partition length
/// cheaply (sources, caches, checkpoints), each slice asks the parent for
/// only its `lo..hi` range via [`PartSrc::compute_slice`] — the parent is
/// computed **once**, not once per slice.  Opaque parents (arbitrary map
/// closures) fall back to recompute-and-slice; `cache()` or `checkpoint()`
/// first when such a parent is expensive.
struct SplitNode<T: Data> {
    parent: Arc<dyn PartSrc<T>>,
    factor: usize,
}

impl<T: Data> SplitNode<T> {
    /// Bounds of `part`'s slice within its parent partition, when the
    /// parent length is knowable without computing.
    fn parent_bounds(&self, part: usize) -> Result<Option<(usize, usize)>> {
        let parent_part = part / self.factor;
        Ok(self
            .parent
            .part_len(parent_part)?
            .map(|n| slice_bounds(n, self.factor, part % self.factor)))
    }
}

impl<T: Data> PartSrc<T> for SplitNode<T> {
    fn num_parts(&self) -> usize {
        self.parent.num_parts() * self.factor
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        let parent_part = part / self.factor;
        if let Some((lo, hi)) = self.parent_bounds(part)? {
            return self.parent.compute_slice(parent_part, lo, hi);
        }
        // Opaque parent: recompute the partition and slice locally.
        let data = self.parent.compute(parent_part)?;
        let (lo, hi) = slice_bounds(data.len(), self.factor, part % self.factor);
        Ok(data.into_iter().skip(lo).take(hi - lo).collect())
    }

    fn part_len(&self, part: usize) -> Result<Option<usize>> {
        Ok(self.parent_bounds(part)?.map(|(lo, hi)| hi - lo))
    }

    fn compute_slice(&self, part: usize, lo: usize, hi: usize) -> Result<Vec<T>> {
        if let Some((slo, shi)) = self.parent_bounds(part)? {
            // Nested split: translate the sub-range into parent space.
            let (lo, hi) = clamp_range(shi - slo, lo, hi);
            return self.parent.compute_slice(part / self.factor, slo + lo, slo + hi);
        }
        let data = self.compute(part)?;
        let (lo, hi) = clamp_range(data.len(), lo, hi);
        Ok(data.into_iter().skip(lo).take(hi - lo).collect())
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        self.parent.shuffle_deps()
    }
}

/// Merge adjacent parent partitions down to `parts` outputs (narrow; the
/// inverse of [`SplitNode`], Spark's `coalesce`).
struct CoalesceNode<T: Data> {
    parent: Arc<dyn PartSrc<T>>,
    parts: usize,
}

impl<T: Data> PartSrc<T> for CoalesceNode<T> {
    fn num_parts(&self) -> usize {
        self.parts
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        let n = self.parent.num_parts();
        let base = n / self.parts;
        let extra = n % self.parts;
        let lo = part * base + part.min(extra);
        let hi = lo + base + usize::from(part < extra);
        let mut out = Vec::new();
        for p in lo..hi {
            out.extend(self.parent.compute(p)?);
        }
        Ok(out)
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        self.parent.shuffle_deps()
    }
}

/// Element type of the block-pairing primitives: the two block ids plus
/// both blocks' contents.
pub type BlockPair<T, U> = ((u64, u64), (Vec<T>, Vec<U>));

/// One output partition per (left partition, right partition) pair, each
/// holding a single element: the block ids plus both blocks' contents —
/// the narrow pairwise-tile primitive the distmat subsystem schedules
/// over.  Parents are recomputed once per pair they appear in; `cache()`
/// or `checkpoint()` an expensive parent first.
struct CartesianBlocksNode<T: Data, U: Data> {
    left: Arc<dyn PartSrc<T>>,
    right: Arc<dyn PartSrc<U>>,
}

impl<T: Data, U: Data> PartSrc<BlockPair<T, U>> for CartesianBlocksNode<T, U> {
    fn num_parts(&self) -> usize {
        self.left.num_parts() * self.right.num_parts()
    }

    fn compute(&self, part: usize) -> Result<Vec<BlockPair<T, U>>> {
        let nr = self.right.num_parts();
        let (bi, bj) = (part / nr, part % nr);
        Ok(vec![((bi as u64, bj as u64), (self.left.compute(bi)?, self.right.compute(bj)?))])
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        let mut deps = self.left.shuffle_deps();
        deps.extend(self.right.shuffle_deps());
        deps
    }
}

/// Self-pairing restricted to the lower triangle: one partition per
/// block pair (bi, bj) with `bj <= bi`, enumerated in triangular order
/// (`bi(bi+1)/2 + bj`) so partition indices line up with the distmat
/// tile grid's tile indices.
struct TriangleBlocksNode<T: Data> {
    parent: Arc<dyn PartSrc<T>>,
}

impl<T: Data> PartSrc<BlockPair<T, T>> for TriangleBlocksNode<T> {
    fn num_parts(&self) -> usize {
        let nb = self.parent.num_parts();
        nb * (nb + 1) / 2
    }

    fn compute(&self, part: usize) -> Result<Vec<BlockPair<T, T>>> {
        let (bi, bj) = crate::util::triangle_coords(part);
        let left = self.parent.compute(bi)?;
        // Diagonal tiles pair a block with itself: clone instead of
        // recomputing the parent partition a second time.
        let right = if bi == bj { left.clone() } else { self.parent.compute(bj)? };
        Ok(vec![((bi as u64, bj as u64), (left, right))])
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        self.parent.shuffle_deps()
    }
}

struct UnionNode<T: Data> {
    left: Arc<dyn PartSrc<T>>,
    right: Arc<dyn PartSrc<T>>,
}

impl<T: Data> PartSrc<T> for UnionNode<T> {
    fn num_parts(&self) -> usize {
        self.left.num_parts() + self.right.num_parts()
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        let nl = self.left.num_parts();
        if part < nl {
            self.left.compute(part)
        } else {
            self.right.compute(part - nl)
        }
    }

    fn part_len(&self, part: usize) -> Result<Option<usize>> {
        let nl = self.left.num_parts();
        if part < nl {
            self.left.part_len(part)
        } else {
            self.right.part_len(part - nl)
        }
    }

    fn compute_slice(&self, part: usize, lo: usize, hi: usize) -> Result<Vec<T>> {
        let nl = self.left.num_parts();
        if part < nl {
            self.left.compute_slice(part, lo, hi)
        } else {
            self.right.compute_slice(part - nl, lo, hi)
        }
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        let mut deps = self.left.shuffle_deps();
        deps.extend(self.right.shuffle_deps());
        deps
    }
}

/// Cached node: first computation per partition is stored (and charged to
/// the owning worker); later computations clone from cache — Spark's
/// `persist(MEMORY_ONLY)`.
struct CacheNode<T: Data> {
    ctx: Cluster,
    parent: Arc<dyn PartSrc<T>>,
    slots: Vec<Mutex<Option<Arc<Vec<T>>>>>,
}

impl<T: Data> CacheNode<T> {
    /// The cached partition, computing (and charging) it on first touch.
    fn cached(&self, part: usize) -> Result<Arc<Vec<T>>> {
        let mut slot = self.slots[part].lock().unwrap();
        if let Some(cached) = slot.as_ref() {
            return Ok(cached.clone());
        }
        let data = self.parent.compute(part)?;
        let worker = self.ctx.executor().worker_for(part);
        self.ctx.memory().worker(worker).acquire(slice_bytes(&data));
        let arc = Arc::new(data);
        *slot = Some(arc.clone());
        Ok(arc)
    }
}

impl<T: Data> PartSrc<T> for CacheNode<T> {
    fn num_parts(&self) -> usize {
        self.parent.num_parts()
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        Ok(self.cached(part)?.as_ref().clone())
    }

    fn part_len(&self, part: usize) -> Result<Option<usize>> {
        // Materializes the slot on first touch: a split over a cached
        // parent then costs exactly one parent computation total.
        Ok(Some(self.cached(part)?.len()))
    }

    fn compute_slice(&self, part: usize, lo: usize, hi: usize) -> Result<Vec<T>> {
        let data = self.cached(part)?;
        let (lo, hi) = clamp_range(data.len(), lo, hi);
        Ok(data[lo..hi].to_vec())
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        self.parent.shuffle_deps()
    }
}

impl<T: Data> Drop for CacheNode<T> {
    fn drop(&mut self) {
        for (part, slot) in self.slots.iter().enumerate() {
            if let Some(data) = slot.lock().unwrap().take() {
                let worker = self.ctx.executor().worker_for(part);
                self.ctx.memory().worker(worker).release(slice_bytes(&data));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Rdd API
// ---------------------------------------------------------------------------

impl<T: Data> Rdd<T> {
    pub(crate) fn from_src(ctx: Cluster, src: Arc<dyn PartSrc<T>>) -> Self {
        Self { ctx, src }
    }

    /// `Cluster::parallelize` — chunk a local vec into `parts` partitions
    /// and charge them to their owning workers (they are "cached input").
    pub(crate) fn from_vec(ctx: Cluster, items: Vec<T>, parts: usize) -> Self {
        let n = items.len();
        let per = n.div_ceil(parts.max(1)).max(1);
        let mut chunks: Vec<Arc<Vec<T>>> = Vec::new();
        let mut iter = items.into_iter();
        loop {
            let chunk: Vec<T> = iter.by_ref().take(per).collect();
            if chunk.is_empty() && !chunks.is_empty() {
                break;
            }
            let done = chunk.len() < per;
            chunks.push(Arc::new(chunk));
            if done {
                break;
            }
        }
        let mut charged = Vec::new();
        for (p, c) in chunks.iter().enumerate() {
            let worker = ctx.executor().worker_for(p);
            let bytes = slice_bytes(c.as_ref());
            ctx.memory().worker(worker).acquire(bytes);
            charged.push((worker, bytes));
        }
        let node = SourceNode { ctx: ctx.clone(), parts: chunks, charged };
        Self::from_src(ctx, Arc::new(node))
    }

    pub fn context(&self) -> &Cluster {
        &self.ctx
    }

    pub fn num_partitions(&self) -> usize {
        self.src.num_parts()
    }

    // -- transformations ---------------------------------------------------

    pub fn map_partitions_with_index<U: Data>(
        &self,
        f: impl Fn(usize, Vec<T>) -> Vec<U> + Send + Sync + 'static,
    ) -> Rdd<U> {
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(MapPartsNode { parent: self.src.clone(), f: Arc::new(f) }),
        )
    }

    /// Fallible [`map_partitions_with_index`]: the closure's `Err` becomes
    /// a task failure the executor retries through lineage (instead of a
    /// worker panic) — use for partitions whose computation can fail at
    /// runtime, e.g. accelerator batch dispatch.
    ///
    /// [`map_partitions_with_index`]: Rdd::map_partitions_with_index
    pub fn try_map_partitions_with_index<U: Data>(
        &self,
        f: impl Fn(usize, Vec<T>) -> Result<Vec<U>> + Send + Sync + 'static,
    ) -> Rdd<U> {
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(TryMapPartsNode { parent: self.src.clone(), f: Arc::new(f) }),
        )
    }

    pub fn map<U: Data>(&self, f: impl Fn(T) -> U + Send + Sync + 'static) -> Rdd<U> {
        self.map_partitions_with_index(move |_, xs| xs.into_iter().map(&f).collect())
    }

    pub fn filter(&self, f: impl Fn(&T) -> bool + Send + Sync + 'static) -> Rdd<T> {
        self.map_partitions_with_index(move |_, xs| xs.into_iter().filter(|x| f(x)).collect())
    }

    pub fn flat_map<U: Data>(
        &self,
        f: impl Fn(T) -> Vec<U> + Send + Sync + 'static,
    ) -> Rdd<U> {
        self.map_partitions_with_index(move |_, xs| xs.into_iter().flat_map(&f).collect())
    }

    pub fn key_by<K: Data>(&self, f: impl Fn(&T) -> K + Send + Sync + 'static) -> Rdd<(K, T)> {
        self.map(move |x| (f(&x), x))
    }

    /// Bernoulli sample without replacement; deterministic per (seed, part).
    pub fn sample(&self, fraction: f64, seed: u64) -> Rdd<T> {
        self.map_partitions_with_index(move |part, xs| {
            let mut rng = Rng::seed_from_u64(seed ^ (part as u64).wrapping_mul(0x9E37));
            xs.into_iter().filter(|_| rng.chance(fraction)).collect()
        })
    }

    /// Narrow repartitioning: split every partition into `factor`
    /// contiguous slices (element order preserved), so long partitions
    /// become finer-grained tasks the work-stealing executor can balance.
    /// Slice-aware over sources, caches and checkpoints: each slice
    /// computes only its own element range, so the parent is not
    /// recomputed `factor` times (see [`PartSrc::compute_slice`]).
    pub fn split_partitions(&self, factor: usize) -> Rdd<T> {
        if factor <= 1 {
            return self.clone();
        }
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(SplitNode { parent: self.src.clone(), factor }),
        )
    }

    /// Merge adjacent partitions down to at most `parts` (element order
    /// preserved) — Spark's `coalesce`.
    pub fn coalesce(&self, parts: usize) -> Rdd<T> {
        let n = self.src.num_parts();
        let parts = parts.clamp(1, n.max(1));
        if parts >= n {
            return self.clone();
        }
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(CoalesceNode { parent: self.src.clone(), parts }),
        )
    }

    /// Pair every partition of `self` with every partition of `other`:
    /// one output partition per (bi, bj) combination, holding a single
    /// element `((bi, bj), (block_i, block_j))`.  This is the pairwise
    /// block-job primitive — each pair is an independently stealable
    /// task, which is how the distmat subsystem turns an O(n²) distance
    /// matrix into engine-scheduled tiles.  Narrow: parents recompute
    /// once per pair, so `cache()` expensive parents first.
    pub fn cartesian_blocks<U: Data>(&self, other: &Rdd<U>) -> Rdd<BlockPair<T, U>> {
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(CartesianBlocksNode { left: self.src.clone(), right: other.src.clone() }),
        )
    }

    /// [`cartesian_blocks`] of `self` with itself, restricted to the
    /// lower triangle (`bj <= bi`, diagonal included) and enumerated in
    /// triangular order — exactly the tile set of a symmetric pairwise
    /// matrix, at half the task count of the full cartesian product.
    ///
    /// [`cartesian_blocks`]: Rdd::cartesian_blocks
    pub fn lower_triangle_blocks(&self) -> Rdd<BlockPair<T, T>> {
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(TriangleBlocksNode { parent: self.src.clone() }),
        )
    }

    pub fn union(&self, other: &Rdd<T>) -> Rdd<T> {
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(UnionNode { left: self.src.clone(), right: other.src.clone() }),
        )
    }

    /// Persist partitions in worker memory after first computation.
    pub fn cache(&self) -> Rdd<T> {
        let slots = (0..self.src.num_parts()).map(|_| Mutex::new(None)).collect();
        Rdd::from_src(
            self.ctx.clone(),
            Arc::new(CacheNode { ctx: self.ctx.clone(), parent: self.src.clone(), slots }),
        )
    }

    /// Pair each element with a global index (two-pass, like Spark's
    /// `zipWithIndex`: a count job then an offset map).
    pub fn zip_with_index(&self) -> Result<Rdd<(u64, T)>> {
        let lens = self.partition_lengths()?;
        let mut offsets = vec![0u64; lens.len() + 1];
        for (i, l) in lens.iter().enumerate() {
            offsets[i + 1] = offsets[i] + *l as u64;
        }
        Ok(self.map_partitions_with_index(move |part, xs| {
            xs.into_iter()
                .enumerate()
                .map(|(i, x)| (offsets[part] + i as u64, x))
                .collect()
        }))
    }

    // -- actions -----------------------------------------------------------

    fn prepare(&self) -> Result<()> {
        for dep in self.src.shuffle_deps() {
            dep.ensure_materialized()?;
        }
        Ok(())
    }

    /// Run one task per partition, handing each task its computed
    /// partition. The fundamental action the others build on.
    pub fn run_partitions<R: Send + 'static>(
        &self,
        f: impl Fn(usize, Vec<T>) -> Result<R> + Send + Sync + 'static,
    ) -> Result<Vec<R>> {
        self.prepare()?;
        let n = self.src.num_parts();
        let out: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let src = self.src.clone();
        let ctx = self.ctx.clone();
        let out2 = out.clone();
        self.ctx.executor().run_tasks(
            n,
            self.ctx.config().max_retries,
            move |part| {
                let data = src.compute(part)?;
                // Charge the in-flight partition to the worker for the
                // task's duration (transient stage memory).
                let worker = ctx.executor().worker_for(part);
                let bytes = slice_bytes(&data);
                ctx.memory().worker(worker).acquire(bytes);
                let result = f(part, data);
                ctx.memory().worker(worker).release(bytes);
                let value = result?;
                // The results Vec is taken once the stage completes; an
                // abandoned speculative/straggler duplicate finishing
                // late must not index into the emptied Vec.
                if let Some(slot) = out2.lock().unwrap().get_mut(part) {
                    *slot = Some(value);
                }
                Ok(())
            },
        )?;
        let collected = std::mem::take(&mut *out.lock().unwrap());
        collected
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.ok_or_else(|| anyhow!("partition {i} produced no result")))
            .collect()
    }

    pub fn collect(&self) -> Result<Vec<T>> {
        let parts = self.run_partitions(|_, xs| Ok(xs))?;
        Ok(parts.into_iter().flatten().collect())
    }

    pub fn count(&self) -> Result<usize> {
        Ok(self.run_partitions(|_, xs| Ok(xs.len()))?.into_iter().sum())
    }

    fn partition_lengths(&self) -> Result<Vec<usize>> {
        self.run_partitions(|_, xs| Ok(xs.len()))
    }

    pub fn first(&self) -> Result<Option<T>> {
        // Cheap for sources; computes all partitions otherwise (fine for
        // our workloads, which call this on small RDDs).
        Ok(self.collect()?.into_iter().next())
    }

    pub fn reduce(&self, f: impl Fn(T, T) -> T + Send + Sync + 'static) -> Result<Option<T>> {
        let f = Arc::new(f);
        let g = f.clone();
        let partials = self.run_partitions(move |_, xs| Ok(xs.into_iter().reduce(|a, b| g(a, b))))?;
        Ok(partials.into_iter().flatten().reduce(|a, b| f(a, b)))
    }

    /// Job-boundary materialization. In `DiskKv` (Hadoop) mode the
    /// partitions are encoded and written to the scratch dir, then read
    /// back lazily — the inter-job HDFS round trip of a MapReduce chain.
    /// In `InMemory` (Spark) mode this is `cache()`.
    pub fn checkpoint(&self) -> Result<Rdd<T>>
    where
        T: Encode + Decode,
    {
        match self.ctx.backend() {
            Backend::InMemory => {
                let cached = self.cache();
                // Materialize now (a job boundary is eager in Hadoop, so
                // keep the comparison honest).
                cached.run_partitions(|_, _| Ok(()))?;
                Ok(cached)
            }
            Backend::DiskKv => {
                let dir = self
                    .ctx
                    .scratch_dir()?
                    .join(format!("checkpoint-{}", self.ctx.next_shuffle_id()));
                std::fs::create_dir_all(&dir)?;
                let dir2 = dir.clone();
                let ctx = self.ctx.clone();
                // Once-only byte crediting per partition: the executor
                // runs tasks at-least-once (speculation, retries), and a
                // duplicate re-writing its files must replace its slot in
                // the IO accounting, not accumulate — otherwise the
                // Fig-5/Table-2 numbers drift run to run.
                let counted: Arc<super::shuffle::CreditOnce<usize>> =
                    Arc::new(super::shuffle::CreditOnce::new());
                let lens = self.run_partitions(move |part, xs| {
                    let n = xs.len();
                    // Job-boundary write pays the same taxes as a shuffle
                    // spill: serialization buffers with JVM KV bloat, and
                    // HDFS-style block replication.  The indexed framing
                    // (per-element byte offsets up front) is what lets a
                    // downstream `compute_slice` seek straight to its
                    // range instead of decoding the partition prefix.
                    let bytes = encode_indexed(&xs);
                    let worker = ctx.executor().worker_for(part);
                    let charge = bytes.len() * 2 * ctx.config().kv_overhead.max(1);
                    ctx.memory().worker(worker).acquire(charge);
                    let result = (|| -> Result<u64> {
                        let mut written = 0u64;
                        for copy in 0..ctx.config().disk_replication.max(1) {
                            let name = if copy == 0 {
                                format!("part-{part:05}.kv")
                            } else {
                                format!("part-{part:05}.kv.r{copy}")
                            };
                            // Atomic (tmp + rename) so a speculative
                            // duplicate re-writing the file can never be
                            // observed half-written by a reader.
                            super::shuffle::write_atomic(&dir2.join(name), &bytes)?;
                            written += bytes.len() as u64;
                        }
                        Ok(written)
                    })();
                    ctx.memory().worker(worker).release(charge);
                    let written = result?;
                    let io = ctx.io();
                    // Checkpoints spill through the same accounting as
                    // shuffle buckets; they add no spill-file count.
                    counted.credit(part, written, 0, &io.shuffle_bytes_written, &io.spill_files);
                    Ok(n)
                })?;
                let ctx = self.ctx.clone();
                Ok(Rdd::from_src(
                    self.ctx.clone(),
                    Arc::new(DiskPartsNode { ctx, dir, lens, _marker: std::marker::PhantomData }),
                ))
            }
        }
    }
}

/// Checkpoint file framing: `u64` element count, then `count + 1` `u64`
/// byte offsets into the payload (offset `i` = start of element `i`,
/// offset `count` = payload length), then the encoded elements
/// back-to-back.  The offset index costs 8 bytes per element on disk
/// and buys `compute_slice` a real seek: decoding `lo..hi` touches
/// exactly that range's payload bytes, never the prefix.
fn encode_indexed<T: Encode>(xs: &[T]) -> Vec<u8> {
    let mut payload = Vec::new();
    let mut offsets = Vec::with_capacity(xs.len() + 1);
    offsets.push(0u64);
    for x in xs {
        x.encode(&mut payload);
        offsets.push(payload.len() as u64);
    }
    let mut out = Vec::with_capacity(8 + 8 * offsets.len() + payload.len());
    (xs.len() as u64).encode(&mut out);
    for o in &offsets {
        o.encode(&mut out);
    }
    out.extend_from_slice(&payload);
    out
}

/// Decode elements `lo..hi` (clamped) from an indexed checkpoint file,
/// seeking via the offset table.  Returns the elements plus the payload
/// bytes actually decoded — the quantity the
/// `checkpoint_bytes_decoded` counter audits.
fn decode_indexed_range<T: Decode>(
    mut bytes: &[u8],
    lo: usize,
    hi: usize,
) -> Result<(Vec<T>, u64)> {
    let input = &mut bytes;
    let total = u64::decode(input)? as usize;
    // u128 math so a corrupt count can't overflow the index-size check.
    anyhow::ensure!(
        (total as u128 + 1) * 8 <= input.len() as u128,
        "checkpoint offset index truncated (count {total}, {} bytes left)",
        input.len()
    );
    let (index, payload) = input.split_at((total + 1) * 8);
    let off = |i: usize| -> usize {
        // lint: allow(panic) an 8-byte slice always converts to [u8; 8]
        u64::from_le_bytes(index[i * 8..i * 8 + 8].try_into().expect("8-byte offset")) as usize
    };
    let hi = hi.min(total);
    let lo = lo.min(hi);
    let (olo, ohi) = (off(lo), off(hi));
    anyhow::ensure!(
        olo <= ohi && ohi <= payload.len(),
        "checkpoint offsets corrupt ({olo}..{ohi} of {})",
        payload.len()
    );
    let mut slice = &payload[olo..ohi];
    let mut out = Vec::with_capacity(hi - lo);
    for _ in lo..hi {
        out.push(T::decode(&mut slice)?);
    }
    anyhow::ensure!(slice.is_empty(), "checkpoint slice has trailing bytes");
    Ok((out, (ohi - olo) as u64))
}

/// Positioned 8-byte read used by the slice path's header/offset probes.
fn read_u64_at(f: &mut std::fs::File, pos: u64) -> Result<u64> {
    use std::io::{Read as _, Seek as _, SeekFrom};
    let mut buf = [0u8; 8];
    f.seek(SeekFrom::Start(pos))?;
    f.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

/// Partitions persisted as indexed encoded files (checkpoint outputs).
/// Element counts are recorded at write time so `split_partitions` can
/// slice without a read; the in-file offset index makes each slice read
/// *read and* decode only its own byte range (header word, two
/// bracketing offsets, payload range — via positioned reads, never the
/// whole file); and reads fall back to the HDFS-style `.r1`/`.r2`
/// replica copies when the primary file is missing (lost node).
struct DiskPartsNode<T> {
    ctx: Cluster,
    dir: std::path::PathBuf,
    /// Element count per partition, captured when the checkpoint wrote.
    lens: Vec<usize>,
    _marker: std::marker::PhantomData<fn() -> T>,
}

impl<T: Data + Encode + Decode> DiskPartsNode<T> {
    /// Read a partition's bytes, trying the primary then each replica in
    /// turn — a missing primary must fall back, not fail, for the
    /// replication copies to be worth their write cost.
    fn read_part_bytes(&self, part: usize) -> Result<Vec<u8>> {
        let mut last_err: Option<std::io::Error> = None;
        for copy in 0..self.ctx.config().disk_replication.max(1) {
            let name = if copy == 0 {
                format!("part-{part:05}.kv")
            } else {
                format!("part-{part:05}.kv.r{copy}")
            };
            match std::fs::read(self.dir.join(&name)) {
                Ok(bytes) => {
                    self.ctx
                        .io()
                        .shuffle_bytes_read
                        .fetch_add(bytes.len() as u64, std::sync::atomic::Ordering::Relaxed);
                    return Ok(bytes);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(anyhow!(
            "checkpoint partition {part} unreadable in {} (all {} copies): {}",
            self.dir.display(),
            self.ctx.config().disk_replication.max(1),
            last_err.map(|e| e.to_string()).unwrap_or_else(|| "no copies tried".into()),
        ))
    }

    /// Positioned read of a slice from one partition file: the header
    /// word, the two bracketing offsets `off[lo]`/`off[hi]`, and the
    /// payload range between them — never the whole file.  Returns the
    /// payload bytes, the clamped bounds, and the file bytes read.
    fn read_slice_file(
        &self,
        path: &std::path::Path,
        lo: usize,
        hi: usize,
    ) -> Result<(Vec<u8>, usize, usize, u64)> {
        use std::io::{Read as _, Seek as _, SeekFrom};
        let mut f = std::fs::File::open(path)?;
        let file_len = f.metadata()?.len();
        let total = read_u64_at(&mut f, 0)? as usize;
        // u128 math so a corrupt count can't overflow the index-size check.
        anyhow::ensure!(
            8 + (total as u128 + 1) * 8 <= file_len as u128,
            "checkpoint offset index truncated (count {total}, {file_len}-byte file)"
        );
        let hi = hi.min(total);
        let lo = lo.min(hi);
        let olo = read_u64_at(&mut f, 8 + lo as u64 * 8)?;
        let ohi = read_u64_at(&mut f, 8 + hi as u64 * 8)?;
        let payload_base = 8 + (total as u64 + 1) * 8;
        anyhow::ensure!(
            olo <= ohi && payload_base + ohi <= file_len,
            "checkpoint offsets corrupt ({olo}..{ohi} of {} payload bytes)",
            file_len - payload_base
        );
        let mut payload = vec![0u8; (ohi - olo) as usize];
        f.seek(SeekFrom::Start(payload_base + olo))?;
        f.read_exact(&mut payload)?;
        // Header + two offset probes + the payload range.
        let read = 8 + 16 + payload.len() as u64;
        Ok((payload, lo, hi, read))
    }

    /// Decode elements `lo..hi` from an indexed partition file — a seek
    /// to `off[lo]` plus exactly the requested range's payload bytes
    /// (charged with the usual reduce-side KV bloat, audited through the
    /// `checkpoint_bytes_decoded` counter).
    fn decode_range(&self, part: usize, bytes: &[u8], lo: usize, hi: usize) -> Result<Vec<T>> {
        let worker = self.ctx.executor().worker_for(part);
        let charge = bytes.len() * self.ctx.config().kv_overhead.max(1);
        self.ctx.memory().worker(worker).acquire(charge);
        let result = decode_indexed_range(bytes, lo, hi);
        self.ctx.memory().worker(worker).release(charge);
        let (out, decoded) = result?;
        self.ctx
            .io()
            .checkpoint_bytes_decoded
            .fetch_add(decoded, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }
}

impl<T: Data + Encode + Decode> PartSrc<T> for DiskPartsNode<T> {
    fn num_parts(&self) -> usize {
        self.lens.len()
    }

    fn compute(&self, part: usize) -> Result<Vec<T>> {
        // Reduce-side deserialization buffer with the JVM KV bloat —
        // every downstream job re-pays this at the boundary (the paper's
        // "key-value pair conversion operators").
        let bytes = self.read_part_bytes(part)?;
        self.decode_range(part, &bytes, 0, usize::MAX)
    }

    fn part_len(&self, part: usize) -> Result<Option<usize>> {
        Ok(Some(self.lens[part]))
    }

    fn compute_slice(&self, part: usize, lo: usize, hi: usize) -> Result<Vec<T>> {
        // Positioned reads: a slice touches the header, two offsets and
        // its own payload byte range — `fs::read`-ing the whole
        // partition file here made every 2-element split read (and get
        // charged memory for) the entire checkpoint.  Primary-then-
        // replica fallback as in `read_part_bytes`.
        let mut last_err: Option<anyhow::Error> = None;
        let mut got = None;
        for copy in 0..self.ctx.config().disk_replication.max(1) {
            let name = if copy == 0 {
                format!("part-{part:05}.kv")
            } else {
                format!("part-{part:05}.kv.r{copy}")
            };
            match self.read_slice_file(&self.dir.join(&name), lo, hi) {
                Ok(v) => {
                    got = Some(v);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let Some((payload, lo, hi, read)) = got else {
            return Err(anyhow!(
                "checkpoint partition {part} unreadable in {} (all {} copies): {}",
                self.dir.display(),
                self.ctx.config().disk_replication.max(1),
                last_err.map(|e| e.to_string()).unwrap_or_else(|| "no copies tried".into()),
            ));
        };
        self.ctx
            .io()
            .shuffle_bytes_read
            .fetch_add(read, std::sync::atomic::Ordering::Relaxed);
        let worker = self.ctx.executor().worker_for(part);
        let charge = payload.len() * self.ctx.config().kv_overhead.max(1);
        self.ctx.memory().worker(worker).acquire(charge);
        let result = (|| -> Result<Vec<T>> {
            let mut slice = &payload[..];
            let mut out = Vec::with_capacity(hi - lo);
            for _ in lo..hi {
                out.push(T::decode(&mut slice)?);
            }
            anyhow::ensure!(slice.is_empty(), "checkpoint slice has trailing bytes");
            Ok(out)
        })();
        self.ctx.memory().worker(worker).release(charge);
        let out = result?;
        self.ctx
            .io()
            .checkpoint_bytes_decoded
            .fetch_add(payload.len() as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::{Cluster, ClusterConfig};

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::spark(3))
    }

    #[test]
    fn parallelize_partitions_evenly() {
        let c = cluster();
        let rdd = c.parallelize((0..100u32).collect(), 7);
        assert_eq!(rdd.num_partitions(), 7);
        let mut all = rdd.collect().unwrap();
        all.sort();
        assert_eq!(all, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn parallelize_empty_is_single_empty_partition() {
        let c = cluster();
        let rdd = c.parallelize(Vec::<u32>::new(), 4);
        assert_eq!(rdd.count().unwrap(), 0);
    }

    #[test]
    fn map_filter_flatmap_chain() {
        let c = cluster();
        let out = c
            .parallelize((1..=10u32).collect(), 3)
            .map(|x| x * 2)
            .filter(|x| x % 3 == 0)
            .flat_map(|x| vec![x, x + 1])
            .collect()
            .unwrap();
        let mut sorted = out.clone();
        sorted.sort();
        assert_eq!(sorted, vec![6, 7, 12, 13, 18, 19]);
    }

    #[test]
    fn count_and_reduce() {
        let c = cluster();
        let rdd = c.parallelize((1..=100u64).collect(), 8);
        assert_eq!(rdd.count().unwrap(), 100);
        assert_eq!(rdd.reduce(|a, b| a + b).unwrap(), Some(5050));
    }

    #[test]
    fn reduce_empty_is_none() {
        let c = cluster();
        assert_eq!(
            c.parallelize(Vec::<u32>::new(), 2).reduce(|a, b| a + b).unwrap(),
            None
        );
    }

    #[test]
    fn sample_is_deterministic_and_rough() {
        let c = cluster();
        let rdd = c.parallelize((0..2000u32).collect(), 5);
        let a = rdd.sample(0.1, 7).collect().unwrap();
        let b = rdd.sample(0.1, 7).collect().unwrap();
        assert_eq!(a, b);
        assert!(a.len() > 120 && a.len() < 300, "got {}", a.len());
    }

    #[test]
    fn union_concatenates() {
        let c = cluster();
        let a = c.parallelize(vec![1u32, 2], 2);
        let b = c.parallelize(vec![3u32], 1);
        let mut out = a.union(&b).collect().unwrap();
        out.sort();
        assert_eq!(out, vec![1, 2, 3]);
        assert_eq!(a.union(&b).num_partitions(), 3);
    }

    #[test]
    fn zip_with_index_is_globally_contiguous() {
        let c = cluster();
        let rdd = c.parallelize((10..60u32).collect(), 4).zip_with_index().unwrap();
        let mut out = rdd.collect().unwrap();
        out.sort_by_key(|(i, _)| *i);
        assert_eq!(out.len(), 50);
        assert_eq!(out[0], (0, 10));
        assert_eq!(out[49], (49, 59));
    }

    #[test]
    fn cache_computes_parent_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let c = cluster();
        let calls = Arc::new(AtomicUsize::new(0));
        let k = calls.clone();
        let rdd = c
            .parallelize((0..20u32).collect(), 4)
            .map(move |x| {
                k.fetch_add(1, Ordering::SeqCst);
                x
            })
            .cache();
        rdd.collect().unwrap();
        rdd.collect().unwrap();
        rdd.count().unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 20, "parent ran once");
    }

    #[test]
    fn cache_charges_worker_memory_until_drop() {
        let c = cluster();
        let before = c.memory().total_current();
        {
            let rdd = c.parallelize(vec![vec![0u8; 1000]; 12], 3).cache();
            rdd.collect().unwrap();
            assert!(c.memory().total_current() >= before + 12_000);
            drop(rdd);
        }
        assert!(c.memory().total_current() <= before + 1000);
    }

    #[test]
    fn checkpoint_roundtrips_in_both_backends() {
        for cfg in [ClusterConfig::spark(2), ClusterConfig::hadoop(2)] {
            let is_disk = cfg.backend == Backend::DiskKv;
            let c = Cluster::new(cfg);
            let rdd = c.parallelize((0..50u32).collect(), 4).map(|x| x + 1);
            let ck = rdd.checkpoint().unwrap();
            let mut out = ck.collect().unwrap();
            out.sort();
            assert_eq!(out, (1..=50).collect::<Vec<u32>>());
            if is_disk {
                assert!(c.stats().shuffle_bytes_written > 0);
            } else {
                assert_eq!(c.stats().shuffle_bytes_written, 0);
            }
        }
    }

    #[test]
    fn split_partitions_preserves_order_and_multiplies_tasks() {
        let c = cluster();
        let rdd = c.parallelize((0..101u32).collect(), 4);
        let fine = rdd.split_partitions(3);
        assert_eq!(fine.num_partitions(), 12);
        assert_eq!(fine.collect().unwrap(), (0..101).collect::<Vec<u32>>());
        // factor 1 is the identity.
        assert_eq!(rdd.split_partitions(1).num_partitions(), 4);
    }

    #[test]
    fn split_partitions_handles_empty_and_tiny_partitions() {
        let c = cluster();
        let rdd = c.parallelize(vec![7u32, 8], 2).split_partitions(4);
        assert_eq!(rdd.num_partitions(), 8);
        assert_eq!(rdd.collect().unwrap(), vec![7, 8]);
    }

    #[test]
    fn coalesce_merges_adjacent_partitions() {
        let c = cluster();
        let rdd = c.parallelize((0..50u32).collect(), 7);
        let coarse = rdd.coalesce(3);
        assert_eq!(coarse.num_partitions(), 3);
        assert_eq!(coarse.collect().unwrap(), (0..50).collect::<Vec<u32>>());
        // Requests beyond the current count are the identity.
        assert_eq!(rdd.coalesce(10).num_partitions(), 7);
    }

    #[test]
    fn split_then_coalesce_roundtrips() {
        let c = cluster();
        let rdd = c.parallelize((0..40u32).collect(), 5);
        let back = rdd.split_partitions(4).coalesce(5);
        assert_eq!(back.num_partitions(), 5);
        assert_eq!(back.collect().unwrap(), (0..40).collect::<Vec<u32>>());
    }

    /// Instrumented slice-aware source: counts full computes vs sliced
    /// elements so tests can prove `split_partitions` never multiplies
    /// parent computation.
    struct CountingSrc {
        parts: Vec<Vec<u32>>,
        full: std::sync::atomic::AtomicUsize,
        sliced: std::sync::atomic::AtomicUsize,
    }

    impl PartSrc<u32> for CountingSrc {
        fn num_parts(&self) -> usize {
            self.parts.len()
        }

        fn compute(&self, part: usize) -> Result<Vec<u32>> {
            self.full.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            Ok(self.parts[part].clone())
        }

        fn part_len(&self, part: usize) -> Result<Option<usize>> {
            Ok(Some(self.parts[part].len()))
        }

        fn compute_slice(&self, part: usize, lo: usize, hi: usize) -> Result<Vec<u32>> {
            let (lo, hi) = clamp_range(self.parts[part].len(), lo, hi);
            self.sliced.fetch_add(hi - lo, std::sync::atomic::Ordering::SeqCst);
            Ok(self.parts[part][lo..hi].to_vec())
        }

        fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleNode>> {
            Vec::new()
        }
    }

    #[test]
    fn split_on_sliceable_parent_computes_each_element_exactly_once() {
        use std::sync::atomic::Ordering;
        let c = cluster();
        let src = Arc::new(CountingSrc {
            parts: (0..3).map(|p| (p * 10..p * 10 + 10).collect()).collect(),
            full: Default::default(),
            sliced: Default::default(),
        });
        let fine = Rdd::from_src(c, src.clone() as Arc<dyn PartSrc<u32>>).split_partitions(4);
        assert_eq!(fine.num_partitions(), 12);
        assert_eq!(fine.collect().unwrap(), (0..30).collect::<Vec<u32>>());
        assert_eq!(
            src.full.load(Ordering::SeqCst),
            0,
            "slice-aware split must never recompute a full parent partition"
        );
        assert_eq!(
            src.sliced.load(Ordering::SeqCst),
            30,
            "each parent element must be computed exactly once across slices"
        );
    }

    #[test]
    fn split_property_each_element_once_across_random_shapes() {
        use std::sync::atomic::Ordering;
        let mut rng = crate::util::Rng::seed_from_u64(0x5117CE);
        for case in 0..100 {
            let nparts = 1 + rng.below(5);
            let factor = 1 + rng.below(7);
            let parts: Vec<Vec<u32>> = (0..nparts)
                .map(|p| {
                    let len = rng.below(40) as u32;
                    (0..len).map(|i| ((p as u32) << 16) | i).collect()
                })
                .collect();
            let expect: Vec<u32> = parts.iter().flatten().copied().collect();
            let total = expect.len();
            let src = Arc::new(CountingSrc {
                parts,
                full: Default::default(),
                sliced: Default::default(),
            });
            let c = cluster();
            let fine =
                Rdd::from_src(c, src.clone() as Arc<dyn PartSrc<u32>>).split_partitions(factor);
            assert_eq!(fine.collect().unwrap(), expect, "case {case}: order preserved");
            if factor > 1 {
                assert_eq!(src.full.load(Ordering::SeqCst), 0, "case {case}");
                assert_eq!(src.sliced.load(Ordering::SeqCst), total, "case {case}");
            }
        }
    }

    #[test]
    fn split_on_cached_parent_computes_parent_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let c = cluster();
        let calls = Arc::new(AtomicUsize::new(0));
        let k = calls.clone();
        let fine = c
            .parallelize((0..40u32).collect(), 4)
            .map(move |x| {
                k.fetch_add(1, Ordering::SeqCst);
                x
            })
            .cache()
            .split_partitions(4);
        assert_eq!(fine.num_partitions(), 16);
        assert_eq!(fine.collect().unwrap(), (0..40).collect::<Vec<u32>>());
        assert_eq!(
            calls.load(Ordering::SeqCst),
            40,
            "cached parent must compute each element once, not once per slice"
        );
        fine.collect().unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 40, "re-collect stays cached");
    }

    #[test]
    fn split_on_checkpoint_does_not_recompute_lineage() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        for cfg in [ClusterConfig::spark(3), ClusterConfig::hadoop(3)] {
            let c = Cluster::new(cfg);
            let calls = Arc::new(AtomicUsize::new(0));
            let k = calls.clone();
            let ck = c
                .parallelize((0..60u32).collect(), 4)
                .map(move |x| {
                    k.fetch_add(1, Ordering::SeqCst);
                    x
                })
                .checkpoint()
                .unwrap();
            assert_eq!(calls.load(Ordering::SeqCst), 60, "checkpoint materializes once");
            let fine = ck.split_partitions(5);
            assert_eq!(fine.collect().unwrap(), (0..60).collect::<Vec<u32>>());
            assert_eq!(
                calls.load(Ordering::SeqCst),
                60,
                "slices read the checkpoint, never the lineage above it"
            );
        }
    }

    #[test]
    fn nested_split_still_slices_through_to_the_source() {
        use std::sync::atomic::Ordering;
        let src = Arc::new(CountingSrc {
            parts: vec![(0..24).collect()],
            full: Default::default(),
            sliced: Default::default(),
        });
        let c = cluster();
        let fine = Rdd::from_src(c, src.clone() as Arc<dyn PartSrc<u32>>)
            .split_partitions(2)
            .split_partitions(3);
        assert_eq!(fine.num_partitions(), 6);
        assert_eq!(fine.collect().unwrap(), (0..24).collect::<Vec<u32>>());
        assert_eq!(src.full.load(Ordering::SeqCst), 0);
        assert_eq!(src.sliced.load(Ordering::SeqCst), 24);
    }

    #[test]
    fn checkpoint_survives_missing_primary_via_replicas() {
        let c = Cluster::new(ClusterConfig::hadoop(2));
        let ck = c.parallelize((0..50u32).collect(), 4).map(|x| x + 1).checkpoint().unwrap();
        // Delete every *primary* part file; the .r1/.r2 replica copies
        // must carry the read.
        let scratch = c.scratch_dir().unwrap();
        let mut deleted = 0;
        for dir in std::fs::read_dir(&scratch).unwrap().flatten() {
            if !dir.file_name().to_string_lossy().starts_with("checkpoint-") {
                continue;
            }
            for f in std::fs::read_dir(dir.path()).unwrap().flatten() {
                if f.file_name().to_string_lossy().ends_with(".kv") {
                    std::fs::remove_file(f.path()).unwrap();
                    deleted += 1;
                }
            }
        }
        assert_eq!(deleted, 4, "one primary per partition");
        let mut out = ck.collect().unwrap();
        out.sort();
        assert_eq!(out, (1..=50).collect::<Vec<u32>>(), "replicas must serve reads");
    }

    #[test]
    fn checkpoint_with_all_copies_gone_reports_error() {
        let c = Cluster::new(ClusterConfig::hadoop(2));
        let ck = c.parallelize((0..10u32).collect(), 2).checkpoint().unwrap();
        let scratch = c.scratch_dir().unwrap();
        for dir in std::fs::read_dir(&scratch).unwrap().flatten() {
            if dir.file_name().to_string_lossy().starts_with("checkpoint-") {
                for f in std::fs::read_dir(dir.path()).unwrap().flatten() {
                    std::fs::remove_file(f.path()).unwrap();
                }
            }
        }
        let err = ck.collect().unwrap_err();
        assert!(format!("{err:#}").contains("unreadable"), "got: {err:#}");
    }

    #[test]
    fn try_map_partitions_propagates_errors_for_retry() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let c = cluster();
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = attempts.clone();
        let rdd = c.parallelize((0..20u32).collect(), 4).try_map_partitions_with_index(
            move |part, xs| {
                if part == 2 && a.fetch_add(1, Ordering::SeqCst) == 0 {
                    anyhow::bail!("transient batch failure");
                }
                Ok(xs)
            },
        );
        let mut out = rdd.collect().unwrap();
        out.sort();
        assert_eq!(out, (0..20).collect::<Vec<u32>>(), "retry must recover the partition");
        assert!(attempts.load(Ordering::SeqCst) >= 2, "the failing attempt was retried");

        // A permanently failing partition surfaces the error.
        let bad = c
            .parallelize((0..8u32).collect(), 2)
            .try_map_partitions_with_index(|_, _| anyhow::bail!("always fails"));
        let err = bad.collect().unwrap_err();
        assert!(format!("{err:#}").contains("always fails"));
    }

    #[test]
    fn cartesian_blocks_pairs_every_partition_combination() {
        let c = cluster();
        let a = c.parallelize((0..12u32).collect(), 3); // chunks of 4
        let b = c.parallelize((100..106u32).collect(), 2); // chunks of 3
        let pairs = a.cartesian_blocks(&b);
        assert_eq!(pairs.num_partitions(), 6);
        let mut out = pairs.collect().unwrap();
        out.sort_by_key(|((bi, bj), _)| (*bi, *bj));
        assert_eq!(out.len(), 6);
        for (k, ((bi, bj), (xs, ys))) in out.iter().enumerate() {
            assert_eq!((*bi as usize, *bj as usize), (k / 2, k % 2));
            let xlo = *bi as u32 * 4;
            assert_eq!(xs, &(xlo..xlo + 4).collect::<Vec<u32>>(), "left block {bi}");
            let ylo = 100 + *bj as u32 * 3;
            assert_eq!(ys, &(ylo..ylo + 3).collect::<Vec<u32>>(), "right block {bj}");
        }
    }

    #[test]
    fn lower_triangle_blocks_covers_each_unordered_pair_once() {
        let c = cluster();
        let r = c.parallelize((0..10u32).collect(), 4); // chunks of 3: last is [9]
        let tri = r.lower_triangle_blocks();
        assert_eq!(tri.num_partitions(), 10, "4 blocks -> 4*5/2 pairs");
        let out = tri.collect().unwrap();
        let block = |b: u64| -> Vec<u32> {
            let lo = b as u32 * 3;
            (lo..(lo + 3).min(10)).collect()
        };
        let mut seen = std::collections::HashSet::new();
        for ((bi, bj), (xs, ys)) in out {
            assert!(bj <= bi, "only the lower triangle");
            assert!(seen.insert((bi, bj)), "pair ({bi},{bj}) emitted twice");
            assert_eq!(xs, block(bi), "row block {bi}");
            assert_eq!(ys, block(bj), "col block {bj}");
        }
        assert_eq!(seen.len(), 10);
        // Triangular partition order matches the distmat tile indexing.
        let direct = tri.src.compute(4).unwrap();
        assert_eq!(direct[0].0, (2, 1), "partition 4 = tile (2,1)");
    }

    #[test]
    fn checkpoint_tail_slice_seeks_instead_of_decoding_prefix() {
        use std::sync::atomic::Ordering;
        let c = Cluster::new(ClusterConfig::hadoop(2));
        let ck = c.parallelize((0..1000u32).collect(), 1).checkpoint().unwrap();
        let decoded = |f: &dyn Fn() -> Vec<u32>| {
            let before = c.io().checkpoint_bytes_decoded.load(Ordering::Relaxed);
            let read_before = c.io().shuffle_bytes_read.load(Ordering::Relaxed);
            let out = f();
            (
                out,
                c.io().checkpoint_bytes_decoded.load(Ordering::Relaxed) - before,
                c.io().shuffle_bytes_read.load(Ordering::Relaxed) - read_before,
            )
        };
        let (tail, tail_bytes, tail_read) =
            decoded(&|| ck.src.compute_slice(0, 900, 1000).unwrap());
        assert_eq!(tail, (900..1000).collect::<Vec<u32>>());
        let (head, head_bytes, head_read) = decoded(&|| ck.src.compute_slice(0, 0, 100).unwrap());
        assert_eq!(head, (0..100).collect::<Vec<u32>>());
        assert_eq!(
            tail_bytes, head_bytes,
            "a tail slice must decode exactly its own range, not the prefix up to hi"
        );
        // Positioned reads: a slice reads the 8-byte count, two 8-byte
        // bracketing offsets, and its own payload range — nothing else.
        assert_eq!(
            tail_read,
            tail_bytes + 24,
            "a tail slice must read only header + two offsets + its payload range"
        );
        assert_eq!(head_read, head_bytes + 24);
        let (full, full_bytes, full_read) = decoded(&|| ck.src.compute(0).unwrap());
        assert_eq!(full.len(), 1000);
        assert!(
            tail_bytes * 5 < full_bytes,
            "100 of 1000 elements must decode ~1/10th of the payload \
             (tail {tail_bytes}, full {full_bytes})"
        );
        assert!(
            tail_read * 5 < full_read,
            "a slice must not read the whole partition file \
             (slice read {tail_read}, full read {full_read})"
        );
    }

    #[test]
    fn indexed_checkpoint_roundtrips_variable_width_elements() {
        // Strings have variable encoded widths — the offset index must
        // still land every slice exactly.
        let c = Cluster::new(ClusterConfig::hadoop(2));
        let items: Vec<String> = (0..40).map(|i| "x".repeat(i % 7) + &i.to_string()).collect();
        let ck = c.parallelize(items.clone(), 3).checkpoint().unwrap();
        let mut out = ck.collect().unwrap();
        out.sort();
        let mut want = items.clone();
        want.sort();
        assert_eq!(out, want);
        // Sliced reads agree with direct indexing per partition.
        for part in 0..ck.num_partitions() {
            let whole = ck.src.compute(part).unwrap();
            for lo in 0..whole.len() {
                let slice = ck.src.compute_slice(part, lo, lo + 2).unwrap();
                let want: Vec<String> =
                    whole.iter().skip(lo).take(2).cloned().collect();
                assert_eq!(slice, want, "part {part} slice {lo}..{}", lo + 2);
            }
        }
    }

    #[test]
    fn run_partitions_preserves_order() {
        let c = cluster();
        let rdd = c.parallelize((0..40u32).collect(), 5);
        let sums = rdd.run_partitions(|_, xs| Ok(xs.iter().sum::<u32>())).unwrap();
        assert_eq!(sums.len(), 5);
        assert_eq!(sums.iter().sum::<u32>(), (0..40).sum());
    }

    #[test]
    fn failing_partition_surfaces_error() {
        let c = cluster();
        let rdd = c.parallelize((0..10u32).collect(), 2);
        let err = rdd
            .run_partitions(|p, _| {
                if p == 1 {
                    anyhow::bail!("bad partition")
                } else {
                    Ok(())
                }
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("bad partition"));
    }
}

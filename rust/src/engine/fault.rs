//! Fault injection: deterministic plans describing which (worker, task,
//! attempt) triples fail — or which worker is killed outright — used to
//! exercise lineage recompute, retry, and deque-drain paths (RDDs "will
//! be recomputed after data loss" — paper §Methods).
//!
//! Kills are consumed by the executor: when [`FaultPlan::should_kill`]
//! fires during task submission, the executor marks the node dead and
//! drains its deque back into the steal pool (see
//! [`super::executor::Executor::kill_worker`]).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

#[derive(Debug, Clone, Default)]
enum Mode {
    #[default]
    None,
    /// First attempt of any task placed on this worker fails.
    FailFirstAttemptOnWorker(usize),
    /// Fail the task with this global submission ordinal (first attempt).
    FailNthTask(usize),
    /// Fail every first attempt with probability p (seeded, deterministic
    /// per submission ordinal).
    RandomFirstAttempt { p_milli: usize, seed: u64 },
    /// Kill this worker once the global submission ordinal reaches `at`
    /// (one-shot; the executor drains the dead worker's deque).
    KillWorkerAt { worker: usize, at: usize },
}

/// Shared, cheaply clonable fault plan.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    mode: Mode,
    fired: Arc<AtomicUsize>,
    kill_fired: Arc<AtomicBool>,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn fail_first_attempt_on_worker(w: usize) -> Self {
        Self { mode: Mode::FailFirstAttemptOnWorker(w), ..Self::default() }
    }

    pub fn fail_nth_task(n: usize) -> Self {
        Self { mode: Mode::FailNthTask(n), ..Self::default() }
    }

    pub fn random(p: f64, seed: u64) -> Self {
        Self {
            mode: Mode::RandomFirstAttempt {
                p_milli: (p.clamp(0.0, 1.0) * 1000.0) as usize,
                seed,
            },
            ..Self::default()
        }
    }

    /// Kill `worker` once the global submission ordinal reaches `at`.
    pub fn kill_worker_at(worker: usize, at: usize) -> Self {
        Self { mode: Mode::KillWorkerAt { worker, at }, ..Self::default() }
    }

    /// How many injections have fired so far.
    pub fn fired(&self) -> usize {
        self.fired.load(Ordering::Relaxed)
    }

    /// Consult the kill rule for this submission ordinal; returns the
    /// worker to kill at most once over the plan's lifetime.
    pub fn should_kill(&self, ordinal: usize) -> Option<usize> {
        match self.mode {
            Mode::KillWorkerAt { worker, at } if ordinal >= at => {
                if self
                    .kill_fired
                    .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
                {
                    self.fired.fetch_add(1, Ordering::Relaxed);
                    Some(worker)
                } else {
                    None
                }
            }
            _ => None,
        }
    }

    /// Decide whether this (worker, submission ordinal, attempt) fails.
    pub fn should_fail(&self, worker: usize, ordinal: usize, attempt: usize) -> bool {
        let hit = match self.mode {
            Mode::None => false,
            Mode::FailFirstAttemptOnWorker(w) => attempt == 0 && worker == w,
            Mode::FailNthTask(n) => attempt == 0 && ordinal == n,
            Mode::RandomFirstAttempt { p_milli, seed } => {
                if attempt != 0 {
                    false
                } else {
                    // SplitMix64 hash of the ordinal — deterministic replay.
                    let mut z = (ordinal as u64).wrapping_add(seed).wrapping_add(0x9E3779B97F4A7C15);
                    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
                    ((z >> 33) % 1000) < p_milli as u64
                }
            }
        };
        if hit {
            self.fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_fires() {
        let p = FaultPlan::none();
        for i in 0..100 {
            assert!(!p.should_fail(i % 4, i, 0));
        }
        assert_eq!(p.fired(), 0);
    }

    #[test]
    fn worker_plan_only_hits_first_attempts_of_that_worker() {
        let p = FaultPlan::fail_first_attempt_on_worker(2);
        assert!(p.should_fail(2, 0, 0));
        assert!(!p.should_fail(2, 1, 1));
        assert!(!p.should_fail(1, 2, 0));
    }

    #[test]
    fn nth_task_plan_is_one_shot_per_ordinal() {
        let p = FaultPlan::fail_nth_task(5);
        assert!(!p.should_fail(0, 4, 0));
        assert!(p.should_fail(0, 5, 0));
        assert!(!p.should_fail(0, 6, 0));
    }

    #[test]
    fn kill_plan_fires_once_at_threshold() {
        let p = FaultPlan::kill_worker_at(2, 5);
        assert_eq!(p.should_kill(4), None);
        assert_eq!(p.should_kill(5), Some(2));
        assert_eq!(p.should_kill(6), None, "kill is one-shot");
        assert_eq!(p.fired(), 1);
        // Non-kill plans never kill.
        assert_eq!(FaultPlan::random(0.9, 1).should_kill(100), None);
    }

    #[test]
    fn random_plan_is_deterministic() {
        let a = FaultPlan::random(0.3, 9);
        let b = FaultPlan::random(0.3, 9);
        for i in 0..200 {
            assert_eq!(a.should_fail(0, i, 0), b.should_fail(0, i, 0));
        }
        assert!(a.fired() > 20, "p=0.3 over 200 should fire often");
        assert!(a.fired() < 120);
    }
}

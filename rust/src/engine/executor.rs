//! Worker-pool executor: N long-lived threads, one per simulated cluster
//! node, each with its own task queue and busy-time/task metrics.
//!
//! Tasks are routed to workers by partition index (`part % workers`) —
//! Spark-style stable placement so cached partitions and shuffle map
//! outputs have an owning node, which the fault injector can then "kill".
//!
//! Wall-clock on a 1-core CI box timeshares, so the metrics also record
//! per-worker *busy time*; Fig-6 reports both (see EXPERIMENTS.md).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::fault::FaultPlan;

type Job = Box<dyn FnOnce() -> Result<()> + Send>;

struct WorkerState {
    tx: Sender<Job>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// Per-worker counters (busy nanos, tasks run, failures injected).
#[derive(Debug, Default)]
pub struct WorkerMetrics {
    pub busy_nanos: AtomicU64,
    pub tasks: AtomicUsize,
    pub failures: AtomicUsize,
}

pub struct Executor {
    workers: Vec<Mutex<WorkerState>>,
    metrics: Vec<Arc<WorkerMetrics>>,
    fault: FaultPlan,
    task_counter: AtomicUsize,
}

impl Executor {
    pub fn new(num_workers: usize, fault: FaultPlan) -> Self {
        assert!(num_workers > 0);
        let mut workers = Vec::with_capacity(num_workers);
        let mut metrics = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || {
                    while let Ok(job) = rx.recv() {
                        // Task panics are converted to Err at the submit
                        // site; a panic escaping here would poison the node.
                        let _ = job();
                    }
                })
                .expect("spawning worker thread");
            workers.push(Mutex::new(WorkerState { tx, handle: Some(handle) }));
            metrics.push(Arc::new(WorkerMetrics::default()));
        }
        Self { workers, metrics, fault, task_counter: AtomicUsize::new(0) }
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    pub fn metrics(&self) -> &[Arc<WorkerMetrics>] {
        &self.metrics
    }

    pub fn total_busy(&self) -> Duration {
        Duration::from_nanos(
            self.metrics.iter().map(|m| m.busy_nanos.load(Ordering::Relaxed)).sum(),
        )
    }

    pub fn fault_plan(&self) -> &FaultPlan {
        &self.fault
    }

    /// Which worker owns partition `part` (stable placement).
    pub fn worker_for(&self, part: usize) -> usize {
        part % self.workers.len()
    }

    /// Run one task set: task `i` executes `f(i)` on its owning worker;
    /// blocks until all tasks finish.  Individual task errors (including
    /// injected faults) are retried up to `max_retries` times by
    /// re-invoking `f(i)` — lineage recompute happens naturally because
    /// `f` recomputes its inputs.
    pub fn run_tasks<F>(&self, num_tasks: usize, max_retries: usize, f: F) -> Result<()>
    where
        F: Fn(usize) -> Result<()> + Send + Sync + 'static,
    {
        if num_tasks == 0 {
            return Ok(());
        }
        let f = Arc::new(f);
        let (done_tx, done_rx) = channel::<(usize, Result<()>)>();

        let submit = |task: usize, attempt: usize| -> Result<()> {
            let w = self.worker_for(task + attempt); // retries migrate nodes
            let metrics = self.metrics[w].clone();
            let f = f.clone();
            let done = done_tx.clone();
            let fail_this = self.fault.should_fail(
                w,
                self.task_counter.fetch_add(1, Ordering::Relaxed),
                attempt,
            );
            let job: Job = Box::new(move || {
                let start = Instant::now();
                let result = if fail_this {
                    metrics.failures.fetch_add(1, Ordering::Relaxed);
                    Err(anyhow!("injected fault on worker {w} (task {task})"))
                } else {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(task)))
                        .unwrap_or_else(|p| {
                            Err(anyhow!("task {task} panicked: {}", panic_msg(p.as_ref())))
                        })
                };
                metrics
                    .busy_nanos
                    .fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
                metrics.tasks.fetch_add(1, Ordering::Relaxed);
                let _ = done.send((task, result));
                Ok(())
            });
            self.workers[w]
                .lock()
                .unwrap()
                .tx
                .send(job)
                .map_err(|_| anyhow!("worker {w} is gone"))
        };

        let mut attempts = vec![0usize; num_tasks];
        for t in 0..num_tasks {
            submit(t, 0)?;
        }
        let mut remaining = num_tasks;
        while remaining > 0 {
            let (task, result) = done_rx
                .recv()
                .map_err(|_| anyhow!("all workers died mid-job"))?;
            match result {
                Ok(()) => remaining -= 1,
                Err(e) => {
                    attempts[task] += 1;
                    if attempts[task] > max_retries {
                        return Err(e.context(format!(
                            "task {task} failed after {} attempts",
                            attempts[task]
                        )));
                    }
                    submit(task, attempts[task])?;
                }
            }
        }
        Ok(())
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic>".into())
}

impl Drop for Executor {
    fn drop(&mut self) {
        let me = std::thread::current().id();
        for w in &self.workers {
            let mut st = w.lock().unwrap();
            // Dropping the sender closes the channel; join the thread.
            let (dead_tx, _) = channel();
            st.tx = dead_tx;
            if let Some(h) = st.handle.take() {
                // A task closure can hold the last Cluster handle, making
                // a *worker* run this drop — never join yourself, detach.
                if h.thread().id() != me {
                    let _ = h.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_all_tasks_once() {
        let ex = Executor::new(4, FaultPlan::none());
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        ex.run_tasks(37, 0, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 37);
    }

    #[test]
    fn spreads_tasks_across_workers() {
        let ex = Executor::new(3, FaultPlan::none());
        ex.run_tasks(30, 0, |_| Ok(())).unwrap();
        for m in ex.metrics() {
            assert!(m.tasks.load(Ordering::SeqCst) >= 9);
        }
    }

    #[test]
    fn task_errors_are_retried() {
        let ex = Executor::new(2, FaultPlan::none());
        let tries = Arc::new(AtomicUsize::new(0));
        let t = tries.clone();
        ex.run_tasks(1, 3, move |_| {
            if t.fetch_add(1, Ordering::SeqCst) < 2 {
                anyhow::bail!("transient");
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(tries.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn exhausted_retries_propagate_error() {
        let ex = Executor::new(2, FaultPlan::none());
        let err = ex
            .run_tasks(4, 1, |t| {
                if t == 2 {
                    anyhow::bail!("always fails")
                }
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("task 2"));
    }

    #[test]
    fn panics_become_errors_not_hangs() {
        let ex = Executor::new(2, FaultPlan::none());
        let err = ex
            .run_tasks(1, 0, |_| panic!("boom"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn injected_faults_recover_via_retry() {
        // Fail every task's first attempt on worker 0.
        let ex = Executor::new(2, FaultPlan::fail_first_attempt_on_worker(0));
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        ex.run_tasks(8, 2, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 8);
        let injected: usize = ex
            .metrics()
            .iter()
            .map(|m| m.failures.load(Ordering::SeqCst))
            .sum();
        assert!(injected > 0, "fault plan should have fired");
    }
}

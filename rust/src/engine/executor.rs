//! Work-stealing executor: N long-lived threads, one per simulated cluster
//! node, each with its own deque plus the ability to steal from the
//! busiest peer when idle.
//!
//! Two queue architectures, selected by [`SchedulerMode`]:
//!
//! * **Sharded** (default): every worker owns a `Mutex<VecDeque<Job>>`
//!   touched only by its owner on the hot path; an idle worker steals
//!   **half** of the busiest victim's deque in one batch (one lock
//!   round-trip migrates many tasks instead of one), and `enqueue` /
//!   `kill_worker` / shutdown coordinate through a small control block
//!   (atomic liveness flags plus a wake-epoch condvar) instead of a
//!   global lock.  Past `steal_sample_threshold` workers the victim scan
//!   itself becomes O(1): sampled two-choice probes replace the full
//!   length-mirror sweep (the pre-park rescan stays exhaustive for
//!   liveness).  This is the per-domain decomposition that keeps
//!   scheduling cheap past ~12 workers.
//! * **GlobalLock**: the original single `Mutex<SchedState>` scheduler,
//!   kept as the A/B baseline for the Fig-6 sharded-vs-global scenario.
//!
//! Placement is locality-preferred in both modes: task `i` of a stage is
//! enqueued on worker `i % workers` (the partition's *owning* node, so
//! cached partitions and shuffle map outputs keep a stable home the fault
//! injector can target), but any idle worker may steal queued tasks —
//! the delay/speculative scheduling story of Spark, which is what keeps
//! one slow node from stalling a whole stage.
//!
//! Straggler mitigation: once a stage is past its speculation quantile
//! (default 75% of tasks complete), tasks whose *execution* (measured
//! from the worker-side start timestamp, not from enqueue — queue wait
//! must not inflate the average task duration) has outrun the stage's
//! variance-derived deadline (mean + k·stddev of completed execution
//! times, floored at 100ms) are re-submitted as speculative duplicates
//! on another node; the first completion wins and the duplicate's result
//! is discarded.  Task closures therefore run with *at-least-once*
//! semantics and must be idempotent — every engine task is (they
//! recompute deterministic partitions and write keyed slots).
//!
//! Fault kills: [`Executor::kill_worker`] (usually driven by a
//! [`FaultPlan`] kill rule) marks a node dead and drains its deque back
//! into the steal pool, so queued tasks migrate instead of being lost.
//!
//! Wall-clock on a 1-core CI box timeshares, so the metrics also record
//! per-worker *busy time*; Fig-6 reports both plus the busy-time skew
//! (max/mean busy nanos), the load-balance signal the stealer improves.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::fault::FaultPlan;
use crate::obs::{EngineObs, Registry, TraceKind, TraceSink};

/// A unit of queued work; receives the id of the worker that executes it.
type Job = Box<dyn FnOnce(usize) + Send>;

/// Queue architecture: per-worker sharded deques (default) vs the single
/// global-mutex scheduler kept as the scaling baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerMode {
    /// Per-worker `Mutex<VecDeque>` shards, steal-half batches, control
    /// block coordination — no global lock on the hot path.
    Sharded,
    /// One `Mutex` around every queue (the pre-sharding scheduler); every
    /// pop/steal/enqueue serializes through it.
    GlobalLock,
}

/// Scheduler tuning knobs (see [`super::context::ClusterConfig`]).
#[derive(Debug, Clone)]
pub struct ExecutorOptions {
    /// Idle workers steal from the busiest peer's deque.
    pub work_stealing: bool,
    /// Re-execute stragglers speculatively near the end of a stage.
    pub speculation: bool,
    /// Fraction of a stage that must be complete before speculating.
    pub speculation_quantile: f64,
    /// Stages smaller than this never speculate.
    pub speculation_min_tasks: usize,
    /// Straggler deadline = mean + `speculation_sigma` · stddev of the
    /// stage's completed execution times (floored at 100ms), so tight
    /// stages duplicate aggressively and naturally-spread stages don't.
    pub speculation_sigma: f64,
    /// Sharded mode: above this worker count, steal victims are picked
    /// by sampled two-choice (O(1) probes) instead of the O(workers)
    /// length-mirror scan; the pre-park rescan always runs the full scan
    /// so a sampled miss can never strand queued work.
    pub steal_sample_threshold: usize,
    /// Queue architecture (sharded deques vs single global mutex).
    pub mode: SchedulerMode,
    /// Per-lane capacity of the lifecycle trace rings ([`crate::obs`]);
    /// 0 (the default) disables tracing entirely — `emit` returns after
    /// one field load, so un-traced runs pay nothing on the hot path.
    pub trace_capacity: usize,
}

impl Default for ExecutorOptions {
    fn default() -> Self {
        Self {
            work_stealing: true,
            speculation: true,
            speculation_quantile: 0.75,
            speculation_min_tasks: 4,
            speculation_sigma: 3.0,
            steal_sample_threshold: 128,
            mode: SchedulerMode::Sharded,
            trace_capacity: 0,
        }
    }
}

use crate::util::hash::splitmix64;

/// Per-stage adaptive straggler deadline: mean + `sigma` · stddev of
/// completed worker-side execution nanos, floored at 100ms.  A stage of
/// uniform durations gets a deadline barely above its mean (any real
/// straggler is duplicated fast); a stage with genuine duration spread
/// (bimodal workloads) widens its own deadline so the natural slow half
/// is not pointlessly duplicated.
fn variance_deadline(sum_nanos: u64, sum_sq_nanos: f64, count: usize, sigma: f64) -> u64 {
    const FLOOR_NANOS: u64 = 100_000_000;
    if count == 0 {
        return FLOOR_NANOS;
    }
    let mean = sum_nanos as f64 / count as f64;
    let var = (sum_sq_nanos / count as f64 - mean * mean).max(0.0);
    ((mean + sigma * var.sqrt()) as u64).max(FLOOR_NANOS)
}

/// Per-worker counters (busy nanos, tasks run, failures injected, tasks
/// stolen from peers, steal batches, scheduler-lock contention events,
/// speculative duplicates enqueued on this worker).
#[derive(Debug, Default)]
pub struct WorkerMetrics {
    pub busy_nanos: AtomicU64,
    pub tasks: AtomicUsize,
    pub failures: AtomicUsize,
    /// Tasks this worker migrated out of peers' deques.
    pub steals: AtomicUsize,
    /// Steal operations (each migrates up to half the victim's deque).
    pub steal_batches: AtomicUsize,
    /// Times a scheduler lock was already held when this worker wanted it
    /// (`try_lock` miss) — the lock-contention proxy Fig-6 reports.
    pub lock_contention: AtomicUsize,
    pub speculations: AtomicUsize,
}

// ---------------------------------------------------------------------------
// GlobalLock backend — the pre-sharding scheduler, kept as the baseline.
// ---------------------------------------------------------------------------

struct SchedState {
    queues: Vec<VecDeque<Job>>,
    alive: Vec<bool>,
    shutdown: bool,
}

impl SchedState {
    /// Least-loaded alive worker — the single placement fallback shared
    /// by dead-owner reroutes and kill-drain redistribution.
    fn least_loaded_alive(&self) -> Option<usize> {
        (0..self.queues.len())
            .filter(|&v| self.alive[v])
            .min_by_key(|&v| self.queues[v].len())
    }
}

struct GlobalQueues {
    state: Mutex<SchedState>,
    cv: Condvar,
    steal: bool,
    obs: Arc<EngineObs>,
}

impl GlobalQueues {
    fn new(workers: usize, steal: bool, obs: Arc<EngineObs>) -> Self {
        Self {
            state: Mutex::new(SchedState {
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                alive: vec![true; workers],
                shutdown: false,
            }),
            cv: Condvar::new(),
            steal,
            obs,
        }
    }

    fn lock_state(&self, m: Option<&WorkerMetrics>) -> MutexGuard<'_, SchedState> {
        match self.state.try_lock() {
            Ok(g) => g,
            Err(_) => {
                if let Some(m) = m {
                    m.lock_contention.fetch_add(1, Ordering::Relaxed);
                }
                self.obs.lock_contention.inc();
                self.state.lock().unwrap()
            }
        }
    }

    /// Block until a job is available for `w`; `None` = shutdown or dead.
    fn next_job(&self, w: usize, m: &WorkerMetrics) -> Option<Job> {
        let mut st = self.lock_state(Some(m));
        loop {
            if st.shutdown || !st.alive[w] {
                return None;
            }
            if let Some(job) = st.queues[w].pop_front() {
                return Some(job);
            }
            if self.steal {
                // Steal from the back of the busiest non-empty deque.
                let victim = (0..st.queues.len())
                    .filter(|&v| v != w && !st.queues[v].is_empty())
                    .max_by_key(|&v| st.queues[v].len());
                if let Some(job) = victim.and_then(|v| st.queues[v].pop_back()) {
                    m.steals.fetch_add(1, Ordering::Relaxed);
                    m.steal_batches.fetch_add(1, Ordering::Relaxed);
                    self.obs.tasks_stolen.inc();
                    self.obs.steal_batches.inc();
                    self.obs.trace.emit(w, TraceKind::Steal, 1);
                    return Some(job);
                }
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn enqueue(&self, owner: usize, job: Job) -> Result<usize> {
        let target = {
            let mut st = self.lock_state(None);
            let target = if st.alive[owner] {
                owner
            } else {
                st.least_loaded_alive().ok_or_else(|| anyhow!("all workers are dead"))?
            };
            st.queues[target].push_back(job);
            target
        };
        self.cv.notify_all();
        Ok(target)
    }

    fn kill(&self, w: usize) -> bool {
        let drained_count;
        {
            let mut st = self.lock_state(None);
            if w >= st.alive.len() || !st.alive[w] {
                return false;
            }
            if st.alive.iter().filter(|&&a| a).count() <= 1 {
                return false;
            }
            st.alive[w] = false;
            let drained: Vec<Job> = st.queues[w].drain(..).collect();
            drained_count = drained.len();
            for job in drained {
                // lint: allow(panic) alive count checked > 1 above under this state lock
                let target = st.least_loaded_alive().expect("one alive worker remains");
                st.queues[target].push_back(job);
            }
        }
        // Driver lane (one past the workers) records the drain.
        let lanes = self.obs.trace.num_lanes();
        self.obs.trace.emit(lanes.saturating_sub(1), TraceKind::KillDrain, drained_count as u64);
        self.cv.notify_all();
        true
    }

    fn alive_count(&self) -> usize {
        self.state.lock().unwrap().alive.iter().filter(|&&a| a).count()
    }

    fn begin_shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Sharded backend — per-worker deques + control block, steal-half batches.
// ---------------------------------------------------------------------------

struct Shard {
    deque: Mutex<VecDeque<Job>>,
    /// Mirror of `deque.len()`, updated under the deque lock; lets victim
    /// selection and least-loaded routing run without touching any lock.
    len: AtomicUsize,
}

struct ShardedQueues {
    shards: Vec<Shard>,
    /// Control block: liveness + shutdown are plain atomics so the hot
    /// path (owner pop) takes exactly one uncontended shard lock.
    alive: Vec<AtomicBool>,
    shutdown: AtomicBool,
    /// Wake epoch: bumped under the mutex whenever work is enqueued,
    /// redistributed, or liveness changes; idle workers park on it.  Only
    /// touched on the idle path, never on a successful pop.
    epoch: Mutex<u64>,
    cv: Condvar,
    /// Serializes kills so "never kill the last alive worker" is atomic.
    kill_lock: Mutex<()>,
    steal: bool,
    /// Worker counts above this use sampled two-choice victim selection.
    sample_above: usize,
    /// Monotone counter feeding the victim-sampling hash.
    steal_tick: AtomicU64,
    obs: Arc<EngineObs>,
}

impl ShardedQueues {
    fn new(workers: usize, steal: bool, sample_above: usize, obs: Arc<EngineObs>) -> Self {
        Self {
            shards: (0..workers)
                .map(|_| Shard { deque: Mutex::new(VecDeque::new()), len: AtomicUsize::new(0) })
                .collect(),
            alive: (0..workers).map(|_| AtomicBool::new(true)).collect(),
            shutdown: AtomicBool::new(false),
            epoch: Mutex::new(0),
            cv: Condvar::new(),
            kill_lock: Mutex::new(()),
            steal,
            sample_above,
            steal_tick: AtomicU64::new(0),
            obs,
        }
    }

    fn lock_shard(
        &self,
        s: usize,
        m: Option<&WorkerMetrics>,
    ) -> MutexGuard<'_, VecDeque<Job>> {
        match self.shards[s].deque.try_lock() {
            Ok(g) => g,
            Err(_) => {
                if let Some(m) = m {
                    m.lock_contention.fetch_add(1, Ordering::Relaxed);
                }
                self.obs.lock_contention.inc();
                self.shards[s].deque.lock().unwrap()
            }
        }
    }

    fn bump_epoch(&self) {
        *self.epoch.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    fn least_loaded_alive(&self) -> Option<usize> {
        // lint: allow(relaxed-handshake) Relaxed is the shard len counter; alive is SeqCst
        (0..self.shards.len())
            .filter(|&v| self.alive[v].load(Ordering::SeqCst))
            .min_by_key(|&v| self.shards[v].len.load(Ordering::Relaxed))
    }

    /// Pop the front of the worker's own deque (owner-only hot path).
    fn pop_own(&self, w: usize, m: &WorkerMetrics) -> Option<Job> {
        if self.shards[w].len.load(Ordering::Relaxed) == 0 {
            return None;
        }
        let mut q = self.lock_shard(w, Some(m));
        let job = q.pop_front();
        self.shards[w].len.store(q.len(), Ordering::Relaxed);
        job
    }

    /// Steal the back half of a peer's deque in one batch: one lock
    /// round-trip migrates ~half the victim's queue instead of a single
    /// task.  Victim selection is the busiest-shard scan of the length
    /// mirrors — O(workers) per steal — unless the worker count exceeds
    /// `sample_above` and this is not a `thorough` attempt, in which case
    /// two deterministic pseudo-random shards are probed and the longer
    /// one wins (power-of-two-choices).  A sampled probe can miss the
    /// only non-empty shard; callers therefore pass `thorough = true` on
    /// the final pre-park rescan so queued work is never stranded behind
    /// a sampling miss.  Returns the first stolen job to run now; the
    /// rest are appended to the thief's own deque (where peers may
    /// steal-chain).
    fn steal_half(&self, w: usize, m: &WorkerMetrics, thorough: bool) -> Option<Job> {
        if !self.alive[w].load(Ordering::SeqCst) {
            // Killed since the caller's liveness check: don't take on new
            // work.  A kill racing past this check is still benign — the
            // append below bumps the epoch and dead shards remain valid
            // steal victims, so any jobs parked there get re-stolen.
            return None;
        }
        let nb = self.shards.len();
        let load = |v: usize| self.shards[v].len.load(Ordering::Relaxed);
        let victim = if !thorough && nb > self.sample_above {
            let tick = self.steal_tick.fetch_add(1, Ordering::Relaxed);
            let h = splitmix64(((w as u64) << 32) ^ tick);
            let c0 = (h % nb as u64) as usize;
            let c1 = ((h >> 32) % nb as u64) as usize;
            let ok = |v: usize| v != w && load(v) > 0;
            match (ok(c0), ok(c1)) {
                (true, true) => Some(if load(c0) >= load(c1) { c0 } else { c1 }),
                (true, false) => Some(c0),
                (false, true) => Some(c1),
                (false, false) => None,
            }
        } else {
            (0..nb).filter(|&v| v != w && load(v) > 0).max_by_key(|&v| load(v))
        };
        let victim = victim?;
        let mut batch = {
            let mut vq = self.lock_shard(victim, Some(m));
            let n = vq.len();
            if n == 0 {
                return None; // raced: victim drained before we locked
            }
            let batch = vq.split_off(n - n.div_ceil(2));
            self.shards[victim].len.store(vq.len(), Ordering::Relaxed);
            batch
        };
        m.steals.fetch_add(batch.len(), Ordering::Relaxed);
        m.steal_batches.fetch_add(1, Ordering::Relaxed);
        self.obs.tasks_stolen.add(batch.len() as u64);
        self.obs.steal_batches.inc();
        self.obs.trace.emit(w, TraceKind::Steal, batch.len() as u64);
        let first = batch.pop_front()?;
        if !batch.is_empty() {
            let mut q = self.lock_shard(w, Some(m));
            q.append(&mut batch);
            self.shards[w].len.store(q.len(), Ordering::Relaxed);
            drop(q);
            // The thief's deque just gained work other idle workers may
            // steal-chain from.
            self.bump_epoch();
        }
        Some(first)
    }

    /// Block until a job is available for `w`; `None` = shutdown or dead.
    fn next_job(&self, w: usize, m: &WorkerMetrics) -> Option<Job> {
        loop {
            if self.shutdown.load(Ordering::SeqCst) || !self.alive[w].load(Ordering::SeqCst) {
                return None;
            }
            if let Some(job) = self.pop_own(w, m) {
                return Some(job);
            }
            if self.steal {
                if let Some(job) = self.steal_half(w, m, false) {
                    return Some(job);
                }
            }
            // Idle path: snapshot the wake epoch, rescan once (an enqueue
            // that bumped the epoch before our snapshot also finished its
            // push before it — the epoch mutex orders the two), then park
            // until the epoch moves.  The rescan steal is `thorough`
            // (full victim scan even above the sampling threshold): a
            // worker must never park behind a two-choice sampling miss.
            let seen = *self.epoch.lock().unwrap();
            if let Some(job) = self.pop_own(w, m) {
                return Some(job);
            }
            if self.steal {
                if let Some(job) = self.steal_half(w, m, true) {
                    return Some(job);
                }
            }
            let mut epoch = self.epoch.lock().unwrap();
            while *epoch == seen
                && !self.shutdown.load(Ordering::SeqCst)
                && self.alive[w].load(Ordering::SeqCst)
            {
                epoch = self.cv.wait(epoch).unwrap();
            }
        }
    }

    fn enqueue(&self, owner: usize, job: Job) -> Result<usize> {
        let mut job = Some(job);
        loop {
            let target = if self.alive[owner].load(Ordering::SeqCst) {
                owner
            } else {
                self.least_loaded_alive().ok_or_else(|| anyhow!("all workers are dead"))?
            };
            let mut q = self.shards[target].deque.lock().unwrap();
            // Re-check liveness under the shard lock: `kill` marks a node
            // dead *before* locking its deque to drain it, so any push
            // that observed `alive` here is guaranteed to be drained (or
            // the push sees `dead` and retries elsewhere) — a job can
            // never strand in a dead worker's deque.
            if self.alive[target].load(Ordering::SeqCst) {
                // lint: allow(panic) the job is taken exactly once: this arm returns
                q.push_back(job.take().expect("job still to be placed"));
                self.shards[target].len.store(q.len(), Ordering::Relaxed);
                drop(q);
                self.bump_epoch();
                return Ok(target);
            }
        }
    }

    fn kill(&self, w: usize) -> bool {
        let _serialized = self.kill_lock.lock().unwrap();
        if w >= self.alive.len() || !self.alive[w].load(Ordering::SeqCst) {
            return false;
        }
        if self.alive.iter().filter(|a| a.load(Ordering::SeqCst)).count() <= 1 {
            return false;
        }
        // Dead before drain — see the enqueue liveness re-check.
        self.alive[w].store(false, Ordering::SeqCst);
        let drained: Vec<Job> = {
            let mut q = self.shards[w].deque.lock().unwrap();
            let d = q.drain(..).collect();
            self.shards[w].len.store(0, Ordering::Relaxed);
            d
        };
        // Redistribute to the least-loaded alive workers; targets cannot
        // die concurrently because kills are serialized.
        let drained_count = drained.len();
        for job in drained {
            // lint: allow(panic) kill refuses to remove the last alive worker above
            let target = self.least_loaded_alive().expect("one alive worker remains");
            let mut q = self.shards[target].deque.lock().unwrap();
            q.push_back(job);
            self.shards[target].len.store(q.len(), Ordering::Relaxed);
        }
        // Driver lane (one past the workers) records the drain.
        self.obs.trace.emit(self.shards.len(), TraceKind::KillDrain, drained_count as u64);
        self.bump_epoch();
        true
    }

    fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| a.load(Ordering::SeqCst)).count()
    }

    fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.bump_epoch();
    }
}

// ---------------------------------------------------------------------------
// Backend dispatch
// ---------------------------------------------------------------------------

enum Queues {
    Global(GlobalQueues),
    Sharded(ShardedQueues),
}

impl Queues {
    fn next_job(&self, w: usize, m: &WorkerMetrics) -> Option<Job> {
        match self {
            Queues::Global(q) => q.next_job(w, m),
            Queues::Sharded(q) => q.next_job(w, m),
        }
    }

    fn enqueue(&self, owner: usize, job: Job) -> Result<usize> {
        match self {
            Queues::Global(q) => q.enqueue(owner, job),
            Queues::Sharded(q) => q.enqueue(owner, job),
        }
    }

    fn kill(&self, w: usize) -> bool {
        match self {
            Queues::Global(q) => q.kill(w),
            Queues::Sharded(q) => q.kill(w),
        }
    }

    fn alive_count(&self) -> usize {
        match self {
            Queues::Global(q) => q.alive_count(),
            Queues::Sharded(q) => q.alive_count(),
        }
    }

    fn begin_shutdown(&self) {
        match self {
            Queues::Global(q) => q.begin_shutdown(),
            Queues::Sharded(q) => q.begin_shutdown(),
        }
    }
}

struct Shared {
    queues: Queues,
    metrics: Vec<Arc<WorkerMetrics>>,
    obs: Arc<EngineObs>,
}

struct TaskDone {
    task: usize,
    speculative: bool,
    result: Result<()>,
    /// Worker-side execution time (excludes queue wait).
    exec_nanos: u64,
}

pub struct Executor {
    shared: Arc<Shared>,
    handles: Vec<Option<std::thread::JoinHandle<()>>>,
    fault: FaultPlan,
    opts: ExecutorOptions,
    /// The cluster-wide metrics registry every subsystem registers into
    /// (engine families here; shuffle/spill via `IoCounters`, cache and
    /// request families via the server).
    registry: Arc<Registry>,
    task_counter: AtomicUsize,
    /// Stages executed via `run_tasks`, numbered from 1 in submission
    /// order.  The stage id is packed into the high 32 bits of every
    /// task-lifecycle trace payload (`(stage << 32) | task`), so the
    /// post-hoc profiler can group spans per stage and walk the
    /// barrier-ordered stage chain as its dependency edges.
    stage_counter: AtomicU64,
    /// Mean worker-side execution nanos of the most recent stage — the
    /// quantity the speculation deadline is derived from (regression
    /// hook: queue wait must never leak into it).
    last_stage_avg_exec_nanos: AtomicU64,
    /// Most recent variance-derived straggler deadline (regression hook
    /// for the mean + k·stddev formula).
    last_stage_deadline_nanos: AtomicU64,
}

fn worker_loop(w: usize, shared: Arc<Shared>) {
    let metrics = shared.metrics[w].clone();
    while let Some(job) = shared.queues.next_job(w, &metrics) {
        job(w);
    }
}

impl Executor {
    pub fn new(num_workers: usize, fault: FaultPlan) -> Self {
        Self::with_options(num_workers, fault, ExecutorOptions::default())
    }

    pub fn with_options(num_workers: usize, fault: FaultPlan, opts: ExecutorOptions) -> Self {
        assert!(num_workers > 0);
        let registry = Registry::new();
        let obs = EngineObs::register(&registry, num_workers, opts.trace_capacity);
        let queues = match opts.mode {
            SchedulerMode::Sharded => Queues::Sharded(ShardedQueues::new(
                num_workers,
                opts.work_stealing,
                opts.steal_sample_threshold,
                obs.clone(),
            )),
            SchedulerMode::GlobalLock => {
                Queues::Global(GlobalQueues::new(num_workers, opts.work_stealing, obs.clone()))
            }
        };
        let shared = Arc::new(Shared {
            queues,
            metrics: (0..num_workers).map(|_| Arc::new(WorkerMetrics::default())).collect(),
            obs,
        });
        let mut handles = Vec::with_capacity(num_workers);
        for w in 0..num_workers {
            let shared = shared.clone();
            // lint: allow(panic) driver-side startup, before any task runs; spawn
            // failure here means the process cannot host workers at all
            let handle = std::thread::Builder::new()
                .name(format!("worker-{w}"))
                .spawn(move || worker_loop(w, shared))
                .expect("spawning worker thread");
            handles.push(Some(handle));
        }
        Self {
            shared,
            handles,
            fault,
            opts,
            registry,
            task_counter: AtomicUsize::new(0),
            stage_counter: AtomicU64::new(0),
            last_stage_avg_exec_nanos: AtomicU64::new(0),
            last_stage_deadline_nanos: AtomicU64::new(0),
        }
    }

    pub fn num_workers(&self) -> usize {
        self.shared.metrics.len()
    }

    pub fn metrics(&self) -> &[Arc<WorkerMetrics>] {
        &self.shared.metrics
    }

    pub fn options(&self) -> &ExecutorOptions {
        &self.opts
    }

    /// The cluster-wide metrics registry (scraped by `GET /metrics`).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The engine's registered instruments (counters + task latency).
    pub fn obs(&self) -> &Arc<EngineObs> {
        &self.shared.obs
    }

    /// The lifecycle trace sink (disabled unless
    /// `ExecutorOptions::trace_capacity > 0`).
    pub fn trace(&self) -> &Arc<TraceSink> {
        &self.shared.obs.trace
    }

    /// Stages executed so far via [`Executor::run_tasks`].  Stage ids in
    /// trace payloads count from 1 up to this value; `run_tasks` is a
    /// barrier, so stage `s` depends on stage `s - 1` — the edge list the
    /// profiler's critical-path extraction walks.
    pub fn stages_run(&self) -> u64 {
        self.stage_counter.load(Ordering::Relaxed)
    }

    /// Mean worker-side execution nanos per completed task in the most
    /// recent `run_tasks` stage (0 before any stage ran).  Excludes queue
    /// wait by construction — the speculation deadline derives from it.
    pub fn last_stage_avg_task_nanos(&self) -> u64 {
        self.last_stage_avg_exec_nanos.load(Ordering::Relaxed)
    }

    /// The variance-derived straggler deadline (mean + k·stddev, floored
    /// at 100ms) most recently used by a speculation scan — 0 when no
    /// stage has crossed its speculation quantile yet.
    pub fn last_stage_speculation_deadline_nanos(&self) -> u64 {
        self.last_stage_deadline_nanos.load(Ordering::Relaxed)
    }

    pub fn total_busy(&self) -> Duration {
        Duration::from_nanos(
            self.shared
                .metrics
                .iter()
                .map(|m| m.busy_nanos.load(Ordering::Relaxed))
                .sum(),
        )
    }

    /// Busy-time skew: max over workers of busy nanos divided by the mean
    /// (1.0 = perfectly balanced; large = one node did all the work).
    pub fn busy_skew(&self) -> f64 {
        let busy: Vec<u64> =
            self.shared.metrics.iter().map(|m| m.busy_nanos.load(Ordering::Relaxed)).collect();
        let total: u64 = busy.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / busy.len() as f64;
        busy.iter().max().copied().unwrap_or(0) as f64 / mean
    }

    pub fn fault_plan(&self) -> &FaultPlan {
        &self.fault
    }

    /// Which worker owns partition `part` (stable placement for caches,
    /// shuffle map outputs and the fault injector; execution may migrate).
    pub fn worker_for(&self, part: usize) -> usize {
        part % self.num_workers()
    }

    /// Number of workers still alive (not killed by a fault plan).
    pub fn alive_workers(&self) -> usize {
        self.shared.queues.alive_count()
    }

    /// Kill a worker: mark it dead and drain its deque back into the
    /// steal pool (queued tasks are redistributed to the least-loaded
    /// alive workers).  The last alive worker can never be killed, so a
    /// stage always retains capacity to finish.  Returns whether the kill
    /// happened.
    pub fn kill_worker(&self, w: usize) -> bool {
        self.shared.queues.kill(w)
    }

    /// Run one task set: task `i` executes `f(i)`, preferring its owning
    /// worker; blocks until every task has completed at least once.
    /// Individual task errors (including injected faults) are retried up
    /// to `max_retries` times by re-invoking `f(i)` — lineage recompute
    /// happens naturally because `f` recomputes its inputs.  Near the end
    /// of the stage, stragglers may be duplicated speculatively; `f` must
    /// therefore be idempotent (every engine task is).
    pub fn run_tasks<F>(&self, num_tasks: usize, max_retries: usize, f: F) -> Result<()>
    where
        F: Fn(usize) -> Result<()> + Send + Sync + 'static,
    {
        if num_tasks == 0 {
            return Ok(());
        }
        // Stage ids count from 1; 0 in a payload's high half means the
        // event predates stage packing (or isn't a task-lifecycle event).
        let stage = self.stage_counter.fetch_add(1, Ordering::Relaxed) + 1;
        let f = Arc::new(f);
        let (done_tx, done_rx) = channel::<TaskDone>();
        let completed: Arc<Vec<AtomicBool>> =
            Arc::new((0..num_tasks).map(|_| AtomicBool::new(false)).collect());
        // Worker-side execution start per task, as nanos-since-stage-epoch
        // plus one (0 = not yet executing).  The speculation deadline is
        // measured from here, NOT from enqueue: queue wait must neither
        // inflate the average task duration nor mark a merely-queued task
        // as a straggler.
        let stage_epoch = Instant::now();
        let exec_start: Arc<Vec<AtomicU64>> =
            Arc::new((0..num_tasks).map(|_| AtomicU64::new(0)).collect());

        let submit = |task: usize, attempt: usize, speculative: bool| -> Result<()> {
            let owner = self.worker_for(task + attempt); // retries migrate nodes
            let ordinal = self.task_counter.fetch_add(1, Ordering::Relaxed);
            if let Some(kw) = self.fault.should_kill(ordinal) {
                self.kill_worker(kw);
            }
            // Fault decisions key off the *owning* node, not the executing
            // one, so worker-keyed plans are unaffected by stealing.
            // Ordinal-keyed plans (fail_nth_task, random) replay exactly
            // only while the submission order does: retries and
            // speculative duplicates also consume ordinals, so under
            // races those plans may hit different submissions run-to-run
            // (results stay correct either way — only which attempts
            // fail varies).
            let fail_this = self.fault.should_fail(owner, ordinal, attempt);
            // Trace payload: stage in the high 32 bits, task ordinal in
            // the low 32 — one u64 identifies the span across lanes.
            let span = (stage << 32) | task as u64;
            let f = f.clone();
            let done = done_tx.clone();
            let completed = completed.clone();
            let exec_start = exec_start.clone();
            let shared = self.shared.clone();
            let job: Job = Box::new(move |exec_w: usize| {
                if completed[task].load(Ordering::Acquire) {
                    return; // first completion already won; drop the duplicate
                }
                exec_start[task].store(
                    stage_epoch.elapsed().as_nanos() as u64 + 1,
                    Ordering::Release,
                );
                let m = &shared.metrics[exec_w];
                shared.obs.trace.emit(exec_w, TraceKind::Start, span);
                let start = Instant::now();
                let result = if fail_this {
                    m.failures.fetch_add(1, Ordering::Relaxed);
                    Err(anyhow!("injected fault on worker {owner} (task {task})"))
                } else {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(task)))
                        .unwrap_or_else(|p| {
                            Err(anyhow!("task {task} panicked: {}", panic_msg(p.as_ref())))
                        })
                };
                let exec_nanos = start.elapsed().as_nanos() as u64;
                m.busy_nanos.fetch_add(exec_nanos, Ordering::Relaxed);
                m.tasks.fetch_add(1, Ordering::Relaxed);
                shared.obs.tasks_run.inc();
                if result.is_err() {
                    shared.obs.task_failures.inc();
                }
                shared.obs.task_exec.record(exec_nanos);
                shared.obs.trace.emit(exec_w, TraceKind::Finish, span);
                let _ = done.send(TaskDone { task, speculative, result, exec_nanos });
            });
            // Enqueue/speculation decisions happen on the driver lane.
            let driver_lane = self.num_workers();
            if speculative {
                self.shared.obs.speculative_launches.inc();
                self.shared.obs.trace.emit(driver_lane, TraceKind::SpeculativeLaunch, span);
            }
            let target = self.shared.queues.enqueue(owner, job)?;
            self.shared.obs.trace.emit(driver_lane, TraceKind::Enqueue, span);
            if speculative {
                // Counted against the worker the duplicate actually
                // landed on (the preferred owner may be dead).
                self.shared.metrics[target].speculations.fetch_add(1, Ordering::Relaxed);
            }
            Ok(())
        };

        let mut attempts = vec![0usize; num_tasks];
        let mut speculated = vec![false; num_tasks];
        for t in 0..num_tasks {
            submit(t, 0, false)?;
        }

        let spec_enabled = self.opts.speculation && num_tasks >= self.opts.speculation_min_tasks;
        let spec_threshold = ((num_tasks as f64) * self.opts.speculation_quantile).ceil() as usize;
        let spec_threshold = spec_threshold.clamp(1, num_tasks);
        let mut done_count = 0usize;
        let mut sum_done_nanos = 0u64;
        // Sum of squared execution nanos (f64: squares overflow u64) —
        // feeds the per-stage variance the straggler deadline derives
        // from.
        let mut sum_sq_done_nanos = 0f64;
        // Straggler candidates, built lazily when the stage first crosses
        // the speculation quantile (so the scan is bounded by the tail of
        // the stage, not by num_tasks).
        let mut spec_candidates: Option<Vec<usize>> = None;

        while done_count < num_tasks {
            // The speculation quantile can only be crossed by a done
            // message, so until then (and always when speculation is off)
            // block on the channel instead of polling.
            let msg = if spec_enabled && done_count >= spec_threshold {
                match done_rx.recv_timeout(Duration::from_millis(25)) {
                    Ok(msg) => Some(msg),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(anyhow!("all workers died mid-job"));
                    }
                }
            } else {
                Some(done_rx.recv().map_err(|_| anyhow!("all workers died mid-job"))?)
            };

            if let Some(TaskDone { task, speculative, result, exec_nanos }) = msg {
                if !completed[task].load(Ordering::Acquire) {
                    match result {
                        Ok(()) => {
                            completed[task].store(true, Ordering::Release);
                            done_count += 1;
                            // Execution time only — a deep queue must not
                            // stretch the deadline that gates duplicates.
                            sum_done_nanos += exec_nanos;
                            sum_sq_done_nanos += (exec_nanos as f64) * (exec_nanos as f64);
                        }
                        Err(e) => {
                            if speculative {
                                // A failed duplicate never burns the
                                // original's retry budget.
                            } else {
                                attempts[task] += 1;
                                if attempts[task] > max_retries {
                                    return Err(e.context(format!(
                                        "task {task} failed after {} attempts",
                                        attempts[task]
                                    )));
                                }
                                // The retry hasn't started executing yet.
                                exec_start[task].store(0, Ordering::Release);
                                submit(task, attempts[task], false)?;
                            }
                        }
                    }
                }
            }

            // Speculative re-execution: past the quantile, duplicate tasks
            // whose current execution has run much longer than the average
            // completed task (first completion wins).  Tasks still waiting
            // in a queue are not stragglers — stealing migrates those.
            if spec_enabled && done_count >= spec_threshold && done_count < num_tasks {
                let candidates = spec_candidates.get_or_insert_with(|| {
                    (0..num_tasks)
                        .filter(|&t| !completed[t].load(Ordering::Acquire))
                        .collect()
                });
                // Adaptive deadline from the stage's own duration
                // distribution, not a static multiple of the mean.
                let deadline_nanos = variance_deadline(
                    sum_done_nanos,
                    sum_sq_done_nanos,
                    done_count,
                    self.opts.speculation_sigma,
                );
                self.last_stage_deadline_nanos.store(deadline_nanos, Ordering::Relaxed);
                let now = stage_epoch.elapsed().as_nanos() as u64;
                let mut still_waiting = Vec::with_capacity(candidates.len());
                for &t in candidates.iter() {
                    if completed[t].load(Ordering::Acquire) || speculated[t] {
                        continue; // finished or already duplicated: drop
                    }
                    let started = exec_start[t].load(Ordering::Acquire);
                    if started > 0 && now.saturating_sub(started - 1) >= deadline_nanos {
                        speculated[t] = true;
                        submit(t, attempts[t] + 1, true)?;
                    } else {
                        still_waiting.push(t);
                    }
                }
                *candidates = still_waiting;
            }
        }
        self.last_stage_avg_exec_nanos
            .store(sum_done_nanos / num_tasks as u64, Ordering::Relaxed);
        Ok(())
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    p.downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| p.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "<non-string panic>".into())
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.shared.queues.begin_shutdown();
        let me = std::thread::current().id();
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                // A task closure can hold the last Cluster handle, making
                // a *worker* run this drop — never join yourself, detach.
                if h.thread().id() != me {
                    let _ = h.join();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn no_spec() -> ExecutorOptions {
        ExecutorOptions { speculation: false, ..ExecutorOptions::default() }
    }

    fn both_modes() -> [SchedulerMode; 2] {
        [SchedulerMode::Sharded, SchedulerMode::GlobalLock]
    }

    #[test]
    fn runs_all_tasks_once() {
        // Speculation off: exactly-once execution of the happy path.
        for mode in both_modes() {
            let ex = Executor::with_options(
                4,
                FaultPlan::none(),
                ExecutorOptions { mode, ..no_spec() },
            );
            let count = Arc::new(AtomicUsize::new(0));
            let c = count.clone();
            ex.run_tasks(37, 0, move |_| {
                c.fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            assert_eq!(count.load(Ordering::SeqCst), 37, "{mode:?}");
        }
    }

    #[test]
    fn no_steal_mode_preserves_modulo_placement() {
        for mode in both_modes() {
            let opts = ExecutorOptions {
                work_stealing: false,
                speculation: false,
                mode,
                ..Default::default()
            };
            let ex = Executor::with_options(3, FaultPlan::none(), opts);
            ex.run_tasks(30, 0, |_| Ok(())).unwrap();
            for m in ex.metrics() {
                assert_eq!(m.tasks.load(Ordering::SeqCst), 10, "static placement is exact");
                assert_eq!(m.steals.load(Ordering::SeqCst), 0);
            }
        }
    }

    #[test]
    fn idle_worker_steals_from_busy_queue() {
        // Worker 0's first task blocks until every other task has run.
        // Tasks 2,4,6,8 are queued behind it on worker 0's deque, so the
        // stage can only finish if worker 1 steals them.
        let ex = Executor::with_options(2, FaultPlan::none(), ExecutorOptions::default());
        let sync = Arc::new((Mutex::new(0usize), Condvar::new()));
        let s = sync.clone();
        ex.run_tasks(10, 0, move |task| {
            let (count, cv) = &*s;
            if task == 0 {
                let done = count.lock().unwrap();
                let (done, timeout) = cv
                    .wait_timeout_while(done, Duration::from_secs(20), |c| *c < 9)
                    .unwrap();
                anyhow::ensure!(
                    !timeout.timed_out(),
                    "only {} of 9 peer tasks ran: stealing is broken",
                    *done
                );
            } else {
                *count.lock().unwrap() += 1;
                cv.notify_all();
            }
            Ok(())
        })
        .unwrap();
        let stolen: usize =
            ex.metrics().iter().map(|m| m.steals.load(Ordering::SeqCst)).sum();
        assert!(stolen >= 4, "tasks 2,4,6,8 must have been stolen (got {stolen})");
    }

    #[test]
    fn sharded_steal_moves_half_the_victims_queue_per_batch() {
        // Same topology as above: worker 0 blocks in task 0 with four
        // tasks queued behind it.  Peer tasks sleep briefly so every task
        // is enqueued before worker 1 goes idle; its *first* steal must
        // then grab a batch of several tasks, so the total steal count
        // must exceed the number of steal operations.
        let ex = Executor::with_options(2, FaultPlan::none(), ExecutorOptions::default());
        let sync = Arc::new((Mutex::new(0usize), Condvar::new()));
        let s = sync.clone();
        ex.run_tasks(10, 0, move |task| {
            let (count, cv) = &*s;
            if task == 0 {
                let done = count.lock().unwrap();
                let (_, timeout) = cv
                    .wait_timeout_while(done, Duration::from_secs(20), |c| *c < 9)
                    .unwrap();
                anyhow::ensure!(!timeout.timed_out(), "peer tasks never ran");
            } else {
                std::thread::sleep(Duration::from_millis(10));
                *count.lock().unwrap() += 1;
                cv.notify_all();
            }
            Ok(())
        })
        .unwrap();
        let stolen: usize =
            ex.metrics().iter().map(|m| m.steals.load(Ordering::SeqCst)).sum();
        let batches: usize =
            ex.metrics().iter().map(|m| m.steal_batches.load(Ordering::SeqCst)).sum();
        assert!(batches >= 1, "at least one steal batch must have happened");
        assert!(
            stolen > batches,
            "steal-half must move multiple tasks per batch (stolen {stolen}, batches {batches})"
        );
    }

    #[test]
    fn straggler_is_speculatively_reexecuted() {
        // Task 0's first execution blocks until a speculative duplicate
        // has run; the stage can only finish because the duplicate's
        // completion wins.  Without speculation this test would error out
        // after the 20s guard instead of hanging.
        let ex = Executor::with_options(2, FaultPlan::none(), ExecutorOptions::default());
        let sync = Arc::new((Mutex::new(false), Condvar::new()));
        let execs = Arc::new(AtomicUsize::new(0));
        let s = sync.clone();
        let e = execs.clone();
        ex.run_tasks(8, 0, move |task| {
            if task != 0 {
                return Ok(());
            }
            let (dup_ran, cv) = &*s;
            if e.fetch_add(1, Ordering::SeqCst) == 0 {
                // Original attempt: straggle until the duplicate runs.
                let flag = dup_ran.lock().unwrap();
                let (_, timeout) = cv
                    .wait_timeout_while(flag, Duration::from_secs(20), |ran| !*ran)
                    .unwrap();
                anyhow::ensure!(!timeout.timed_out(), "no speculative duplicate was launched");
            } else {
                // Speculative duplicate: finish fast and release the original.
                *dup_ran.lock().unwrap() = true;
                cv.notify_all();
            }
            Ok(())
        })
        .unwrap();
        assert!(execs.load(Ordering::SeqCst) >= 2, "task 0 must have been duplicated");
        let specs: usize =
            ex.metrics().iter().map(|m| m.speculations.load(Ordering::SeqCst)).sum();
        assert!(specs >= 1, "speculation counter must have fired");
    }

    #[test]
    fn speculation_deadline_uses_execution_time_not_queue_wait() {
        // One worker, everything queued up front: the last task *waits*
        // ~31x longer than it *executes*.  The recorded average task
        // duration must reflect execution only — the old submit-time
        // accounting averaged ~16x the execution time here, which is
        // exactly what suppressed duplicates under deep queues.
        let opts = ExecutorOptions { work_stealing: false, speculation: false, ..Default::default() };
        let ex = Executor::with_options(1, FaultPlan::none(), opts);
        ex.run_tasks(32, 0, |_| {
            std::thread::sleep(Duration::from_millis(3));
            Ok(())
        })
        .unwrap();
        let avg = ex.last_stage_avg_task_nanos();
        assert!(avg >= 2_000_000, "tasks sleep 3ms each (avg {avg}ns)");
        assert!(
            avg < 24_000_000,
            "avg task duration must exclude queue wait (avg {avg}ns; \
             submit-time accounting would report ~48ms)"
        );
    }

    #[test]
    fn queued_but_unstarted_tasks_are_not_speculated() {
        // Stealing off, 1 worker: when the quantile is crossed the
        // remaining tasks are merely queued, not straggling.  None of
        // them must be duplicated (exec-start gating), yet the stage
        // still completes exactly.
        let opts = ExecutorOptions { work_stealing: false, ..Default::default() };
        let ex = Executor::with_options(1, FaultPlan::none(), opts);
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        ex.run_tasks(16, 0, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(2));
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 16, "exactly-once: no queued task duplicated");
        let specs: usize =
            ex.metrics().iter().map(|m| m.speculations.load(Ordering::SeqCst)).sum();
        assert_eq!(specs, 0, "queue wait alone must never trigger speculation");
    }

    #[test]
    fn kill_drains_deque_back_into_steal_pool() {
        // Three workers all blocked in their first task; worker 0 is then
        // killed while its deque still holds queued tasks, which must be
        // redistributed and completed by the survivors.
        for mode in both_modes() {
            let ex = Arc::new(Executor::with_options(
                3,
                FaultPlan::none(),
                ExecutorOptions { mode, ..no_spec() },
            ));
            let gate = Arc::new((Mutex::new(false), Condvar::new()));
            let count = Arc::new(AtomicUsize::new(0));

            let opener = {
                let ex = ex.clone();
                let gate = gate.clone();
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis(150));
                    assert!(ex.kill_worker(0), "kill must succeed");
                    let (open, cv) = &*gate;
                    *open.lock().unwrap() = true;
                    cv.notify_all();
                })
            };

            let g = gate.clone();
            let c = count.clone();
            ex.run_tasks(12, 0, move |task| {
                if task < 3 {
                    // One gate task per worker keeps all deques populated
                    // until the kill has happened.
                    let (open, cv) = &*g;
                    let opened = open.lock().unwrap();
                    let (_, timeout) = cv
                        .wait_timeout_while(opened, Duration::from_secs(20), |o| !*o)
                        .unwrap();
                    anyhow::ensure!(!timeout.timed_out(), "gate never opened");
                }
                c.fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            opener.join().unwrap();

            assert_eq!(count.load(Ordering::SeqCst), 12, "drained tasks must not be lost");
            assert_eq!(ex.alive_workers(), 2);
            // New work keeps flowing around the dead node.
            let c2 = Arc::new(AtomicUsize::new(0));
            let c2c = c2.clone();
            ex.run_tasks(9, 0, move |_| {
                c2c.fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            assert_eq!(c2.load(Ordering::SeqCst), 9);
        }
    }

    #[test]
    fn last_alive_worker_cannot_be_killed() {
        for mode in both_modes() {
            let ex = Executor::with_options(
                2,
                FaultPlan::none(),
                ExecutorOptions { mode, ..Default::default() },
            );
            assert!(ex.kill_worker(1));
            assert!(!ex.kill_worker(0), "the last worker must survive");
            assert_eq!(ex.alive_workers(), 1);
            ex.run_tasks(4, 0, |_| Ok(())).unwrap();
        }
    }

    #[test]
    fn task_errors_are_retried() {
        let ex = Executor::with_options(2, FaultPlan::none(), no_spec());
        let tries = Arc::new(AtomicUsize::new(0));
        let t = tries.clone();
        ex.run_tasks(1, 3, move |_| {
            if t.fetch_add(1, Ordering::SeqCst) < 2 {
                anyhow::bail!("transient");
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(tries.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn exhausted_retries_propagate_error() {
        let ex = Executor::new(2, FaultPlan::none());
        let err = ex
            .run_tasks(4, 1, |t| {
                if t == 2 {
                    anyhow::bail!("always fails")
                }
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("task 2"));
    }

    #[test]
    fn panics_become_errors_not_hangs() {
        let ex = Executor::new(2, FaultPlan::none());
        let err = ex.run_tasks(1, 0, |_| panic!("boom")).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
    }

    #[test]
    fn injected_faults_recover_via_retry() {
        // Fail every task's first attempt whose owner is worker 0.
        let ex = Executor::with_options(
            2,
            FaultPlan::fail_first_attempt_on_worker(0),
            no_spec(),
        );
        let count = Arc::new(AtomicUsize::new(0));
        let c = count.clone();
        ex.run_tasks(8, 2, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(())
        })
        .unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 8);
        let injected: usize = ex
            .metrics()
            .iter()
            .map(|m| m.failures.load(Ordering::SeqCst))
            .sum();
        assert!(injected > 0, "fault plan should have fired");
    }

    #[test]
    fn fault_plan_kill_drains_and_stage_completes() {
        // A kill rule in the fault plan fires mid-submission; the stage
        // must still complete on the surviving worker.
        for mode in both_modes() {
            let plan = FaultPlan::kill_worker_at(0, 5);
            let ex =
                Executor::with_options(2, plan, ExecutorOptions { mode, ..no_spec() });
            let count = Arc::new(AtomicUsize::new(0));
            let c = count.clone();
            ex.run_tasks(16, 0, move |_| {
                c.fetch_add(1, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            assert_eq!(count.load(Ordering::SeqCst), 16);
            assert_eq!(ex.alive_workers(), 1);
        }
    }

    #[test]
    fn busy_skew_is_unity_when_idle() {
        let ex = Executor::new(3, FaultPlan::none());
        assert_eq!(ex.busy_skew(), 1.0);
    }

    #[test]
    fn sharded_and_global_agree_at_scale() {
        // 32 workers x 2000 tasks, speculation off: every queue
        // architecture — global mutex, sharded with the full victim
        // scan, and sharded with sampled two-choice victim picks
        // (threshold 1 forces sampling at 32 workers) — must run every
        // task exactly once and produce identical per-slot results.
        let run = |mode: SchedulerMode, steal_sample_threshold: usize| {
            let opts = ExecutorOptions {
                mode,
                speculation: false,
                steal_sample_threshold,
                ..Default::default()
            };
            let ex = Executor::with_options(32, FaultPlan::none(), opts);
            let slots: Arc<Vec<AtomicUsize>> =
                Arc::new((0..2000).map(|_| AtomicUsize::new(0)).collect());
            let s = slots.clone();
            ex.run_tasks(2000, 0, move |t| {
                s[t].fetch_add(1 + t * t, Ordering::SeqCst);
                Ok(())
            })
            .unwrap();
            slots.iter().map(|s| s.load(Ordering::SeqCst)).collect::<Vec<_>>()
        };
        let sharded = run(SchedulerMode::Sharded, 128); // below threshold: full scan
        let sampled = run(SchedulerMode::Sharded, 1); // above threshold: two-choice
        let global = run(SchedulerMode::GlobalLock, 128);
        assert_eq!(sharded, global, "queue architecture must not change results");
        assert_eq!(sampled, global, "sampled victim selection must not change results");
        for (t, &v) in sharded.iter().enumerate() {
            assert_eq!(v, 1 + t * t, "task {t} must run exactly once");
        }
    }

    #[test]
    fn sampled_stealing_still_drains_a_single_hot_deque() {
        // Threshold 1 forces two-choice sampling on 4 workers.  Worker
        // 0's first task blocks until every peer task has run; the tasks
        // queued behind it can only finish if sampled (or thorough
        // pre-park) steals migrate them — a sampling miss must park and
        // retry, never strand the stage.
        let opts = ExecutorOptions {
            speculation: false,
            steal_sample_threshold: 1,
            ..Default::default()
        };
        let ex = Executor::with_options(4, FaultPlan::none(), opts);
        let sync = Arc::new((Mutex::new(0usize), Condvar::new()));
        let s = sync.clone();
        ex.run_tasks(20, 0, move |task| {
            let (count, cv) = &*s;
            if task == 0 {
                let done = count.lock().unwrap();
                let (done, timeout) = cv
                    .wait_timeout_while(done, Duration::from_secs(20), |c| *c < 19)
                    .unwrap();
                anyhow::ensure!(
                    !timeout.timed_out(),
                    "only {} of 19 peer tasks ran: sampled stealing stranded the deque",
                    *done
                );
            } else {
                *count.lock().unwrap() += 1;
                cv.notify_all();
            }
            Ok(())
        })
        .unwrap();
        let stolen: usize = ex.metrics().iter().map(|m| m.steals.load(Ordering::SeqCst)).sum();
        assert!(stolen >= 4, "worker 0's queued tasks must have been stolen (got {stolen})");
    }

    #[test]
    fn stage_ids_are_packed_into_trace_payloads() {
        let opts = ExecutorOptions { trace_capacity: 1 << 10, ..no_spec() };
        let ex = Executor::with_options(2, FaultPlan::none(), opts);
        ex.run_tasks(4, 0, |_| Ok(())).unwrap();
        ex.run_tasks(3, 0, |_| Ok(())).unwrap();
        assert_eq!(ex.stages_run(), 2);
        let mut seen = [false; 2];
        for e in ex.trace().drain_new() {
            if matches!(e.kind, TraceKind::Enqueue | TraceKind::Start | TraceKind::Finish) {
                let (stage, task) = (e.payload >> 32, e.payload & 0xffff_ffff);
                assert!((1..=2).contains(&stage), "stage {stage} out of range");
                assert!(task < 4, "task {task} out of range");
                seen[stage as usize - 1] = true;
            }
        }
        assert!(seen[0] && seen[1], "both stages must appear in the trace");
    }

    #[test]
    fn variance_deadline_tracks_bimodal_spread() {
        let floor = 100_000_000u64;
        // Empty stage: floor.
        assert_eq!(variance_deadline(0, 0.0, 0, 3.0), floor);
        // Uniform 200ms stage: zero variance, deadline collapses to the
        // mean — a real straggler is duplicated after ~1x the mean, not
        // the old static 4x.
        let uni = vec![200_000_000u64; 20];
        let sum: u64 = uni.iter().sum();
        let sq: f64 = uni.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let d_uni = variance_deadline(sum, sq, uni.len(), 3.0);
        assert!(
            (200_000_000..=201_000_000).contains(&d_uni),
            "uniform stage deadline must sit at its mean (got {d_uni})"
        );
        // Synthetic bimodal stage (10x 5ms + 10x 500ms): mean 252.5ms,
        // stddev 247.5ms -> deadline ~995ms, so the natural slow half is
        // not flagged as straggling.
        let bi: Vec<u64> = [vec![5_000_000u64; 10], vec![500_000_000u64; 10]].concat();
        let sum: u64 = bi.iter().sum();
        let sq: f64 = bi.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let d_bi = variance_deadline(sum, sq, bi.len(), 3.0);
        assert!(
            (900_000_000..=1_100_000_000).contains(&d_bi),
            "bimodal deadline must be mean + 3 sigma (got {d_bi})"
        );
        assert!(d_bi > 3 * d_uni, "spread must widen the deadline, uniformity must not");
        // Sub-floor stages clamp up.
        assert_eq!(variance_deadline(10_000, 100.0 * 100.0, 1, 3.0), floor);
    }

    #[test]
    fn bimodal_stage_records_variance_deadline() {
        // Stage with real duration spread: 2 slow tasks (150ms) + 14
        // fast (1ms) on 2 workers.  By the last speculation scan the
        // completed set contains at least one slow task, so the recorded
        // variance deadline must sit strictly above the 100ms floor —
        // the old static `4 * avg` formula is gone.
        let ex = Executor::with_options(2, FaultPlan::none(), ExecutorOptions::default());
        ex.run_tasks(16, 0, |task| {
            std::thread::sleep(Duration::from_millis(if task < 2 { 150 } else { 1 }));
            Ok(())
        })
        .unwrap();
        let deadline = ex.last_stage_speculation_deadline_nanos();
        assert!(deadline > 0, "a speculation scan must have run past the quantile");
        assert!(
            deadline > 100_000_000,
            "a bimodal stage's deadline must exceed the floor (got {deadline}ns)"
        );
    }

    #[test]
    fn sharded_survives_kills_under_load() {
        // Kill two of eight workers while a 500-task stage is in flight;
        // drained deques and rerouted enqueues must lose nothing.
        let ex = Arc::new(Executor::with_options(8, FaultPlan::none(), no_spec()));
        let count = Arc::new(AtomicUsize::new(0));
        let killer = {
            let ex = ex.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(5));
                ex.kill_worker(3);
                ex.kill_worker(6);
            })
        };
        let c = count.clone();
        ex.run_tasks(500, 0, move |_| {
            c.fetch_add(1, Ordering::SeqCst);
            std::thread::yield_now();
            Ok(())
        })
        .unwrap();
        killer.join().unwrap();
        assert_eq!(count.load(Ordering::SeqCst), 500);
        assert!(ex.alive_workers() >= 6);
    }
}
